"""Event-driven simulator tests — the paper's §IV protocol."""
import numpy as np
import pytest

from repro.core import (NetworkModel, SimProblem, make_synthetic,
                        simulate_amtl, simulate_smtl)


def test_amtl_faster_than_smtl_under_delay():
    """Paper Table I direction: AMTL wall-clock < SMTL at equal epochs."""
    prob = make_synthetic(num_tasks=5, samples=100, dim=50, seed=0)
    net = NetworkModel(delay_offset=5.0, compute_time=0.1, prox_time=0.05)
    ra = simulate_amtl(prob, net, num_epochs=10, seed=1,
                       record_objective=False)
    rs = simulate_smtl(prob, net, num_epochs=10, seed=1,
                       record_objective=False)
    assert ra.total_time < rs.total_time


def test_gap_grows_with_task_count():
    """Paper Fig. 3a: the AMTL/SMTL gap widens with more tasks."""
    net = NetworkModel(delay_offset=2.0, compute_time=0.1, prox_time=0.02)
    ratios = []
    for T in (5, 15):
        prob = make_synthetic(num_tasks=T, samples=100, dim=50, seed=0)
        ra = simulate_amtl(prob, net, num_epochs=5, seed=1,
                           record_objective=False)
        rs = simulate_smtl(prob, net, num_epochs=5, seed=1,
                           record_objective=False)
        ratios.append(rs.total_time / ra.total_time)
    assert ratios[1] > ratios[0] * 0.9  # non-decreasing advantage (noisy)
    assert ratios[1] > 1.0


def test_smtl_time_scales_with_offset():
    """Paper Table I rows: SMTL-30 >> SMTL-5."""
    prob = make_synthetic(num_tasks=5, samples=50, dim=20, seed=0)
    times = []
    for off in (5.0, 30.0):
        net = NetworkModel(delay_offset=off)
        times.append(simulate_smtl(prob, net, num_epochs=5, seed=0,
                                   record_objective=False).total_time)
    assert times[1] > times[0] * 4


def test_amtl_objective_decreases():
    prob = make_synthetic(num_tasks=5, samples=50, dim=20, seed=0)
    net = NetworkModel(delay_offset=1.0)
    res = simulate_amtl(prob, net, num_epochs=30, seed=0)
    assert res.objectives[-1] < res.objectives[0]


def test_dynamic_step_lowers_objective_under_delay():
    """Paper Tables IV-VI: at a fixed iteration budget with delays, the
    dynamic step size reaches a lower objective."""
    prob = make_synthetic(num_tasks=10, samples=100, dim=50, seed=0)
    net = NetworkModel(delay_offset=10.0, compute_time=0.1, prox_time=0.05)
    fixed = simulate_amtl(prob, net, num_epochs=10, seed=3,
                          dynamic_step=False)
    dyn = simulate_amtl(prob, net, num_epochs=10, seed=3, dynamic_step=True)
    assert dyn.objectives[-1] < fixed.objectives[-1]


def test_heterogeneous_losses():
    """Sec. III-A: regression + classification tasks mixed."""
    rng = np.random.default_rng(0)
    xs = [rng.standard_normal((40, 10)) for _ in range(4)]
    w = rng.standard_normal(10)
    ys = [x @ w + 0.1 * rng.standard_normal(40) for x in xs]
    losses = ["lstsq", "logistic", "lstsq", "logistic"]
    ys = [np.where(y > 0, 1.0, -1.0) if l == "logistic" else y
          for y, l in zip(ys, losses)]
    prob = SimProblem(xs, ys, losses, "nuclear", 0.05)
    net = NetworkModel(delay_offset=0.5)
    res = simulate_amtl(prob, net, num_epochs=40, seed=0)
    assert res.objectives[-1] < res.objectives[0]
    assert np.isfinite(res.objectives[-1])


def test_ragged_task_sizes():
    rng = np.random.default_rng(1)
    sizes = [22, 251, 100]
    xs = [rng.standard_normal((n, 28)) for n in sizes]
    ys = [rng.standard_normal(n) for n in sizes]
    prob = SimProblem(xs, ys, "lstsq", "nuclear", 0.1)
    net = NetworkModel(delay_offset=1.0,
                       compute_time=[n * 1e-3 for n in sizes])
    res = simulate_amtl(prob, net, num_epochs=20, seed=0)
    assert res.iterations == 20 * 3
    assert np.isfinite(res.objectives[-1])


def test_determinism_under_seed():
    prob = make_synthetic(num_tasks=4, samples=30, dim=10, seed=0)
    net = NetworkModel(delay_offset=2.0)
    a = simulate_amtl(prob, net, num_epochs=10, seed=7)
    b = simulate_amtl(prob, net, num_epochs=10, seed=7)
    assert a.total_time == b.total_time
    np.testing.assert_array_equal(a.w, b.w)
