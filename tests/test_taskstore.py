"""TaskStore + ragged cohort contracts (PR 9).

Three layers, matching how raggedness enters the stack:

  * the store itself: arrival-order appends, power-of-two capacity
    doubling, cached problem view, bitwise checkpoint round-trip;
  * the ragged engine math: masked gradients equal per-task-trimmed
    dense gradients, the valid-row cutoff keeps exactly min(b, n_t)
    rows under the unbiased (n_t/bsz) scaling, uniform row_counts are
    BITWISE the row_counts=None baseline, and row_counts never touch
    the activation/PRNG event stream;
  * the serving platform: label-carrying `submit_feedback` folds
    accepted rows at chunk boundaries such that the state is bitwise a
    fold/rebuild/run replay of one engine session, resume (store +
    engine) is bitwise invisible through capacity growth, and the
    label-free path never creates a store.

Deterministic sweeps here; the hypothesis-driven generalizations live
in tests/test_sampling_properties.py (skipped when hypothesis is
absent, as conftest documents).
"""
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (AMTLConfig, MTLProblem, NetworkModel, SimProblem,
                        amtl_events_only, amtl_solve, make_engine,
                        simulate_amtl)
from repro.core.operators import amtl_max_step
from repro.data import TaskStore, stack_ragged
from repro.kernels import ops, ref
from repro.serve import AMTLServer, ServeConfig

RAGGED_ENGINES = ("delta", "batch", "sharded")


def _ragged_lists(sizes, d, seed=0):
    rng = np.random.default_rng(seed)
    xs = [(rng.standard_normal((n, d)) / np.sqrt(d)).astype(np.float32)
          for n in sizes]
    ys = [rng.standard_normal(n).astype(np.float32) for n in sizes]
    return xs, ys


@pytest.fixture(scope="module")
def ragged_problem():
    xs, ys = _ragged_lists([6, 17, 11, 3], d=8, seed=1)
    return stack_ragged(xs, ys, "lstsq", "nuclear", 0.1)


def _cfg(problem, engine, **kw):
    eta = 1.0 / problem.lipschitz()
    if engine in ("batch", "sharded"):
        kw.setdefault("event_batch", 4)
        kw.setdefault("prox_every", kw["event_batch"])
    return AMTLConfig(eta=eta, eta_k=0.7, tau=3, engine=engine, **kw)


def _mesh1():
    from repro.launch.mesh import make_task_mesh
    return make_task_mesh(1)


def _run(problem, cfg, n_events, mesh=None, key=0):
    eng = make_engine(problem, cfg, mesh)
    w0 = jnp.zeros((problem.dim, problem.num_tasks), jnp.float32)
    return eng, eng.run(eng.init(w0, jax.random.PRNGKey(key)), None, n_events)


def _assert_states_equal(a, b, msg=""):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b), strict=True):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=msg)


# ================================================================= store


def test_from_ragged_pads_and_masks():
    xs, ys = _ragged_lists([3, 7, 2], d=5, seed=2)
    store = TaskStore.from_ragged(xs, ys, "lstsq", "nuclear", 0.1)
    assert (store.num_tasks, store.capacity, store.dim) == (3, 7, 5)
    assert store.row_counts.tolist() == [3, 7, 2]
    assert store.num_rows == 12
    prob = store.problem()
    assert prob.xs.shape == (3, 7, 5)
    np.testing.assert_array_equal(np.asarray(prob.row_counts), [3, 7, 2])
    # valid rows are the cohorts verbatim; padding rows are zero
    np.testing.assert_array_equal(np.asarray(prob.xs[0, :3]), xs[0])
    np.testing.assert_array_equal(np.asarray(prob.xs[0, 3:]), 0.0)
    np.testing.assert_array_equal(np.asarray(prob.ys[2, :2]), ys[2])


def test_append_arrival_order_and_pow2_growth():
    store = TaskStore.from_ragged(*_ragged_lists([2, 3], d=4, seed=3),
                                  loss_name="lstsq", reg_name="nuclear",
                                  lam=0.1)
    assert store.capacity == 3
    rng = np.random.default_rng(4)
    x6 = rng.standard_normal((6, 4)).astype(np.float32)
    y6 = rng.standard_normal(6).astype(np.float32)
    # task 0 takes 4 rows (2 -> 6 > 3: doubles 3 -> 6), task 1 takes 2
    assert store.append([0, 1, 0, 0, 1, 0], x6, y6) == 6
    assert store.capacity == 6
    assert store.row_counts.tolist() == [6, 5]
    prob = store.problem()
    # arrival order within a task: submissions 0, 2, 3, 5 land at rows
    # 2, 3, 4, 5 of task 0
    np.testing.assert_array_equal(np.asarray(prob.xs[0, 2:]),
                                  x6[[0, 2, 3, 5]])
    np.testing.assert_array_equal(np.asarray(prob.ys[1, 3:5]), y6[[1, 4]])
    # one more overflow doubles again: 6 -> 12
    store.append([1, 1], x6[:2], y6[:2])
    assert store.capacity == 12
    assert store.row_counts.tolist() == [6, 7]


def test_append_validates():
    store = TaskStore.from_ragged(*_ragged_lists([2, 2], d=3, seed=5),
                                  loss_name="lstsq", reg_name="nuclear",
                                  lam=0.1)
    with pytest.raises(ValueError, match="append expects features"):
        store.append([0], np.zeros((1, 5), np.float32), [0.0])
    with pytest.raises(ValueError, match="append expects features"):
        store.append([0, 1], np.zeros((2, 3), np.float32), [0.0])
    with pytest.raises(ValueError, match="task_ids must lie"):
        store.append([2], np.zeros((1, 3), np.float32), [0.0])
    assert store.append([], np.zeros((0, 3), np.float32), []) == 0


def test_problem_view_cached_until_append():
    store = TaskStore.from_ragged(*_ragged_lists([2, 4], d=3, seed=6),
                                  loss_name="lstsq", reg_name="nuclear",
                                  lam=0.1)
    p1 = store.problem()
    assert store.problem() is p1       # same arrays -> same jit cache keys
    store.append([0], np.ones((1, 3), np.float32), [1.0])
    p2 = store.problem()
    assert p2 is not p1
    assert np.asarray(p2.row_counts).tolist() == [3, 4]


def test_checkpoint_roundtrip_bitwise(tmp_path):
    store = TaskStore.from_ragged(*_ragged_lists([5, 9, 2], d=6, seed=7),
                                  loss_name="lstsq", reg_name="nuclear",
                                  lam=0.1)
    rng = np.random.default_rng(8)
    store.append(np.zeros(8, np.int64),
                 rng.standard_normal((8, 6)).astype(np.float32),
                 rng.standard_normal(8).astype(np.float32))
    assert store.capacity == 18        # 9 -> 18: growth history on disk
    store.save(str(tmp_path), 7, keep_last=2)
    back = TaskStore.restore(str(tmp_path), 7, "lstsq", "nuclear", 0.1)
    assert back.capacity == store.capacity
    a, b = store.state(), back.state()
    np.testing.assert_array_equal(a.xs, b.xs)
    np.testing.assert_array_equal(a.ys, b.ys)
    np.testing.assert_array_equal(a.row_counts, b.row_counts)


# ===================================================== ragged engine math


@pytest.mark.parametrize("engine", RAGGED_ENGINES)
@pytest.mark.parametrize("batch_size", (None, 4))
def test_uniform_row_counts_are_bitwise_baseline(small_problem, engine,
                                                 batch_size):
    """Acceptance anchor: row_counts == n everywhere + no appends must
    reproduce the row_counts=None engine BITWISE on the full state."""
    cfg = _cfg(small_problem, engine, batch_size=batch_size)
    mesh = _mesh1() if engine == "sharded" else None
    n = small_problem.xs.shape[1]
    uniform = small_problem._replace(row_counts=jnp.full(
        (small_problem.num_tasks,), n, jnp.int32))
    _, st_none = _run(small_problem, cfg, 24, mesh)
    _, st_uni = _run(uniform, cfg, 24, mesh)
    _assert_states_equal(st_none, st_uni, f"{engine}/bsz={batch_size}")


def test_dense_engine_rejects_ragged(ragged_problem):
    with pytest.raises(ValueError, match="dense"):
        make_engine(ragged_problem, _cfg(ragged_problem, "dense"))


def test_ragged_grad_matches_trimmed_dense(ragged_problem):
    """Masked per-task gradients equal the gradient over the trimmed
    (n_t, d) cohort.  Not bitwise — XLA reassociates the contraction
    differently across row counts — but ulp-tight."""
    counts = np.asarray(ragged_problem.row_counts)
    w = jax.random.normal(jax.random.PRNGKey(9),
                          (ragged_problem.dim, ragged_problem.num_tasks),
                          jnp.float32)
    g_masked = np.asarray(ragged_problem.full_grad(w))
    for t in range(ragged_problem.num_tasks):
        n_t = int(counts[t])
        trimmed = 2.0 * (np.asarray(ragged_problem.xs[t, :n_t]).T
                         @ (np.asarray(ragged_problem.xs[t, :n_t])
                            @ np.asarray(w[:, t])
                            - np.asarray(ragged_problem.ys[t, :n_t])))
        np.testing.assert_allclose(g_masked[:, t], trimmed, rtol=2e-4,
                                   atol=1e-6)
    # the masked loss value likewise sums only valid rows
    v = float(ragged_problem.loss_value(w))
    want = sum(float(np.sum((np.asarray(ragged_problem.xs[t, :counts[t]])
                             @ np.asarray(w[:, t])
                             - np.asarray(ragged_problem.ys[t, :counts[t]]))
                            ** 2))
               for t in range(ragged_problem.num_tasks))
    np.testing.assert_allclose(v, want, rtol=1e-5)


def test_ragged_cutoff_keeps_exactly_min_b_nt_rows():
    """The masked counter-hash selection keeps exactly min(b, n_t) VALID
    rows for every (n, b, n_t, seed) in the sweep, and the kernel
    (interpret mode) emits the oracle's bits."""
    for n, b, n_t, seed in [(12, 4, 7, 0), (12, 4, 2, 1), (12, 12, 5, 2),
                            (37, 9, 37, 3), (37, 40, 17, 4), (5, 1, 0, 5),
                            (600, 50, 300, 6), (600, 700, 600, 7)]:
        s = jnp.asarray(seed, jnp.uint32)
        nt = jnp.asarray(n_t, jnp.int32)
        mask = np.asarray(ref.sample_mask_masked_ref(n, b, s, nt))
        assert mask.sum() == min(b, n_t), (n, b, n_t, seed)
        assert not mask[n_t:].any()           # never selects padding
        got = np.asarray(ops.sample_mask(n, b, s, n_t=nt, interpret=True))
        np.testing.assert_array_equal(got, mask, err_msg=str((n, b, n_t)))
        if n_t == n:                          # uniform: bitwise unmasked law
            np.testing.assert_array_equal(
                mask, np.asarray(ref.sample_mask_ref(n, b, s)))


def test_ragged_sampled_grad_saturates_to_masked_full():
    """batch_size >= n_t: selection saturates to all valid rows and the
    (n_t/bsz) scale to 1 — bitwise the masked full gradient."""
    n, d = 14, 6
    kx, kw, ky = jax.random.split(jax.random.PRNGKey(10), 3)
    x = jax.random.normal(kx, (n, d), jnp.float32)
    w = jax.random.normal(kw, (d,), jnp.float32)
    y = jax.random.normal(ky, (n,), jnp.float32)
    for n_t in (3, 9, 14):
        nt = jnp.asarray(n_t, jnp.int32)
        got = ops.lstsq_grad_sampled(x, w, y, jnp.uint32(5), batch_size=n,
                                     n_t=nt, use_pallas=False)
        want = ops.lstsq_grad(x, w, y, n_t=nt, use_pallas=False)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ragged_minibatch_gradient_unbiased_over_seeds():
    """E_seed over the masked selection approaches the masked full
    gradient under the (n_t/bsz) scaling — the simulator's law."""
    n, d, b, n_t = 40, 6, 10, 23
    kx, kw, ky = jax.random.split(jax.random.PRNGKey(11), 3)
    x = jax.random.normal(kx, (n, d), jnp.float32)
    w = jax.random.normal(kw, (d,), jnp.float32)
    y = jax.random.normal(ky, (n,), jnp.float32)
    nt = jnp.asarray(n_t, jnp.int32)
    seeds = jnp.arange(6000, dtype=jnp.uint32)
    grads = jax.vmap(lambda s: ref.lstsq_grad_sampled_masked_ref(
        x, w, y, s, b, nt))(seeds)
    mean = np.asarray(grads, np.float64).mean(axis=0)
    full = np.asarray(ref.lstsq_grad_masked_ref(x, w, y, nt), np.float64)
    rel = np.linalg.norm(mean - full) / np.linalg.norm(full)
    assert rel < 0.08, rel


@pytest.mark.parametrize("batch_size", (None, 3))
def test_row_counts_leave_event_stream_untouched(ragged_problem, batch_size):
    """Raggedness only reshapes gradients: the PRNG chain head and the
    (task, staleness) history are data-independent, so they must match
    the same problem with row_counts dropped."""
    cfg = _cfg(ragged_problem, "delta", batch_size=batch_size)
    uniform = ragged_problem._replace(row_counts=None)
    w0 = jnp.zeros((ragged_problem.dim, ragged_problem.num_tasks),
                   jnp.float32)
    key = jax.random.PRNGKey(12)
    st_r = amtl_events_only(ragged_problem, cfg, w0, key, 16)
    st_u = amtl_events_only(uniform, cfg, w0, key, 16)
    np.testing.assert_array_equal(np.asarray(st_r.key), np.asarray(st_u.key))
    np.testing.assert_array_equal(np.asarray(st_r.history.buf),
                                  np.asarray(st_u.history.buf))


def test_mid_session_append_continues_event_stream(ragged_problem):
    """Rebuilding the engine against a grown store mid-session continues
    the SAME activation stream: the chain state lives in the engine
    state, not the problem."""
    cfg = _cfg(ragged_problem, "delta")
    store = TaskStore.from_problem(ragged_problem)
    eng1 = make_engine(store.problem(), cfg)
    w0 = jnp.zeros((ragged_problem.dim, ragged_problem.num_tasks),
                   jnp.float32)
    st = eng1.run(eng1.init(w0, jax.random.PRNGKey(13)), None, 8)
    rng = np.random.default_rng(14)
    store.append([0, 3], rng.standard_normal((2, 8)).astype(np.float32),
                 rng.standard_normal(2).astype(np.float32))
    eng2 = make_engine(store.problem(), cfg)
    st2 = eng2.run(st, None, 8)
    # reference: the un-grown engine run the same 16 events
    ref_st = eng1.run(st, None, 8)
    np.testing.assert_array_equal(np.asarray(st2.key), np.asarray(ref_st.key))
    np.testing.assert_array_equal(np.asarray(st2.history.buf),
                                  np.asarray(ref_st.history.buf))
    assert int(st2.event) == 16


@pytest.mark.parametrize("engine", ("batch", "sharded"))
@pytest.mark.parametrize("batch_size", (None, 3))
def test_ragged_engines_agree_bitwise(ragged_problem, engine, batch_size):
    """delta/batch/sharded on the same ragged problem replay the same
    event stream and masked arithmetic — full state bitwise (the
    multi-shard boundary is the CI serving smoke at 8 fake devices)."""
    base = _cfg(ragged_problem, "delta", batch_size=batch_size,
                prox_every=4)
    other = base._replace(engine=engine, event_batch=4)
    mesh = _mesh1() if engine == "sharded" else None
    _, st_d = _run(ragged_problem, base, 16)
    _, st_o = _run(ragged_problem, other, 16, mesh)
    np.testing.assert_array_equal(np.asarray(st_d.v), np.asarray(st_o.v))
    np.testing.assert_array_equal(np.asarray(st_d.key),
                                  np.asarray(st_o.key))


# ================================================ ragged vs f64 simulator

SIM_SIZES = (18, 30, 24, 12)
SIM_T, SIM_D, SIM_TAU, SIM_EPOCHS = len(SIM_SIZES), 10, 3, 250


def test_ragged_engine_tracks_trimmed_float64_simulator():
    """The ragged delta engine's trajectory must track the float64
    event-driven reference run DIRECTLY on the per-task-trimmed ragged
    cohorts — the cross-validation that the masked math implements the
    paper's per-node objective, not an artifact of the padding."""
    xs, ys = _ragged_lists(SIM_SIZES, SIM_D, seed=15)
    sim_prob = SimProblem(xs, ys, "lstsq", "nuclear", 0.1)
    stacked = stack_ragged(xs, ys, "lstsq", "nuclear", 0.1)
    eta = 1.0 / stacked.lipschitz()
    eta_k = amtl_max_step(SIM_TAU, SIM_T)
    sim = simulate_amtl(sim_prob,
                        NetworkModel(delay_offset=0.0, delay_jitter=1.0),
                        num_epochs=SIM_EPOCHS, eta=float(eta),
                        eta_k=float(eta_k), tau=SIM_TAU, seed=0)
    sim_traj = np.asarray(sim.objectives)[SIM_T - 1::SIM_T]

    cfg = AMTLConfig(eta=eta, eta_k=eta_k, tau=SIM_TAU, engine="delta")
    w0 = jnp.zeros((SIM_D, SIM_T), jnp.float32)
    res = amtl_solve(stacked, cfg, w0, jax.random.PRNGKey(0),
                     num_epochs=SIM_EPOCHS)
    objs = np.asarray(res.objectives, np.float64)
    rel = np.abs(objs - sim_traj) / sim_traj
    assert rel.max() < 0.35, rel.max()        # independent transients
    assert rel[100:].max() < 0.05, rel[100:].max()
    assert rel[-1] < 0.02, rel[-1]
    assert objs[-1] < objs[100] < objs[0]
    w_rel = (np.linalg.norm(np.asarray(res.w, np.float64) - sim.w)
             / np.linalg.norm(sim.w))
    assert w_rel < 0.05, w_rel


# ======================================================= serving platform


def _server(problem, cfg, serve_cfg, key=0):
    w0 = jnp.zeros((problem.dim, problem.num_tasks), jnp.float32)
    return AMTLServer(problem, cfg, w0, jax.random.PRNGKey(key), serve_cfg)


def _labeled_batch(problem, k, rng):
    t = rng.integers(0, problem.num_tasks, size=k)
    x = rng.standard_normal((k, problem.dim)).astype(np.float32)
    y = rng.standard_normal(k).astype(np.float32)
    return t, x, y


@pytest.mark.parametrize("engine", ("delta", "batch"))
def test_labeled_feedback_replays_fold_run_sequence_bitwise(small_problem,
                                                            engine):
    """The acceptance contract: after any mix of labeled and label-free
    feedback, the server state is bitwise the replay — fold the same
    rows at the same chunk boundaries, rebuild, run — over ONE engine
    session against a replayed TaskStore."""
    cfg = _cfg(small_problem, engine)
    per = 4 if engine == "batch" else 1
    server = _server(small_problem, cfg, ServeConfig(chunk_events=2 * per))
    rng = np.random.default_rng(16)
    log = []                               # (rows | None, chunk size)
    for i in range(6):
        if i % 2 == 0:
            t, x, y = _labeled_batch(small_problem, 2 * per, rng)
            assert server.submit_feedback(t, x, y).accepted == 2 * per
            rows = (t, x, y)
        else:
            server.submit_feedback(
                rng.integers(0, small_problem.num_tasks, size=2 * per))
            rows = None
        log.append((rows, server.step()))
    n0 = small_problem.num_tasks * small_problem.xs.shape[1]
    assert server.store_rows == n0 + 3 * 2 * per

    store = TaskStore.from_problem(small_problem)
    prob = small_problem
    eng = make_engine(prob, cfg)
    w0 = jnp.zeros((prob.dim, prob.num_tasks), jnp.float32)
    st = eng.init(w0, jax.random.PRNGKey(0))
    for rows, n in log:
        if rows is not None:
            store.append(*rows)
            prob = store.problem()
            eng = make_engine(prob, cfg)
        if n:
            st = eng.run(st, None, n)
    np.testing.assert_array_equal(np.asarray(server.iterate()),
                                  np.asarray(eng.iterate(st)))
    _assert_states_equal(server._state, st, engine)


def test_label_free_path_never_creates_store(small_problem):
    """Satellite (a) regression: the PR-8 API (no features/labels) must
    stay bitwise PR-8 — same replay, no store, no problem rebuild."""
    cfg = _cfg(small_problem, "delta")
    server = _server(small_problem, cfg, ServeConfig(chunk_events=4))
    prob_obj = server.problem
    eng_obj = server.engine
    rng = np.random.default_rng(17)
    for _ in range(4):
        server.submit_feedback(
            rng.integers(0, small_problem.num_tasks, size=5))
        server.step()
    assert server._store is None and server.store_rows is None
    assert server.problem is prob_obj and server.engine is eng_obj
    eng = make_engine(small_problem, cfg)
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    st = eng.run(eng.init(w0, jax.random.PRNGKey(0)), None,
                 sum(server.chunk_log))
    _assert_states_equal(server._state, st)


def test_submit_feedback_validates_rows(small_problem):
    server = _server(small_problem, _cfg(small_problem, "delta"),
                     ServeConfig(chunk_events=4))
    with pytest.raises(ValueError, match="given together"):
        server.submit_feedback([0], features=np.zeros((1, small_problem.dim),
                                                      np.float32))
    with pytest.raises(ValueError, match="given together"):
        server.submit_feedback([0], labels=[1.0])
    with pytest.raises(ValueError, match="features must be"):
        server.submit_feedback([0, 1], np.zeros((2, 3), np.float32),
                               [0.0, 1.0])
    dense = _server(small_problem, _cfg(small_problem, "dense"),
                    ServeConfig(chunk_events=4))
    with pytest.raises(ValueError, match="dense"):
        dense.submit_feedback([0], np.zeros((1, small_problem.dim),
                                            np.float32), [0.0])


def test_rejected_items_drop_their_rows(small_problem):
    """Admission caps apply to the item: a rejected item contributes
    neither an event nor a row."""
    server = _server(small_problem, _cfg(small_problem, "delta"),
                     ServeConfig(chunk_events=4, max_pending_per_task=3))
    rng = np.random.default_rng(18)
    x = rng.standard_normal((10, small_problem.dim)).astype(np.float32)
    y = rng.standard_normal(10).astype(np.float32)
    receipt = server.submit_feedback([0] * 10, x, y)
    assert receipt == (3, 7)
    assert server.stats()["pending_rows"] == 3
    server.step()
    n = small_problem.xs.shape[1]
    assert server._store.row_counts[0] == n + 3
    # the three ACCEPTED rows, in arrival order, right after the
    # adopted cohort (capacity doubled past n, so the tail is padding)
    np.testing.assert_array_equal(
        np.asarray(server._store.problem().xs[0, n:n + 3]), x[:3])


def test_feedback_rows_change_future_predictions(small_problem):
    """Appended rows reshape the gradients the next chunks use: two
    servers fed the same events, one with rows and one without, serve
    different predictions after the fold."""
    cfg = _cfg(small_problem, "delta")
    a = _server(small_problem, cfg, ServeConfig(chunk_events=4))
    b = _server(small_problem, cfg, ServeConfig(chunk_events=4))
    rng = np.random.default_rng(19)
    t, x, y = _labeled_batch(small_problem, 4, rng)
    # rows big enough to move the lstsq gradients measurably
    a.submit_feedback(t, 5.0 * x, 5.0 * y)
    b.submit_feedback(t)
    a.step()
    b.step()
    q_t, q_x = t[:3], x[:3]
    pa = np.asarray(a.predict(q_t, q_x))
    pb = np.asarray(b.predict(q_t, q_x))
    assert not np.array_equal(pa, pb)


def test_resume_with_store_is_bitwise_invisible(small_problem, tmp_path):
    """Kill a server whose store grew past a capacity doubling; resume
    must restore store + engine state such that identical subsequent
    traffic produces bitwise identical predictions and states."""
    cfg = _cfg(small_problem, "delta")
    serve_cfg = ServeConfig(chunk_events=4, ckpt_dir=str(tmp_path),
                            keep_last=2)
    a = _server(small_problem, cfg, serve_cfg, key=1)
    b = _server(small_problem, cfg, serve_cfg._replace(ckpt_dir=None), key=1)
    n0 = small_problem.xs.shape[1]
    rng_a, rng_b = (np.random.default_rng(20), np.random.default_rng(20))
    for srv, rng in ((a, rng_a), (b, rng_b)):
        for _ in range(4):
            # 68 rows on one task crosses the 50 -> 100 -> 200 doublings
            t = np.full(17, 0, np.int64)
            x = rng.standard_normal((17, small_problem.dim)).astype(
                np.float32)
            y = rng.standard_normal(17).astype(np.float32)
            srv.submit_feedback(t, x, y)
            while srv.step():
                pass
    assert a._store.capacity == 4 * n0
    a.checkpoint()
    del a
    c = AMTLServer.resume(
        small_problem, cfg,
        jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32),
        jax.random.PRNGKey(1), serve_cfg)
    assert c._store is not None
    assert c._store.capacity == 4 * n0
    np.testing.assert_array_equal(c._store.row_counts, b._store.row_counts)
    # identical post-restart traffic, bitwise identical serving
    rng_c, rng_b2 = (np.random.default_rng(21), np.random.default_rng(21))
    for srv, rng in ((c, rng_c), (b, rng_b2)):
        t, x, y = _labeled_batch(small_problem, 4, rng)
        srv.submit_feedback(t, x, y)
        while srv.step():
            pass
    _assert_states_equal(c._state, b._state)
    q = np.random.default_rng(22).standard_normal(
        (5, small_problem.dim)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(c.predict([0, 1, 2, 3, 4], q)),
                                  np.asarray(b.predict([0, 1, 2, 3, 4], q)))


def test_store_checkpoints_pair_with_engine_records(small_problem, tmp_path):
    """Once labeled rows fold, every checkpoint writes a store record at
    the same step under <ckpt_dir>/store/, rotated with the same
    keep_last; resume reads the paired record."""
    cfg = _cfg(small_problem, "delta")
    serve_cfg = ServeConfig(chunk_events=4, ckpt_dir=str(tmp_path),
                            checkpoint_every=4, keep_last=2)
    server = _server(small_problem, cfg, serve_cfg)
    rng = np.random.default_rng(23)
    for _ in range(3):
        t, x, y = _labeled_batch(small_problem, 4, rng)
        server.submit_feedback(t, x, y)
        server.step()                      # chunk + auto-checkpoint
    engine_records = sorted(f for f in os.listdir(tmp_path)
                            if f.endswith(".npz"))
    store_records = sorted(os.listdir(tmp_path / "store"))
    assert engine_records == ["step_00000008.npz", "step_00000012.npz"]
    assert store_records == engine_records
