"""Sharded task-parallel AMTL engine: 1-device-mesh bitwise equivalence to
the batch engine on the CPU oracle path, the shard-local rollback and
sentinel-task batch dispatch, and the engine='sharded' validation surface.

Real multi-shard boundaries (2/8 fake devices, shard-count invariance, the
straggler shard) are exercised by the slow subprocess suite in
tests/test_amtl_sharded_multidevice.py.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import AMTLConfig, amtl_solve
from repro.core.amtl import amtl_events_only
from repro.core.operators import (rollback_columns_batch,
                                  rollback_columns_shard)
from repro.core.prox import (ProxPlan, sketch_width, svt_randomized,
                             svt_randomized_dist)
from repro.distributed.sharding import TASK_AXIS, shard_map_compat
from repro.kernels.ops import amtl_event_batch, amtl_event_batch_sharded
from repro.kernels.ref import shard_local_tasks
from repro.launch.mesh import make_task_mesh


def _cfg_pair(problem, tau, bsz, **kw):
    """(batch cfg, sharded cfg) aligned: prox_every == event_batch."""
    eta = 1.0 / problem.lipschitz()
    batch = AMTLConfig(eta=eta, eta_k=0.7, tau=tau, engine="batch",
                       prox_every=bsz, event_batch=bsz, **kw)
    return batch, batch._replace(engine="sharded")


@pytest.fixture(scope="module")
def mesh1():
    return make_task_mesh(1)


# ----------------------------------------------------------- equivalence
@pytest.mark.parametrize("tau,bsz", [(0, 4), (3, 5), (8, 5), (3, 1), (4, 10)])
def test_sharded_1shard_bitwise_matches_batch(small_problem, mesh1, tau, bsz):
    """On a 1-device "tasks" mesh every shard-local expression degenerates
    to the batch engine's, so iterates, objectives, and residuals must
    match bitwise on the CPU oracle path (incl. event_batch > ring depth
    and event_batch=1)."""
    batch_cfg, sharded_cfg = _cfg_pair(small_problem, tau, bsz)
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(3)
    epe = 10 if bsz != 4 else 8
    batch = amtl_solve(small_problem, batch_cfg, w0, key, num_epochs=8,
                       events_per_epoch=epe)
    sharded = amtl_solve(small_problem, sharded_cfg, w0, key, num_epochs=8,
                         events_per_epoch=epe, mesh=mesh1)
    np.testing.assert_array_equal(np.asarray(batch.v), np.asarray(sharded.v))
    np.testing.assert_array_equal(np.asarray(batch.w), np.asarray(sharded.w))
    np.testing.assert_array_equal(np.asarray(batch.objectives),
                                  np.asarray(sharded.objectives))
    np.testing.assert_array_equal(np.asarray(batch.residuals),
                                  np.asarray(sharded.residuals))


def test_sharded_bitwise_under_delays_dynamic_step_and_sketch(
        small_problem, mesh1):
    """The folded sketch key, delay-adaptive KM step, and per-event history
    recording must all replay exactly through the shard_map path."""
    batch_cfg, sharded_cfg = _cfg_pair(small_problem, tau=4, bsz=5,
                                       dynamic_step=True, prox_rank=5)
    offsets = jnp.asarray([3.0, 1.0, 0.0, 2.0, 4.0])
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(11)
    batch = amtl_solve(small_problem, batch_cfg, w0, key, num_epochs=6,
                       delay_offsets=offsets)
    sharded = amtl_solve(small_problem, sharded_cfg, w0, key, num_epochs=6,
                         delay_offsets=offsets, mesh=mesh1)
    np.testing.assert_array_equal(np.asarray(batch.v), np.asarray(sharded.v))


def test_sharded_state_stream_matches_batch(small_problem, mesh1):
    """Beyond the iterate: the private undo ring, the global-id task ring,
    pointer, event counter, PRNG chain, and delay history must equal the
    batch engine's — they seed every later stale read."""
    batch_cfg, sharded_cfg = _cfg_pair(small_problem, tau=3, bsz=5)
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(5)
    b = amtl_events_only(small_problem, batch_cfg, w0, key, 25)
    s = amtl_events_only(small_problem, sharded_cfg, w0, key, 25, mesh=mesh1)
    assert s.delta_ring.shape[0] == 1  # one shard -> one private ring
    np.testing.assert_array_equal(np.asarray(b.v), np.asarray(s.v))
    np.testing.assert_array_equal(np.asarray(b.delta_ring),
                                  np.asarray(s.delta_ring[0]))
    np.testing.assert_array_equal(np.asarray(b.task_ring),
                                  np.asarray(s.task_ring))
    assert int(b.ptr) == int(s.ptr)
    assert int(b.event) == int(s.event) == 25
    np.testing.assert_array_equal(np.asarray(b.key), np.asarray(s.key))
    np.testing.assert_array_equal(np.asarray(b.history.buf),
                                  np.asarray(s.history.buf))
    np.testing.assert_array_equal(np.asarray(b.history.count),
                                  np.asarray(s.history.count))


# ------------------------------------------- rank-distributed server prox
def test_svt_randomized_dist_1shard_bitwise_matches_serial(mesh1):
    """On a 1-shard mesh the psum and both gathers degenerate to the
    identity, Omega is un-partitioned, and every expression in
    `svt_randomized_dist` is the serial path's — so the distributed prox
    must reproduce `svt_randomized` BITWISE on the CPU oracle path."""
    d, T, rank = 24, 8, 4
    w = jax.random.normal(jax.random.PRNGKey(0), (d, T), jnp.float32)
    t = jnp.asarray(0.3, jnp.float32)
    key = jax.random.PRNGKey(42)
    plan = ProxPlan(axis=TASK_AXIS, num_tasks=T, n_local=T)
    from jax.sharding import PartitionSpec as P
    dist = shard_map_compat(
        lambda w_loc: svt_randomized_dist(w_loc, t, rank=rank, key=key,
                                          plan=plan),
        mesh=mesh1, in_specs=(P(None, TASK_AXIS),),
        out_specs=P(None, TASK_AXIS))
    want = svt_randomized(w, t, rank=rank, key=key)
    np.testing.assert_array_equal(np.asarray(dist(w)), np.asarray(want))


@pytest.mark.parametrize("tau,bsz,k", [(3, 5, 1), (3, 4, 2), (0, 2, 3)])
def test_sharded_distributed_prox_1shard_bitwise_matches_batch(
        small_problem, mesh1, tau, bsz, k):
    """engine='sharded' with prox_mode='distributed' on a 1-shard mesh must
    reproduce the batch engine (replicated randomized prox) bitwise on the
    CPU oracle path — full state including the (column-sharded) prox cache
    at the decoupled cadence k > 1."""
    batch_cfg, sharded_cfg = _cfg_pair(small_problem, tau, bsz, prox_rank=4)
    batch_cfg = batch_cfg._replace(prox_every=k * bsz)
    dist_cfg = sharded_cfg._replace(prox_every=k * bsz,
                                    prox_mode="distributed")
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(9)
    n_events = 8 * bsz * k
    b = amtl_events_only(small_problem, batch_cfg, w0, key, n_events)
    s = amtl_events_only(small_problem, dist_cfg, w0, key, n_events,
                         mesh=mesh1)
    np.testing.assert_array_equal(np.asarray(b.v), np.asarray(s.v))
    np.testing.assert_array_equal(np.asarray(b.p_cache),
                                  np.asarray(s.p_cache))
    np.testing.assert_array_equal(np.asarray(b.task_ring),
                                  np.asarray(s.task_ring))
    np.testing.assert_array_equal(np.asarray(b.key), np.asarray(s.key))
    np.testing.assert_array_equal(np.asarray(b.delta_ring),
                                  np.asarray(s.delta_ring[0]))


def test_sharded_distributed_prox_dynamic_step_and_straggler_offsets(
        small_problem, mesh1):
    """Distributed prox composed with the delay-adaptive KM step and skewed
    per-task delays: still bitwise vs the batch engine at 1 shard."""
    batch_cfg, sharded_cfg = _cfg_pair(small_problem, tau=4, bsz=5,
                                       dynamic_step=True, prox_rank=5)
    dist_cfg = sharded_cfg._replace(prox_mode="distributed")
    offsets = jnp.asarray([3.0, 1.0, 0.0, 2.0, 4.0])
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(11)
    batch = amtl_solve(small_problem, batch_cfg, w0, key, num_epochs=6,
                       delay_offsets=offsets)
    dist = amtl_solve(small_problem, dist_cfg, w0, key, num_epochs=6,
                      delay_offsets=offsets, mesh=mesh1)
    np.testing.assert_array_equal(np.asarray(batch.v), np.asarray(dist.v))


def test_prox_plan_comm_bytes_beats_replicated_gather():
    """The collective payload the plan advertises must be the (d, p) psum +
    (p, T) core gather, and strictly under the replicated (d, T)
    all_gather at the bench scale (d=8192, T=128, rank=16)."""
    d, T, rank = 8192, 128, 16
    plan = ProxPlan(axis=TASK_AXIS, num_tasks=T, n_local=T // 8)
    p = sketch_width(rank, d, T)
    assert plan.comm_bytes_per_refresh(d, rank) == (d * p + p * T) * 4
    assert plan.comm_bytes_per_refresh(d, rank) < d * T * 4


# ------------------------------------------------- shard-local primitives
def test_rollback_columns_shard_tiles_the_batch_rollback():
    """Concatenating per-shard rollbacks in shard order must equal the
    global vectorized rollback bitwise, for every (ptr, nu) and a task ring
    with duplicates spanning shard boundaries."""
    d, T, tau, n_shards = 6, 8, 4, 4
    n_local = T // n_shards
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.standard_normal((d, T)), jnp.float32)
    ring = jnp.asarray(rng.standard_normal((tau + 1, d)), jnp.float32)
    task_ring = jnp.asarray([1, 6, 1, 0, 7], jnp.int32)
    for ptr in range(tau + 1):
        for nu in range(tau + 1):
            ptr_j = jnp.asarray(ptr, jnp.int32)
            nu_j = jnp.asarray(nu, jnp.int32)
            want = rollback_columns_batch(v, ring, task_ring, ptr_j, nu_j,
                                          tau)
            got = jnp.concatenate([
                rollback_columns_shard(
                    v[:, s * n_local:(s + 1) * n_local], ring, task_ring,
                    ptr_j, nu_j, tau, jnp.asarray(s * n_local, jnp.int32))
                for s in range(n_shards)], axis=1)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_shard_local_tasks_sentinel_and_ownership():
    tasks = jnp.asarray([0, 3, 4, 7, 2], jnp.int32)
    local, owned = shard_local_tasks(tasks, jnp.asarray(4, jnp.int32), 4)
    np.testing.assert_array_equal(np.asarray(owned),
                                  [False, False, True, True, False])
    np.testing.assert_array_equal(np.asarray(local), [4, 4, 0, 3, 4])


def test_sharded_batch_dispatch_drops_sentinel_events():
    """Foreign events (sentinel column id T_local) must leave the local
    block untouched while owned events match the unsharded op bitwise —
    including a duplicate chain that spans owned and foreign events."""
    d, T, b = 16, 6, 8
    n_local, t_off = 3, 3
    k = jax.random.PRNGKey(0)
    kv, kp, kg, ke = jax.random.split(k, 4)
    v = jax.random.normal(kv, (d, T), jnp.float32)
    p = jax.random.normal(kp, (d, b), jnp.float32)
    g = jax.random.normal(kg, (d, b), jnp.float32)
    eta_ks = jax.random.uniform(ke, (b,), minval=0.1, maxval=0.9)
    eta = jnp.asarray(0.05)
    tasks = jnp.asarray([0, 4, 4, 1, 5, 0, 3, 4], jnp.int32)

    want_v, want_u = amtl_event_batch(v, p, g, tasks, eta, eta_ks)
    local, owned = shard_local_tasks(tasks, jnp.asarray(t_off, jnp.int32),
                                     n_local)
    got_v, got_u = amtl_event_batch_sharded(v[:, t_off:t_off + n_local], p,
                                            g, local, eta, eta_ks)
    np.testing.assert_array_equal(np.asarray(got_v),
                                  np.asarray(want_v[:, t_off:t_off + n_local]))
    np.testing.assert_array_equal(
        np.asarray(got_u)[np.asarray(owned)],
        np.asarray(want_u)[np.asarray(owned)])


# ----------------------------------------------------- validation surface
def test_sharded_requires_prox_alignment(small_problem, mesh1):
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    eta = 1.0 / small_problem.lipschitz()
    cfg = AMTLConfig(eta=eta, eta_k=0.7, tau=3, engine="sharded",
                     prox_every=2, event_batch=4)
    with pytest.raises(ValueError,
                       match=r"prox_every \(2\) must be a multiple of "
                             r"event_batch \(4\)"):
        amtl_solve(small_problem, cfg, w0, jax.random.PRNGKey(0),
                   num_epochs=1, events_per_epoch=4, mesh=mesh1)


def test_distributed_prox_requires_sharded_engine(small_problem):
    from repro.core import validate_config
    cfg = AMTLConfig(eta=0.05, eta_k=0.7, tau=3, engine="batch",
                     prox_every=4, event_batch=4, prox_rank=4,
                     prox_mode="distributed")
    with pytest.raises(ValueError, match="no shards to distribute over"):
        validate_config(cfg, small_problem.reg_name)


def test_distributed_prox_requires_prox_rank(small_problem):
    from repro.core import validate_config
    cfg = AMTLConfig(eta=0.05, eta_k=0.7, tau=3, engine="sharded",
                     prox_every=4, event_batch=4, prox_mode="distributed")
    with pytest.raises(ValueError, match="prox_rank must be set"):
        validate_config(cfg, small_problem.reg_name)


def test_unknown_prox_mode_rejected(small_problem):
    from repro.core import validate_config
    cfg = AMTLConfig(eta=0.05, eta_k=0.7, tau=3, engine="sharded",
                     prox_every=4, event_batch=4, prox_rank=4,
                     prox_mode="sketchy")
    with pytest.raises(ValueError, match="unknown prox_mode"):
        validate_config(cfg, small_problem.reg_name)


def test_sharded_requires_tasks_axis(small_problem):
    from repro.launch.mesh import make_host_mesh
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    eta = 1.0 / small_problem.lipschitz()
    cfg = AMTLConfig(eta=eta, eta_k=0.7, tau=3, engine="sharded",
                     prox_every=4, event_batch=4)
    with pytest.raises(ValueError, match=r"mesh with a 'tasks' axis"):
        amtl_solve(small_problem, cfg, w0, jax.random.PRNGKey(0),
                   num_epochs=1, events_per_epoch=4, mesh=make_host_mesh())


def test_mesh_rejected_for_unsharded_engines(small_problem, mesh1):
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    eta = 1.0 / small_problem.lipschitz()
    cfg = AMTLConfig(eta=eta, eta_k=0.7, tau=3, engine="delta")
    with pytest.raises(ValueError, match=r"mesh is only meaningful.*sharded"):
        amtl_solve(small_problem, cfg, w0, jax.random.PRNGKey(0),
                   num_epochs=1, mesh=mesh1)


def test_make_task_mesh_validates_device_count():
    with pytest.raises(ValueError, match=r"num_shards must be in"):
        make_task_mesh(jax.local_device_count() + 1)
    with pytest.raises(ValueError, match=r"num_shards must be in"):
        make_task_mesh(0)
