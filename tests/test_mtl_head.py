"""Mesh-AMTL head: stale reads, KM updates, probe math, convergence on a
fixed representation (the transformer-integration form of the paper)."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import MTLCfg
from repro.core.mtl_head import (amtl_head_update, head_weights,
                                 init_mtl_state, probe_loss,
                                 probe_predictions, probe_task_grads,
                                 stale_read)

D, T = 16, 4
CFG = MTLCfg(num_tasks=T, reg_name="nuclear", lam=0.01, tau=3,
             activation_rate=1.0, dynamic_step=False, eta=0.05, km_relax=0.8)


def _data(key, n=256, noise=0.02):
    kw, kx, kt, kn = jax.random.split(key, 4)
    w_true = jax.random.normal(kw, (D, T)) / np.sqrt(D)
    pooled = jax.random.normal(kx, (n, D))
    task_ids = jax.random.randint(kt, (n,), 0, T)
    y = probe_predictions(w_true, pooled, task_ids)
    y = y + noise * jax.random.normal(kn, (n,))
    return w_true, pooled, task_ids, y


def test_probe_grads_match_autodiff():
    key = jax.random.PRNGKey(0)
    w, pooled, tids, y = _data(key)
    p0 = jax.random.normal(jax.random.PRNGKey(1), (D, T)) * 0.1

    def per_task_loss(p):
        r = probe_predictions(p, pooled, tids) - y
        onehot = jax.nn.one_hot(tids, T)
        per = jnp.einsum("b,bt->t", r * r, onehot) / \
            jnp.maximum(jnp.sum(onehot, 0), 1.0)
        return per

    auto = jax.jacrev(lambda p: per_task_loss(p))(p0)   # (T, D, T)
    # column t of analytic grad == d per_task_loss[t] / d p[:, t]
    analytic = probe_task_grads(p0, pooled, tids, y)
    for t in range(T):
        np.testing.assert_allclose(np.asarray(analytic[:, t]),
                                   np.asarray(auto[t, :, t]),
                                   rtol=1e-4, atol=1e-5)


def test_stale_read_bounded_staleness():
    state = init_mtl_state(D, CFG)
    # push distinguishable iterates
    for k in range(6):
        ring = state.ring.at[(state.ptr + 1) % (CFG.tau + 1)].set(
            jnp.full((D, T), float(k + 1)))
        state = state._replace(ring=ring, ptr=(state.ptr + 1) % (CFG.tau + 1),
                               step=state.step + 1)
    v_hat, nu = stale_read(state, CFG, jax.random.PRNGKey(0))
    assert int(nu.max()) <= CFG.tau
    # every column equals one of the last tau+1 iterates
    vals = set(np.asarray(v_hat[0]).tolist())
    assert vals.issubset({3.0, 4.0, 5.0, 6.0})


def test_head_converges_on_fixed_representation():
    """With a frozen backbone (fixed pooled features), repeated mesh-AMTL
    rounds drive the probe loss near the noise floor — Theorem 1 in the
    integrated setting."""
    key = jax.random.PRNGKey(0)
    w_true, pooled, tids, y = _data(key, n=512)
    state = init_mtl_state(D, CFG)
    losses = []
    for i in range(400):
        k = jax.random.fold_in(jax.random.PRNGKey(1), i)
        state, _ = amtl_head_update(state, pooled, tids, y, CFG, k)
        if i % 50 == 0:
            w = head_weights(state, CFG)
            losses.append(float(probe_loss(w, pooled, tids, y)))
    assert losses[-1] < 0.05 * losses[0]
    assert losses[-1] < 0.02


def test_dynamic_step_still_converges():
    cfg = dataclasses.replace(CFG, dynamic_step=True, activation_rate=0.5)
    key = jax.random.PRNGKey(0)
    _, pooled, tids, y = _data(key, n=512)
    state = init_mtl_state(D, cfg)
    for i in range(400):
        k = jax.random.fold_in(jax.random.PRNGKey(2), i)
        state, m = amtl_head_update(state, pooled, tids, y, cfg, k)
    w = head_weights(state, cfg)
    assert float(probe_loss(w, pooled, tids, y)) < 0.05
    assert 0.2 < float(m["mtl_active_frac"]) < 0.9


def test_nuclear_coupling_low_rank():
    """Strong lam => the learned head matrix collapses toward low rank."""
    cfg = dataclasses.replace(CFG, lam=3.0)
    key = jax.random.PRNGKey(3)
    _, pooled, tids, y = _data(key, n=512)
    state = init_mtl_state(D, cfg)
    for i in range(300):
        k = jax.random.fold_in(jax.random.PRNGKey(4), i)
        state, _ = amtl_head_update(state, pooled, tids, y, cfg, k)
    w = head_weights(state, cfg)
    s = jnp.linalg.svd(w.astype(jnp.float32), compute_uv=False)
    assert int(jnp.sum(s > 1e-3 * s[0])) < T   # rank reduced


def test_activation_mask_freezes_inactive_blocks():
    cfg = dataclasses.replace(CFG, activation_rate=0.0)
    state = init_mtl_state(D, cfg)
    _, pooled, tids, y = _data(jax.random.PRNGKey(5))
    s2, _ = amtl_head_update(state, pooled, tids, y, cfg,
                             jax.random.PRNGKey(6))
    np.testing.assert_array_equal(np.asarray(s2.ring[s2.ptr]),
                                  np.asarray(state.ring[state.ptr]))
