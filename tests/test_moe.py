"""MoE: routing semantics, dense-vs-EP parity on a 1x1 mesh, capacity drops."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import MoECfg
import dataclasses

from repro.models.moe import (ParallelCtx, _capacity, _dispatch_indices,
                              _router, moe_dense, moe_ep, init_moe)
from repro.launch.mesh import make_host_mesh


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("dbrx-132b").reduced()
    # generous capacity so the EP path is dropless for parity checking
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5
    return cfg, p, x


def test_router_topk_normalized(setup):
    cfg, p, x = setup
    x2 = x.reshape(-1, cfg.d_model)
    w, idx, aux = _router(p["router"], x2, cfg.moe)
    assert w.shape == (x2.shape[0], cfg.moe.top_k)
    np.testing.assert_allclose(np.sum(np.asarray(w), -1), 1.0, rtol=1e-5)
    assert int(idx.max()) < cfg.moe.num_experts
    assert float(aux) >= 1.0 - 1e-3   # E * sum f_e p_e >= 1 (Cauchy-Schwarz)


def test_dispatch_capacity_semantics():
    dest = jnp.asarray([0, 0, 0, 1, 0], jnp.int32)
    slot, keep = _dispatch_indices(dest, n_dest=2, cap=2)
    np.testing.assert_array_equal(np.asarray(slot), [0, 1, 2, 0, 3])
    np.testing.assert_array_equal(np.asarray(keep), [1, 1, 0, 1, 0])


def test_capacity_formula():
    assert _capacity(4096, 8, 256, 1.25) == 160
    assert _capacity(1, 8, 256, 1.25) == 1


def test_ep_matches_dense_on_host_mesh(setup):
    """shard_map EP path (1x1 mesh) == dense dropless oracle when capacity
    is generous."""
    cfg, p, x = setup
    y_dense, aux_d = moe_dense(p, x, cfg)
    mesh = make_host_mesh()
    ctx = ParallelCtx(mesh=mesh, data_axes=("data",))
    y_ep, aux_e = moe_ep(p, x, cfg, ctx, P("data", None, None))
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_dense),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_e), float(aux_d), rtol=1e-4)


def test_ep_with_shared_expert():
    cfg = get_config("deepseek-v3-671b").reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)) * 0.5
    y_dense, _ = moe_dense(p, x, cfg)
    mesh = make_host_mesh()
    ctx = ParallelCtx(mesh=mesh)
    y_ep, _ = moe_ep(p, x, cfg, ctx, P("data", None, None))
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_dense),
                               rtol=2e-4, atol=2e-4)


def test_tight_capacity_drops_but_stays_finite(setup):
    cfg, p, x = setup
    tight = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.3))
    mesh = make_host_mesh()
    y, aux = moe_ep(p, x, tight, ParallelCtx(mesh=mesh),
                    P("data", None, None))
    assert bool(jnp.all(jnp.isfinite(y)))
    # dropped tokens -> output norm below the dropless one
    y_full, _ = moe_dense(p, x, cfg)
    assert float(jnp.linalg.norm(y)) <= float(jnp.linalg.norm(y_full)) + 1e-3


def test_aux_loss_penalizes_imbalance():
    """A router collapsed onto one expert has aux ~= E; uniform ~= 1."""
    m = MoECfg(num_experts=4, top_k=1, d_expert=8)
    n, d = 256, 16
    x2 = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    collapsed = jnp.zeros((d, 4)).at[:, 0].set(10.0)
    x2 = jnp.abs(x2)   # keep logits[:, 0] uniformly dominant
    uniform = jnp.zeros((d, 4))
    _, _, aux_c = _router(collapsed, x2, m)
    _, _, aux_u = _router(uniform, x2, m)
    assert float(aux_c) > 2.0
    assert abs(float(aux_u) - 1.0) < 0.3
