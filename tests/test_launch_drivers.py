"""End-to-end launcher tests: train (fresh + resume) and serve drivers
run in-process on reduced configs with the host mesh."""
import sys

import pytest

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def _run(mod, argv, monkeypatch):
    monkeypatch.setattr(sys, "argv", ["prog"] + argv)
    mod.main()


def test_train_driver_runs_and_resumes(tmp_path, monkeypatch, capsys):
    ckpt = str(tmp_path / "ck")
    _run(train_mod, ["--arch", "gemma2-2b", "--reduced", "--steps", "4",
                     "--batch", "2", "--seq", "32", "--log-every", "2",
                     "--ckpt", ckpt], monkeypatch)
    out = capsys.readouterr().out
    assert "step     0" in out and "final checkpoint" in out
    assert "nan" not in out.lower()

    _run(train_mod, ["--arch", "gemma2-2b", "--reduced", "--steps", "6",
                     "--batch", "2", "--seq", "32", "--log-every", "1",
                     "--ckpt", ckpt], monkeypatch)
    out = capsys.readouterr().out
    assert "resumed" in out and "step     4" in out


def test_serve_driver_decodes(monkeypatch, capsys):
    _run(serve_mod, ["--arch", "gemma2-2b", "--reduced", "--batch", "2",
                     "--prompt-len", "8", "--gen", "4"], monkeypatch)
    out = capsys.readouterr().out
    assert "decoded 4 x 2 tokens" in out


def test_serve_rejects_encoder_only(monkeypatch):
    with pytest.raises(SystemExit):
        _run(serve_mod, ["--arch", "hubert-xlarge", "--reduced"],
             monkeypatch)
