"""Property-based invariants of the event-driven AMTL simulator,
including the beyond-paper features (SGD-AMTL, prox batching)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import NetworkModel, make_synthetic, simulate_amtl, \
    simulate_smtl


def _net(offset=0.5):
    return NetworkModel(delay_offset=offset, delay_jitter=0.1,
                        compute_time=0.05, prox_time=0.01)


@settings(max_examples=10, deadline=None)
@given(tasks=st.integers(2, 8), epochs=st.integers(1, 5),
       seed=st.integers(0, 100))
def test_event_count_and_monotone_clock(tasks, epochs, seed):
    prob = make_synthetic(num_tasks=tasks, samples=20, dim=8, seed=seed)
    r = simulate_amtl(prob, _net(), epochs, seed=seed)
    assert r.iterations == tasks * epochs
    assert all(b >= a for a, b in zip(r.event_times, r.event_times[1:]))
    assert r.total_time == r.event_times[-1]


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 50))
def test_single_task_zero_delay_is_backward_forward(seed):
    """T=1, no staleness, eta_k=1 => exact backward-forward iteration."""
    prob = make_synthetic(num_tasks=1, samples=30, dim=6, seed=seed)
    net = NetworkModel(delay_offset=0.0, delay_jitter=0.0,
                       compute_time=0.01, prox_time=0.01)
    epochs = 7
    r = simulate_amtl(prob, net, epochs, eta_k=1.0, tau=0, seed=seed,
                      record_objective=False)
    eta = 1.0 / prob.lipschitz()
    v = np.zeros((prob.dim, 1))
    for _ in range(epochs):
        p = prob.prox(v, eta * prob.lam)
        g = prob.task_grad(0, p[:, 0])
        v = p - eta * g[:, None]
    w_ref = prob.prox(v, eta * prob.lam)
    assert np.allclose(r.w, w_ref, atol=1e-10)


@settings(max_examples=6, deadline=None)
@given(tasks=st.integers(2, 6), seed=st.integers(0, 50))
def test_objective_decreases(tasks, seed):
    prob = make_synthetic(num_tasks=tasks, samples=40, dim=10, seed=seed)
    r = simulate_amtl(prob, _net(), 15, eta_k=1.0, seed=seed)
    assert r.objectives[-1] < r.objectives[0]


@settings(max_examples=6, deadline=None)
@given(tasks=st.integers(2, 5), k=st.integers(2, 6),
       seed=st.integers(0, 50))
def test_prox_batching_saves_server_time(tasks, k, seed):
    prob = make_synthetic(num_tasks=tasks, samples=20, dim=8, seed=seed)
    net = NetworkModel(delay_offset=0.2, delay_jitter=0.0,
                       compute_time=0.05, prox_time=0.5)  # prox-dominated
    r1 = simulate_amtl(prob, net, 5, seed=seed, record_objective=False)
    rk = simulate_amtl(prob, net, 5, seed=seed, record_objective=False,
                       prox_every=k)
    assert rk.iterations == r1.iterations
    assert rk.total_time < r1.total_time


@settings(max_examples=6, deadline=None)
@given(tasks=st.integers(2, 5), seed=st.integers(0, 50))
def test_full_batch_sgd_equals_full_gradient(tasks, seed):
    """batch_size == n is the exact full gradient (order-invariant sum)."""
    prob = make_synthetic(num_tasks=tasks, samples=25, dim=8, seed=seed)
    r_full = simulate_amtl(prob, _net(), 4, eta_k=1.0, seed=seed,
                           record_objective=False)
    r_sgd = simulate_amtl(prob, _net(), 4, eta_k=1.0, seed=seed,
                          record_objective=False, batch_size=25)
    assert np.allclose(r_full.w, r_sgd.w, atol=1e-9)


def test_smtl_amtl_same_fixed_point_direction():
    """Both reach comparable objectives with practical steps."""
    prob = make_synthetic(num_tasks=6, samples=60, dim=12, seed=3)
    ra = simulate_amtl(prob, _net(), 40, eta_k=1.0, seed=2,
                       record_objective=False)
    rs = simulate_smtl(prob, _net(), 40, seed=2, record_objective=False)
    oa, os_ = prob.objective(ra.w), prob.objective(rs.w)
    assert abs(oa - os_) / os_ < 0.05
