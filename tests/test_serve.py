"""Learning-while-serving platform contracts (`repro.serve.AMTLServer`).

The double-buffer equivalence contract (module doc of
`repro.serve.server`):

  * frozen-mode serving is bitwise `engine.iterate(engine.init(...))`;
  * feedback-driven serving reproduces a plain `engine.run` over the
    same coalesced event chunks bitwise;
  * checkpoint-restart of a live server is invisible to subsequent
    predictions;

for every engine, sharded included (degenerate 1-device "tasks" mesh
here; the multi-shard boundary is the CI serving smoke at 8 fake
devices).  Plus the feedback router's admission/QoS semantics and the
predict micro-batching surface.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import AMTLConfig, make_engine
from repro.launch.mesh import make_task_mesh
from repro.serve import AMTLServer, ServeConfig

ENGINES = ("dense", "delta", "batch", "sharded")


def _cfg(problem, engine, tau=3, **kw):
    eta = 1.0 / problem.lipschitz()
    if engine in ("batch", "sharded"):
        kw.setdefault("event_batch", 4)
        kw.setdefault("prox_every", kw["event_batch"])
    return AMTLConfig(eta=eta, eta_k=0.7, tau=tau, engine=engine, **kw)


@pytest.fixture(scope="module")
def mesh1():
    return make_task_mesh(1)


def _server(problem, cfg, mesh1, serve_cfg=ServeConfig(chunk_events=4),
            key=0, cls_kw=None):
    w0 = jnp.zeros((problem.dim, problem.num_tasks), jnp.float32)
    mesh = mesh1 if cfg.engine == "sharded" else None
    return AMTLServer(problem, cfg, w0, jax.random.PRNGKey(key), serve_cfg,
                      mesh=mesh, **(cls_kw or {}))


def _requests(problem, n, seed=0):
    rng = np.random.default_rng(seed)
    t = rng.integers(0, problem.num_tasks, size=n)
    x = rng.standard_normal((n, problem.dim)).astype(np.float32)
    return t, x


# ------------------------------------------------------------- frozen path
@pytest.mark.parametrize("engine", ENGINES)
def test_frozen_serving_is_bitwise_frozen_engine(small_problem, mesh1,
                                                 engine):
    """Zero feedback: the served iterate is bitwise the frozen engine's,
    and predictions are exactly scores off that iterate."""
    cfg = _cfg(small_problem, engine)
    server = _server(small_problem, cfg, mesh1,
                     ServeConfig(chunk_events=4, learning=False))
    eng = make_engine(small_problem, cfg,
                      mesh1 if engine == "sharded" else None)
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    frozen = eng.iterate(eng.init(w0, jax.random.PRNGKey(0)))
    np.testing.assert_array_equal(np.asarray(server.iterate()),
                                  np.asarray(frozen))
    t, x = _requests(small_problem, 7)
    preds, receipt, ran = server.serve(t, x, feedback_task_ids=t)
    assert ran == 0 and receipt.accepted == 0 and receipt.rejected == 7
    want = np.einsum("bd,bd->b", x, np.asarray(frozen)[:, t].T)
    np.testing.assert_allclose(np.asarray(preds), want, rtol=1e-6)
    # still frozen after the request batch
    np.testing.assert_array_equal(np.asarray(server.iterate()),
                                  np.asarray(frozen))


def test_zero_feedback_learning_server_is_also_frozen(small_problem, mesh1):
    """learning=True but no feedback submitted: step() never runs a chunk
    and the served iterate stays the init iterate bitwise."""
    server = _server(small_problem, _cfg(small_problem, "batch"), mesh1)
    before = np.asarray(server.iterate())
    t, x = _requests(small_problem, 5)
    for _ in range(3):
        server.predict(t, x)
        assert server.step() == 0
    np.testing.assert_array_equal(np.asarray(server.iterate()), before)
    assert server.chunk_log == []


# -------------------------------------------------------- feedback replay
@pytest.mark.parametrize("engine", ENGINES)
def test_feedback_serving_replays_plain_run_bitwise(small_problem, mesh1,
                                                    engine):
    """After any sequence of chunk boundaries the server state is bitwise
    one plain `engine.run` over the same coalesced chunks, and the
    serving buffer is that state's iterate."""
    cfg = _cfg(small_problem, engine)
    per = 4 if engine in ("batch", "sharded") else 1
    server = _server(small_problem, cfg, mesh1,
                     ServeConfig(chunk_events=2 * per))
    rng = np.random.default_rng(3)
    t, x = _requests(small_problem, 6)
    for i in range(5):
        fb = rng.integers(0, small_problem.num_tasks,
                          size=rng.integers(1, 3 * per))
        server.serve(t, x, feedback_task_ids=fb)
    assert sum(server.chunk_log) > 0
    for n in server.chunk_log:
        assert n % per == 0 and 0 < n <= 2 * per

    eng = make_engine(small_problem, cfg,
                      mesh1 if engine == "sharded" else None)
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    state = eng.init(w0, jax.random.PRNGKey(0))
    state = eng.run(state, None, sum(server.chunk_log))
    np.testing.assert_array_equal(np.asarray(server.iterate()),
                                  np.asarray(eng.iterate(state)))
    for la, lb in zip(jax.tree.leaves(server._state),
                      jax.tree.leaves(state), strict=True):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=engine)


def test_serving_buffer_swaps_only_at_chunk_boundaries(small_problem, mesh1):
    """A request batch's predictions come off the buffer committed at the
    PREVIOUS boundary: feedback in batch k moves predictions from batch
    k+1 on, never batch k's."""
    server = _server(small_problem, _cfg(small_problem, "delta"), mesh1,
                     ServeConfig(chunk_events=4))
    t, x = _requests(small_problem, 4)
    before = np.asarray(server.predict(t, x))
    preds, _, ran = server.serve(t, x, feedback_task_ids=[0, 1, 2, 3])
    assert ran == 4
    np.testing.assert_array_equal(np.asarray(preds), before)
    after = np.asarray(server.predict(t, x))
    assert not np.array_equal(after, before)


# --------------------------------------------------- checkpoint / restart
@pytest.mark.parametrize("engine", ENGINES)
def test_restart_is_invisible_to_predictions(small_problem, mesh1, engine,
                                             tmp_path):
    """Kill a live server after a rotated checkpoint; `resume` must serve
    bitwise what the uninterrupted server serves, through further
    feedback chunks."""
    cfg = _cfg(small_problem, engine)
    per = 4 if engine in ("batch", "sharded") else 1
    serve_cfg = ServeConfig(chunk_events=2 * per, ckpt_dir=str(tmp_path),
                            checkpoint_every=2 * per, keep_last=2)
    a = _server(small_problem, cfg, mesh1, serve_cfg, key=1)
    b = _server(small_problem, cfg, mesh1, serve_cfg, key=1)
    t, x = _requests(small_problem, 5, seed=9)
    fb = [i % small_problem.num_tasks for i in range(2 * per)]
    a.serve(t, x, feedback_task_ids=fb)     # chunk + auto-checkpoint
    b_preds0, _, _ = b.serve(t, x, feedback_task_ids=fb)

    # "crash" a; resume from its rotated checkpoints
    del a
    c = AMTLServer.resume(
        small_problem, cfg,
        jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32),
        jax.random.PRNGKey(1), serve_cfg,
        mesh=mesh1 if engine == "sharded" else None)
    assert c.event_count == 2 * per
    np.testing.assert_array_equal(np.asarray(c.iterate()),
                                  np.asarray(b.iterate()))
    # identical subsequent traffic -> identical predictions, bitwise
    for i in range(3):
        pc, _, rc = c.serve(t, x, feedback_task_ids=fb)
        pb, _, rb = b.serve(t, x, feedback_task_ids=fb)
        assert rc == rb
        np.testing.assert_array_equal(np.asarray(pc), np.asarray(pb))
    np.testing.assert_array_equal(np.asarray(c.iterate()),
                                  np.asarray(b.iterate()))


def test_checkpoint_rotation_on_disk(small_problem, mesh1, tmp_path):
    """The auto-checkpoint cadence rotates via save(..., keep_last=k)."""
    serve_cfg = ServeConfig(chunk_events=4, ckpt_dir=str(tmp_path),
                            checkpoint_every=4, keep_last=2)
    server = _server(small_problem, _cfg(small_problem, "batch"), mesh1,
                     serve_cfg)
    t, x = _requests(small_problem, 3)
    for _ in range(5):
        server.serve(t, x, feedback_task_ids=[0, 1, 2, 3])
    import os
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000016.npz", "step_00000020.npz"]


def test_resume_with_empty_dir_is_fresh_init(small_problem, mesh1,
                                             tmp_path):
    serve_cfg = ServeConfig(chunk_events=4, ckpt_dir=str(tmp_path))
    server = AMTLServer.resume(
        small_problem, _cfg(small_problem, "delta"),
        jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32),
        jax.random.PRNGKey(0), serve_cfg)
    assert server.event_count == 0


# ------------------------------------------------------- admission / QoS
def test_admission_cap_rejects_burst(small_problem, mesh1):
    server = _server(small_problem, _cfg(small_problem, "delta"), mesh1,
                     ServeConfig(chunk_events=4, max_pending_per_task=3))
    receipt = server.submit_feedback([0] * 10)
    assert receipt == (3, 7)
    assert server.pending_feedback == 3
    assert server.stats()["rejected_feedback"] == 7


def test_chunk_quota_stops_bursty_task_starving_budget(small_problem,
                                                       mesh1):
    """Task 0 floods the queue; the per-chunk quota keeps every other
    task's feedback flowing within the same chunk."""
    server = _server(small_problem, _cfg(small_problem, "delta"), mesh1,
                     ServeConfig(chunk_events=6, task_chunk_quota=2))
    server.submit_feedback([0] * 50)
    server.submit_feedback([1, 2, 3, 4])
    ran = server.step()
    assert ran == 6
    # quota'd: 2 events from task 0, the rest from tasks 1..4
    assert server._pending[0] == 48
    assert server._pending[1:].sum() == 0
    # the backlog keeps draining at quota pace on later chunks
    assert server.step() == 2
    assert server._pending[0] == 46


def test_coalesce_floors_to_events_per_step(small_problem, mesh1):
    """A batch engine can only run multiples of event_batch: the floored
    remainder stays queued for the next chunk, never dropped."""
    server = _server(small_problem, _cfg(small_problem, "batch"), mesh1,
                     ServeConfig(chunk_events=8))
    server.submit_feedback([0, 1, 2, 3, 4, 0])      # 6 items, per = 4
    assert server.step() == 4
    assert server.pending_feedback == 2
    server.submit_feedback([1, 2])
    assert server.step() == 4
    assert server.pending_feedback == 0


def test_resume_restores_mixed_padding_checkpoint(small_problem, mesh1,
                                                  tmp_path):
    """Regression: `latest_step` parses step_5.npz to 5 but `restore`
    re-formatted it as step_00000005.npz and raised FileNotFoundError —
    `AMTLServer.resume` crashed on a directory the rotation fix of PR 7
    deliberately tolerates."""
    import os
    serve_cfg = ServeConfig(chunk_events=4, ckpt_dir=str(tmp_path))
    server = _server(small_problem, _cfg(small_problem, "delta"), mesh1,
                     serve_cfg)
    server.submit_feedback([0, 1, 2, 3])
    server.step()
    server.checkpoint()
    os.rename(tmp_path / "step_00000004.npz", tmp_path / "step_4.npz")
    want = np.asarray(server.iterate())
    del server
    resumed = AMTLServer.resume(
        small_problem, _cfg(small_problem, "delta"),
        jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32),
        jax.random.PRNGKey(0), serve_cfg)
    assert resumed.event_count == 4
    np.testing.assert_array_equal(np.asarray(resumed.iterate()), want)


def test_resume_builds_init_state_once(small_problem, mesh1, tmp_path,
                                       monkeypatch):
    """Regression: `resume` computed `engine.init(v0, key)` twice (ctor
    + `like`) and materialized a front buffer it immediately replaced.
    Now the init state is built once and only the state actually served
    materializes a snapshot."""
    import repro.serve.server as srv_mod
    serve_cfg = ServeConfig(chunk_events=4, ckpt_dir=str(tmp_path))
    server = _server(small_problem, _cfg(small_problem, "delta"), mesh1,
                     serve_cfg)
    server.submit_feedback([0, 1, 2])
    server.step()
    server.checkpoint()
    del server

    init_calls = []
    real_make_engine = srv_mod.make_engine

    def spying_make_engine(problem, cfg, mesh=None):
        eng = real_make_engine(problem, cfg, mesh)
        real_init = eng.init

        def counted_init(v0, key):
            init_calls.append(1)
            return real_init(v0, key)
        return eng._replace(init=counted_init)

    monkeypatch.setattr(srv_mod, "make_engine", spying_make_engine)
    resumed = AMTLServer.resume(
        small_problem, _cfg(small_problem, "delta"),
        jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32),
        jax.random.PRNGKey(0), serve_cfg)
    assert len(init_calls) == 1
    assert resumed.event_count == 3


# ------------------------------------------------------- predict surface
@pytest.mark.parametrize("loss_name", ("lstsq", "logistic"))
def test_predict_empty_batch_returns_empty_scores(small_problem, mesh1,
                                                  loss_name):
    """Regression: `predict([], zeros((0, d)))` reached
    `jnp.concatenate([])` (the slice loop never runs) and raised
    ValueError.  An empty request batch is a valid request: it returns
    an empty (0,) score array in the link's dtype."""
    problem = small_problem._replace(loss_name=loss_name)
    server = _server(problem, _cfg(problem, "delta"), mesh1)
    out = server.predict([], np.zeros((0, problem.dim), np.float32))
    assert out.shape == (0,)
    assert out.dtype == jnp.float32
    assert server.stats()["requests"] == 1
    assert server.stats()["predictions"] == 0
    # non-empty requests on the same server still serve normally
    t, x = _requests(problem, 3)
    assert np.asarray(server.predict(t, x)).shape == (3,)


def test_predict_micro_batches_pad_and_slice(small_problem, mesh1):
    """Bucketed padding and max_batch slicing return exactly the
    unpadded scores in request order."""
    server = _server(small_problem, _cfg(small_problem, "delta"), mesh1,
                     ServeConfig(chunk_events=4, max_batch=4))
    server.submit_feedback([0, 1, 2])
    server.step()
    t, x = _requests(small_problem, 11, seed=4)
    got = np.asarray(server.predict(t, x))
    assert got.shape == (11,)
    v = np.asarray(server.iterate())
    np.testing.assert_allclose(got, np.einsum("bd,bd->b", x, v[:, t].T),
                               rtol=1e-6)
    one = np.asarray(server.predict(t[:1], x[:1]))
    np.testing.assert_allclose(one, got[:1], rtol=1e-6)


def test_logistic_predictions_are_probabilities(small_problem, mesh1):
    logit = small_problem._replace(loss_name="logistic")
    server = _server(logit, _cfg(logit, "delta"), mesh1)
    t, x = _requests(logit, 6)
    p = np.asarray(server.predict(t, x))
    assert ((p > 0) & (p < 1)).all()


def test_predict_validates_inputs(small_problem, mesh1):
    server = _server(small_problem, _cfg(small_problem, "delta"), mesh1)
    with pytest.raises(ValueError, match="features must be"):
        server.predict([0, 1], np.zeros((2, 3), np.float32))
    with pytest.raises(ValueError, match="task_ids must be in"):
        server.predict([small_problem.num_tasks],
                       np.zeros((1, small_problem.dim), np.float32))
    with pytest.raises(ValueError, match="feedback task_ids"):
        server.submit_feedback([-1])


def test_serve_config_validates(small_problem, mesh1):
    with pytest.raises(ValueError, match="multiple of the engine's"):
        _server(small_problem, _cfg(small_problem, "batch"), mesh1,
                ServeConfig(chunk_events=6))
    with pytest.raises(ValueError, match="task_chunk_quota"):
        _server(small_problem, _cfg(small_problem, "delta"), mesh1,
                ServeConfig(chunk_events=4, task_chunk_quota=0))
    with pytest.raises(ValueError, match="nowhere to write"):
        _server(small_problem, _cfg(small_problem, "delta"), mesh1,
                ServeConfig(chunk_events=4, checkpoint_every=4))


def test_stats_telemetry(small_problem, mesh1):
    server = _server(small_problem, _cfg(small_problem, "delta"), mesh1,
                     ServeConfig(chunk_events=4))
    t, x = _requests(small_problem, 3)
    server.serve(t, x, feedback_task_ids=[0, 1])
    s = server.stats()
    assert s["requests"] == 1 and s["predictions"] == 3
    assert s["events"] == 2 and s["chunks"] == 1
    assert s["learning"] is True
