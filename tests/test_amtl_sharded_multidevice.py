"""Sharded AMTL engine across real shard boundaries: the event stream and
final iterate must be invariant to shard count (1, 2, 8), including with a
straggler shard (delay_offsets skewed to one shard's tasks).  Runs in a
subprocess with 8 fake host devices so real shard_map collectives are
exercised."""
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # 8-fake-device subprocess; excluded from tier-1

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp

from repro.core import MTLProblem, make_synthetic
from repro.core.amtl import AMTLConfig, amtl_events_only, amtl_solve
from repro.core.operators import backward
from repro.launch.mesh import make_task_mesh

assert jax.local_device_count() == 8

prob = make_synthetic(num_tasks=8, samples=12, dim=6, seed=1)
problem = MTLProblem(jnp.asarray(np.stack(prob.xs), jnp.float32),
                     jnp.asarray(np.stack(prob.ys), jnp.float32),
                     "lstsq", "nuclear", 0.1)
eta = 1.0 / problem.lipschitz()
w0 = jnp.zeros((6, 8), jnp.float32)
key = jax.random.PRNGKey(2)

def states(cfg, offs):
    # the serial reference is the batch engine, whose prox is by
    # definition the replicated one
    ref = amtl_events_only(problem,
                           cfg._replace(engine="batch",
                                        prox_mode="replicated"),
                           w0, key, 40, delay_offsets=offs)
    outs = {n: amtl_events_only(problem, cfg, w0, key, 40,
                                delay_offsets=offs, mesh=make_task_mesh(n))
            for n in (1, 2, 8)}
    return ref, outs

def assert_stream_and_iterate(ref, st, label):
    # The (task, staleness) event stream: the global-id task ring, the
    # per-task delay recordings, and the per-task event counts must all
    # equal the serial-replay batch engine's, as must the PRNG chain head.
    np.testing.assert_array_equal(np.asarray(st.task_ring),
                                  np.asarray(ref.task_ring), err_msg=label)
    np.testing.assert_array_equal(np.asarray(st.history.buf),
                                  np.asarray(ref.history.buf), err_msg=label)
    np.testing.assert_array_equal(np.asarray(st.history.count),
                                  np.asarray(ref.history.count),
                                  err_msg=label)
    np.testing.assert_array_equal(np.asarray(st.key), np.asarray(ref.key),
                                  err_msg=label)
    assert int(st.ptr) == int(ref.ptr) and int(st.event) == int(ref.event)
    # Final iterate (and hence W = prox(V)): bitwise on the CPU oracle path.
    np.testing.assert_array_equal(np.asarray(st.v), np.asarray(ref.v),
                                  err_msg=label)

# Uniform delays, exact prox.
cfg = AMTLConfig(eta=eta, eta_k=0.6, tau=3, engine="sharded", prox_every=4,
                 event_batch=4)
ref, outs = states(cfg, None)
for n, st in outs.items():
    assert_stream_and_iterate(ref, st, f"uniform/{n}-shards")

# Straggler shard: tasks 0-3 (shard 0 of 2, shards 0-3 of 8) lag at the
# staleness cap while the rest read fresh — the paper's slow-node regime.
# The other shards' event stream and updates must be unaffected by the
# straggler, i.e. identical to serial replay at every shard count.
straggle = jnp.asarray([3.0, 3.0, 3.0, 3.0, 0.0, 0.0, 0.0, 0.0])
cfg_d = cfg._replace(dynamic_step=True, prox_rank=4)
ref_s, outs_s = states(cfg_d, straggle)
for n, st in outs_s.items():
    assert_stream_and_iterate(ref_s, st, f"straggler/{n}-shards")
mean_delay = np.asarray(ref_s.history.buf).sum(axis=1) / np.maximum(
    np.minimum(np.asarray(ref_s.history.count), 5), 1)
assert mean_delay[:4].min() >= 2.0, mean_delay   # lagging shard reads stale
assert mean_delay[4:].max() <= 1.0, mean_delay   # fresh shards unaffected
# Throughput accounting: the straggler does not stall the others — every
# task keeps getting activated (events land on both halves of the mesh).
counts = np.asarray(ref_s.history.count)
assert counts[4:].sum() > 0 and counts[:4].sum() > 0, counts

# SGD-AMTL minibatching (batch_size=3 of 12 samples): the sampling seed
# is folded OFF the replicated PRNG chain per event and every shard
# derives the identical seed, so the (task, staleness) event stream AND
# the minibatch-gradient iterates stay bitwise shard-count-invariant at
# 1/2/8 shards — the PR-6 acceptance criterion.
cfg_sgd = cfg._replace(batch_size=3)
ref_g, outs_g = states(cfg_sgd, None)
for n, st in outs_g.items():
    assert_stream_and_iterate(ref_g, st, f"sgd/{n}-shards")
# Enabling minibatching must not perturb the chain: same stream as the
# full-gradient runs above (bitwise), different iterates (the gradients
# genuinely subsample — a saturated mask would make this vacuous).
np.testing.assert_array_equal(np.asarray(ref_g.task_ring),
                              np.asarray(ref.task_ring))
np.testing.assert_array_equal(np.asarray(ref_g.key), np.asarray(ref.key))
assert not np.array_equal(np.asarray(ref_g.v), np.asarray(ref.v))

# Minibatching under the straggler + dynamic step + sketch regime.
cfg_sgd_d = cfg_d._replace(batch_size=3)
ref_gs, outs_gs = states(cfg_sgd_d, straggle)
for n, st in outs_gs.items():
    assert_stream_and_iterate(ref_gs, st, f"sgd-straggler/{n}-shards")

# Rank-distributed server prox (prox_mode="distributed"), straggler +
# dynamic step + sketch: the (task, staleness) event stream is driven by
# the replicated PRNG chain, which the distributed collectives never
# touch, so the stream stays BITWISE shard-count-invariant.  The iterate
# is bitwise at 1 shard (every collective degenerates to the identity);
# at 2/8 shards the (d, p) psum regroups the sketch's reduction over T,
# so the iterate agrees to float32 ulp accumulated over refreshes, not
# bitwise — the documented equivalence contract of svt_randomized_dist.
cfg_dist = cfg_d._replace(prox_mode="distributed")
ref_dp, outs_dp = states(cfg_dist, straggle)
for n, st in outs_dp.items():
    label = f"distprox-straggler/{n}-shards"
    np.testing.assert_array_equal(np.asarray(st.task_ring),
                                  np.asarray(ref_dp.task_ring), err_msg=label)
    np.testing.assert_array_equal(np.asarray(st.history.buf),
                                  np.asarray(ref_dp.history.buf),
                                  err_msg=label)
    np.testing.assert_array_equal(np.asarray(st.key), np.asarray(ref_dp.key),
                                  err_msg=label)
    assert int(st.ptr) == int(ref_dp.ptr)
    assert int(st.event) == int(ref_dp.event)
    if n == 1:
        np.testing.assert_array_equal(np.asarray(st.v), np.asarray(ref_dp.v),
                                      err_msg=label)
    else:
        np.testing.assert_allclose(np.asarray(st.v), np.asarray(ref_dp.v),
                                   rtol=5e-4, atol=1e-5, err_msg=label)
# The straggler regime itself is unchanged by the prox mode: the lagging
# shard's tasks still read at high staleness, the fresh shards don't.
mean_dp = np.asarray(ref_dp.history.buf).sum(axis=1) / np.maximum(
    np.minimum(np.asarray(ref_dp.history.count), 5), 1)
assert mean_dp[:4].min() >= 2.0 and mean_dp[4:].max() <= 1.0, mean_dp

# Distributed prox at the decoupled cadence (prox_every = 2*event_batch):
# the carried prox cache is column-sharded; resuming it across shard
# counts must preserve the stream bitwise and the iterate to ulp.
cfg_dist_k = cfg_dist._replace(prox_every=8)
ref_k, outs_k = states(cfg_dist_k, straggle)
for n, st in outs_k.items():
    np.testing.assert_array_equal(np.asarray(st.task_ring),
                                  np.asarray(ref_k.task_ring))
    if n == 1:
        np.testing.assert_array_equal(np.asarray(st.v), np.asarray(ref_k.v))
    else:
        np.testing.assert_allclose(np.asarray(st.v), np.asarray(ref_k.v),
                                   rtol=5e-4, atol=1e-5)

# amtl_solve end-to-end on a 2-shard mesh: iterates bitwise against the
# batch engine.  The per-epoch objective/residual instrumentation runs
# OUTSIDE shard_map on the task-sharded iterate, so its cross-device
# partial sums reduce in a different order than single-device execution —
# those agree to float32 ulp, not bitwise (the engine contract covers the
# iterate and event stream, not the metric tail's reduction order).
res_b = amtl_solve(problem, cfg._replace(engine="batch"), w0, key,
                   num_epochs=6)
res_s = amtl_solve(problem, cfg, w0, key, num_epochs=6,
                   mesh=make_task_mesh(2))
np.testing.assert_array_equal(np.asarray(res_b.v), np.asarray(res_s.v))
np.testing.assert_allclose(np.asarray(res_s.w), np.asarray(res_b.w),
                           rtol=1e-6, atol=1e-6)
np.testing.assert_allclose(np.asarray(res_s.objectives),
                           np.asarray(res_b.objectives), rtol=1e-5)
np.testing.assert_allclose(np.asarray(res_s.residuals),
                           np.asarray(res_b.residuals), rtol=1e-4,
                           atol=1e-5)

# Validation: T=8 not divisible by a 3-shard mesh.
try:
    amtl_events_only(problem, cfg, w0, key, 4, mesh=make_task_mesh(3))
except ValueError as e:
    assert "divisible" in str(e), e
else:
    raise AssertionError("expected divisibility ValueError for 3 shards")

print("OK")
"""


def test_sharded_engine_invariant_to_shard_count():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env={"PYTHONPATH": "src",
                                       "PATH": "/usr/bin:/bin"},
                       cwd="/root/repo", timeout=600)
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-3000:]
