"""Property-based test of the batched event sampler's serial-replay
contract.

`_sample_activation_batch` is what lets the batch and sharded engines claim
an event stream identical to the one-event engines BY CONSTRUCTION: it must
consume the same PRNG splits and produce the same (task, staleness) draws
as `event_batch` consecutive `_sample_activation` calls — including the
per-position staleness clamp `nu <= min(tau, event + i)` — for every
`event_batch`, `tau`, `delay_offsets`, jitter, and chain position.  PR 2
only covered this implicitly at the fixed bench shapes; here hypothesis
drives arbitrary configurations.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.amtl import (AMTLConfig, _sample_activation,
                             _sample_activation_batch)


@st.composite
def _sampler_setups(draw):
    num_tasks = draw(st.integers(1, 8))
    tau = draw(st.integers(0, 6))
    batch = draw(st.integers(1, 12))
    # chain position: 0 exercises the `nu <= event` warm-up clamp, larger
    # values the steady state
    event0 = draw(st.integers(0, 20))
    jitter = draw(st.floats(0.0, 3.0, allow_nan=False, allow_infinity=False))
    offsets = draw(st.lists(
        st.floats(0.0, 6.0, allow_nan=False, allow_infinity=False),
        min_size=num_tasks, max_size=num_tasks))
    seed = draw(st.integers(0, 2**31 - 1))
    return num_tasks, tau, batch, event0, jitter, offsets, seed


@settings(max_examples=50, deadline=None)
@given(_sampler_setups())
def test_batch_sampler_replays_serial_chain_exactly(setup):
    num_tasks, tau, batch, event0, jitter, offsets, seed = setup
    cfg = AMTLConfig(eta=0.1, eta_k=0.5, tau=tau, delay_jitter=jitter)
    offs = jnp.asarray(offsets, jnp.float32)
    key0 = jax.random.PRNGKey(seed)
    event0_j = jnp.asarray(event0, jnp.int32)

    key = key0
    want_ts, want_nus = [], []
    for i in range(batch):
        key, t, nu = _sample_activation(cfg, offs, key, num_tasks,
                                        event0_j + i)
        want_ts.append(int(t))
        want_nus.append(int(nu))

    got_key, got_ts, got_nus = _sample_activation_batch(
        cfg, offs, key0, num_tasks, event0_j, batch)

    np.testing.assert_array_equal(np.asarray(got_ts), want_ts)
    np.testing.assert_array_equal(np.asarray(got_nus), want_nus)
    # the chain head must also coincide: the next batch continues the same
    # serial split sequence
    np.testing.assert_array_equal(np.asarray(got_key), np.asarray(key))
    # staleness always within the cap and the warm-up window
    assert all(nu <= min(tau, event0 + i)
               for i, nu in enumerate(want_nus))
