"""Property-based tests of the serial-replay contracts.

`_sample_activation_batch` is what lets the batch and sharded engines claim
an event stream identical to the one-event engines BY CONSTRUCTION: it must
consume the same PRNG splits and produce the same (task, staleness) draws
as `event_batch` consecutive `_sample_activation` calls — including the
per-position staleness clamp `nu <= min(tau, event + i)` — for every
`event_batch`, `tau`, `delay_offsets`, jitter, and chain position.  PR 2
only covered this implicitly at the fixed bench shapes; here hypothesis
drives arbitrary configurations.

The session analogue (PR 4): `AMTLEngine.run` must compose bitwise at ANY
step boundary — `run(·, total)` equals `run(run(·, n), total - n)` on the
FULL engine state, for arbitrary engine, tau, event_batch, prox cadence,
and split point, with the mid state additionally round-tripped through the
checkpoint serialization (host numpy and back).  This is the streaming
deployment contract: a server that persists its state after any chunk of
events and restarts resumes the exact event stream.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.amtl import (AMTLConfig, _minibatch_seed, _sample_activation,
                             _sample_activation_batch, amtl_events_only,
                             make_engine)
from repro.core.losses import MTLProblem
from repro.kernels import ops, ref


@st.composite
def _sampler_setups(draw):
    num_tasks = draw(st.integers(1, 8))
    tau = draw(st.integers(0, 6))
    batch = draw(st.integers(1, 12))
    # chain position: 0 exercises the `nu <= event` warm-up clamp, larger
    # values the steady state
    event0 = draw(st.integers(0, 20))
    jitter = draw(st.floats(0.0, 3.0, allow_nan=False, allow_infinity=False))
    offsets = draw(st.lists(
        st.floats(0.0, 6.0, allow_nan=False, allow_infinity=False),
        min_size=num_tasks, max_size=num_tasks))
    seed = draw(st.integers(0, 2**31 - 1))
    return num_tasks, tau, batch, event0, jitter, offsets, seed


@settings(max_examples=50, deadline=None)
@given(_sampler_setups())
def test_batch_sampler_replays_serial_chain_exactly(setup):
    num_tasks, tau, batch, event0, jitter, offsets, seed = setup
    cfg = AMTLConfig(eta=0.1, eta_k=0.5, tau=tau, delay_jitter=jitter)
    offs = jnp.asarray(offsets, jnp.float32)
    key0 = jax.random.PRNGKey(seed)
    event0_j = jnp.asarray(event0, jnp.int32)

    key = key0
    want_ts, want_nus, want_seeds = [], [], []
    for i in range(batch):
        # the minibatch seed is folded off the PRE-event chain key — the
        # exact key the serial delta engine holds when it derives its seed
        want_seeds.append(int(_minibatch_seed(key)))
        key, t, nu = _sample_activation(cfg, offs, key, num_tasks,
                                        event0_j + i)
        want_ts.append(int(t))
        want_nus.append(int(nu))

    got_key, got_ts, got_nus, got_seeds = _sample_activation_batch(
        cfg, offs, key0, num_tasks, event0_j, batch)

    np.testing.assert_array_equal(np.asarray(got_ts), want_ts)
    np.testing.assert_array_equal(np.asarray(got_nus), want_nus)
    # the batched replay derives the SAME per-event sampling seeds as the
    # one-event engine's serial fold — the SGD engines' equivalence hinge
    np.testing.assert_array_equal(np.asarray(got_seeds), want_seeds)
    # the chain head must also coincide: the next batch continues the same
    # serial split sequence
    np.testing.assert_array_equal(np.asarray(got_key), np.asarray(key))
    # staleness always within the cap and the warm-up window
    assert all(nu <= min(tau, event0 + i)
               for i, nu in enumerate(want_nus))


# ------------------------------------------------- session split / resume

_T, _N, _D = 4, 6, 8


def _tiny_problem():
    kx, ky = jax.random.split(jax.random.PRNGKey(0))
    xs = jax.random.normal(kx, (_T, _N, _D)) / np.sqrt(_D)
    ys = jax.random.normal(ky, (_T, _N))
    return MTLProblem(xs, ys, "lstsq", "nuclear", 0.1)


@st.composite
def _session_setups(draw):
    engine = draw(st.sampled_from(["dense", "delta", "batch", "sharded"]))
    tau = draw(st.integers(0, 4))
    if engine in ("batch", "sharded"):
        bsz = draw(st.integers(1, 4))
        prox_every = bsz * draw(st.integers(1, 3))   # incl. decoupled k > 1
    else:
        bsz = 1
        prox_every = 1 if engine == "dense" else draw(st.integers(1, 4))
    total_steps = draw(st.integers(1, 5))
    split = draw(st.integers(0, total_steps))
    dynamic = draw(st.booleans())
    offsets = draw(st.lists(
        st.floats(0.0, 4.0, allow_nan=False, allow_infinity=False),
        min_size=_T, max_size=_T))
    seed = draw(st.integers(0, 2**31 - 1))
    return engine, tau, bsz, prox_every, total_steps, split, dynamic, \
        offsets, seed


def _roundtrip_host(state):
    """The checkpoint serialization boundary: every leaf to host numpy and
    back (what save -> restore does, minus the filesystem)."""
    return jax.tree.map(lambda a: jnp.asarray(np.asarray(a)), state)


@settings(max_examples=25, deadline=None)
@given(_session_setups())
def test_session_split_at_any_event_boundary_resumes_bitwise(setup):
    """The streaming analogue of the serial-chain replay property: for any
    engine/tau/event_batch/cadence and ANY split point, running the session
    in two chunks (with a host round-trip of the mid state) reproduces the
    uninterrupted run's full state bitwise."""
    (engine, tau, bsz, prox_every, total_steps, split, dynamic, offsets,
     seed) = setup
    problem = _tiny_problem()
    cfg = AMTLConfig(eta=1.0 / problem.lipschitz(), eta_k=0.6, tau=tau,
                     engine=engine, event_batch=bsz, prox_every=prox_every,
                     dynamic_step=dynamic)
    mesh = None
    if engine == "sharded":
        from repro.launch.mesh import make_task_mesh
        mesh = make_task_mesh(1)
    eng = make_engine(problem, cfg, mesh)
    offs = jnp.asarray(offsets, jnp.float32)
    w0 = jnp.zeros((_D, _T), jnp.float32)
    key = jax.random.PRNGKey(seed)

    full = eng.run(eng.init(w0, key), offs, total_steps * bsz)
    mid = eng.run(eng.init(w0, key), offs, split * bsz)
    resumed = eng.run(_roundtrip_host(mid), offs, (total_steps - split) * bsz)

    assert int(resumed.event) == total_steps * bsz
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(resumed),
                    strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------ seeded minibatch sampling
#
# SGD-AMTL's forward step (PR 6).  Three contracts:
#   * the in-kernel sampler's keep/drop bits equal the jnp oracle's for
#     every (n, batch_size, seed) — selection is pure counter arithmetic;
#   * the minibatch gradient is unbiased: averaged over seeds it converges
#     to the full gradient under the (n/bsz) scaling;
#   * batch_size >= n (and batch_size=None at the engine level) degrades
#     to the exact full-gradient path, bitwise on a fixed backend.


@st.composite
def _mask_setups(draw):
    n = draw(st.integers(1, 1100))          # crosses the 512 block boundary
    b = draw(st.integers(1, 1100))          # incl. batch_size >= n
    seed = draw(st.integers(0, 2**32 - 1))
    return n, b, seed


@settings(max_examples=40, deadline=None)
@given(_mask_setups())
def test_sample_mask_kernel_matches_oracle_bitwise(setup):
    """The Pallas sampler (interpret mode) and the jnp oracle must emit the
    SAME selection bits — they share `counter_hash`/`sample_cutoff`, and
    this pins that the kernel's iota/padding plumbing preserves them."""
    n, b, seed = setup
    seed_j = jnp.asarray(seed, jnp.uint32)
    want = np.asarray(ref.sample_mask_ref(n, b, seed_j))
    got = np.asarray(ops.sample_mask(n, b, seed_j, interpret=True))
    np.testing.assert_array_equal(got, want)
    # rank-based selection keeps EXACTLY min(b, n) rows — what licenses
    # the oracle's static-size gather
    assert got.sum() == min(b, n)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(0, 40))
def test_batch_size_at_least_n_is_bitwise_full_gradient(seed, extra):
    """batch_size >= n: mask all-ones and scale (n/bsz) == 1, so the sampled
    op must reproduce `ops.lstsq_grad` BITWISE on the oracle path — the
    engines' batch_size=None arithmetic is this path."""
    n, d = 13, 5
    kx, kw, ky = jax.random.split(jax.random.PRNGKey(2), 3)
    x = jax.random.normal(kx, (n, d), jnp.float32)
    w = jax.random.normal(kw, (d,), jnp.float32)
    y = jax.random.normal(ky, (n,), jnp.float32)
    got = ops.lstsq_grad_sampled(x, w, y, jnp.asarray(seed, jnp.uint32),
                                 batch_size=n + extra, use_pallas=False)
    want = ops.lstsq_grad(x, w, y, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_minibatch_gradient_is_unbiased_over_seeds():
    """E_seed[(n/bsz) 2 X_S^T(X_S w - y_S)] = 2 X^T(X w - y): the mean over
    a large fixed seed set must approach the full gradient (deterministic
    seed set, statistical tolerance — no flake)."""
    n, d, b = 40, 6, 10
    kx, kw, ky = jax.random.split(jax.random.PRNGKey(7), 3)
    x = jax.random.normal(kx, (n, d), jnp.float32)
    w = jax.random.normal(kw, (d,), jnp.float32)
    y = jax.random.normal(ky, (n,), jnp.float32)
    seeds = jnp.arange(6000, dtype=jnp.uint32)
    grads = jax.vmap(
        lambda s: ref.lstsq_grad_sampled_ref(x, w, y, s, b))(seeds)
    mean = np.asarray(grads, np.float64).mean(axis=0)
    full = np.asarray(ref.lstsq_grad_ref(x, w, y), np.float64)
    rel = np.linalg.norm(mean - full) / np.linalg.norm(full)
    assert rel < 0.08, rel


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, _N), st.integers(0, 4))
def test_delta_and_batch_engines_agree_bitwise_with_minibatching(
        seed, batch_size, tau):
    """Aligned delta/batch configs with batch_size set: both engines must
    fold the SAME per-event sampling seed off the same chain position, so
    their full states stay bitwise equal on the CPU oracle path."""
    problem = _tiny_problem()
    eta = 1.0 / problem.lipschitz()
    delta_cfg = AMTLConfig(eta=eta, eta_k=0.6, tau=tau, engine="delta",
                           prox_every=3, batch_size=batch_size)
    batch_cfg = delta_cfg._replace(engine="batch", event_batch=3)
    w0 = jnp.zeros((_D, _T), jnp.float32)
    key = jax.random.PRNGKey(seed)
    d_st = amtl_events_only(problem, delta_cfg, w0, key, 12)
    b_st = amtl_events_only(problem, batch_cfg, w0, key, 12)
    np.testing.assert_array_equal(np.asarray(d_st.v), np.asarray(b_st.v))
    np.testing.assert_array_equal(np.asarray(d_st.key), np.asarray(b_st.key))
    assert int(d_st.event) == int(b_st.event) == 12


# --------------------------------------------------- ragged row masking
#
# PR 9: `MTLProblem.row_counts` restricts every loss, gradient, and
# minibatch selection to each task's first n_t rows of the shared padded
# buffer.  Deterministic sweeps live in tests/test_taskstore.py; here
# hypothesis drives arbitrary (n, batch_size, n_t, seed) configurations.


@st.composite
def _masked_setups(draw):
    n = draw(st.integers(1, 700))           # crosses the 512 block boundary
    b = draw(st.integers(1, 700))
    n_t = draw(st.integers(0, n))           # incl. empty and full cohorts
    seed = draw(st.integers(0, 2**32 - 1))
    return n, b, n_t, seed


@settings(max_examples=40, deadline=None)
@given(_masked_setups())
def test_masked_cutoff_keeps_exactly_min_b_nt_valid_rows(setup):
    """The valid-row cutoff law: exactly min(b, n_t) rows survive, all of
    them valid, the kernel emits the oracle's bits, and n_t == n reduces
    bitwise to the unmasked selection."""
    n, b, n_t, seed = setup
    seed_j = jnp.asarray(seed, jnp.uint32)
    nt = jnp.asarray(n_t, jnp.int32)
    want = np.asarray(ref.sample_mask_masked_ref(n, b, seed_j, nt))
    assert want.sum() == min(b, n_t)
    assert not want[n_t:].any()
    got = np.asarray(ops.sample_mask(n, b, seed_j, n_t=nt, interpret=True))
    np.testing.assert_array_equal(got, want)
    if n_t == n:
        np.testing.assert_array_equal(
            want, np.asarray(ref.sample_mask_ref(n, b, seed_j)))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(1, 40), st.integers(0, 40))
def test_masked_grad_matches_trimmed_dense_grad(seed, n, n_t_raw):
    """The masked lstsq gradient over a padded (n, d) buffer equals the
    dense gradient over the trimmed (n_t, d) cohort — ulp-tight, not
    bitwise (XLA reassociates across contraction sizes) — and the
    saturated sampled op equals the masked full grad bitwise."""
    n_t = min(n_t_raw, n)
    d = 7
    kx, kw, ky = jax.random.split(jax.random.PRNGKey(seed % 2**31), 3)
    x = jax.random.normal(kx, (n, d), jnp.float32)
    w = jax.random.normal(kw, (d,), jnp.float32)
    y = jax.random.normal(ky, (n,), jnp.float32)
    nt = jnp.asarray(n_t, jnp.int32)
    got = np.asarray(ref.lstsq_grad_masked_ref(x, w, y, nt), np.float64)
    x64 = np.asarray(x, np.float64)[:n_t]
    y64 = np.asarray(y, np.float64)[:n_t]
    want = 2.0 * (x64.T @ (x64 @ np.asarray(w, np.float64) - y64))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    sat = ops.lstsq_grad_sampled(x, w, y, jnp.asarray(seed, jnp.uint32),
                                 batch_size=n, n_t=nt, use_pallas=False)
    np.testing.assert_array_equal(
        np.asarray(sat), np.asarray(ops.lstsq_grad(x, w, y, n_t=nt,
                                                   use_pallas=False)))


@st.composite
def _ragged_stream_setups(draw):
    engine = draw(st.sampled_from(["delta", "batch", "sharded"]))
    counts = draw(st.lists(st.integers(0, _N), min_size=_T, max_size=_T))
    batch_size = draw(st.one_of(st.none(), st.integers(1, _N)))
    split = draw(st.integers(0, 3))
    seed = draw(st.integers(0, 2**31 - 1))
    return engine, counts, batch_size, split, seed


@settings(max_examples=15, deadline=None)
@given(_ragged_stream_setups())
def test_row_counts_and_appends_leave_event_stream_untouched(setup):
    """row_counts — and a mid-session append that rebuilds the engine
    against a grown buffer — must not perturb the PRNG chain head or the
    (task, staleness) history: activation sampling is data-independent,
    so every staleness/shard-invariance contract survives raggedness."""
    engine, counts, batch_size, split, seed = setup
    problem = _tiny_problem()
    ragged = problem._replace(row_counts=jnp.asarray(counts, jnp.int32))
    eb = 2 if engine in ("batch", "sharded") else 1
    cfg = AMTLConfig(eta=1.0 / problem.lipschitz(), eta_k=0.6, tau=2,
                     engine=engine, event_batch=eb, prox_every=2,
                     batch_size=batch_size)
    mesh = None
    if engine == "sharded":
        from repro.launch.mesh import make_task_mesh
        mesh = make_task_mesh(1)
    eng_u = make_engine(problem, cfg, mesh)
    eng_r = make_engine(ragged, cfg, mesh)
    w0 = jnp.zeros((_D, _T), jnp.float32)
    key = jax.random.PRNGKey(seed)
    st_u = eng_u.run(eng_u.init(w0, key), None, 8)
    # ragged run with a mid-session append at `split` batches: pad one
    # more row onto every task's buffer and bump the counts — the
    # engine-rebuild boundary the serving platform crosses at a fold
    st_r = eng_r.run(eng_r.init(w0, key), None, 2 * split)
    grown = ragged._replace(
        xs=jnp.pad(ragged.xs, ((0, 0), (0, 1), (0, 0))),
        ys=jnp.pad(ragged.ys, ((0, 0), (0, 1))),
        row_counts=ragged.row_counts + 1)
    eng_g = make_engine(grown, cfg, mesh)
    st_r = eng_g.run(st_r, None, 8 - 2 * split)
    np.testing.assert_array_equal(np.asarray(st_u.key), np.asarray(st_r.key))
    np.testing.assert_array_equal(np.asarray(st_u.history.buf),
                                  np.asarray(st_r.history.buf))
    assert int(st_r.event) == 8


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, _N))
def test_minibatching_leaves_event_stream_untouched(seed, batch_size):
    """The sampling seeds are folded OFF the chain (fold_in derivations,
    never split): enabling batch_size must not perturb the PRNG chain head,
    so the (task, staleness) stream — and hence every staleness/shard
    contract — is identical to the full-gradient run's."""
    problem = _tiny_problem()
    eta = 1.0 / problem.lipschitz()
    full_cfg = AMTLConfig(eta=eta, eta_k=0.6, tau=2, engine="delta",
                          prox_every=2)
    sgd_cfg = full_cfg._replace(batch_size=batch_size)
    w0 = jnp.zeros((_D, _T), jnp.float32)
    key = jax.random.PRNGKey(seed)
    full_st = amtl_events_only(problem, full_cfg, w0, key, 10)
    sgd_st = amtl_events_only(problem, sgd_cfg, w0, key, 10)
    np.testing.assert_array_equal(np.asarray(full_st.key),
                                  np.asarray(sgd_st.key))
    np.testing.assert_array_equal(np.asarray(full_st.history.buf),
                                  np.asarray(sgd_st.history.buf))
    if batch_size >= _N:     # saturated minibatch IS the full gradient
        np.testing.assert_array_equal(np.asarray(full_st.v),
                                      np.asarray(sgd_st.v))
