"""Property-based tests of the serial-replay contracts.

`_sample_activation_batch` is what lets the batch and sharded engines claim
an event stream identical to the one-event engines BY CONSTRUCTION: it must
consume the same PRNG splits and produce the same (task, staleness) draws
as `event_batch` consecutive `_sample_activation` calls — including the
per-position staleness clamp `nu <= min(tau, event + i)` — for every
`event_batch`, `tau`, `delay_offsets`, jitter, and chain position.  PR 2
only covered this implicitly at the fixed bench shapes; here hypothesis
drives arbitrary configurations.

The session analogue (PR 4): `AMTLEngine.run` must compose bitwise at ANY
step boundary — `run(·, total)` equals `run(run(·, n), total - n)` on the
FULL engine state, for arbitrary engine, tau, event_batch, prox cadence,
and split point, with the mid state additionally round-tripped through the
checkpoint serialization (host numpy and back).  This is the streaming
deployment contract: a server that persists its state after any chunk of
events and restarts resumes the exact event stream.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.amtl import (AMTLConfig, _sample_activation,
                             _sample_activation_batch, make_engine)
from repro.core.losses import MTLProblem


@st.composite
def _sampler_setups(draw):
    num_tasks = draw(st.integers(1, 8))
    tau = draw(st.integers(0, 6))
    batch = draw(st.integers(1, 12))
    # chain position: 0 exercises the `nu <= event` warm-up clamp, larger
    # values the steady state
    event0 = draw(st.integers(0, 20))
    jitter = draw(st.floats(0.0, 3.0, allow_nan=False, allow_infinity=False))
    offsets = draw(st.lists(
        st.floats(0.0, 6.0, allow_nan=False, allow_infinity=False),
        min_size=num_tasks, max_size=num_tasks))
    seed = draw(st.integers(0, 2**31 - 1))
    return num_tasks, tau, batch, event0, jitter, offsets, seed


@settings(max_examples=50, deadline=None)
@given(_sampler_setups())
def test_batch_sampler_replays_serial_chain_exactly(setup):
    num_tasks, tau, batch, event0, jitter, offsets, seed = setup
    cfg = AMTLConfig(eta=0.1, eta_k=0.5, tau=tau, delay_jitter=jitter)
    offs = jnp.asarray(offsets, jnp.float32)
    key0 = jax.random.PRNGKey(seed)
    event0_j = jnp.asarray(event0, jnp.int32)

    key = key0
    want_ts, want_nus = [], []
    for i in range(batch):
        key, t, nu = _sample_activation(cfg, offs, key, num_tasks,
                                        event0_j + i)
        want_ts.append(int(t))
        want_nus.append(int(nu))

    got_key, got_ts, got_nus = _sample_activation_batch(
        cfg, offs, key0, num_tasks, event0_j, batch)

    np.testing.assert_array_equal(np.asarray(got_ts), want_ts)
    np.testing.assert_array_equal(np.asarray(got_nus), want_nus)
    # the chain head must also coincide: the next batch continues the same
    # serial split sequence
    np.testing.assert_array_equal(np.asarray(got_key), np.asarray(key))
    # staleness always within the cap and the warm-up window
    assert all(nu <= min(tau, event0 + i)
               for i, nu in enumerate(want_nus))


# ------------------------------------------------- session split / resume

_T, _N, _D = 4, 6, 8


def _tiny_problem():
    kx, ky = jax.random.split(jax.random.PRNGKey(0))
    xs = jax.random.normal(kx, (_T, _N, _D)) / np.sqrt(_D)
    ys = jax.random.normal(ky, (_T, _N))
    return MTLProblem(xs, ys, "lstsq", "nuclear", 0.1)


@st.composite
def _session_setups(draw):
    engine = draw(st.sampled_from(["dense", "delta", "batch", "sharded"]))
    tau = draw(st.integers(0, 4))
    if engine in ("batch", "sharded"):
        bsz = draw(st.integers(1, 4))
        prox_every = bsz * draw(st.integers(1, 3))   # incl. decoupled k > 1
    else:
        bsz = 1
        prox_every = 1 if engine == "dense" else draw(st.integers(1, 4))
    total_steps = draw(st.integers(1, 5))
    split = draw(st.integers(0, total_steps))
    dynamic = draw(st.booleans())
    offsets = draw(st.lists(
        st.floats(0.0, 4.0, allow_nan=False, allow_infinity=False),
        min_size=_T, max_size=_T))
    seed = draw(st.integers(0, 2**31 - 1))
    return engine, tau, bsz, prox_every, total_steps, split, dynamic, \
        offsets, seed


def _roundtrip_host(state):
    """The checkpoint serialization boundary: every leaf to host numpy and
    back (what save -> restore does, minus the filesystem)."""
    return jax.tree.map(lambda a: jnp.asarray(np.asarray(a)), state)


@settings(max_examples=25, deadline=None)
@given(_session_setups())
def test_session_split_at_any_event_boundary_resumes_bitwise(setup):
    """The streaming analogue of the serial-chain replay property: for any
    engine/tau/event_batch/cadence and ANY split point, running the session
    in two chunks (with a host round-trip of the mid state) reproduces the
    uninterrupted run's full state bitwise."""
    (engine, tau, bsz, prox_every, total_steps, split, dynamic, offsets,
     seed) = setup
    problem = _tiny_problem()
    cfg = AMTLConfig(eta=1.0 / problem.lipschitz(), eta_k=0.6, tau=tau,
                     engine=engine, event_batch=bsz, prox_every=prox_every,
                     dynamic_step=dynamic)
    mesh = None
    if engine == "sharded":
        from repro.launch.mesh import make_task_mesh
        mesh = make_task_mesh(1)
    eng = make_engine(problem, cfg, mesh)
    offs = jnp.asarray(offsets, jnp.float32)
    w0 = jnp.zeros((_D, _T), jnp.float32)
    key = jax.random.PRNGKey(seed)

    full = eng.run(eng.init(w0, key), offs, total_steps * bsz)
    mid = eng.run(eng.init(w0, key), offs, split * bsz)
    resumed = eng.run(_roundtrip_host(mid), offs, (total_steps - split) * bsz)

    assert int(resumed.event) == total_steps * bsz
    for a, b in zip(jax.tree.leaves(full), jax.tree.leaves(resumed),
                    strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
