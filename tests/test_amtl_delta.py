"""Delta-ring AMTL engine: event-for-event equivalence with the seed dense
ring, prox amortization (paper §III-C), and the amtl_event kernel oracle."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import AMTLConfig, amtl_solve
from repro.core.amtl import amtl_events_only, current_iterate
from repro.core.operators import rollback_columns
from repro.kernels import ref
from repro.kernels.amtl_event import amtl_event as amtl_event_pallas
from repro.kernels.ops import amtl_event


def _base_cfg(problem, tau=3, **kw):
    eta = 1.0 / problem.lipschitz()
    return AMTLConfig(eta=eta, eta_k=0.7, tau=tau, **kw)


# ----------------------------------------------------------- equivalence
@pytest.mark.parametrize("tau", [0, 1, 3, 8])
def test_delta_engine_bitwise_matches_dense(small_problem, tau):
    """Same PRNG key, prox_every=1: the delta ring reconstructs exactly the
    stale reads of the seed (tau+1, d, T) ring, event for event."""
    cfg = _base_cfg(small_problem, tau=tau)
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(3)
    dense = amtl_solve(small_problem, cfg._replace(engine="dense"), w0, key,
                       num_epochs=8)
    delta = amtl_solve(small_problem, cfg._replace(engine="delta"), w0, key,
                       num_epochs=8)
    np.testing.assert_array_equal(np.asarray(dense.v), np.asarray(delta.v))
    np.testing.assert_array_equal(np.asarray(dense.w), np.asarray(delta.w))
    np.testing.assert_array_equal(np.asarray(dense.objectives),
                                  np.asarray(delta.objectives))
    np.testing.assert_array_equal(np.asarray(dense.residuals),
                                  np.asarray(delta.residuals))


def test_delta_engine_bitwise_under_delays_and_dynamic_step(small_problem):
    """Equivalence must survive nonzero staleness and the delay-adaptive
    step (Eq. III.5/III.6), which both consume extra state."""
    cfg = _base_cfg(small_problem, tau=4, dynamic_step=True)
    offsets = jnp.asarray([3.0, 1.0, 0.0, 2.0, 4.0])
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(11)
    dense = amtl_solve(small_problem, cfg._replace(engine="dense"), w0, key,
                       num_epochs=6, delay_offsets=offsets)
    delta = amtl_solve(small_problem, cfg._replace(engine="delta"), w0, key,
                       num_epochs=6, delay_offsets=offsets)
    np.testing.assert_array_equal(np.asarray(dense.v), np.asarray(delta.v))


def test_events_only_matches_solve(small_problem):
    cfg = _base_cfg(small_problem)
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(1)
    st = amtl_events_only(small_problem, cfg, w0, key, 15)
    full = amtl_solve(small_problem, cfg, w0, key, num_epochs=1,
                      events_per_epoch=15)
    np.testing.assert_array_equal(np.asarray(current_iterate(st)),
                                  np.asarray(full.v))


def test_engine_config_validation(small_problem):
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match="dense"):
        amtl_solve(small_problem,
                   _base_cfg(small_problem, engine="dense", prox_every=4),
                   w0, key, num_epochs=1)
    with pytest.raises(ValueError, match="unknown AMTL engine"):
        amtl_solve(small_problem, _base_cfg(small_problem, engine="sparse"),
                   w0, key, num_epochs=1)


# ----------------------------------------------------- prox amortization
def test_prox_every_objective_decreases(small_problem):
    """Amortized server prox (§III-C) still drives the objective down."""
    cfg = _base_cfg(small_problem, tau=3, prox_every=4)
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    res = amtl_solve(small_problem, cfg, w0, jax.random.PRNGKey(0),
                     num_epochs=120)
    objs = np.asarray(res.objectives)
    assert objs[-1] < objs[0]
    assert objs[-1] < objs[len(objs) // 2] + 1e-3  # keeps improving late

def _amortized_oracle_numpy(problem, cfg, key, num_events):
    """Sequential pure-numpy replay of the amortized algorithm (§III-C).

    Event k: sample (t, nu) with the engine's exact PRNG calls; if
    k % prox_every == 0 recompute the server prox on the stale read
    (iterate from nu events ago, own column patched current) and cache it,
    else reuse the cache; then apply the KM-relaxed forward step to column
    t.  Float64 numpy arithmetic — the test checks the engine produces THE
    amortized iterates, not merely a decreasing objective.
    """
    xs = np.asarray(problem.xs, np.float64)
    ys = np.asarray(problem.ys, np.float64)
    T = xs.shape[0]
    v = np.zeros((problem.dim, T))
    history = [v.copy()]
    p_cache = None
    for k in range(num_events):
        key, k_task, k_delay = jax.random.split(key, 3)
        t = int(jax.random.randint(k_task, (), 0, T))
        raw = cfg.delay_jitter * float(jax.random.uniform(k_delay))
        nu = min(int(np.round(raw)), min(cfg.tau, k))
        if k % cfg.prox_every == 0:
            v_hat = history[len(history) - 1 - nu].copy()
            v_hat[:, t] = v[:, t]
            u, s, vt = np.linalg.svd(v_hat, full_matrices=False)
            s = np.maximum(s - cfg.eta * problem.lam, 0.0)
            p_cache = (u * s[None, :]) @ vt
        p_t = p_cache[:, t]
        g_t = 2.0 * (xs[t].T @ (xs[t] @ p_t - ys[t]))
        v = v.copy()
        v[:, t] = v[:, t] + cfg.eta_k * (p_t - cfg.eta * g_t - v[:, t])
        history.append(v.copy())
    return v


@pytest.mark.parametrize("prox_every", [2, 4])
def test_prox_every_matches_sequential_oracle(small_problem, prox_every):
    """The amortized engine's iterates are the ones §III-C specifies: a
    refresh exactly at events 0, K, 2K, ... on the then-current stale read,
    the cached prox in between — verified column-for-column against an
    event-by-event numpy replay, not just by objective decrease."""
    cfg = _base_cfg(small_problem, tau=3, prox_every=prox_every)
    key = jax.random.PRNGKey(17)
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    num_events = 24
    got = amtl_events_only(small_problem, cfg, w0, key, num_events)
    want = _amortized_oracle_numpy(small_problem, cfg, key, num_events)
    np.testing.assert_allclose(np.asarray(current_iterate(got), np.float64),
                               want, rtol=5e-4, atol=5e-5)
    # the caching matters: an exact-prox (prox_every=1) run must NOT match
    exact = amtl_events_only(small_problem, cfg._replace(prox_every=1),
                             w0, key, num_events)
    assert not np.allclose(np.asarray(current_iterate(exact), np.float64),
                           want, rtol=5e-4, atol=5e-5)


def test_randomized_prox_refresh_converges(small_problem):
    """Randomized SVT refresh (large-d*T mode) reaches a comparable
    objective to the exact-prox run."""
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(0)
    exact = amtl_solve(small_problem, _base_cfg(small_problem), w0, key,
                       num_epochs=120)
    sketch = amtl_solve(small_problem,
                        _base_cfg(small_problem, prox_every=2,
                                  prox_rank=small_problem.num_tasks),
                        w0, key, num_epochs=120)
    assert float(sketch.objectives[-1]) <= float(exact.objectives[-1]) * 1.1


def test_sketch_mode_keeps_event_stream(small_problem):
    """The randomized-refresh key is folded, not split, off the main PRNG
    chain, so the activation/staleness sequence matches the dense engine
    even with prox_rank set (recorded delays are the witness)."""
    cfg = _base_cfg(small_problem, tau=3)
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(9)
    dense = amtl_events_only(small_problem, cfg._replace(engine="dense"),
                             w0, key, 25)
    sketch = amtl_events_only(
        small_problem, cfg._replace(prox_every=2, prox_rank=5), w0, key, 25)
    np.testing.assert_array_equal(np.asarray(dense.history.buf),
                                  np.asarray(sketch.history.buf))


# --------------------------------------------------------- rollback unit
def test_rollback_columns_replays_undo_log():
    """Restoring the nu newest log entries reproduces the older iterate
    bitwise, including repeated writes to the same column."""
    d, T, tau = 6, 3, 4
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.standard_normal((d, T)), jnp.float32)
    history = [np.asarray(v)]
    delta_ring = jnp.zeros((tau + 1, d), jnp.float32)
    task_ring = jnp.zeros((tau + 1,), jnp.int32)
    ptr = 0
    for k, t in enumerate([1, 2, 1, 0]):   # column 1 written twice
        ptr = (ptr + 1) % (tau + 1)
        delta_ring = delta_ring.at[ptr].set(v[:, t])
        task_ring = task_ring.at[ptr].set(t)
        v = v.at[:, t].set(jnp.asarray(rng.standard_normal(d), jnp.float32))
        history.append(np.asarray(v))
    for nu in range(5):
        got = rollback_columns(v, delta_ring, task_ring,
                               jnp.asarray(ptr, jnp.int32),
                               jnp.asarray(nu, jnp.int32), tau)
        np.testing.assert_array_equal(np.asarray(got), history[len(history) - 1 - nu])


# ------------------------------------------------------- kernel validation
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("d", [7, 128, 1000, 1024, 5000])
def test_amtl_event_kernel_matches_ref(d, dtype):
    """Interpret-mode Pallas kernel vs the jnp oracle; the undo-log output
    must be the exact pre-write bits."""
    kv, kp, kg = jax.random.split(jax.random.PRNGKey(0), 3)
    v = jax.random.normal(kv, (d,), dtype)
    p = jax.random.normal(kp, (d,), dtype)
    g = jax.random.normal(kg, (d,), dtype)
    eta, eta_k = jnp.asarray(0.05), jnp.asarray(0.8)
    got_v, got_old = amtl_event_pallas(v, p, g, eta, eta_k, interpret=True)
    want_v, _ = ref.amtl_event_ref(v.astype(jnp.float32),
                                   p.astype(jnp.float32),
                                   g.astype(jnp.float32), eta, eta_k)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got_v, np.float32),
                               np.asarray(want_v), rtol=tol, atol=tol)
    np.testing.assert_array_equal(np.asarray(got_old), np.asarray(v))


def test_amtl_event_ops_dispatch_cpu_is_oracle():
    """On CPU the ops wrapper must hit the jnp oracle path bitwise."""
    kv, kp, kg = jax.random.split(jax.random.PRNGKey(2), 3)
    v, p, g = (jax.random.normal(kk, (513,)) for kk in (kv, kp, kg))
    eta, eta_k = jnp.asarray(0.1), jnp.asarray(0.6)
    got_v, got_old = amtl_event(v, p, g, eta, eta_k)
    want_v, want_old = ref.amtl_event_ref(v, p, g, eta, eta_k)
    # jit may contract the mul-adds into FMAs, so the update matches to ulp
    # tolerance; the undo-log output is a verbatim copy and must be exact.
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(want_v),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(got_old), np.asarray(want_old))
