"""Concurrent learn-while-serve front-end (PR 8): the background
learner thread, the atomic snapshot flip, and the latency-SLO admission
controller.

Contracts pinned here:

  * NO TORN READS — under N predict threads hammering while the learner
    runs, every observed serving snapshot `(v, event)` is bitwise the
    chunk-boundary `engine.iterate` at that event (reconstructed by
    replaying the server's own chunk log through a fresh engine).
  * DRAIN == COOPERATIVE — with no concurrent submissions,
    `start_learner()` ... `stop_learner(drain=True)` reproduces the
    cooperative `while step(): pass` loop's chunk log and full engine
    state bitwise.
  * REPLAY LAW — even with submissions racing the learner, the final
    state is bitwise ONE `engine.run(init, offs, sum(chunk_log))`.
  * SLO PURITY — the admission controller's decision/chunk-size trace
    is a pure function of the recorded latency sequence.

Plus the learner lifecycle (exceptions surfaced on join, cooperative
`step()` fenced off while the thread owns the chunk loop, checkpoint
cadence preserved on the learner thread).
"""
import os
import re
import threading

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import AMTLConfig, make_engine
from repro.serve import (AMTLServer, LatencySLOController, ServeConfig,
                         degraded_budget)


def _cfg(problem, engine="delta", tau=3, **kw):
    eta = 1.0 / problem.lipschitz()
    if engine in ("batch", "sharded"):
        kw.setdefault("event_batch", 4)
        kw.setdefault("prox_every", kw["event_batch"])
    return AMTLConfig(eta=eta, eta_k=0.7, tau=tau, engine=engine, **kw)


def _server(problem, cfg, serve_cfg=ServeConfig(chunk_events=4), key=0):
    w0 = jnp.zeros((problem.dim, problem.num_tasks), jnp.float32)
    return AMTLServer(problem, cfg, w0, jax.random.PRNGKey(key), serve_cfg)


def _requests(problem, n, seed=0):
    rng = np.random.default_rng(seed)
    t = rng.integers(0, problem.num_tasks, size=n)
    x = rng.standard_normal((n, problem.dim)).astype(np.float32)
    return t, x


def _boundary_iterates(problem, cfg, chunk_log):
    """event -> iterate bytes at every chunk boundary of `chunk_log`,
    replayed incrementally (the PR-4 composition contract makes the
    incremental replay bitwise the one-shot run)."""
    eng = make_engine(problem, cfg)
    w0 = jnp.zeros((problem.dim, problem.num_tasks), jnp.float32)
    state = eng.init(w0, jax.random.PRNGKey(0))
    out = {0: np.asarray(eng.iterate(state)).tobytes()}
    event = 0
    for n in chunk_log:
        state = eng.run(state, None, n)
        event += n
        out[event] = np.asarray(eng.iterate(state)).tobytes()
    return out


# --------------------------------------------------------- torn-read stress
def test_no_torn_reads_under_concurrent_predict_load(small_problem):
    """4 predict threads hammer while the learner absorbs a feedback
    stream: every snapshot any thread ever observes must be bitwise a
    chunk-boundary iterate of the server's own chunk log."""
    cfg = _cfg(small_problem, "delta")
    server = _server(small_problem, cfg, ServeConfig(chunk_events=4))
    t, x = _requests(small_problem, 8, seed=1)
    observed = [[] for _ in range(4)]
    stop = threading.Event()

    def hammer(slot):
        while not stop.is_set():
            snap = server.serving()
            server.predict(t, x)
            observed[slot].append(snap)

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(4)]
    server.start_learner()
    for th in threads:
        th.start()
    rng = np.random.default_rng(7)
    for _ in range(25):
        server.submit_feedback(rng.integers(0, small_problem.num_tasks,
                                            size=rng.integers(1, 6)))
    server.stop_learner(drain=True)
    stop.set()
    for th in threads:
        th.join()

    assert sum(server.chunk_log) > 0
    boundaries = _boundary_iterates(small_problem, cfg, server.chunk_log)
    seen_events = set()
    for snaps in observed:
        assert snaps, "every predict thread observed at least one snapshot"
        for snap in snaps:
            assert snap.event in boundaries, \
                f"served event {snap.event} is not a chunk boundary"
            assert np.asarray(snap.v).tobytes() == boundaries[snap.event], \
                f"torn read: snapshot at event {snap.event} is not the " \
                "committed boundary iterate"
            seen_events.add(snap.event)
    # the final committed snapshot is the last boundary
    final = server.serving()
    assert final.event == sum(server.chunk_log)
    assert np.asarray(final.v).tobytes() == boundaries[final.event]


def test_threaded_final_state_replays_chunk_log(small_problem):
    """Submissions racing the learner: whatever chunk sizes it coalesced,
    the final state is bitwise ONE plain run over their sum."""
    cfg = _cfg(small_problem, "batch")
    server = _server(small_problem, cfg, ServeConfig(chunk_events=8))
    server.start_learner()
    rng = np.random.default_rng(0)
    for _ in range(20):
        server.submit_feedback(rng.integers(0, small_problem.num_tasks,
                                            size=rng.integers(1, 7)))
    server.stop_learner(drain=True)
    assert sum(server.chunk_log) > 0
    eng = make_engine(small_problem, cfg)
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    state = eng.run(eng.init(w0, jax.random.PRNGKey(0)), None,
                    sum(server.chunk_log))
    for la, lb in zip(jax.tree.leaves(server._state),
                      jax.tree.leaves(state), strict=True):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ------------------------------------------------------ drain == cooperative
@pytest.mark.parametrize("engine", ("delta", "batch"))
def test_drain_then_join_equals_cooperative_loop_bitwise(small_problem,
                                                         engine):
    """Same queued feedback, no concurrent submissions: the drained
    learner's chunk log and full state are bitwise the cooperative
    step() loop's."""
    cfg = _cfg(small_problem, engine)
    fb = [i % small_problem.num_tasks for i in range(13)]
    a = _server(small_problem, cfg, ServeConfig(chunk_events=8,
                                                task_chunk_quota=3))
    b = _server(small_problem, cfg, ServeConfig(chunk_events=8,
                                                task_chunk_quota=3))
    a.submit_feedback(fb)
    b.submit_feedback(fb)
    a.start_learner()
    learned = a.stop_learner(drain=True)
    while b.step():
        pass
    assert learned == sum(a.chunk_log)
    assert a.chunk_log == b.chunk_log
    assert a.pending_feedback == b.pending_feedback
    for la, lb in zip(jax.tree.leaves(a._state),
                      jax.tree.leaves(b._state), strict=True):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=engine)
    np.testing.assert_array_equal(np.asarray(a.iterate()),
                                  np.asarray(b.iterate()))


def test_threaded_then_resume_matches_cooperative(small_problem, tmp_path):
    """Threaded phase -> drain -> checkpoint -> crash -> resume: the
    resumed server serves bitwise the cooperative reference."""
    cfg = _cfg(small_problem, "delta")
    sc = ServeConfig(chunk_events=4, ckpt_dir=str(tmp_path), keep_last=2)
    fb = [i % small_problem.num_tasks for i in range(9)]
    a = _server(small_problem, cfg, sc, key=2)
    ref = _server(small_problem, cfg, sc._replace(ckpt_dir=None), key=2)
    a.submit_feedback(fb)
    ref.submit_feedback(fb)
    a.start_learner()
    a.stop_learner(drain=True)
    while ref.step():
        pass
    a.checkpoint()
    del a
    c = AMTLServer.resume(
        small_problem, cfg,
        jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32),
        jax.random.PRNGKey(2), sc)
    assert c.event_count == ref.event_count
    t, x = _requests(small_problem, 6, seed=3)
    np.testing.assert_array_equal(np.asarray(c.predict(t, x)),
                                  np.asarray(ref.predict(t, x)))
    # and learning continues bitwise after the restart, on the learner
    c.submit_feedback(fb)
    ref.submit_feedback(fb)
    c.start_learner()
    c.stop_learner(drain=True)
    while ref.step():
        pass
    np.testing.assert_array_equal(np.asarray(c.iterate()),
                                  np.asarray(ref.iterate()))


# ----------------------------------------------------------- learner lifecycle
def test_cooperative_step_is_fenced_while_learner_runs(small_problem):
    server = _server(small_problem, _cfg(small_problem, "delta"))
    server.start_learner()
    with pytest.raises(RuntimeError, match="owns the chunk loop"):
        server.step()
    with pytest.raises(RuntimeError, match="already running"):
        server.start_learner()
    server.stop_learner()
    assert server.step() == 0          # cooperative again after stop
    assert server.stop_learner() == 0  # idempotent


def test_learner_exception_surfaces_on_stop(small_problem):
    server = _server(small_problem, _cfg(small_problem, "delta"))

    def boom(state, offs, n):
        raise RuntimeError("engine exploded")

    server.engine = server.engine._replace(run=boom)
    before = server.serving()
    server.submit_feedback([0, 1, 2])
    server.start_learner()
    with pytest.raises(RuntimeError, match="engine exploded"):
        server.stop_learner(drain=True, timeout=30)
    # a dead learner never corrupts serving: the committed snapshot and
    # state are untouched and the request path still answers
    assert server.serving() is before
    assert not server.learner_running
    t, x = _requests(small_problem, 3)
    assert np.asarray(server.predict(t, x)).shape == (3,)


def test_frozen_server_refuses_learner(small_problem):
    server = _server(small_problem, _cfg(small_problem, "delta"),
                     ServeConfig(chunk_events=4, learning=False))
    with pytest.raises(RuntimeError, match="frozen"):
        server.start_learner()


def test_checkpoint_cadence_preserved_on_learner_thread(small_problem,
                                                        tmp_path):
    """Auto-checkpoints keep landing (and rotating) when the chunk loop
    runs on the background thread."""
    sc = ServeConfig(chunk_events=4, ckpt_dir=str(tmp_path),
                     checkpoint_every=4, keep_last=2)
    server = _server(small_problem, _cfg(small_problem, "delta"), sc)
    server.submit_feedback([i % small_problem.num_tasks for i in range(16)])
    server.start_learner()
    server.stop_learner(drain=True)
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000012.npz", "step_00000016.npz"]
    assert all(re.fullmatch(r"step_\d{8}\.npz", f) for f in names)


def test_serve_leaves_chunks_to_running_learner(small_problem):
    """serve() never steps cooperatively while the learner owns the
    chunk loop — it reports 0 events and lets the thread absorb."""
    server = _server(small_problem, _cfg(small_problem, "delta"))
    t, x = _requests(small_problem, 4)
    server.start_learner()
    _, receipt, ran = server.serve(t, x, feedback_task_ids=[0, 1])
    assert receipt.accepted == 2 and ran == 0
    server.stop_learner(drain=True)
    assert sum(server.chunk_log) == 2


# ------------------------------------------------------------ SLO admission
def _trace(controller):
    return [(d.sample, d.level_before, d.level, d.chunk_events)
            for d in controller.decisions]


def test_slo_trace_is_pure_function_of_latency_sequence():
    """Identical latency sequences -> identical decision traces, level
    transitions follow the tumbling-window p95 law exactly."""
    rng = np.random.default_rng(5)
    lat = list(rng.uniform(0.1, 2.0, size=40)) \
        + list(rng.uniform(30.0, 60.0, size=60)) \
        + list(rng.uniform(0.1, 2.0, size=60))
    a = LatencySLOController(10.0, 32, 4, window=20)
    b = LatencySLOController(10.0, 32, 4, window=20)
    for v in lat:
        a.record(v)
    for v in lat:
        b.record(v)
    assert _trace(a) == _trace(b)
    assert a.violations == b.violations == sum(v > 10.0 for v in lat)
    # windows: [fast] healthy, [fast20+slow..] then slow -> shrink, then
    # fast windows restore; every decision obeys the one-step law
    level = 0
    for d in a.decisions:
        assert d.level_before == level
        want = min(level + 1, a._max_level) if d.p95_ms > 10.0 \
            else max(level - 1, 0)
        assert d.level == want
        assert d.chunk_events == degraded_budget(32, 4, d.level)
        level = d.level
    assert any(d.level > d.level_before for d in a.decisions)   # degraded
    assert a.level == 0                                         # recovered


def test_degraded_budget_halves_floored_to_events_per_step():
    assert [degraded_budget(32, 4, L) for L in range(5)] == \
        [32, 16, 8, 4, 4]
    assert degraded_budget(8, 8, 3) == 8          # never below one step
    c = LatencySLOController(1.0, 32, 4, window=2)
    for _ in range(40):                            # violate forever
        c.record(100.0)
    assert c.level == c._max_level == 3
    assert c.chunk_events == 4
    c.record(0.001)
    c.record(0.001)                                # one healthy window
    assert c.level == 2 and c.chunk_events == 8    # restores one level


def test_server_degrades_chunk_budget_under_slo_violation(small_problem):
    """An impossible SLO (every predict violates) shrinks the coalesced
    chunk sizes; the decisions land in stats()["slo"]."""
    sc = ServeConfig(chunk_events=8, slo_ms=1e-6, slo_window=4)
    server = _server(small_problem, _cfg(small_problem, "delta"), sc)
    t, x = _requests(small_problem, 4)
    for _ in range(12):                 # 3 windows, every sample violates
        server.predict(t, x)
    slo = server.stats()["slo"]
    assert slo["level"] == 3 and slo["chunk_events"] == 1
    assert slo["violations"] == 12
    assert [d["level"] for d in slo["decisions"]] == [1, 2, 3]
    server.submit_feedback([0, 1, 2, 3, 4])
    assert server.step() == 1           # degraded budget, not the base 8
    assert server.chunk_log == [1]
    # a healthy SLO would have coalesced the full budget
    relaxed = _server(small_problem, _cfg(small_problem, "delta"),
                      ServeConfig(chunk_events=8, slo_ms=1e6, slo_window=4))
    relaxed.submit_feedback([0, 1, 2, 3, 4])
    assert relaxed.step() == 5


def test_slo_shed_rejects_feedback_while_degraded(small_problem):
    sc = ServeConfig(chunk_events=8, slo_ms=1e-6, slo_window=2,
                     slo_shed=True)
    server = _server(small_problem, _cfg(small_problem, "delta"), sc)
    assert server.submit_feedback([0, 1]).accepted == 2   # healthy: flows
    t, x = _requests(small_problem, 4)
    server.predict(t, x)
    server.predict(t, x)                                  # window closes
    assert server.stats()["slo"]["level"] == 1
    receipt = server.submit_feedback([0, 1, 2])
    assert receipt == (0, 3)
    assert server.stats()["shed_feedback"] == 3
    assert server.pending_feedback == 2                   # earlier items kept


def test_slo_config_validates(small_problem):
    with pytest.raises(ValueError, match="slo_shed requires slo_ms"):
        _server(small_problem, _cfg(small_problem, "delta"),
                ServeConfig(chunk_events=4, slo_shed=True))
    with pytest.raises(ValueError, match="slo_ms must be > 0"):
        _server(small_problem, _cfg(small_problem, "delta"),
                ServeConfig(chunk_events=4, slo_ms=0.0))
    with pytest.raises(ValueError, match="slo_window must be >= 1"):
        _server(small_problem, _cfg(small_problem, "delta"),
                ServeConfig(chunk_events=4, slo_ms=5.0, slo_window=0))
