"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py forces 512 devices."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

# Property-based test modules guard themselves with
# `pytest.importorskip("hypothesis")` at module scope (declared in
# requirements.txt / pyproject [test] extra): without hypothesis they
# report as skipped at collection instead of hard-erroring the session.


@pytest.fixture(scope="session")
def small_problem():
    from repro.core import MTLProblem, make_synthetic
    prob = make_synthetic(num_tasks=5, samples=50, dim=20, seed=0)
    xs = jnp.asarray(np.stack(prob.xs), jnp.float32)
    ys = jnp.asarray(np.stack(prob.ys), jnp.float32)
    return MTLProblem(xs, ys, "lstsq", "nuclear", 0.1)


@pytest.fixture(scope="session")
def small_optimum(small_problem):
    from repro.core import reference_optimum
    return reference_optimum(small_problem, num_iters=1500)
