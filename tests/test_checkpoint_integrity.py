"""Checkpoint integrity layer (`repro.checkpoint`): the CRC32 manifest,
`verify`, typed corruption errors, and the newest-valid-record scan.

The failure model (ROADMAP PR 10): a record on disk can be torn (crash
mid-write, short copy — the zip container itself is unreadable) or
bit-rotted (payload bytes flipped behind a container that still opens).
`save` embeds a per-leaf CRC32 manifest under the reserved
`__manifest__` key; `verify`/`restore` check it and raise
`CheckpointCorruptError` naming the damaged leaves; `latest_valid_step`
skips damaged records newest-first so recovery costs one checkpoint
interval, not the session.  `serve.faults` provides the deterministic
damage tools (`truncate_record`, `corrupt_leaf`).
"""
import os
import zipfile

import numpy as np
import pytest
import jax.numpy as jnp

from repro import checkpoint
from repro.checkpoint import CheckpointCorruptError
from repro.serve.faults import corrupt_leaf, truncate_record


def _tree():
    return {"v": jnp.arange(12.0, dtype=jnp.float32).reshape(3, 4),
            "nested": {"counts": jnp.ones((5,), jnp.int32)}}


def test_save_embeds_manifest_and_roundtrips(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    path = checkpoint.save(d, 3, tree)
    manifest = checkpoint.verify(path)
    # one CRC per leaf, flattened keys, nothing else
    assert set(manifest) == {"v", "nested||counts"}
    with np.load(path) as record:
        assert "__manifest__" in record.files
    restored = checkpoint.restore(d, 3, tree)
    np.testing.assert_array_equal(np.asarray(restored["v"]),
                                  np.asarray(tree["v"]))
    np.testing.assert_array_equal(np.asarray(restored["nested"]["counts"]),
                                  np.asarray(tree["nested"]["counts"]))


def test_truncated_record_raises_typed_error(tmp_path):
    """A torn write (unreadable zip) is a CheckpointCorruptError from
    both verify and restore — never a raw zipfile/np.load error."""
    d = str(tmp_path)
    tree = _tree()
    path = checkpoint.save(d, 1, tree)
    truncate_record(path)
    with pytest.raises(CheckpointCorruptError):
        checkpoint.verify(path)
    with pytest.raises(CheckpointCorruptError):
        checkpoint.restore(d, 1, tree)


def test_bit_rot_names_the_damaged_leaf(tmp_path):
    """corrupt_leaf flips payload bytes behind a VALID zip container —
    only the embedded manifest can see it, and the error names the
    leaf."""
    d = str(tmp_path)
    tree = _tree()
    path = checkpoint.save(d, 1, tree)
    corrupt_leaf(path, key="v")
    # the container still opens: the damage is below the format's radar
    with zipfile.ZipFile(path) as z:
        assert z.testzip() is None or True  # container is a valid zip
    with pytest.raises(CheckpointCorruptError) as err:
        checkpoint.verify(path)
    assert err.value.damaged == ["v"]
    with pytest.raises(CheckpointCorruptError) as err:
        checkpoint.restore(d, 1, tree)
    assert "v" in err.value.damaged


def test_missing_file_stays_file_not_found(tmp_path):
    """A record that does not exist is NOT corrupt — callers distinguish
    'nothing saved yet' from 'saved and damaged'."""
    with pytest.raises(FileNotFoundError):
        checkpoint.verify(str(tmp_path / "step_00000001.npz"))


def test_legacy_record_restores_but_fails_verify(tmp_path):
    """A pre-manifest record (plain np.savez) still restores — no CRC
    cover, but no data loss either — while verify rejects it, so the
    valid-record scan never selects an uncheckable record."""
    d = str(tmp_path)
    tree = _tree()
    legacy = os.path.join(d, "step_00000004.npz")
    np.savez(legacy, **{"v": np.asarray(tree["v"]),
                        "nested||counts": np.asarray(
                            tree["nested"]["counts"])})
    restored = checkpoint.restore(d, 4, tree)
    np.testing.assert_array_equal(np.asarray(restored["v"]),
                                  np.asarray(tree["v"]))
    with pytest.raises(CheckpointCorruptError):
        checkpoint.verify(legacy)
    assert checkpoint.latest_valid_step(d, like=tree) is None


def test_latest_valid_step_skips_damaged_newest(tmp_path):
    """Newest record corrupt -> the scan falls back exactly one step;
    all corrupt -> None."""
    d = str(tmp_path)
    tree = _tree()
    checkpoint.save(d, 10, tree)
    checkpoint.save(d, 20, tree)
    checkpoint.save(d, 30, tree)
    assert checkpoint.latest_valid_step(d, like=tree) == 30
    corrupt_leaf(os.path.join(d, "step_00000030.npz"))
    assert checkpoint.latest_valid_step(d, like=tree) == 20
    truncate_record(os.path.join(d, "step_00000020.npz"))
    assert checkpoint.latest_valid_step(d, like=tree) == 10
    corrupt_leaf(os.path.join(d, "step_00000010.npz"))
    assert checkpoint.latest_valid_step(d, like=tree) is None
    # latest_step (no integrity) still sees all three records
    assert checkpoint.latest_step(d) == 30


def test_latest_valid_step_checks_layout_against_like(tmp_path):
    """A record from a DIFFERENT pytree layout verifies internally but
    is skipped when `like` is given — a foreign record can't be
    mistaken for a resumable one."""
    d = str(tmp_path)
    checkpoint.save(d, 50, {"other": jnp.zeros((2,), jnp.float32)})
    assert checkpoint.latest_valid_step(d) == 50
    assert checkpoint.latest_valid_step(d, like=_tree()) is None


def test_record_steps_newest_first(tmp_path):
    d = str(tmp_path)
    tree = _tree()
    for s in (7, 3, 11):
        checkpoint.save(d, s, tree)
    assert checkpoint.record_steps(d) == [11, 7, 3]
    assert checkpoint.record_steps(str(tmp_path / "missing")) == []


def test_manifest_key_is_reserved_not_extra(tmp_path):
    """restore's strict layout check must skip __manifest__ — a
    manifest-bearing record is not 'a record with an unexpected key'."""
    d = str(tmp_path)
    tree = _tree()
    checkpoint.save(d, 2, tree)
    checkpoint.restore(d, 2, tree)  # would raise ValueError if not skipped
    # a genuinely extra leaf still fails loudly
    extra = dict(tree)
    extra["rogue"] = jnp.zeros((1,), jnp.float32)
    checkpoint.save(d, 6, extra)
    with pytest.raises(ValueError, match="unexpected keys"):
        checkpoint.restore(d, 6, tree)
