"""Optimizers, schedules, prox wrapper, checkpointing, data pipeline."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.optim import (adafactor, adamw, cosine_warmup, make_optimizer,
                         proximal_wrap, sgdm)


def _quadratic_params():
    return {"a": {"w": jnp.ones((8, 4)) * 2.0}, "b": jnp.ones((5,))}


def _quadratic_grads(params):
    return jax.grad(lambda p: sum(jnp.sum(l ** 2) for l in
                                  jax.tree.leaves(p)))(params)


@pytest.mark.parametrize("name", ["adamw", "adafactor", "sgdm"])
def test_optimizers_descend(name):
    opt = make_optimizer(name, lambda s: jnp.asarray(0.05))
    params = _quadratic_params()
    state = opt.init(params)
    loss0 = sum(float(jnp.sum(l ** 2)) for l in jax.tree.leaves(params))
    for step in range(30):
        grads = _quadratic_grads(params)
        params, state = opt.update(grads, state, params,
                                   jnp.asarray(step, jnp.int32))
    loss1 = sum(float(jnp.sum(l ** 2)) for l in jax.tree.leaves(params))
    assert loss1 < 0.5 * loss0


def test_adamw_bf16_master_fp32():
    opt = adamw(lambda s: jnp.asarray(0.01))
    params = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    state = opt.init(params)
    assert state["master"]["w"].dtype == jnp.float32
    grads = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    params, state = opt.update(grads, state, params, jnp.asarray(0))
    assert params["w"].dtype == jnp.bfloat16


def test_adafactor_factored_state_small():
    opt = adafactor(lambda s: jnp.asarray(0.01))
    params = {"w": jnp.ones((64, 32))}
    state = opt.init(params)
    assert state["w"]["vr"].shape == (64,)
    assert state["w"]["vc"].shape == (32,)


def test_cosine_schedule_monotone_warmup():
    fn = cosine_warmup(1e-3, warmup=10, total=100)
    vals = [float(fn(jnp.asarray(s))) for s in range(100)]
    assert vals[0] < vals[9]
    assert vals[99] < vals[20]


def test_proximal_wrapper_projects():
    """l2,1 prox on a selected leaf drives whole rows to zero — the MALSAR
    joint-feature-selection formulation on top of a smooth optimizer."""
    opt = proximal_wrap(sgdm(lambda s: jnp.asarray(0.1)), "l21", lam=0.5,
                        select=lambda path: "w_mtl" in path)
    params = {"w_mtl": jax.random.normal(jax.random.PRNGKey(0), (20, 4)),
              "other": jnp.ones((3, 3))}
    state = opt.init(params)
    for step in range(5):
        grads = {"w_mtl": 0.01 * jnp.ones((20, 4)),
                 "other": jnp.zeros((3, 3))}
        params, state = opt.update(grads, state, params, jnp.asarray(step))
    rows = np.linalg.norm(np.asarray(params["w_mtl"]), axis=1)
    assert np.sum(rows < 1e-6) > 0          # some rows zeroed
    np.testing.assert_allclose(np.asarray(params["other"]), 1.0)


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import latest_step, restore, save
    tree = {"a": {"w": jnp.arange(12.0).reshape(3, 4)},
            "b": [jnp.ones((2,)), jnp.zeros((1,), jnp.int32)]}
    save(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    back = restore(str(tmp_path), 7, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_data_pipeline_batches():
    from repro.data import ShardedBatcher, synthetic_lm_batches
    it = synthetic_lm_batches(vocab=100, seq=16, batch=4, num_tasks=3)
    b = next(ShardedBatcher(it))
    assert b["tokens"].shape == (4, 16)
    assert b["task_ids"].shape == (4,)
    assert int(b["task_ids"].max()) < 3
    # targets are next-token shifted
    np.testing.assert_array_equal(np.asarray(b["targets"][:, :-1]),
                                  np.asarray(b["tokens"][:, 1:]))


def test_mtl_problem_generator():
    from repro.data import make_mtl_problem
    p = make_mtl_problem(num_tasks=6, samples=20, dim=12, rank=2)
    assert p.xs.shape == (6, 20, 12)
    w = jnp.zeros((12, 6))
    assert float(p.objective(w)) > 0
