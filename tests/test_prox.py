"""Unit + property tests for the proximal operators (paper Eq. III.3/IV.2)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.prox import (REGISTRY, get_regularizer, l21_prox,
                             sketch_width, svt, svt_randomized)

mats = st.tuples(st.integers(2, 24), st.integers(1, 8)).flatmap(
    lambda dt: st.lists(
        st.floats(-5, 5, allow_nan=False, width=32),
        min_size=dt[0] * dt[1], max_size=dt[0] * dt[1],
    ).map(lambda v: np.asarray(v, np.float32).reshape(dt)))

steps = st.floats(1e-3, 3.0, allow_nan=False)


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_prox_zero_step_is_identity(name):
    reg = get_regularizer(name)
    w = jax.random.normal(jax.random.PRNGKey(0), (12, 5))
    np.testing.assert_allclose(reg.prox(w, jnp.asarray(0.0)), w,
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(mats, steps)
@pytest.mark.parametrize("name", ["nuclear", "l21", "l1", "elastic_net", "ridge"])
def test_prox_optimality(name, w, t):
    """prox output minimizes (1/2t)||z-w||^2 + g(z): check vs random z."""
    reg = get_regularizer(name)
    w = jnp.asarray(w)
    p = reg.prox(w, jnp.asarray(t, jnp.float32))

    def moreau(z):
        return 0.5 / t * jnp.sum((z - w) ** 2) + float(reg.value(z))

    base = moreau(p)
    rng = np.random.default_rng(0)
    for _ in range(5):
        z = p + jnp.asarray(rng.standard_normal(w.shape) * 0.1, jnp.float32)
        assert base <= moreau(z) + 1e-3 * max(1.0, abs(float(base)))


@settings(max_examples=30, deadline=None)
@given(mats, steps)
def test_prox_nonexpansive_nuclear(w, t):
    """prox is firmly nonexpansive: ||prox(a)-prox(b)|| <= ||a-b||."""
    a = jnp.asarray(w)
    b = a + 0.5
    pa, pb = svt(a, t), svt(b, t)
    assert float(jnp.linalg.norm(pa - pb)) <= float(jnp.linalg.norm(a - b)) + 1e-4


@settings(max_examples=30, deadline=None)
@given(mats, steps)
def test_prox_nonexpansive_l21(w, t):
    a = jnp.asarray(w)
    b = a * 0.3 + 1.0
    pa, pb = l21_prox(a, t), l21_prox(b, t)
    assert float(jnp.linalg.norm(pa - pb)) <= float(jnp.linalg.norm(a - b)) + 1e-4


def test_svt_matches_definition():
    """SVT = U (S - t)_+ V^T exactly (paper Eq. IV.2)."""
    w = np.random.default_rng(1).standard_normal((20, 6)).astype(np.float32)
    t = 0.7
    u, s, vt = np.linalg.svd(w, full_matrices=False)
    expect = (u * np.maximum(s - t, 0.0)) @ vt
    np.testing.assert_allclose(svt(jnp.asarray(w), jnp.asarray(t)), expect,
                               rtol=1e-4, atol=1e-4)


def test_svt_shrinks_rank():
    rng = np.random.default_rng(2)
    w = (rng.standard_normal((30, 8)) @ np.diag([10, 5, 1, .1, .1, .1, .1, .1])
         @ rng.standard_normal((8, 8))).astype(np.float32)
    p = np.asarray(svt(jnp.asarray(w), jnp.asarray(3.0)))
    s = np.linalg.svd(p, compute_uv=False)
    assert np.sum(s > 1e-4) < np.sum(np.linalg.svd(w, compute_uv=False) > 1e-4)


def test_randomized_svt_close_to_exact():
    rng = np.random.default_rng(3)
    w = (rng.standard_normal((64, 16)) * 1.0).astype(np.float32)
    exact = svt(jnp.asarray(w), jnp.asarray(0.5))
    approx = svt_randomized(jnp.asarray(w), jnp.asarray(0.5), rank=16,
                            key=jax.random.PRNGKey(0))
    np.testing.assert_allclose(approx, exact, rtol=1e-3, atol=1e-3)


# --------------------------------------------- rank-distributed sketch ---

# (d, p, column-split): the split is a list of per-shard column counts, so
# arbitrary shard counts AND uneven "shard" widths are both exercised —
# the psum identity sum_s W_s @ Omega_s = W @ Omega does not care about
# the equal-width layout the engine happens to use.
sketch_cases = st.tuples(
    st.integers(1, 20), st.integers(1, 8),
    st.lists(st.integers(1, 5), min_size=1, max_size=6))


@settings(max_examples=60, deadline=None)
@given(sketch_cases, st.integers(0, 2 ** 31 - 1))
def test_partitioned_sketch_psum_reproduces_serial_contraction(case, seed):
    """The distributed prox's one structural assumption: partitioning the
    rows of Omega by the column split of W and summing the per-part
    (d, p) sketches reproduces the serial contraction W @ Omega — exactly
    for one part, and to float32 ulp for any part count (the sum regroups
    the reduction over T, which is the documented ulp-level caveat of
    prox.svt_randomized_dist at n > 1 shards)."""
    d, p, split = case
    T = sum(split)
    kw, ko = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(kw, (d, T), jnp.float32)
    omega = jax.random.normal(ko, (T, p), jnp.float32)
    serial = w @ omega
    parts, off = [], 0
    for width in split:
        parts.append(w[:, off:off + width] @ omega[off:off + width, :])
        off += width
    summed = sum(parts[1:], parts[0])
    if len(split) == 1:
        np.testing.assert_array_equal(np.asarray(summed), np.asarray(serial))
    else:
        np.testing.assert_allclose(np.asarray(summed), np.asarray(serial),
                                   rtol=1e-5, atol=1e-5 * np.sqrt(T))


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 2000), st.integers(1, 500), st.integers(1, 64))
def test_sketch_width_clips_to_matrix(d, T, rank):
    p = sketch_width(rank, d, T)
    assert 1 <= p <= min(d, T)
    assert p == min(rank + 8, min(d, T))


def test_l21_rows_zeroed():
    w = jnp.asarray([[0.1, 0.1], [3.0, 4.0]], jnp.float32)
    p = l21_prox(w, jnp.asarray(1.0))
    np.testing.assert_allclose(p[0], 0.0)          # ||row0|| < t -> zeroed
    np.testing.assert_allclose(jnp.linalg.norm(p[1]), 4.0, rtol=1e-5)
