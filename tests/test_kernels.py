"""Pallas kernel validation: shape/dtype sweeps vs. the ref.py oracles,
executed in interpret mode on CPU (kernel bodies run exactly as written)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.km_update import km_update
from repro.kernels.l21_prox import l21_prox
from repro.kernels.lstsq_grad import lstsq_grad

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- km_update
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", [(8, 4), (50, 20), (256, 128), (300, 130),
                                   (1000, 16), (7, 1)])
def test_km_update_matches_ref(shape, dtype):
    k = jax.random.PRNGKey(0)
    kv, kp, kg = jax.random.split(k, 3)
    v = jax.random.normal(kv, shape, dtype)
    p = jax.random.normal(kp, shape, dtype)
    g = jax.random.normal(kg, shape, dtype)
    eta, eta_k = jnp.asarray(0.05), jnp.asarray(0.8)
    got = km_update(v, p, g, eta, eta_k, interpret=True)
    want = ref.km_update_ref(v.astype(jnp.float32), p.astype(jnp.float32),
                             g.astype(jnp.float32), eta, eta_k)
    np.testing.assert_allclose(np.asarray(got, np.float32), want, **_tol(dtype))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 300), st.integers(1, 150),
       st.floats(0.0, 1.0), st.floats(0.0, 1.0))
def test_km_update_property(d, t, eta, eta_k):
    key = jax.random.PRNGKey(d * 1000 + t)
    kv, kp, kg = jax.random.split(key, 3)
    v = jax.random.normal(kv, (d, t))
    p = jax.random.normal(kp, (d, t))
    g = jax.random.normal(kg, (d, t))
    got = km_update(v, p, g, jnp.asarray(eta), jnp.asarray(eta_k),
                    interpret=True)
    want = ref.km_update_ref(v, p, g, jnp.asarray(eta), jnp.asarray(eta_k))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- l21_prox
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", [(8, 4), (50, 20), (512, 128), (600, 7),
                                   (1, 1), (1023, 3)])
def test_l21_prox_matches_ref(shape, dtype):
    w = jax.random.normal(jax.random.PRNGKey(1), shape, dtype) * 2.0
    t = jnp.asarray(0.5)
    got = l21_prox(w, t, interpret=True)
    want = ref.l21_prox_ref(w, t)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 600), st.integers(1, 40), st.floats(1e-3, 5.0))
def test_l21_prox_property(d, t_dim, thresh):
    w = jax.random.normal(jax.random.PRNGKey(d + t_dim), (d, t_dim)) * 3.0
    got = l21_prox(w, jnp.asarray(thresh), interpret=True)
    want = ref.l21_prox_ref(w, jnp.asarray(thresh))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_l21_prox_agrees_with_core_prox():
    from repro.core.prox import l21_prox as core_l21
    w = jax.random.normal(jax.random.PRNGKey(2), (100, 10))
    np.testing.assert_allclose(l21_prox(w, jnp.asarray(0.3), interpret=True),
                               core_l21(w, jnp.asarray(0.3)),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------- svt_reconstruct
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("shape", [(8, 4, 4), (64, 24, 128), (300, 24, 16),
                                   (1000, 9, 130), (7, 1, 1), (256, 128, 256)])
def test_svt_reconstruct_matches_ref(shape, dtype):
    """(d, p, m) sweep incl. non-tile-aligned p/m and the engine's shapes
    (p = rank+8 = 24 against a full T and a shard's n_local block)."""
    d, p, m = shape
    from repro.kernels.svt_reconstruct import svt_reconstruct
    kq, ks, kv = jax.random.split(jax.random.PRNGKey(6), 3)
    qu = jax.random.normal(kq, (d, p), dtype)
    s = jax.random.uniform(ks, (p,), jnp.float32, 0.0, 3.0)
    vt = jax.random.normal(kv, (p, m), dtype)
    got = svt_reconstruct(qu, s, vt, interpret=True)
    want = ref.svt_reconstruct_ref(qu, s, vt)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_svt_reconstruct_zero_sigma_kills_directions():
    """A zeroed (thresholded-away) singular value must contribute exactly
    nothing, even when its qu/vt factors are wild — the padded-lane
    argument for the kernel relies on this."""
    from repro.kernels.svt_reconstruct import svt_reconstruct
    d, p, m = 40, 6, 10
    kq, kv = jax.random.split(jax.random.PRNGKey(7))
    qu = jax.random.normal(kq, (d, p)) * 1e3
    vt = jax.random.normal(kv, (p, m)) * 1e3
    s = jnp.asarray([1.0, 0.0, 2.0, 0.0, 0.0, 0.5], jnp.float32)
    got = svt_reconstruct(qu, s, vt, interpret=True)
    kept = jnp.asarray([0, 2, 5])
    want = (qu[:, kept] * s[kept][None, :]) @ vt[kept, :]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-2)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 300), st.integers(1, 40), st.integers(1, 150))
def test_svt_reconstruct_property(d, p, m):
    from repro.kernels.svt_reconstruct import svt_reconstruct
    kq, ks, kv = jax.random.split(jax.random.PRNGKey(d * 131 + p * 7 + m), 3)
    qu = jax.random.normal(kq, (d, p))
    s = jax.random.uniform(ks, (p,), jnp.float32, 0.0, 2.0)
    vt = jax.random.normal(kv, (p, m))
    got = svt_reconstruct(qu, s, vt, interpret=True)
    want = ref.svt_reconstruct_ref(qu, s, vt)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# -------------------------------------------------------------- lstsq_grad
@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("shape", [(16, 8), (100, 50), (512, 128), (700, 130),
                                   (1, 5), (1000, 28)])
def test_lstsq_grad_matches_ref(shape, dtype):
    n, d = shape
    kx, kw, ky = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.normal(kx, (n, d), dtype) / np.sqrt(d)
    w = jax.random.normal(kw, (d,), dtype)
    y = jax.random.normal(ky, (n,), dtype)
    got = lstsq_grad(x, w, y, interpret=True)
    want = ref.lstsq_grad_ref(x, w, y)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_lstsq_grad_bf16_accumulates_fp32():
    n, d = 512, 128
    kx, kw, ky = jax.random.split(jax.random.PRNGKey(4), 3)
    x = jax.random.normal(kx, (n, d), jnp.bfloat16) / np.sqrt(d)
    w = jax.random.normal(kw, (d,), jnp.bfloat16)
    y = jax.random.normal(ky, (n,), jnp.bfloat16)
    got = np.asarray(lstsq_grad(x, w, y, interpret=True), np.float32)
    want = np.asarray(ref.lstsq_grad_ref(x, w, y), np.float32)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 700), st.integers(1, 160))
def test_lstsq_grad_property(n, d):
    kx, kw, ky = jax.random.split(jax.random.PRNGKey(n * 7 + d), 3)
    x = jax.random.normal(kx, (n, d)) / np.sqrt(max(d, 1))
    w = jax.random.normal(kw, (d,))
    y = jax.random.normal(ky, (n,))
    got = lstsq_grad(x, w, y, interpret=True)
    want = ref.lstsq_grad_ref(x, w, y)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_lstsq_grad_is_true_gradient():
    """Oracle itself equals autodiff of ||Xw-y||^2."""
    n, d = 64, 32
    kx, kw, ky = jax.random.split(jax.random.PRNGKey(5), 3)
    x = jax.random.normal(kx, (n, d))
    w = jax.random.normal(kw, (d,))
    y = jax.random.normal(ky, (n,))
    auto = jax.grad(lambda ww: jnp.sum((x @ ww - y) ** 2))(w)
    np.testing.assert_allclose(ref.lstsq_grad_ref(x, w, y), auto,
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------ lstsq_grad_sampled
@pytest.mark.parametrize("seed", [0, 1, 0xDEADBEEF])
@pytest.mark.parametrize("shape,bsz", [((16, 8), 4), ((100, 50), 25),
                                       ((512, 128), 64), ((700, 130), 33),
                                       ((1, 5), 1), ((1000, 28), 512),
                                       ((30, 10), 30), ((30, 10), 99)])
def test_lstsq_grad_sampled_matches_ref(shape, bsz, seed):
    """Seeded-minibatch kernel vs oracle across block boundaries, bsz = n,
    and the saturated bsz > n clamp."""
    from repro.kernels.lstsq_grad_sampled import lstsq_grad_sampled
    n, d = shape
    kx, kw, ky = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.normal(kx, (n, d), jnp.float32) / np.sqrt(d)
    w = jax.random.normal(kw, (d,), jnp.float32)
    y = jax.random.normal(ky, (n,), jnp.float32)
    s = jnp.asarray(seed, jnp.uint32)
    got = lstsq_grad_sampled(x, w, y, s, batch_size=bsz, interpret=True)
    want = ref.lstsq_grad_sampled_ref(x, w, y, s, bsz)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_lstsq_grad_sampled_saturated_equals_full_kernel():
    """batch_size >= n inside the KERNEL: all-ones mask and unit scale must
    reproduce the full-gradient kernel's arithmetic."""
    from repro.kernels.lstsq_grad_sampled import lstsq_grad_sampled
    n, d = 600, 40
    kx, kw, ky = jax.random.split(jax.random.PRNGKey(8), 3)
    x = jax.random.normal(kx, (n, d), jnp.float32) / np.sqrt(d)
    w = jax.random.normal(kw, (d,), jnp.float32)
    y = jax.random.normal(ky, (n,), jnp.float32)
    got = lstsq_grad_sampled(x, w, y, jnp.asarray(5, jnp.uint32),
                             batch_size=n, interpret=True)
    want = lstsq_grad(x, w, y, interpret=True)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 700), st.integers(1, 160), st.integers(1, 700),
       st.integers(0, 2**32 - 1))
def test_lstsq_grad_sampled_property(n, d, bsz, seed):
    from repro.kernels.lstsq_grad_sampled import lstsq_grad_sampled
    kx, kw, ky = jax.random.split(jax.random.PRNGKey(n * 7 + d), 3)
    x = jax.random.normal(kx, (n, d)) / np.sqrt(max(d, 1))
    w = jax.random.normal(kw, (d,))
    y = jax.random.normal(ky, (n,))
    s = jnp.asarray(seed, jnp.uint32)
    got = lstsq_grad_sampled(x, w, y, s, batch_size=bsz, interpret=True)
    want = ref.lstsq_grad_sampled_ref(x, w, y, s, bsz)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 1100), st.integers(1, 1100), st.integers(0, 2**32 - 1))
def test_sample_mask_kernel_bitwise(n, bsz, seed):
    """Selection bits are EXACT (pure uint32 arithmetic): kernel == oracle
    with array_equal, no tolerance."""
    from repro.kernels.lstsq_grad_sampled import sample_mask
    s = jnp.asarray(seed, jnp.uint32)
    got = sample_mask(n, bsz, s, interpret=True)
    want = ref.sample_mask_ref(n, bsz, s)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ----------------------------------------------------------- gauss_sketch
@pytest.mark.parametrize("d,t,p", [(8, 4, 4), (64, 24, 24), (300, 16, 9),
                                   (1024, 128, 24), (2000, 130, 130),
                                   (7, 1, 1)])
def test_gauss_sketch_matches_ref(d, t, p):
    """In-kernel counter-generated Omega vs the materializing oracle,
    incl. p > 128 (multi-lane-tile Omega) and non-aligned shapes."""
    from repro.kernels.gauss_sketch import gauss_sketch
    w = jax.random.normal(jax.random.PRNGKey(9), (d, t), jnp.float32)
    s = jnp.asarray(0xC0FFEE, jnp.uint32)
    off = jnp.zeros((), jnp.int32)
    got = gauss_sketch(w, s, off, p=p, interpret=True)
    want = ref.gauss_sketch_ref(w, s, off, p)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gauss_sketch_row_offset_partitions_globally():
    """Shard semantics: row blocks of W sketched at their global offsets
    must sum to the full sketch — the psum identity of the distributed
    randomized SVT."""
    from repro.kernels.gauss_sketch import gauss_sketch
    d, t, p = 96, 12, 8
    w = jax.random.normal(jax.random.PRNGKey(10), (d, t), jnp.float32)
    s = jnp.asarray(1234, jnp.uint32)
    full = gauss_sketch(w, s, jnp.zeros((), jnp.int32), p=p, interpret=True)
    parts = sum(
        gauss_sketch(w[:, o:o + 4], s, jnp.asarray(o, jnp.int32), p=p,
                     interpret=True)
        for o in (0, 4, 8))
    np.testing.assert_allclose(parts, full, rtol=1e-5, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 500), st.integers(1, 140), st.integers(1, 140),
       st.integers(0, 2**32 - 1))
def test_gauss_sketch_property(d, t, p, seed):
    from repro.kernels.gauss_sketch import gauss_sketch
    w = jax.random.normal(jax.random.PRNGKey(d * 13 + t), (d, t))
    s = jnp.asarray(seed, jnp.uint32)
    off = jnp.zeros((), jnp.int32)
    got = gauss_sketch(w, s, off, p=p, interpret=True)
    want = ref.gauss_sketch_ref(w, s, off, p)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- ops layer
def test_ops_dispatch_cpu_uses_ref():
    from repro.kernels import ops
    v = jax.random.normal(jax.random.PRNGKey(6), (32, 8))
    out = ops.km_update(v, v, v, jnp.asarray(0.1), jnp.asarray(0.5))
    want = ref.km_update_ref(v, v, v, jnp.asarray(0.1), jnp.asarray(0.5))
    np.testing.assert_allclose(out, want, rtol=1e-6)


# ------------------------------------------------- flash attention kernel
@pytest.mark.parametrize("s,h,hkv,hd", [(64, 4, 4, 64), (200, 4, 2, 72),
                                        (256, 8, 1, 128), (100, 2, 2, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(s, h, hkv, hd, dtype):
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = (jax.random.normal(ks[0], (s, h, hd)) * 0.3).astype(dtype)
    k = (jax.random.normal(ks[1], (s, hkv, hd)) * 0.3).astype(dtype)
    v = (jax.random.normal(ks[2], (s, hkv, hd)) * 0.3).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    kr = jnp.repeat(k, h // hkv, axis=1)
    vr = jnp.repeat(v, h // hkv, axis=1)
    want = ref.sliding_flash_attention_ref(q, kr, vr, window=None)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@settings(max_examples=8, deadline=None)
@given(s=st.integers(16, 180), window=st.integers(4, 64),
       softcap=st.one_of(st.none(), st.floats(10.0, 50.0)))
def test_flash_attention_window_softcap_property(s, window, softcap):
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(s), 3)
    q = jax.random.normal(ks[0], (s, 2, 48)) * 0.3
    k = jax.random.normal(ks[1], (s, 2, 48)) * 0.3
    v = jax.random.normal(ks[2], (s, 2, 48)) * 0.3
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              softcap=softcap, interpret=True)
    want = ref.sliding_flash_attention_ref(q, k, v, window=window,
                                           softcap=softcap)
    np.testing.assert_allclose(out, want, atol=3e-5)


# ---------------------------------------------------- rwkv6 scan kernel
@pytest.mark.parametrize("s,h,d", [(64, 2, 64), (200, 3, 64), (128, 1, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rwkv6_scan_shapes_dtypes(s, h, d, dtype):
    from repro.kernels import ops
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r = (jax.random.normal(ks[0], (s, h, d)) * 0.3).astype(dtype)
    k = (jax.random.normal(ks[1], (s, h, d)) * 0.3).astype(dtype)
    v = (jax.random.normal(ks[2], (s, h, d)) * 0.3).astype(dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (s, h, d))).astype(dtype)
    u = (jax.random.normal(ks[4], (h, d)) * 0.3).astype(dtype)
    out = ops.rwkv6_scan(r, k, v, w, u, interpret=True)
    want = ref.rwkv6_scan_ref(r, k, v, w, u)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol)
