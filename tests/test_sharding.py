"""Rule-engine unit tests: pspec assignment, divisibility fallback, ZeRO."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import (batch_pspec, cache_pspec,
                                        moe_sharding_mode, param_pspec,
                                        param_pspecs, with_zero)

AX = {"data": 16, "model": 16}
AX_MP = {"pod": 2, "data": 16, "model": 16}


def test_embedding_vocab_sharded():
    cfg = get_config("granite-8b")
    assert param_pspec(("embed",), (49152, 4096), cfg, AX) == \
        P("model", None)


def test_attn_projections():
    cfg = get_config("granite-8b")
    assert param_pspec(("group0", "b0", "attn", "wq"), (36, 4096, 4096),
                       cfg, AX) == P(None, None, "model")
    assert param_pspec(("group0", "b0", "attn", "wo"), (36, 4096, 4096),
                       cfg, AX) == P(None, "model", None)


def test_norms_replicated():
    cfg = get_config("granite-8b")
    assert param_pspec(("group0", "b0", "norm1", "scale"), (36, 4096),
                       cfg, AX) == P(None, None)


def test_divisibility_fallback():
    cfg = get_config("granite-8b")
    # 28 not divisible by 16 -> replicate that dim
    assert param_pspec(("group0", "b0", "ffn", "w_in"), (36, 4096, 28),
                       cfg, AX) == P(None, None, None)


def test_moe_modes():
    ds = get_config("deepseek-v3-671b")
    assert moe_sharding_mode(ds, AX) == "full"      # 256 % 256 == 0
    dbrx = get_config("dbrx-132b")
    assert moe_sharding_mode(dbrx, AX) == "model"   # 16 % 16 == 0
    # deepseek experts spread over (data, model)
    assert param_pspec(("group1", "b0", "moe", "w_in"),
                       (58, 256, 7168, 2048), ds, AX) == \
        P(None, ("data", "model"), None, None)
    # dbrx: expert dim over model, FFN dim FSDP'd over data
    assert param_pspec(("group0", "b0", "moe", "w_in"),
                       (40, 16, 6144, 10752), dbrx, AX) == \
        P(None, "model", None, "data")
    assert param_pspec(("group0", "b0", "moe", "w_out"),
                       (40, 16, 10752, 6144), dbrx, AX) == \
        P(None, "model", "data", None)


def test_zero_adds_data_axis():
    spec = with_zero(P(None, "model"), (49152, 4096), AX)
    assert spec == P("data", "model")
    # already data-sharded: unchanged
    spec2 = with_zero(P(("data", "model"), None), (256, 7168), AX)
    assert spec2 == P(("data", "model"), None)
    # nothing divisible: unchanged
    assert with_zero(P(None,), (17,), AX) == P(None)


def test_batch_pspec():
    assert batch_pspec("tokens", (256, 4096), AX) == P("data", None)
    assert batch_pspec("tokens", (1, 4096), AX) == P(None, None)
    assert batch_pspec("tokens", (256, 4096), AX_MP,
                       ("pod", "data")) == P(("pod", "data"), None)


def test_cache_pspec_kv():
    # (n, B, S, Hkv, hd): batch->data, seq->model
    assert cache_pspec(("group0", "b0", "k"), (36, 128, 32768, 8, 128),
                       AX) == P(None, "data", "model", None, None)
    # batch=1 long context: only seq sharded
    assert cache_pspec(("group0", "b0", "k"), (36, 1, 524288, 8, 128),
                       AX) == P(None, None, "model", None, None)


def test_cache_pspec_states():
    # rwkv wkv (n, B, H=40, 64, 64): 40 % 16 != 0 -> heads replicated
    assert cache_pspec(("g", "b0", "wkv"), (32, 128, 40, 64, 64), AX) == \
        P(None, "data", None, None, None)
    # zamba ssm (n, B, H=112, P, N): 112 % 16 == 0 -> heads sharded
    assert cache_pspec(("g", "b0", "ssm"), (13, 128, 112, 64, 64), AX) == \
        P(None, "data", "model", None, None)


def test_full_param_tree_covers_all_leaves():
    cfg = get_config("zamba2-7b").reduced()
    from repro.models import init_params
    params = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.random.PRNGKey(0))
    specs = param_pspecs(params, cfg, AX)
    n_params = len(jax.tree_util.tree_leaves(params))
    n_specs = len(jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)))
    assert n_params == n_specs
