"""Batched multi-event AMTL engine: bitwise serial-replay equivalence for
aligned configs, within-batch conflict semantics, the amtl_event_batch
kernel/oracle, and the AMTLConfig validation surface for engine='batch'."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import AMTLConfig, amtl_solve
from repro.core.amtl import amtl_events_only, current_iterate
from repro.core.operators import rollback_columns, rollback_columns_batch
from repro.kernels import ref
from repro.kernels.amtl_event_batch import \
    amtl_event_batch as amtl_event_batch_pallas
from repro.kernels.ops import amtl_event_batch


def _base_cfg(problem, tau=3, **kw):
    eta = 1.0 / problem.lipschitz()
    return AMTLConfig(eta=eta, eta_k=0.7, tau=tau, **kw)


def _batch_pair(problem, tau, bsz, **kw):
    """(delta cfg, batch cfg) aligned: prox_every == event_batch."""
    delta = _base_cfg(problem, tau=tau, engine="delta", prox_every=bsz, **kw)
    batch = delta._replace(engine="batch", event_batch=bsz)
    return delta, batch


# ----------------------------------------------------------- equivalence
@pytest.mark.parametrize("tau,bsz", [(0, 5), (3, 5), (8, 5), (3, 1), (4, 10)])
def test_batch_engine_bitwise_matches_delta(small_problem, tau, bsz):
    """Aligned configs (prox_every == event_batch, same key): the batch
    engine replays the serial delta engine's iterates bitwise on the CPU
    oracle path.  tau=3/bsz=5 exercises event_batch > ring depth (only the
    newest tau+1 undo entries survive a batch)."""
    delta_cfg, batch_cfg = _batch_pair(small_problem, tau, bsz)
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(3)
    epe = 10 if bsz != 5 else 5
    delta = amtl_solve(small_problem, delta_cfg, w0, key, num_epochs=8,
                       events_per_epoch=epe)
    batch = amtl_solve(small_problem, batch_cfg, w0, key, num_epochs=8,
                       events_per_epoch=epe)
    np.testing.assert_array_equal(np.asarray(delta.v), np.asarray(batch.v))
    np.testing.assert_array_equal(np.asarray(delta.w), np.asarray(batch.w))
    np.testing.assert_array_equal(np.asarray(delta.objectives),
                                  np.asarray(batch.objectives))
    np.testing.assert_array_equal(np.asarray(delta.residuals),
                                  np.asarray(batch.residuals))


def test_batch_engine_bitwise_under_delays_dynamic_step_and_sketch(
        small_problem):
    """The batch engine must replay the delay history (per-event recording
    order), the delay-adaptive KM step, and the folded sketch key exactly."""
    delta_cfg, batch_cfg = _batch_pair(small_problem, tau=4, bsz=5,
                                       dynamic_step=True, prox_rank=5)
    offsets = jnp.asarray([3.0, 1.0, 0.0, 2.0, 4.0])
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(11)
    delta = amtl_solve(small_problem, delta_cfg, w0, key, num_epochs=6,
                       delay_offsets=offsets)
    batch = amtl_solve(small_problem, batch_cfg, w0, key, num_epochs=6,
                       delay_offsets=offsets)
    np.testing.assert_array_equal(np.asarray(delta.v), np.asarray(batch.v))


def test_batch_engine_state_stream_matches_delta(small_problem):
    """Beyond the iterate: the undo ring, ring pointer, event counter, PRNG
    key, and delay history of the batch engine must equal serial replay —
    they are what the next batch's stale read is reconstructed from."""
    delta_cfg, batch_cfg = _batch_pair(small_problem, tau=3, bsz=5)
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(5)
    d = amtl_events_only(small_problem, delta_cfg, w0, key, 25)
    b = amtl_events_only(small_problem, batch_cfg, w0, key, 25)
    np.testing.assert_array_equal(np.asarray(d.v), np.asarray(b.v))
    np.testing.assert_array_equal(np.asarray(d.delta_ring),
                                  np.asarray(b.delta_ring))
    np.testing.assert_array_equal(np.asarray(d.task_ring),
                                  np.asarray(b.task_ring))
    assert int(d.ptr) == int(b.ptr)
    assert int(d.event) == int(b.event) == 25
    np.testing.assert_array_equal(np.asarray(d.key), np.asarray(b.key))
    np.testing.assert_array_equal(np.asarray(d.history.buf),
                                  np.asarray(b.history.buf))


def test_batch_events_only_matches_solve(small_problem):
    _, cfg = _batch_pair(small_problem, tau=3, bsz=5)
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(1)
    st = amtl_events_only(small_problem, cfg, w0, key, 15)
    full = amtl_solve(small_problem, cfg, w0, key, num_epochs=1,
                      events_per_epoch=15)
    np.testing.assert_array_equal(np.asarray(current_iterate(st)),
                                  np.asarray(full.v))


# ----------------------------------------------------- validation surface
def test_event_batch_must_be_positive(small_problem):
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(0)
    for bad in (0, -3):
        with pytest.raises(ValueError, match=r"event_batch must be >= 1"):
            amtl_solve(small_problem,
                       _base_cfg(small_problem, engine="batch",
                                 prox_every=1, event_batch=bad),
                       w0, key, num_epochs=1)


@pytest.mark.parametrize("engine", ["dense", "delta"])
def test_one_event_engines_reject_event_batch(small_problem, engine):
    """The error must name event_batch (the offending parameter), not the
    prox knobs."""
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    with pytest.raises(ValueError, match=r"event_batch=4.*engine='batch'"):
        amtl_solve(small_problem,
                   _base_cfg(small_problem, engine=engine, event_batch=4),
                   w0, jax.random.PRNGKey(0), num_epochs=1)


def test_batch_requires_prox_alignment(small_problem):
    """prox_every may exceed event_batch (decoupled cadence) but must land
    on batch boundaries: non-multiples are rejected."""
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    err = r"prox_every \(2\) must be a multiple of event_batch \(4\)"
    with pytest.raises(ValueError, match=err):
        amtl_solve(small_problem,
                   _base_cfg(small_problem, engine="batch", prox_every=2,
                             event_batch=4),
                   w0, jax.random.PRNGKey(0), num_epochs=1)
    with pytest.raises(ValueError,
                       match=r"prox_every \(6\) must be a multiple"):
        amtl_solve(small_problem,
                   _base_cfg(small_problem, engine="batch", prox_every=6,
                             event_batch=4),
                   w0, jax.random.PRNGKey(0), num_epochs=1)


def test_batch_prox_rank_requires_nuclear(small_problem):
    l21 = small_problem._replace(reg_name="l21")
    w0 = jnp.zeros((l21.dim, l21.num_tasks), jnp.float32)
    with pytest.raises(ValueError, match=r"prox_rank.*nuclear.*'l21'"):
        amtl_solve(l21,
                   _base_cfg(l21, engine="batch", prox_every=4,
                             event_batch=4, prox_rank=3),
                   w0, jax.random.PRNGKey(0), num_epochs=1)


def test_batch_event_count_divisibility(small_problem):
    _, cfg = _batch_pair(small_problem, tau=3, bsz=4)
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(0)
    with pytest.raises(ValueError, match=r"num_events \(10\).*event_batch"):
        amtl_events_only(small_problem, cfg, w0, key, 10)
    with pytest.raises(ValueError,
                       match=r"events_per_epoch \(10\).*event_batch"):
        amtl_solve(small_problem, cfg, w0, key, num_epochs=1,
                   events_per_epoch=10)


# ------------------------------------------------- vectorized rollback
def test_rollback_columns_batch_matches_serial():
    """The one-scatter rollback must agree bitwise with the sequential
    replay for every nu, including masked-out slots and duplicate tasks."""
    d, T, tau = 6, 3, 4
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.standard_normal((d, T)), jnp.float32)
    delta_ring = jnp.asarray(rng.standard_normal((tau + 1, d)), jnp.float32)
    task_ring = jnp.asarray([1, 2, 1, 0, 2], jnp.int32)
    for ptr in range(tau + 1):
        for nu in range(tau + 1):
            want = rollback_columns(v, delta_ring, task_ring,
                                    jnp.asarray(ptr, jnp.int32),
                                    jnp.asarray(nu, jnp.int32), tau)
            got = rollback_columns_batch(v, delta_ring, task_ring,
                                         jnp.asarray(ptr, jnp.int32),
                                         jnp.asarray(nu, jnp.int32), tau)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------ kernel validation
def _random_batch(d, T, b, seed, dtype=jnp.float32, dup_heavy=False):
    k = jax.random.PRNGKey(seed)
    kv, kp, kg, kt, ke = jax.random.split(k, 5)
    v = jax.random.normal(kv, (d, T), dtype)
    p = jax.random.normal(kp, (d, b), dtype)
    g = jax.random.normal(kg, (d, b), dtype)
    hi = 2 if dup_heavy else T
    tasks = jax.random.randint(kt, (b,), 0, hi)
    eta_ks = jax.random.uniform(ke, (b,), minval=0.1, maxval=0.9)
    return v, p, g, tasks, jnp.asarray(0.05), eta_ks


def _numpy_serial_replay(v, p, g, tasks, eta, eta_ks):
    """Literal event-order replay — the within-batch conflict spec."""
    v = np.asarray(v, np.float32).copy()
    p, g = np.asarray(p, np.float32), np.asarray(g, np.float32)
    eta = np.float32(np.asarray(eta))
    undos = []
    for i, t in enumerate(np.asarray(tasks)):
        cur = v[:, t].copy()
        undos.append(cur)
        ek = np.float32(np.asarray(eta_ks[i]))
        v[:, t] = cur + ek * (p[:, i] - eta * g[:, i] - cur)
    return v, np.stack(undos)


def test_batch_ref_matches_numpy_serial_replay():
    """The scan-based oracle IS sequential replay: same bits, duplicate
    tasks chained in event order."""
    v, p, g, tasks, eta, eta_ks = _random_batch(17, 3, 12, 0, dup_heavy=True)
    assert len(set(np.asarray(tasks).tolist())) < 12  # duplicates present
    got_v, got_u = ref.amtl_event_batch_ref(v, p, g, tasks, eta, eta_ks)
    want_v, want_u = _numpy_serial_replay(v, p, g, tasks, eta, eta_ks)
    np.testing.assert_allclose(np.asarray(got_v), want_v, rtol=1e-6,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_u), want_u, rtol=1e-6,
                               atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("d,T,b", [(20, 5, 8), (128, 128, 64), (1000, 7, 3),
                                   (260, 130, 5)])
def test_amtl_event_batch_kernel_matches_ref(d, T, b, dtype):
    """Interpret-mode Pallas kernel vs the jnp oracle, duplicate-free and
    duplicate-heavy shapes, padded and exact lane counts."""
    v, p, g, tasks, eta, eta_ks = _random_batch(d, T, b, d + b, dtype)
    got_v, got_u = amtl_event_batch_pallas(v, p, g, tasks, eta, eta_ks,
                                           interpret=True)
    want_v, want_u = ref.amtl_event_batch_ref(
        v.astype(jnp.float32), p.astype(jnp.float32),
        g.astype(jnp.float32), tasks, eta, eta_ks)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got_v, np.float32),
                               np.asarray(want_v), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(got_u, np.float32),
                               np.asarray(want_u), rtol=tol, atol=tol)


def test_amtl_event_batch_kernel_duplicates_serialize():
    """Duplicate-heavy batch (tasks drawn from {0,1}): the kernel's in-batch
    forwarding must chain updates exactly like serial replay."""
    v, p, g, tasks, eta, eta_ks = _random_batch(64, 4, 16, 9, dup_heavy=True)
    got_v, got_u = amtl_event_batch_pallas(v, p, g, tasks, eta, eta_ks,
                                           interpret=True)
    want_v, want_u = _numpy_serial_replay(v, p, g, tasks, eta, eta_ks)
    np.testing.assert_allclose(np.asarray(got_v), want_v, rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_u), want_u, rtol=1e-5,
                               atol=1e-5)


def test_amtl_event_batch_ops_dispatch_cpu_is_oracle():
    """On CPU the ops wrapper must hit the jnp oracle path bitwise."""
    v, p, g, tasks, eta, eta_ks = _random_batch(129, 6, 7, 2)
    got_v, got_u = amtl_event_batch(v, p, g, tasks, eta, eta_ks)
    want_v, want_u = jax.jit(ref.amtl_event_batch_ref)(v, p, g, tasks, eta,
                                                       eta_ks)
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))
    np.testing.assert_array_equal(np.asarray(got_u), np.asarray(want_u))
