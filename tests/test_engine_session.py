"""Public stepwise engine-session API (`make_engine` / `AMTLEngine`).

Covers the session redesign's three contracts:

  * `run` composes bitwise — a session split at any step boundary resumes
    exactly (the streaming deployment shape: events arrive in chunks);
  * every engine state round-trips through `repro.checkpoint.save/restore`
    and resumes bitwise, including the sharded state under a mesh;
  * the decoupled prox cadence (`prox_every = k * event_batch`) reproduces
    the serial delta engine bitwise at matched cadence on the CPU oracle
    path, for the batch and sharded engines.

Plus the `default_config` engine-kwarg validation surface and the
backward-compat contract of the `amtl_solve`/`amtl_events_only` wrappers.
Multi-shard boundaries are exercised by the slow suite and the CI
checkpoint smoke; here the mesh is the degenerate 1-device "tasks" mesh.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.core import (AMTLConfig, amtl_solve, default_config, make_engine,
                        validate_config)
from repro.core.amtl import (BatchAMTLState, ShardedAMTLState,
                             amtl_events_only, current_iterate)
from repro.launch.mesh import make_task_mesh

ENGINES = ("dense", "delta", "batch", "sharded")


def _cfg(problem, engine, tau=3, **kw):
    eta = 1.0 / problem.lipschitz()
    if engine in ("batch", "sharded"):
        kw.setdefault("event_batch", 4)
        kw.setdefault("prox_every", kw["event_batch"])
    return AMTLConfig(eta=eta, eta_k=0.7, tau=tau, engine=engine, **kw)


@pytest.fixture(scope="module")
def mesh1():
    return make_task_mesh(1)


def _engine_for(problem, cfg, mesh1):
    return make_engine(problem, cfg,
                       mesh1 if cfg.engine == "sharded" else None)


def _assert_states_equal(a, b, context=""):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b), strict=True):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=context)


# ------------------------------------------------------------ API surface
@pytest.mark.parametrize("engine", ENGINES)
def test_engine_metadata_and_iterate(small_problem, mesh1, engine):
    cfg = _cfg(small_problem, engine)
    eng = _engine_for(small_problem, cfg, mesh1)
    assert eng.events_per_step == (4 if engine in ("batch", "sharded") else 1)
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    state = eng.init(w0, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(eng.iterate(state)),
                                  np.asarray(w0))
    assert int(state.event) == 0


@pytest.mark.parametrize("engine", ENGINES)
def test_run_matches_amtl_events_only(small_problem, mesh1, engine):
    """The wrappers are thin: one init + run IS amtl_events_only."""
    cfg = _cfg(small_problem, engine)
    mesh = mesh1 if engine == "sharded" else None
    eng = make_engine(small_problem, cfg, mesh)
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(2)
    got = eng.run(eng.init(w0, key), None, 20)
    want = amtl_events_only(small_problem, cfg, w0, key, 20, mesh=mesh)
    _assert_states_equal(got, want, engine)


def test_solve_wrapper_equals_session_stream(small_problem):
    """amtl_solve(num_epochs=E, events_per_epoch=n) reaches the same final
    iterate bitwise as one uninterrupted session of E*n events."""
    cfg = _cfg(small_problem, "batch")
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(9)
    res = amtl_solve(small_problem, cfg, w0, key, num_epochs=5,
                     events_per_epoch=8)
    eng = make_engine(small_problem, cfg)
    state = eng.run(eng.init(w0, key), None, 40)
    np.testing.assert_array_equal(np.asarray(res.v),
                                  np.asarray(eng.iterate(state)))


def test_run_rejects_non_multiple_num_events(small_problem):
    eng = make_engine(small_problem, _cfg(small_problem, "batch"))
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    state = eng.init(w0, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match=r"num_events \(10\).*event_batch"):
        eng.run(state, None, 10)


def test_make_engine_validates_eagerly(small_problem, mesh1):
    with pytest.raises(ValueError, match="unknown AMTL engine"):
        make_engine(small_problem, _cfg(small_problem, "sparse"))
    with pytest.raises(ValueError, match=r"mesh is only meaningful"):
        make_engine(small_problem, _cfg(small_problem, "delta"), mesh1)


# -------------------------------------------------------- split / resume
@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("split", [0, 1, 3, 5])
def test_session_splits_resume_bitwise(small_problem, mesh1, engine, split):
    """run(state, 2N) == run(run(state, n), 2N - n) at any step boundary —
    full state (iterate, rings, ptr, event counter, history, key)."""
    cfg = _cfg(small_problem, engine)
    eng = _engine_for(small_problem, cfg, mesh1)
    per = eng.events_per_step
    offs = jnp.asarray([2.0, 0.0, 1.0, 0.0, 3.0])
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(4)
    total = 5 * per
    full = eng.run(eng.init(w0, key), offs, total)
    mid = eng.run(eng.init(w0, key), offs, split * per)
    resumed = eng.run(mid, offs, total - split * per)
    _assert_states_equal(full, resumed, f"{engine} split={split}")


# ------------------------------------------------------ checkpoint/restore
@pytest.mark.parametrize("engine", ENGINES)
def test_checkpoint_roundtrip_resumes_bitwise(small_problem, mesh1, engine,
                                              tmp_path):
    """run(2N) == run(N) -> checkpoint.save -> restore -> run(N), for every
    engine (sharded under its mesh), on full state."""
    kw = {} if engine == "dense" else {"prox_rank": 3}
    cfg = _cfg(small_problem, engine, dynamic_step=True, **kw)
    eng = _engine_for(small_problem, cfg, mesh1)
    n = 5 * eng.events_per_step
    offs = jnp.asarray([1.0, 0.0, 2.0, 0.0, 1.0])
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(8)
    full = eng.run(eng.init(w0, key), offs, 2 * n)
    half = eng.run(eng.init(w0, key), offs, n)
    checkpoint.save(str(tmp_path), int(half.event), half)
    assert checkpoint.latest_step(str(tmp_path)) == n
    restored = checkpoint.restore(str(tmp_path), n,
                                  like=eng.init(w0, key))
    _assert_states_equal(half, restored, f"{engine} roundtrip")
    resumed = eng.run(restored, offs, n)
    _assert_states_equal(full, resumed, f"{engine} resume")


def test_checkpoint_roundtrip_decoupled_cadence_cache(small_problem,
                                                      tmp_path):
    """The reinstated prox cache is part of the contract: a mid-cadence
    checkpoint must restore the live (d, T) cache, not refresh early."""
    cfg = _cfg(small_problem, "batch", event_batch=2, prox_every=6)
    eng = make_engine(small_problem, cfg)
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(5)
    # 8 events = 4 batches: stops between refresh events 6 and 12
    full = eng.run(eng.init(w0, key), None, 16)
    half = eng.run(eng.init(w0, key), None, 8)
    assert half.p_cache.shape == (small_problem.dim,
                                  small_problem.num_tasks)
    checkpoint.save(str(tmp_path), 8, half)
    restored = checkpoint.restore(str(tmp_path), 8, like=eng.init(w0, key))
    resumed = eng.run(restored, None, 8)
    _assert_states_equal(full, resumed, "mid-cadence cache resume")


def test_checkpoint_restore_rejects_layout_drift(small_problem, tmp_path):
    """A record must fail loudly — naming the drifted entries — when the
    state layout or shapes disagree with `like`, instead of misloading."""
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(0)
    batch = make_engine(small_problem, _cfg(small_problem, "batch"))
    checkpoint.save(str(tmp_path), 0, batch.init(w0, key))
    dense = make_engine(small_problem, _cfg(small_problem, "dense"))
    with pytest.raises(ValueError, match="does not match the `like` pytree"):
        checkpoint.restore(str(tmp_path), 0, like=dense.init(w0, key))
    deeper = make_engine(small_problem, _cfg(small_problem, "batch", tau=6))
    with pytest.raises(ValueError, match=r"shape"):
        checkpoint.restore(str(tmp_path), 0, like=deeper.init(w0, key))
    st = batch.init(w0, key)
    wrong_dtype = st._replace(event=st.event.astype(jnp.float32))
    with pytest.raises(ValueError, match=r"dtype"):
        checkpoint.restore(str(tmp_path), 0, like=wrong_dtype)


# ------------------------------------------------- decoupled prox cadence
@pytest.mark.parametrize("tau,bsz,k", [(3, 4, 2), (3, 4, 3), (0, 2, 4),
                                       (3, 5, 2), (8, 5, 3)])
def test_batch_decoupled_cadence_matches_delta(small_problem, tau, bsz, k):
    """prox_every = k*event_batch reproduces the serial delta engine at the
    same prox cadence bitwise on the CPU oracle path — full state including
    the carried prox cache.  (3,5,2)/(8,5,3) cover event_batch > ring
    depth and deep rings."""
    delta_cfg = _cfg(small_problem, "delta", tau=tau, prox_every=k * bsz)
    batch_cfg = delta_cfg._replace(engine="batch", event_batch=bsz)
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(3)
    n = 6 * k * bsz
    d = amtl_events_only(small_problem, delta_cfg, w0, key, n)
    b = amtl_events_only(small_problem, batch_cfg, w0, key, n)
    np.testing.assert_array_equal(np.asarray(d.v), np.asarray(b.v))
    np.testing.assert_array_equal(np.asarray(d.p_cache),
                                  np.asarray(b.p_cache))
    np.testing.assert_array_equal(np.asarray(d.delta_ring),
                                  np.asarray(b.delta_ring))
    np.testing.assert_array_equal(np.asarray(d.task_ring),
                                  np.asarray(b.task_ring))
    assert int(d.ptr) == int(b.ptr)
    assert int(d.event) == int(b.event) == n
    np.testing.assert_array_equal(np.asarray(d.key), np.asarray(b.key))


def test_batch_decoupled_cadence_dynamic_step_and_sketch(small_problem):
    """Cadence decoupling must also replay the delay-adaptive KM step and
    fold the sketch key at refresh events only, exactly like delta."""
    delta_cfg = _cfg(small_problem, "delta", tau=4, prox_every=10,
                     dynamic_step=True, prox_rank=5)
    batch_cfg = delta_cfg._replace(engine="batch", event_batch=5)
    offs = jnp.asarray([3.0, 1.0, 0.0, 2.0, 4.0])
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(11)
    d = amtl_events_only(small_problem, delta_cfg, w0, key, 40,
                         delay_offsets=offs)
    b = amtl_events_only(small_problem, batch_cfg, w0, key, 40,
                         delay_offsets=offs)
    np.testing.assert_array_equal(np.asarray(d.v), np.asarray(b.v))
    np.testing.assert_array_equal(np.asarray(d.p_cache),
                                  np.asarray(b.p_cache))
    np.testing.assert_array_equal(np.asarray(d.history.buf),
                                  np.asarray(b.history.buf))


def test_sharded_decoupled_cadence_matches_batch(small_problem, mesh1):
    """The sharded engine pays its all_gather only at refresh batches; on a
    1-device mesh the decoupled cadence must still match batch bitwise."""
    batch_cfg = _cfg(small_problem, "batch", tau=3, event_batch=5,
                     prox_every=15)
    sharded_cfg = batch_cfg._replace(engine="sharded")
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(6)
    b = amtl_events_only(small_problem, batch_cfg, w0, key, 45)
    s = amtl_events_only(small_problem, sharded_cfg, w0, key, 45,
                         mesh=mesh1)
    np.testing.assert_array_equal(np.asarray(b.v), np.asarray(s.v))
    np.testing.assert_array_equal(np.asarray(b.p_cache),
                                  np.asarray(s.p_cache))
    np.testing.assert_array_equal(np.asarray(b.delta_ring),
                                  np.asarray(s.delta_ring[0]))


def test_prox_cache_carried_only_when_decoupled(small_problem, mesh1):
    """Aligned cadence keeps the (0, 0) stub (no dead (d, T) loop carry);
    k > 1 carries the live cache — for batch and sharded states."""
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(0)
    aligned = make_engine(small_problem, _cfg(small_problem, "batch"))
    st = aligned.init(w0, key)
    assert isinstance(st, BatchAMTLState) and st.p_cache.shape == (0, 0)
    decoupled = make_engine(small_problem,
                            _cfg(small_problem, "batch", event_batch=4,
                                 prox_every=8))
    assert decoupled.init(w0, key).p_cache.shape == w0.shape
    sh = make_engine(small_problem,
                     _cfg(small_problem, "sharded", event_batch=4,
                          prox_every=8), mesh1)
    st = sh.init(w0, key)
    assert isinstance(st, ShardedAMTLState) and st.p_cache.shape == w0.shape


# ----------------------------------------------- default_config validation
def test_default_config_accepts_engine_kwargs(small_problem):
    cfg = default_config(small_problem, tau=3, engine="batch",
                         event_batch=8, prox_every=32, prox_rank=4)
    assert (cfg.engine, cfg.event_batch, cfg.prox_every, cfg.prox_rank) == \
        ("batch", 8, 32, 4)
    # the returned config must be directly usable
    eng = make_engine(small_problem, cfg)
    assert eng.events_per_step == 8


def test_default_config_validates_like_make_engine(small_problem):
    """Invalid engine combinations fail at config construction, through
    the same validate_config path make_engine runs."""
    with pytest.raises(ValueError, match=r"event_batch=4.*engine='batch'"):
        default_config(small_problem, engine="delta", event_batch=4)
    with pytest.raises(ValueError, match="unknown AMTL engine"):
        default_config(small_problem, engine="sparse")
    with pytest.raises(ValueError, match=r"must be a multiple of"):
        default_config(small_problem, engine="batch", event_batch=4,
                       prox_every=6)
    with pytest.raises(ValueError, match="seed baseline"):
        default_config(small_problem, engine="dense", prox_every=2)
    l21 = small_problem._replace(reg_name="l21")
    with pytest.raises(ValueError, match=r"prox_rank.*nuclear.*'l21'"):
        default_config(l21, engine="delta", prox_rank=3)


def test_validate_config_standalone(small_problem):
    validate_config(_cfg(small_problem, "batch", event_batch=4,
                         prox_every=12))
    with pytest.raises(ValueError, match="prox_every must be >= 1"):
        validate_config(_cfg(small_problem, "delta", prox_every=0))
