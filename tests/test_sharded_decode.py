"""attn_decode_sharded (shard_map flash-decode, cache seq-sharded over
`model`) must match plain attn_decode numerically.  Runs in a subprocess
with 8 fake host devices so real shard boundaries are exercised."""
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # 8-fake-device subprocess; excluded from tier-1

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models import attention as attn_lib
from repro.models.attention import KVCache
from repro.models.moe import ParallelCtx

cfg = get_config("gemma2-2b").reduced()          # has softcap + GQA
mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = ParallelCtx(mesh=mesh, data_axes=("data",), model_axis="model")

key = jax.random.PRNGKey(0)
p = attn_lib.init_attn(key, cfg, jnp.float32)
b, s_max = 4, 32
x = jax.random.normal(jax.random.PRNGKey(1), (b, 1, cfg.d_model),
                      jnp.float32) * 0.1
ck = jax.random.normal(jax.random.PRNGKey(2),
                       (b, s_max, cfg.num_kv_heads, cfg.head_dim)) * 0.1
cv = jax.random.normal(jax.random.PRNGKey(3), ck.shape) * 0.1

# int8 cache path: quantized flash-decode must track the exact result
import dataclasses
from repro.models.attention import quantize_kv
cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
pos = jnp.asarray(20, jnp.int32)
cache_f = KVCache(k=ck, v=cv)
ref_out, _ = attn_lib.attn_decode(p, x, cache_f, pos, cfg)
kq, ks = quantize_kv(ck)
vq, vs = quantize_kv(cv)
with mesh:
    csp = NamedSharding(mesh, P("data", "model", None, None))
    ssp = NamedSharding(mesh, P("data", "model", None))
    qc = KVCache(k=jax.device_put(kq, csp), v=jax.device_put(vq, csp),
                 k_scale=jax.device_put(ks, ssp),
                 v_scale=jax.device_put(vs, ssp))
    out8, nc8 = jax.jit(lambda xx, cc, pp: attn_lib.attn_decode_sharded(
        p, xx, cc, pp, cfg8, ctx))(x, qc, pos)
assert nc8.k.dtype == jnp.int8 and nc8.k_scale is not None
rel = float(jnp.abs(out8 - ref_out).max() / (jnp.abs(ref_out).max() + 1e-9))
assert rel < 0.05, f"int8 decode rel err {rel}"

for pos_val, window in [(0, None), (5, None), (31, None), (40, 16),
                        (7, 16)]:
    w = min(window, s_max) if window else None
    cache = KVCache(k=ck[:, :w] if w else ck, v=cv[:, :w] if w else cv)
    pos = jnp.asarray(pos_val, jnp.int32)
    ref_out, ref_cache = attn_lib.attn_decode(p, x, cache, pos, cfg,
                                              window=w)
    with mesh:
        csp = NamedSharding(mesh, P("data", "model", None, None))
        sc = KVCache(k=jax.device_put(cache.k, csp),
                     v=jax.device_put(cache.v, csp))
        out, ncache = jax.jit(
            lambda xx, cc, pp: attn_lib.attn_decode_sharded(
                p, xx, cc, pp, cfg, ctx, window=w))(x, sc, pos)
    assert jnp.allclose(ref_out, out, atol=2e-5), (
        pos_val, window, float(jnp.abs(ref_out - out).max()))
    for a, bb in ((ref_cache.k, ncache.k), (ref_cache.v, ncache.v)):
        assert jnp.allclose(a, bb, atol=1e-6), (pos_val, window)
# MLA (deepseek) latent-space sharded decode
cfg_mla = get_config("deepseek-v3-671b").reduced()
pm = attn_lib.init_mla(jax.random.PRNGKey(7), cfg_mla, jnp.float32)
from repro.models.attention import MLACache
m = cfg_mla.mla
xm = jax.random.normal(jax.random.PRNGKey(8), (b, 1, cfg_mla.d_model),
                       jnp.float32) * 0.1
cm = MLACache(
    c_kv=jax.random.normal(jax.random.PRNGKey(9),
                           (b, s_max, m.kv_lora_rank)) * 0.1,
    k_rope=jax.random.normal(jax.random.PRNGKey(10),
                             (b, s_max, m.qk_rope_head_dim)) * 0.1)
for pos_val in (0, 13, 31):
    pos = jnp.asarray(pos_val, jnp.int32)
    ref_o, ref_c = attn_lib.mla_decode(pm, xm, cm, pos, cfg_mla)
    with mesh:
        csp = NamedSharding(mesh, P("data", "model", None))
        sc = MLACache(c_kv=jax.device_put(cm.c_kv, csp),
                      k_rope=jax.device_put(cm.k_rope, csp))
        o, nc = jax.jit(lambda xx, cc, pp: attn_lib.mla_decode_sharded(
            pm, xx, cc, pp, cfg_mla, ctx))(xm, sc, pos)
    assert jnp.allclose(ref_o, o, atol=3e-5), (
        pos_val, float(jnp.abs(ref_o - o).max()))
    assert jnp.allclose(ref_c.c_kv, nc.c_kv, atol=1e-6)
    assert jnp.allclose(ref_c.k_rope, nc.k_rope, atol=1e-6)
print("OK")
"""


def test_sharded_decode_matches_reference():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env={"PYTHONPATH": "src",
                                       "PATH": "/usr/bin:/bin"},
                       cwd="/root/repo", timeout=600)
    assert r.returncode == 0 and "OK" in r.stdout, r.stderr[-3000:]
