"""unroll=True (dry-run cost-analysis mode) must be numerically identical
to the production lax.scan path, and the P=1/P=2 cost extrapolation used
by `dryrun --extrapolate` must reconstruct the full-unroll flops within
tolerance on a reduced config."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.launch import shapes as shp
from repro.models import decode_step, init_cache, init_params, prefill
from repro.models.transformer import forward


@pytest.mark.parametrize("arch", ["gemma2-2b", "zamba2-7b", "dbrx-132b"])
def test_forward_unroll_matches_scan(arch):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, num_periods=3,
                              num_layers=len(cfg.head_blocks)
                              + 3 * len(cfg.period) + len(cfg.tail_blocks))
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = shp.concrete_batch(cfg, shp.ShapeSpec("t", "train", 32, 2),
                               jax.random.PRNGKey(1))
    loss_scan, _ = forward(params, batch, cfg, remat=False)
    loss_unroll, _ = forward(params, batch, cfg, remat=False, unroll=True)
    assert jnp.allclose(loss_scan, loss_unroll, rtol=1e-5)


def test_decode_unroll_matches_scan():
    cfg = get_config("gemma2-2b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    cache = init_cache(cfg, 2, 16)
    tok = jnp.array([[3], [5]], jnp.int32)
    pos = jnp.asarray(0, jnp.int32)
    l1, c1 = decode_step(params, cache, tok, pos, cfg)
    l2, c2 = decode_step(params, cache, tok, pos, cfg, unroll=True)
    assert jnp.allclose(l1, l2, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c2)):
        assert jnp.allclose(a, b, rtol=1e-5)


def test_cost_extrapolation_reconstructs_full_unroll():
    """flops(P=1) + (P-1)*(flops(P=2)-flops(P=1)) ~= flops(P) unrolled."""
    cfg0 = get_config("granite-8b").reduced()

    def with_p(k):
        return dataclasses.replace(
            cfg0, num_periods=k,
            num_layers=len(cfg0.head_blocks) + k * len(cfg0.period)
            + len(cfg0.tail_blocks))

    batch = shp.concrete_batch(cfg0, shp.ShapeSpec("t", "train", 32, 2),
                               jax.random.PRNGKey(1))

    def flops(cfg):
        params = init_params(jax.random.PRNGKey(0), cfg)
        f = jax.jit(lambda p: forward(p, batch, cfg, remat=False,
                                      unroll=True)[0])
        ca = f.lower(params).compile().cost_analysis()
        if isinstance(ca, list):   # jax <= 0.4.x returns [dict], >= 0.5 dict
            ca = ca[0]
        return ca["flops"]

    f1, f2, f6 = flops(with_p(1)), flops(with_p(2)), flops(with_p(6))
    extrapolated = f1 + 5 * (f2 - f1)
    assert abs(extrapolated - f6) / f6 < 0.12, (extrapolated, f6)
