"""Fault-tolerance contracts of the learn-while-serve platform under
DETERMINISTIC fault injection (`repro.serve.faults.FaultPlan`).

Every recovery path is asserted bitwise, exactly like the no-fault
contracts in tests/test_serve.py:

  * supervised learner: a scripted crash auto-restarts under backoff
    and the final state is bitwise ONE `engine.run` replay of the
    surviving chunk log; an exhausted restart budget trips the circuit
    breaker (frozen serving: predictions flow, feedback rejected with
    reason "breaker", terminal exception surfaces once on stop);
  * non-finite guard: NaN feedback is rejected at admission and the
    session is bitwise the one where the poisoned rows were never
    submitted; a poisoned ITERATE is quarantined — state, snapshot,
    chunk log, and the boundary's folded rows all roll back bitwise;
  * resume: a corrupted newest checkpoint record falls back one
    interval (all four engines, sharded under a degenerate 1-device
    mesh) and subsequent predictions are bitwise the uninterrupted
    server's at that boundary; a crash in the store/engine checkpoint
    split window leaves a resumable directory;
  * `BackgroundLearner.join` timeout leaves the learner joinable and
    surfaces a captured exception exactly once (regression).
"""
import os
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import checkpoint
from repro.checkpoint import CheckpointCorruptError
from repro.core import AMTLConfig, make_engine
from repro.launch.mesh import make_task_mesh
from repro.serve import (AMTLServer, BackgroundLearner, FaultPlan,
                         InjectedFault, ServeConfig, corrupt_leaf,
                         truncate_record)

ENGINES = ("dense", "delta", "batch", "sharded")
RAGGED_ENGINES = ("delta", "batch", "sharded")


def _cfg(problem, engine, tau=3, **kw):
    eta = 1.0 / problem.lipschitz()
    if engine in ("batch", "sharded"):
        kw.setdefault("event_batch", 4)
        kw.setdefault("prox_every", kw["event_batch"])
    return AMTLConfig(eta=eta, eta_k=0.7, tau=tau, engine=engine, **kw)


@pytest.fixture(scope="module")
def mesh1():
    return make_task_mesh(1)


def _server(problem, cfg, mesh1, serve_cfg=ServeConfig(chunk_events=4),
            key=0, fault_plan=None):
    w0 = jnp.zeros((problem.dim, problem.num_tasks), jnp.float32)
    mesh = mesh1 if cfg.engine == "sharded" else None
    return AMTLServer(problem, cfg, w0, jax.random.PRNGKey(key), serve_cfg,
                      mesh=mesh, fault_plan=fault_plan)


def _rows(problem, k, seed):
    rng = np.random.default_rng(seed)
    t = rng.integers(0, problem.num_tasks, size=k)
    x = (rng.standard_normal((k, problem.dim))
         / np.sqrt(problem.dim)).astype(np.float32)
    y = rng.standard_normal(k).astype(np.float32)
    return t, x, y


def _wait(predicate, timeout_s=60.0):
    deadline = time.time() + timeout_s
    while time.time() < deadline:
        if predicate():
            return True
        time.sleep(0.01)
    return False


# --------------------------------------------------- supervised learner --
def test_supervised_restart_replays_surviving_chunk_log(small_problem,
                                                        mesh1):
    """A scripted mid-stream crash loses exactly the crashed chunk's
    coalesced events (the documented at-most-once window), the
    supervisor restarts the learner, and the final state is bitwise ONE
    engine.run replay of the surviving chunk log."""
    cfg = _cfg(small_problem, "batch")
    serve_cfg = ServeConfig(chunk_events=4, restart_limit=2,
                            restart_backoff_s=0.01)
    plan = FaultPlan(crash_on_chunks={1})
    server = _server(small_problem, cfg, mesh1, serve_cfg, fault_plan=plan)
    server.start_learner()
    for i in range(4):
        server.submit_feedback(np.arange(4) % small_problem.num_tasks)
    assert _wait(lambda: server.stats()["health"]["learner_restarts"] >= 1
                 and len(server.chunk_log) >= 3)
    learned = server.stop_learner(drain=True, timeout=60)
    health = server.stats()["health"]
    assert health["learner_restarts"] == 1
    assert health["learner_crashes"] == 1
    assert len(health["crash_log"]) == 1
    assert "InjectedFault" in health["crash_log"][0]
    assert len(health["recovery_ms"]) == 1 and health["recovery_ms"][0] > 0
    # 16 events submitted, chunk 1 (4 events) lost to the crash window
    assert server.chunk_log == [4, 4, 4]
    assert learned == sum(server.chunk_log)
    eng = server.engine
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks),
                   jnp.float32)
    state = eng.run(eng.init(w0, jax.random.PRNGKey(0)), None,
                    sum(server.chunk_log))
    np.testing.assert_array_equal(np.asarray(server.iterate()),
                                  np.asarray(eng.iterate(state)))


def test_supervised_no_faults_is_bitwise_plain_learner(small_problem,
                                                       mesh1):
    """restart_limit set but nothing crashing: the supervised drain is
    bitwise the cooperative loop — supervision is pure scaffolding
    until a crash happens."""
    cfg = _cfg(small_problem, "delta")
    fb = [np.arange(4) % small_problem.num_tasks for _ in range(3)]

    sup = _server(small_problem, cfg, mesh1,
                  ServeConfig(chunk_events=4, restart_limit=3))
    sup.start_learner()
    for t in fb:
        sup.submit_feedback(t)
    sup.stop_learner(drain=True, timeout=60)

    coop = _server(small_problem, cfg, mesh1, ServeConfig(chunk_events=4))
    for t in fb:
        coop.submit_feedback(t)
    while coop.step():
        pass

    assert sup.chunk_log == coop.chunk_log
    np.testing.assert_array_equal(np.asarray(sup.iterate()),
                                  np.asarray(coop.iterate()))
    health = sup.stats()["health"]
    assert health["learner_crashes"] == 0
    assert not health["breaker_tripped"]


def test_breaker_latches_frozen_serving(small_problem, mesh1):
    """Crash budget exhausted -> breaker: predictions keep flowing off
    the last committed snapshot, feedback is rejected with reason
    "breaker", cooperative steps are no-ops, the terminal exception
    surfaces exactly once on stop, and the learner cannot be
    restarted."""
    cfg = _cfg(small_problem, "batch")
    serve_cfg = ServeConfig(chunk_events=4, restart_limit=1,
                            restart_backoff_s=0.01)
    plan = FaultPlan(crash_on_chunks=set(range(64)))
    server = _server(small_problem, cfg, mesh1, serve_cfg, fault_plan=plan)
    before = server.serving()
    server.start_learner()
    server.submit_feedback([0, 1, 2, 3])

    def _feed_until_tripped():
        if not server.breaker_tripped:
            server.submit_feedback([0, 1, 2, 3])
        return server.breaker_tripped
    assert _wait(_feed_until_tripped)
    assert not server.learner_running
    # frozen serving: the request path still answers off the snapshot
    rng = np.random.default_rng(0)
    x = rng.standard_normal((3, small_problem.dim)).astype(np.float32)
    preds = server.predict([0, 1, 2], x)
    assert preds.shape == (3,)
    assert server.serving() is before  # nothing ever committed
    receipt = server.submit_feedback([0, 1])
    assert receipt == (0, 2)
    assert receipt.reason == "breaker"
    assert server.step() == 0
    health = server.stats()["health"]
    assert health["breaker_tripped"]
    assert health["breaker_rejected"] >= 2
    assert health["learner_restarts"] == 1     # the budget, spent
    assert health["learner_crashes"] == 2
    with pytest.raises(InjectedFault):
        server.stop_learner(drain=False, timeout=60)
    # surfaced exactly once: a second stop is clean
    assert server.stop_learner(drain=False, timeout=60) == 0
    with pytest.raises(RuntimeError, match="circuit breaker"):
        server.start_learner()


# --------------------------------------------------- non-finite guard ----
def test_nonfinite_feedback_rejected_at_admission(small_problem, mesh1):
    """Rows with non-finite features or labels die at admission with
    their events; the engine and store never see them."""
    cfg = _cfg(small_problem, "batch")
    server = _server(small_problem, cfg, mesh1)
    t, x, y = _rows(small_problem, 6, seed=1)
    x[2, 5] = np.inf
    y[4] = np.nan
    receipt = server.submit_feedback(t, x, y)
    assert receipt == (4, 2)
    assert receipt.reason == "nonfinite"
    assert server.stats()["health"]["nonfinite_feedback"] == 2
    assert server.pending_feedback == 4
    server.step()
    assert np.isfinite(np.asarray(server.iterate())).all()


def test_nan_quarantine_is_bitwise_never_submitted(small_problem, mesh1):
    """The satellite contract: a session whose poisoned rows were
    rejected at admission has chunk log, final state, AND store bitwise
    equal to the same session where those rows were never submitted.

    The poison arrives via the fault plan (scripted NaN injection into
    chosen feedback rows), so both sessions issue IDENTICAL
    submit_feedback calls — the admission guard alone must produce the
    never-submitted outcome."""
    cfg = _cfg(small_problem, "batch")
    t, x, y = _rows(small_problem, 12, seed=2)

    plan = FaultPlan(nan_feedback=[(0, 3), (1, 0)])
    poisoned = _server(small_problem, cfg, mesh1, fault_plan=plan)
    clean = _server(small_problem, cfg, mesh1)
    for lo in (0, 4, 8):  # 3 labeled calls; calls 0 and 1 get a NaN row
        rp = poisoned.submit_feedback(t[lo:lo + 4], x[lo:lo + 4],
                                      y[lo:lo + 4])
        keep = np.ones(4, bool)
        if lo == 0:
            keep[3] = False
        if lo == 4:
            keep[0] = False
        rc = clean.submit_feedback(t[lo:lo + 4][keep], x[lo:lo + 4][keep],
                                   y[lo:lo + 4][keep])
        assert rp.accepted == rc.accepted
    while poisoned.step():
        pass
    while clean.step():
        pass
    assert poisoned.chunk_log == clean.chunk_log
    np.testing.assert_array_equal(np.asarray(poisoned.iterate()),
                                  np.asarray(clean.iterate()))
    assert poisoned.store_rows == clean.store_rows
    sp, sc = poisoned._store.state(), clean._store.state()
    np.testing.assert_array_equal(sp.xs, sc.xs)
    np.testing.assert_array_equal(sp.ys, sc.ys)
    np.testing.assert_array_equal(sp.row_counts, sc.row_counts)
    assert poisoned.stats()["health"]["nonfinite_feedback"] == 2


def test_poisoned_iterate_quarantined_with_rollback(small_problem, mesh1):
    """A non-finite ITERATE (scripted past admission, modelling in-kernel
    divergence) never reaches the snapshot: the chunk is quarantined,
    the boundary's folded rows roll back out of the store bitwise —
    across the capacity doubling the fold caused — and the session
    continues from the last committed state as if the boundary never
    ran."""
    cfg = _cfg(small_problem, "batch")
    plan = FaultPlan(poison_iterate_on_chunks={1})
    server = _server(small_problem, cfg, mesh1, fault_plan=plan)

    t0, x0, y0 = _rows(small_problem, 4, seed=3)
    server.submit_feedback(t0, x0, y0)
    assert server.step() == 4               # chunk 0 commits
    committed = server.serving()
    store_snapshot = server._store.state()
    cap_before = server._store.capacity
    problem_before, engine_before = server.problem, server.engine
    assert len(server.chunk_log) == 1

    # enough rows on one task to force a capacity doubling at the fold
    k = server._store.capacity + 2
    t1 = np.zeros(k, np.int64)
    rng = np.random.default_rng(4)
    x1 = (rng.standard_normal((k, small_problem.dim))
          / np.sqrt(small_problem.dim)).astype(np.float32)
    y1 = rng.standard_normal(k).astype(np.float32)
    server.submit_feedback(t1, x1, y1)
    consumed = server.step()                # chunk 1: poisoned
    assert consumed > 0                     # the boundary consumed events
    assert server.chunk_log == [4]          # ...but nothing committed
    assert server.serving() is committed    # snapshot untouched
    assert np.isfinite(np.asarray(server.iterate())).all()
    # the fold unwound bitwise: buffers, counts, capacity, and the very
    # problem/engine objects (jit cache keys) of the pre-fold session
    assert server._store.capacity == cap_before
    after = server._store.state()
    np.testing.assert_array_equal(after.xs, store_snapshot.xs)
    np.testing.assert_array_equal(after.ys, store_snapshot.ys)
    np.testing.assert_array_equal(after.row_counts,
                                  store_snapshot.row_counts)
    assert server.problem is problem_before
    assert server.engine is engine_before
    health = server.stats()["health"]
    assert health["nonfinite_chunks"] == 1
    assert health["quarantined_feedback"] == consumed
    assert health["quarantine_log"] == [{0: consumed}]

    # the session continues cleanly from the committed state
    t2, x2, y2 = _rows(small_problem, 4, seed=5)
    server.submit_feedback(t2, x2, y2)
    assert server.step() == 4
    assert server.chunk_log == [4, 4]
    eng = server.engine
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks),
                   jnp.float32)
    # bitwise ONE replay of the committed chunk log: fold -> run at the
    # same boundaries, with the quarantined boundary absent entirely
    replay = AMTLServer(small_problem, cfg, jnp.zeros_like(w0),
                        jax.random.PRNGKey(0),
                        ServeConfig(chunk_events=4))
    replay.submit_feedback(t0, x0, y0)
    replay.step()
    replay.submit_feedback(t2, x2, y2)
    replay.step()
    np.testing.assert_array_equal(np.asarray(server.iterate()),
                                  np.asarray(replay.iterate()))


def test_poisoned_chunk_never_reaches_checkpoint(small_problem, mesh1,
                                                 tmp_path):
    """checkpoint_every cadence + a poisoned chunk: the quarantined
    boundary writes nothing, and every record on disk verifies and
    holds finite data."""
    cfg = _cfg(small_problem, "batch")
    serve_cfg = ServeConfig(chunk_events=4, ckpt_dir=str(tmp_path),
                            checkpoint_every=4)
    plan = FaultPlan(poison_iterate_on_chunks={1})
    server = _server(small_problem, cfg, mesh1, serve_cfg, fault_plan=plan)
    for seed in range(3):
        t, x, y = _rows(small_problem, 4, seed=seed)
        server.submit_feedback(t, x, y)
        server.step()
    assert server.stats()["health"]["nonfinite_chunks"] == 1
    steps = checkpoint.record_steps(str(tmp_path))
    assert steps == [8, 4]  # chunk 1's would-be step 8 was the 2nd commit
    for s in steps:
        state = checkpoint.restore(str(tmp_path), s,
                                   like=server.engine.init(
                                       jnp.zeros((small_problem.dim,
                                                  small_problem.num_tasks),
                                                 jnp.float32),
                                       jax.random.PRNGKey(0)))
        for leaf in jax.tree_util.tree_leaves(state):
            arr = np.asarray(leaf)
            if np.issubdtype(arr.dtype, np.floating):
                assert np.isfinite(arr).all()


# ------------------------------------------------------- resume paths ----
@pytest.mark.parametrize("engine", ENGINES)
def test_corrupt_newest_checkpoint_falls_back_one_interval(
        small_problem, mesh1, engine, tmp_path):
    """The satellite contract, all four engines (sharded under a
    degenerate 1-device mesh): bit rot on the newest record costs one
    checkpoint interval — resume lands on the previous boundary and
    subsequent predictions are bitwise an uninterrupted server's at
    that same boundary."""
    cfg = _cfg(small_problem, engine)
    serve_cfg = ServeConfig(chunk_events=4, ckpt_dir=str(tmp_path))
    server = _server(small_problem, cfg, mesh1, serve_cfg)
    server.submit_feedback([0, 1, 2, 3])
    server.step()
    server.checkpoint()                       # step 4 — the fallback
    server.submit_feedback([1, 2, 3, 4])
    server.step()
    server.checkpoint()                       # step 8 — about to rot
    corrupt_leaf(os.path.join(str(tmp_path), "step_00000008.npz"))

    resumed = AMTLServer.resume(
        small_problem, cfg,
        jnp.zeros((small_problem.dim, small_problem.num_tasks),
                  jnp.float32),
        jax.random.PRNGKey(0), serve_cfg,
        mesh=mesh1 if engine == "sharded" else None)
    assert resumed.event_count == 4

    # uninterrupted reference at the same boundary
    reference = _server(small_problem, cfg, mesh1,
                        ServeConfig(chunk_events=4))
    reference.submit_feedback([0, 1, 2, 3])
    reference.step()
    np.testing.assert_array_equal(np.asarray(resumed.iterate()),
                                  np.asarray(reference.iterate()))
    t, x = (np.arange(6) % small_problem.num_tasks,
            np.random.default_rng(9).standard_normal(
                (6, small_problem.dim)).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(resumed.predict(t, x)),
                                  np.asarray(reference.predict(t, x)))
    # and the resumed session keeps advancing bitwise
    resumed.submit_feedback([0, 1, 2, 3])
    reference.submit_feedback([0, 1, 2, 3])
    assert resumed.step() == reference.step() == 4
    np.testing.assert_array_equal(np.asarray(resumed.iterate()),
                                  np.asarray(reference.iterate()))


def test_resume_refuses_all_corrupt_directory(small_problem, mesh1,
                                              tmp_path):
    """Every engine record damaged: resume raises CheckpointCorruptError
    instead of silently restarting the session from scratch."""
    cfg = _cfg(small_problem, "delta")
    serve_cfg = ServeConfig(chunk_events=4, ckpt_dir=str(tmp_path))
    server = _server(small_problem, cfg, mesh1, serve_cfg)
    server.submit_feedback([0, 1, 2, 3])
    server.step()
    server.checkpoint()
    truncate_record(os.path.join(str(tmp_path), "step_00000004.npz"))
    with pytest.raises(CheckpointCorruptError):
        AMTLServer.resume(
            small_problem, cfg,
            jnp.zeros((small_problem.dim, small_problem.num_tasks),
                      jnp.float32),
            jax.random.PRNGKey(0), serve_cfg)


@pytest.mark.parametrize("engine", RAGGED_ENGINES)
def test_resume_drops_to_older_store_record_on_corruption(
        small_problem, mesh1, engine, tmp_path):
    """Satellite bugfix: a corrupt store record (torn zip) used to kill
    resume outright (only FileNotFoundError was caught).  Now the store
    scan drops to the newest remaining valid record."""
    cfg = _cfg(small_problem, engine)
    serve_cfg = ServeConfig(chunk_events=4, ckpt_dir=str(tmp_path))
    server = _server(small_problem, cfg, mesh1, serve_cfg)
    t, x, y = _rows(small_problem, 4, seed=6)
    server.submit_feedback(t, x, y)
    server.step()
    server.checkpoint()                         # store + engine at 4
    rows_at_4 = server.store_rows
    t, x, y = _rows(small_problem, 4, seed=7)
    server.submit_feedback(t, x, y)
    server.step()
    server.checkpoint()                         # store + engine at 8
    truncate_record(os.path.join(str(tmp_path), "store",
                                 "step_00000008.npz"))
    resumed = AMTLServer.resume(
        small_problem, cfg,
        jnp.zeros((small_problem.dim, small_problem.num_tasks),
                  jnp.float32),
        jax.random.PRNGKey(0), serve_cfg,
        mesh=mesh1 if engine == "sharded" else None)
    # engine record at 8 is intact; the store dropped one interval
    assert resumed.event_count == 8
    assert resumed.store_rows == rows_at_4


def test_checkpoint_crash_split_window_resumes(small_problem, mesh1,
                                               tmp_path):
    """A scripted crash between the store write and the engine write
    (the documented split window) leaves one unpaired newer store
    record.  Resume prefers the record PAIRED with the surviving engine
    step; if that pairing is gone too, it drops to the unpaired newer
    record — a superset of the paired rows the engine state never saw
    (appends only affect future chunks)."""
    cfg = _cfg(small_problem, "batch")
    serve_cfg = ServeConfig(chunk_events=4, ckpt_dir=str(tmp_path))
    plan = FaultPlan(fail_checkpoint_calls={1})
    server = _server(small_problem, cfg, mesh1, serve_cfg, fault_plan=plan)
    t, x, y = _rows(small_problem, 4, seed=8)
    server.submit_feedback(t, x, y)
    server.step()
    server.checkpoint()                       # call 0: store 4 + engine 4
    rows_after_first_fold = server.store_rows
    t, x, y = _rows(small_problem, 4, seed=9)
    server.submit_feedback(t, x, y)
    server.step()
    rows_after_second_fold = server.store_rows
    with pytest.raises(InjectedFault):
        server.checkpoint()                   # call 1: store 8, no engine
    assert checkpoint.record_steps(str(tmp_path)) == [4]
    assert checkpoint.record_steps(
        os.path.join(str(tmp_path), "store")) == [8, 4]
    v0 = jnp.zeros((small_problem.dim, small_problem.num_tasks),
                   jnp.float32)
    resumed = AMTLServer.resume(small_problem, cfg, v0,
                                jax.random.PRNGKey(0), serve_cfg)
    assert resumed.event_count == 4
    assert resumed.store_rows == rows_after_first_fold
    # paired record gone too: the unpaired newer record still resumes
    os.remove(os.path.join(str(tmp_path), "store", "step_00000004.npz"))
    resumed = AMTLServer.resume(small_problem, cfg, v0,
                                jax.random.PRNGKey(0), serve_cfg)
    assert resumed.event_count == 4
    assert resumed.store_rows == rows_after_second_fold


# ------------------------------------------ learner join regression ------
def test_learner_join_timeout_retries_and_surfaces_once():
    """Satellite bugfix: a timed-out join used to leave the learner
    half-stopped; now a later stop/join retries cleanly and a captured
    exception surfaces exactly once, never lost to the timeout path."""
    import threading

    gate = threading.Event()

    class _FakeServer:
        def _step_once(self):
            gate.wait()
            raise RuntimeError("boom after the gate")

    learner = BackgroundLearner(_FakeServer())
    learner.start()
    with pytest.raises(TimeoutError, match="retry stop"):
        learner.stop(drain=False, timeout=0.05)
    assert learner.running                      # still joinable
    gate.set()
    with pytest.raises(RuntimeError, match="boom after the gate"):
        learner.stop(drain=False, timeout=60)
    # surfaced exactly once: subsequent stops are clean no-ops
    assert learner.stop(drain=False, timeout=60) == 0
    assert not learner.running
    # and the learner is restartable after the failure was surfaced
    gate.clear()

    class _CleanServer:
        def _step_once(self):
            return 0
    learner2 = BackgroundLearner(_CleanServer())
    learner2.start()
    assert learner2.stop(drain=False, timeout=60) == 0


def test_fault_plan_counters_are_deterministic(small_problem, mesh1):
    """Two identical plans against identical traffic fire identically —
    the whole point of scripting faults instead of timing them."""
    cfg = _cfg(small_problem, "batch")
    logs = []
    for _ in range(2):
        plan = FaultPlan(poison_iterate_on_chunks={0})
        server = _server(small_problem, cfg, mesh1, fault_plan=plan)
        server.submit_feedback([0, 1, 2, 3])
        server.step()
        server.submit_feedback([0, 1, 2, 3])
        server.step()
        logs.append((list(server.chunk_log),
                     server.stats()["health"]["quarantine_log"]))
    assert logs[0] == logs[1]
    assert logs[0][0] == [4]                  # chunk 0 quarantined


def test_serve_config_validates_restart_knobs(small_problem, mesh1):
    with pytest.raises(ValueError, match="restart_limit"):
        _server(small_problem, _cfg(small_problem, "batch"), mesh1,
                ServeConfig(chunk_events=4, restart_limit=-1))
    with pytest.raises(ValueError, match="restart_backoff_s"):
        _server(small_problem, _cfg(small_problem, "batch"), mesh1,
                ServeConfig(chunk_events=4, restart_backoff_s=-0.5))
