"""Cross-engine reference validation: every jitted engine vs the paper-
faithful float64 discrete-event reference dynamics.

`core/simulator.py::simulate_amtl` executes the exact §III.4 mathematics in
float64 numpy with explicit node clocks and stale snapshot reads — it is
the repo's ground-truth AMTL dynamics, previously only compared to the
jitted engines indirectly.  This suite runs all four engines
(dense/delta/batch/sharded) on the same `make_synthetic` problem with the
same (eta, eta_k, tau) and asserts:

  * the four engines produce the SAME iterates bitwise (at prox_every=1 /
    event_batch=1 their event streams coincide by construction);
  * every engine's objective trajectory tracks the simulator's at equal
    event counts — loosely early (the two executions activate tasks in
    different random orders, so transients differ), tightly once both
    settle (the BF fixed point is unique for this strongly convex f);
  * the final iterates agree with the float64 reference W*.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (MTLProblem, NetworkModel, make_synthetic,
                        simulate_amtl)
from repro.core.amtl import AMTLConfig, amtl_solve
from repro.core.operators import amtl_max_step
from repro.launch.mesh import make_task_mesh

T, D, N, TAU, EPOCHS = 4, 12, 30, 4, 400
ENGINES = ("dense", "delta", "batch", "sharded")


@pytest.fixture(scope="module")
def sim_problem():
    return make_synthetic(num_tasks=T, samples=N, dim=D, seed=0)


@pytest.fixture(scope="module")
def stacked_problem(sim_problem):
    return MTLProblem(jnp.asarray(np.stack(sim_problem.xs), jnp.float32),
                      jnp.asarray(np.stack(sim_problem.ys), jnp.float32),
                      "lstsq", "nuclear", 0.1)


@pytest.fixture(scope="module")
def reference(sim_problem, stacked_problem):
    """Float64 event-driven reference run, one objective per event."""
    eta = 1.0 / stacked_problem.lipschitz()
    sim = simulate_amtl(sim_problem,
                        NetworkModel(delay_offset=0.0, delay_jitter=1.0),
                        num_epochs=EPOCHS, eta=float(eta),
                        eta_k=float(amtl_max_step(TAU, T)), tau=TAU, seed=0)
    assert sim.iterations == EPOCHS * T
    # objective after each full sweep of T events, aligned with the
    # engines' per-epoch recording
    return sim, np.asarray(sim.objectives)[T - 1::T]


@pytest.fixture(scope="module")
def engine_runs(stacked_problem):
    eta = 1.0 / stacked_problem.lipschitz()
    eta_k = amtl_max_step(TAU, T)
    w0 = jnp.zeros((D, T), jnp.float32)
    key = jax.random.PRNGKey(0)
    out = {}
    for engine in ENGINES:
        cfg = AMTLConfig(eta=eta, eta_k=eta_k, tau=TAU, engine=engine)
        mesh = None
        if engine in ("batch", "sharded"):
            # event_batch=1 keeps the amortized-prox schedule identical to
            # the one-event engines, so all four event streams coincide.
            cfg = cfg._replace(event_batch=1, prox_every=1)
        if engine == "sharded":
            mesh = make_task_mesh(1)
        out[engine] = amtl_solve(stacked_problem, cfg, w0, key,
                                 num_epochs=EPOCHS, mesh=mesh)
    return out


def test_engines_agree_bitwise_with_each_other(engine_runs):
    """At prox_every=1/event_batch=1 all four engines replay the same event
    stream and arithmetic — iterates and trajectories must be identical."""
    ref = engine_runs["dense"]
    for engine in ENGINES[1:]:
        res = engine_runs[engine]
        np.testing.assert_array_equal(np.asarray(ref.v), np.asarray(res.v),
                                      err_msg=engine)
        np.testing.assert_array_equal(np.asarray(ref.objectives),
                                      np.asarray(res.objectives),
                                      err_msg=engine)


@pytest.mark.parametrize("engine", ENGINES)
def test_objective_trajectory_tracks_float64_reference(engine, engine_runs,
                                                       reference):
    _, sim_traj = reference
    objs = np.asarray(engine_runs[engine].objectives, np.float64)
    rel = np.abs(objs - sim_traj) / sim_traj
    # Transient: task activation orders differ between the event-driven
    # reference and the uniform-sampling engines (measured peak ~0.22).
    assert rel.max() < 0.35, rel.max()
    # Settled: both approach the unique BF fixed point.
    assert rel[100:].max() < 0.03, rel[100:].max()
    assert rel[-1] < 0.01, rel[-1]
    # Objectives must actually decrease toward the reference limit, not
    # merely end close: epoch-100 value strictly below epoch-0.
    assert objs[-1] < objs[100] < objs[0]


@pytest.mark.parametrize("engine", ENGINES)
def test_final_iterate_matches_float64_reference(engine, engine_runs,
                                                 reference):
    sim, _ = reference
    w = np.asarray(engine_runs[engine].w, np.float64)
    rel = np.linalg.norm(w - sim.w) / np.linalg.norm(sim.w)
    assert rel < 0.02, rel  # measured ~0.003 (float32 engine vs float64 ref)


# ----------------------------------------------------------- SGD-AMTL
# Minibatch engines vs the float64 minibatch reference.  Both use the
# unbiased (n_t/bsz)-scaled convention with bsz = min(batch_size, n_t);
# the selection LAWS differ (reference: without-replacement numpy choice;
# engines: counter-hash Bernoulli with expected size bsz) so agreement is
# trajectory-level — same noise scale, same fixed-point neighborhood —
# not bitwise.

BSZ = 10  # of N=30 samples: a genuine 3x-variance minibatch
SGD_ENGINES = ("delta", "batch", "sharded")  # dense rejects batch_size


@pytest.fixture(scope="module")
def sgd_reference(sim_problem, stacked_problem):
    eta = 1.0 / stacked_problem.lipschitz()
    sim = simulate_amtl(sim_problem,
                        NetworkModel(delay_offset=0.0, delay_jitter=1.0),
                        num_epochs=EPOCHS, eta=float(eta),
                        eta_k=float(amtl_max_step(TAU, T)), tau=TAU, seed=0,
                        batch_size=BSZ)
    return sim, np.asarray(sim.objectives)[T - 1::T]


@pytest.fixture(scope="module")
def sgd_engine_runs(stacked_problem):
    eta = 1.0 / stacked_problem.lipschitz()
    eta_k = amtl_max_step(TAU, T)
    w0 = jnp.zeros((D, T), jnp.float32)
    key = jax.random.PRNGKey(0)
    out = {}
    for engine in SGD_ENGINES:
        cfg = AMTLConfig(eta=eta, eta_k=eta_k, tau=TAU, engine=engine,
                         batch_size=BSZ)
        mesh = None
        if engine in ("batch", "sharded"):
            cfg = cfg._replace(event_batch=1, prox_every=1)
        if engine == "sharded":
            mesh = make_task_mesh(1)
        out[engine] = amtl_solve(stacked_problem, cfg, w0, key,
                                 num_epochs=EPOCHS, mesh=mesh)
    return out


def test_sgd_engines_agree_bitwise_with_each_other(sgd_engine_runs):
    """All three minibatch engines fold the same per-event sampling seed
    off the same chain position — with coincident event streams their
    iterates must stay bitwise identical."""
    ref = sgd_engine_runs["delta"]
    for engine in SGD_ENGINES[1:]:
        res = sgd_engine_runs[engine]
        np.testing.assert_array_equal(np.asarray(ref.v), np.asarray(res.v),
                                      err_msg=engine)
        np.testing.assert_array_equal(np.asarray(ref.objectives),
                                      np.asarray(res.objectives),
                                      err_msg=engine)


@pytest.mark.parametrize("engine", SGD_ENGINES)
def test_sgd_trajectory_tracks_float64_minibatch_reference(
        engine, sgd_engine_runs, sgd_reference):
    """The (n_t/bsz) scaling convention is what this pins: a mis-scaled
    engine gradient (e.g. the raw minibatch sum) changes the effective
    step 3x and leaves this envelope immediately."""
    _, sim_traj = sgd_reference
    objs = np.asarray(sgd_engine_runs[engine].objectives, np.float64)
    rel = np.abs(objs - sim_traj) / sim_traj
    # Transient: independent activation orders AND independent minibatch
    # draws (measured peak ~0.30 vs ~0.22 full-gradient).
    assert rel.max() < 0.6, rel.max()
    # Settled: same noise floor around the same fixed point (measured
    # ~0.036 / ~0.002).
    assert rel[100:].max() < 0.08, rel[100:].max()
    assert rel[-1] < 0.02, rel[-1]
    assert objs[-1] < objs[100] < objs[0]


@pytest.mark.parametrize("engine", SGD_ENGINES)
def test_sgd_final_iterate_matches_float64_minibatch_reference(
        engine, sgd_engine_runs, sgd_reference):
    sim, _ = sgd_reference
    w = np.asarray(sgd_engine_runs[engine].w, np.float64)
    rel = np.linalg.norm(w - sim.w) / np.linalg.norm(sim.w)
    assert rel < 0.05, rel  # measured ~0.016


def test_sgd_clamp_batch_size_above_n_is_bitwise_full(stacked_problem):
    """bsz = min(batch_size, n): batch_size > n saturates the selection
    threshold and the scale, so the run must equal the full-gradient
    engine's BITWISE — the engine-side mirror of the simulator clamp."""
    eta = 1.0 / stacked_problem.lipschitz()
    w0 = jnp.zeros((D, T), jnp.float32)
    key = jax.random.PRNGKey(0)
    full_cfg = AMTLConfig(eta=eta, eta_k=amtl_max_step(TAU, T), tau=TAU,
                          engine="delta")
    sgd_cfg = full_cfg._replace(batch_size=N + 69)
    full = amtl_solve(stacked_problem, full_cfg, w0, key, num_epochs=50)
    sgd = amtl_solve(stacked_problem, sgd_cfg, w0, key, num_epochs=50)
    np.testing.assert_array_equal(np.asarray(full.v), np.asarray(sgd.v))
    np.testing.assert_array_equal(np.asarray(full.objectives),
                                  np.asarray(sgd.objectives))
