"""Property-based tests of the undo-log ring invariant.

For any event sequence, the delta ring's contract is: rolling back the nu
newest undo-log entries from the iterate at event k reproduces — bitwise —
the iterate at event (k - nu) that a dense full-iterate ring would have
stored.  A numpy replay maintains the dense history as the oracle; the
generated sequences cover ring wrap-around (more events than slots, so
`ptr` has wrapped and `ptr < nu` index arithmetic goes negative), repeated
writes to the same column, and every reachable staleness nu <= min(tau, k).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.operators import rollback_columns, rollback_columns_batch


@st.composite
def _event_sequences(draw):
    d = draw(st.integers(1, 8))
    num_tasks = draw(st.integers(1, 6))
    tau = draw(st.integers(0, 6))
    # enough events to wrap the (tau+1)-slot ring at least once
    n_events = draw(st.integers(1, 3 * (tau + 1)))
    seed = draw(st.integers(0, 2**31 - 1))
    tasks = draw(st.lists(st.integers(0, num_tasks - 1),
                          min_size=n_events, max_size=n_events))
    return d, num_tasks, tau, seed, tasks


def _replay(d, num_tasks, tau, seed, tasks):
    """Apply the event sequence; return ring state + dense numpy history."""
    rng = np.random.default_rng(seed)
    depth = tau + 1
    v = rng.standard_normal((d, num_tasks)).astype(np.float32)
    history = [v.copy()]
    delta_ring = np.zeros((depth, d), np.float32)
    task_ring = np.zeros((depth,), np.int32)
    ptr = 0
    for t in tasks:
        ptr = (ptr + 1) % depth
        delta_ring[ptr] = v[:, t]          # exact pre-write bits
        task_ring[ptr] = t
        v = v.copy()
        v[:, t] = rng.standard_normal(d).astype(np.float32)
        history.append(v.copy())
    return v, delta_ring, task_ring, ptr, history


@settings(max_examples=60, deadline=None)
@given(_event_sequences())
def test_rollback_reproduces_dense_history(seq):
    d, num_tasks, tau, seed, tasks = seq
    v, delta_ring, task_ring, ptr, history = _replay(d, num_tasks, tau,
                                                     seed, tasks)
    vj = jnp.asarray(v)
    ringj = jnp.asarray(delta_ring)
    tasksj = jnp.asarray(task_ring)
    for nu in range(min(tau, len(tasks)) + 1):
        want = history[len(history) - 1 - nu]
        got = rollback_columns(vj, ringj, tasksj,
                               jnp.asarray(ptr, jnp.int32),
                               jnp.asarray(nu, jnp.int32), tau)
        np.testing.assert_array_equal(np.asarray(got), want)


@settings(max_examples=60, deadline=None)
@given(_event_sequences())
def test_vectorized_rollback_bitwise_matches_serial(seq):
    """rollback_columns_batch (the batch engine's one-scatter path) must be
    indistinguishable from the sequential replay for every reachable nu —
    including nu=0, full-window nu=tau, and wrapped pointers."""
    d, num_tasks, tau, seed, tasks = seq
    v, delta_ring, task_ring, ptr, history = _replay(d, num_tasks, tau,
                                                     seed, tasks)
    vj = jnp.asarray(v)
    ringj = jnp.asarray(delta_ring)
    tasksj = jnp.asarray(task_ring)
    for nu in range(min(tau, len(tasks)) + 1):
        want = history[len(history) - 1 - nu]
        got = rollback_columns_batch(vj, ringj, tasksj,
                                     jnp.asarray(ptr, jnp.int32),
                                     jnp.asarray(nu, jnp.int32), tau)
        np.testing.assert_array_equal(np.asarray(got), want)
