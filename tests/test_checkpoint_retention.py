"""`repro.checkpoint.save(..., keep_last=k)` rotation: long sharded
sessions checkpoint on a cadence and must not grow disk without bound,
while the default behaviour (keep everything) stays bit-identical to the
historical contract."""
import os
import re

import numpy as np
import pytest
import jax.numpy as jnp

from repro import checkpoint


def _tree(step):
    return {"v": jnp.full((3, 2), float(step), jnp.float32),
            "event": jnp.asarray(step, jnp.int32)}


def _steps_on_disk(d):
    return sorted(int(m.group(1)) for f in os.listdir(d)
                  if (m := re.match(r"step_(\d+)\.npz$", f)))


def test_default_keeps_everything(tmp_path):
    d = str(tmp_path)
    for s in range(5):
        checkpoint.save(d, s, _tree(s))
    assert _steps_on_disk(d) == [0, 1, 2, 3, 4]


def test_keep_last_rotates_oldest(tmp_path):
    d = str(tmp_path)
    for s in (10, 20, 30, 40, 50):
        checkpoint.save(d, s, _tree(s), keep_last=3)
    assert _steps_on_disk(d) == [30, 40, 50]
    # the survivors restore intact — rotation deleted files, not data
    got = checkpoint.restore(d, 40, like=_tree(0))
    np.testing.assert_array_equal(np.asarray(got["v"]),
                                  np.asarray(_tree(40)["v"]))
    assert checkpoint.latest_step(d) == 50


def test_keep_last_one_keeps_only_newest(tmp_path):
    d = str(tmp_path)
    for s in range(4):
        checkpoint.save(d, s, _tree(s), keep_last=1)
    assert _steps_on_disk(d) == [3]


def test_keep_last_counts_out_of_order_saves(tmp_path):
    """Rotation ranks by STEP number, not save order: re-saving an old
    step never deletes a newer record — and never deletes ITSELF either,
    so the path `save` returns always exists on return."""
    d = str(tmp_path)
    for s in (5, 9):
        checkpoint.save(d, s, _tree(s), keep_last=2)
    path = checkpoint.save(d, 1, _tree(1), keep_last=2)
    assert os.path.exists(path)
    assert _steps_on_disk(d) == [1, 5, 9]
    # the next in-order save rotates the stale old record out again
    checkpoint.save(d, 12, _tree(12), keep_last=2)
    assert _steps_on_disk(d) == [9, 12]


def test_keep_last_applies_when_enabled_late(tmp_path):
    """A session that starts rotating mid-stream prunes the backlog too."""
    d = str(tmp_path)
    for s in range(6):
        checkpoint.save(d, s, _tree(s))
    checkpoint.save(d, 6, _tree(6), keep_last=2)
    assert _steps_on_disk(d) == [5, 6]


def test_keep_last_ignores_foreign_files(tmp_path):
    d = str(tmp_path)
    (tmp_path / "notes.txt").write_text("keep me")
    (tmp_path / "step_zzz.npz").write_text("not a step record")
    for s in range(3):
        checkpoint.save(d, s, _tree(s), keep_last=1)
    assert _steps_on_disk(d) == [2]
    assert (tmp_path / "notes.txt").exists()
    assert (tmp_path / "step_zzz.npz").exists()


def test_keep_last_rotates_mixed_padding_records(tmp_path):
    """Regression: rotation must remove the FILENAME the regex matched.
    A record written with different zero padding (step_5.npz) parses to
    step 5 but re-formatting it as step_00000005.npz points at a file
    that never existed — the stale record silently survived every
    rotation while counting against the retention window."""
    d = str(tmp_path)
    checkpoint.save(d, 5, _tree(5))
    os.rename(os.path.join(d, "step_00000005.npz"),
              os.path.join(d, "step_5.npz"))
    for s in (6, 7, 8):
        checkpoint.save(d, s, _tree(s), keep_last=2)
    assert _steps_on_disk(d) == [7, 8]
    assert sorted(os.listdir(d)) == ["step_00000007.npz",
                                     "step_00000008.npz"]


def test_keep_last_same_step_other_padding_is_rotatable(tmp_path):
    """A differently-padded duplicate of the step being saved is a stale
    record like any other: only the file `save` just wrote is exempt
    from rotation."""
    d = str(tmp_path)
    checkpoint.save(d, 3, _tree(3))
    os.rename(os.path.join(d, "step_00000003.npz"),
              os.path.join(d, "step_3.npz"))
    path = checkpoint.save(d, 3, _tree(3), keep_last=1)
    assert os.path.exists(path)
    assert os.listdir(d) == ["step_00000003.npz"]


def test_restore_resolves_mixed_padding_record(tmp_path):
    """Regression: `latest_step` parses step_5.npz to 5 but `restore`
    hardcoded step_{step:08d}.npz and raised FileNotFoundError on the
    very step `latest_step` just reported — the
    latest_step -> restore round-trip was broken for any record not
    written with the canonical 8-digit padding."""
    d = str(tmp_path)
    checkpoint.save(d, 5, _tree(5))
    os.rename(os.path.join(d, "step_00000005.npz"),
              os.path.join(d, "step_5.npz"))
    step = checkpoint.latest_step(d)
    assert step == 5
    got = checkpoint.restore(d, step, like=_tree(0))
    np.testing.assert_array_equal(np.asarray(got["v"]),
                                  np.asarray(_tree(5)["v"]))


def test_restore_prefers_padded_name_on_ties(tmp_path):
    """Both step_00000007.npz and step_7.npz present: restore reads the
    canonically padded record (the one `save` writes)."""
    d = str(tmp_path)
    checkpoint.save(d, 7, _tree(7))
    os.rename(os.path.join(d, "step_00000007.npz"),
              os.path.join(d, "step_7.npz"))
    # the padded record is newer and holds different data
    checkpoint.save(d, 7, {"v": jnp.full((3, 2), 99.0, jnp.float32),
                           "event": jnp.asarray(7, jnp.int32)})
    got = checkpoint.restore(d, 7, like=_tree(0))
    np.testing.assert_array_equal(np.asarray(got["v"]),
                                  np.full((3, 2), 99.0, np.float32))


def test_restore_missing_step_names_canonical_file(tmp_path):
    checkpoint.save(str(tmp_path), 1, _tree(1))
    with pytest.raises(FileNotFoundError, match="step_00000009.npz"):
        checkpoint.restore(str(tmp_path), 9, like=_tree(0))


def test_keep_last_validates(tmp_path):
    with pytest.raises(ValueError, match="keep_last must be >= 1"):
        checkpoint.save(str(tmp_path), 0, _tree(0), keep_last=0)
    assert _steps_on_disk(str(tmp_path)) == []


def test_save_sweeps_stale_tmp_litter(tmp_path):
    """Regression: a process that died between np.savez and os.replace
    left its step_*.npz.tmp.npz behind FOREVER — no later save or
    rotation ever removed it.  The next save in the directory sweeps
    matching tmp litter (and only tmp litter: real records and foreign
    files are untouched)."""
    d = str(tmp_path)
    checkpoint.save(d, 1, _tree(1))
    litter = tmp_path / "step_00000099.npz.tmp.npz"
    litter.write_bytes(b"torn half-written record")
    (tmp_path / "notes.tmp").write_text("not checkpoint litter")
    path = checkpoint.save(d, 2, _tree(2))
    assert not litter.exists()
    assert (tmp_path / "notes.tmp").exists()
    assert _steps_on_disk(d) == [1, 2]
    # the new record landed whole despite the sweep
    got = checkpoint.restore(d, 2, like=_tree(0))
    np.testing.assert_array_equal(np.asarray(got["v"]),
                                  np.asarray(_tree(2)["v"]))
    assert os.path.exists(path)
