"""Convergence & operator tests for the AMTL core (Theorem 1, Algorithm 1)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (AMTLConfig, amtl_max_step, amtl_solve, backward,
                        backward_forward, default_config, fista_solve,
                        fixed_point_residual, forward_backward, km_block_update,
                        smtl_solve)


def test_forward_backward_vs_backward_forward_fixed_point(small_problem,
                                                          small_optimum):
    """W* = prox(V*) where V* is a BF fixed point (Sec. III-C)."""
    w_star, _ = small_optimum
    eta = 1.0 / small_problem.lipschitz()
    # v* = w* - eta*grad f(w*) is the BF fixed point mapped from w*.
    v_star = w_star - eta * small_problem.full_grad(w_star)
    assert float(fixed_point_residual(small_problem, v_star, eta)) < 1e-3
    np.testing.assert_allclose(backward(small_problem, v_star, eta), w_star,
                               atol=1e-3)


def test_bf_operator_nonexpansive(small_problem):
    eta = 1.0 / small_problem.lipschitz()
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (small_problem.dim, small_problem.num_tasks))
    b = a + 0.3
    fa = backward_forward(small_problem, a, eta)
    fb = backward_forward(small_problem, b, eta)
    assert float(jnp.linalg.norm(fa - fb)) <= float(jnp.linalg.norm(a - b)) * (1 + 1e-5)


def test_smtl_converges_to_fista_optimum(small_problem, small_optimum):
    _, obj_star = small_optimum
    eta = 1.0 / small_problem.lipschitz()
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    res = smtl_solve(small_problem, w0, eta, 600)
    assert float(res.objectives[-1]) <= float(obj_star) + 1e-2
    # monotone-ish decrease
    assert float(res.objectives[-1]) < float(res.objectives[0])


def test_amtl_converges_theorem1_step(small_problem, small_optimum):
    """AMTL with the Theorem-1 step cap converges to the global optimum."""
    _, obj_star = small_optimum
    cfg = default_config(small_problem, tau=3, c=0.9)
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    res = amtl_solve(small_problem, cfg, w0, jax.random.PRNGKey(0),
                     num_epochs=400)
    assert float(res.objectives[-1]) <= float(obj_star) + 1e-2
    assert float(res.residuals[-1]) < 1e-2


def test_amtl_robust_to_large_staleness(small_problem, small_optimum):
    """Convergence persists under heavy delay (tau=8, offset 4 events)."""
    _, obj_star = small_optimum
    eta = 1.0 / small_problem.lipschitz()
    cfg = AMTLConfig(eta=eta, eta_k=amtl_max_step(8, 5, 0.9), tau=8)
    offsets = jnp.asarray([4.0, 2.0, 0.0, 3.0, 1.0])
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    res = amtl_solve(small_problem, cfg, w0, jax.random.PRNGKey(1),
                     num_epochs=800, delay_offsets=offsets)
    assert float(res.objectives[-1]) <= float(obj_star) + 5e-2


def test_amtl_matches_smtl_solution(small_problem):
    """Unique-solution case: AMTL and SMTL find the same W (Theorem 1)."""
    eta = 1.0 / small_problem.lipschitz()
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    w_sync = smtl_solve(small_problem, w0, eta, 1200).w
    cfg = AMTLConfig(eta=eta, eta_k=0.9, tau=2)
    w_async = amtl_solve(small_problem, cfg, w0, jax.random.PRNGKey(2),
                         num_epochs=600).w
    np.testing.assert_allclose(w_async, w_sync, atol=2e-2)


def test_km_block_update_formula():
    """Eq. III.4 arithmetic."""
    v = jnp.asarray([1.0, 2.0])
    p = jnp.asarray([0.5, 1.0])
    g = jnp.asarray([0.1, 0.2])
    out = km_block_update(v, p, g, jnp.asarray(0.5), jnp.asarray(0.8))
    expect = v + 0.8 * (p - 0.5 * g - v)
    np.testing.assert_allclose(out, expect, rtol=1e-6)


def test_step_size_cap_formula():
    # eta_k <= c / (2 tau / sqrt(T) + 1)
    assert np.isclose(amtl_max_step(4, 16, 0.9), 0.9 / (2 * 4 / 4 + 1))
    with pytest.raises(ValueError):
        amtl_max_step(4, 16, 1.5)


def test_fista_faster_than_ista(small_problem):
    eta = 1.0 / small_problem.lipschitz()
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    ista = smtl_solve(small_problem, w0, eta, 120)
    fista = fista_solve(small_problem, w0, eta, 120)
    assert float(fista.objectives[-1]) <= float(ista.objectives[-1]) + 1e-6


def test_linear_convergence_rate(small_problem, small_optimum):
    """Least-squares + nuclear norm on well-conditioned data: SMTL residuals
    shrink geometrically (linear convergence claim under strong convexity)."""
    _, obj_star = small_optimum
    eta = 1.0 / small_problem.lipschitz()
    w0 = jnp.zeros((small_problem.dim, small_problem.num_tasks), jnp.float32)
    res = smtl_solve(small_problem, w0, eta, 400)
    gaps = np.asarray(res.objectives) - float(obj_star)
    gaps = np.maximum(gaps, 1e-12)
    # Compare the decay over two windows: late window decays at least as a
    # geometric sequence would predict from the early window.
    assert gaps[200] < gaps[50] * 0.2
    # by iter 200+ the gap sits at the float32 noise floor; it may bounce
    # within a few ulps of the optimum, so bound it by the early-window
    # decay instead of demanding monotonicity between noise-floor samples
    assert gaps[399] <= gaps[50] * 0.2
