"""The tp-compute expert path (F-shard partial FFN + psum, chosen when
token bytes << weight-shard bytes) must equal the dense dropless oracle.
Subprocess with 8 fake devices: mesh (data=4, model=2), experts % 2 == 0
but % 8 != 0 => "model" EP mode with d_expert FSDP over data=4."""
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow  # 8-fake-device subprocess; excluded from tier-1

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models.moe import ParallelCtx, init_moe, moe_apply, moe_dense, \
    moe_ep

cfg0 = get_config("dbrx-132b").reduced()
# 4 experts: % model(2) == 0, % chips(8) != 0 -> "model" mode;
# d_expert 128 % data(4) == 0 -> fsdp_gather available
cfg = dataclasses.replace(
    cfg0, moe=dataclasses.replace(cfg0.moe, num_experts=4, top_k=2,
                                  d_expert=128, capacity_factor=8.0))
p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
mesh = jax.make_mesh((4, 2), ("data", "model"))
ctx = ParallelCtx(mesh=mesh, data_axes=("data",), model_axis="model",
                  ep_data_axis="data")

for b, s, label in ((8, 1, "decode-sized (tp-compute)"),
                    (8, 64, "train-sized (weight-gather)")):
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.5
    y_dense, _ = moe_dense(p, x, cfg)
    with mesh:
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
        y_ep, _ = jax.jit(lambda pp, xx: moe_ep(pp, xx, cfg, ctx,
                                                P("data", None, None)))(p, xs)
    err = float(jnp.abs(y_ep - y_dense).max())
    print(label, "maxerr", err)
    assert err < 5e-4, (label, err)
print("OK")
"""


def test_tp_compute_matches_dense():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, env={"PYTHONPATH": "src",
                                       "PATH": "/usr/bin:/bin"},
                       cwd="/root/repo", timeout=600)
    assert r.returncode == 0 and "OK" in r.stdout, (
        r.stdout[-1000:], r.stderr[-3000:])
