"""Per-architecture smoke tests: reduced variant (<=2 scan layers,
d_model<=512, <=4 experts), one forward + one train step on CPU, asserting
output shapes and no NaNs (deliverable (f))."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_config
from repro.launch.shapes import ShapeSpec, concrete_batch
from repro.launch.steps import (default_optimizer, init_train_state,
                                make_train_step)
from repro.models import decode_step, forward, init_params, prefill

B, S = 2, 16
SMOKE = ShapeSpec("smoke", "train", S, B)

# The largest reduced configs (MoE scan stacks, vision tower, hybrid SSM)
# take 10-30s each on CPU even at smoke shapes; tier-1 runs -m "not slow".
SLOW_ARCHS = {"deepseek-v3-671b", "gemma3-12b", "llama-3.2-vision-11b",
              "zamba2-7b", "rwkv6-3b"}


def _arch_params(names):
    return [pytest.param(n, marks=pytest.mark.slow) if n in SLOW_ARCHS
            else n for n in names]


@pytest.fixture(scope="module")
def smoke_cache():
    return {}


def _setup(name, spec=SMOKE):
    cfg = get_config(name).reduced()
    batch = concrete_batch(cfg, spec, jax.random.PRNGKey(1))
    return cfg, batch


@pytest.mark.parametrize("name", _arch_params(ARCH_NAMES))
def test_forward_shapes_and_finite(name):
    cfg, batch = _setup(name)
    params = init_params(jax.random.PRNGKey(0), cfg)
    loss, metrics = forward(params, batch, cfg, remat=False)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{name} loss not finite"
    assert metrics["pooled"].shape == (B, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(metrics["pooled"])))


@pytest.mark.parametrize("name", _arch_params(ARCH_NAMES))
def test_train_step_no_nans(name):
    cfg, batch = _setup(name)
    opt = default_optimizer(cfg)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    step = jax.jit(make_train_step(cfg, opt, remat=False))
    # 4 steps: the Bernoulli activation mask (Assumption 1 thinning) must
    # intersect the batch's task_ids at least once for the head to move
    for i in range(4):
        state, m2 = step(state, batch)
        if i == 0:
            for k, v in m2.items():
                assert bool(jnp.all(jnp.isfinite(v))), \
                    f"{name}: metric {k} not finite"
    assert int(state.step) == 4
    # MTL head actually moved (the paper's technique ran)
    assert float(m2["mtl_v_norm"]) > 0.0
    # params changed
    leaf0 = jax.tree_util.tree_leaves(state.params)[0]
    assert bool(jnp.all(jnp.isfinite(leaf0)))


S32 = 32
SMOKE32 = ShapeSpec("smoke32", "train", S32, B)


@pytest.mark.parametrize("spec,s", [
    pytest.param(SMOKE, S, id="s16"),
    # S=32 keeps decode/forward equivalence covered PAST position 16 —
    # rope/rotary phase, sliding-window, and cache-indexing bugs that only
    # show beyond the first 16 positions land here (coverage the fast
    # smokes dropped when they shrank to S=16).
    pytest.param(SMOKE32, S32, marks=pytest.mark.slow, id="s32"),
])
@pytest.mark.parametrize("name", _arch_params(
    [n for n in ARCH_NAMES if get_config(n).has_decode]))
def test_decode_matches_forward_last_position(name, spec, s):
    """Prefill + decode_step at position s must equal the full forward's
    next-position logits — catches every cache/mask/rope bug."""
    cfg, batch = _setup(name, spec)
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = batch["tokens"]

    # full forward over s+1 tokens
    nxt = jnp.full((B, 1), 7, jnp.int32)
    full = jnp.concatenate([tokens, nxt], axis=1)
    fb = dict(batch)
    fb["tokens"] = full
    fb["targets"] = jnp.roll(full, -1, axis=1)
    logits_p, cache = prefill(params, batch, cfg, s_max=s + 8, remat=False)
    logits_d, _ = decode_step(params, cache, nxt, jnp.asarray(s, jnp.int32),
                              cfg)
    # reference: prefill over the s+1 prompt gives last-position logits
    logits_ref, _ = prefill(params, fb, cfg, s_max=s + 8, remat=False)
    got = np.asarray(logits_d[:, 0], np.float32)
    want = np.asarray(logits_ref[:, 0], np.float32)
    atol = 2e-2 if cfg.moe is None else 1.5e-1   # top-k ties can flip experts
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=atol,
                               err_msg=f"{name} decode != forward at S={s}")


@pytest.mark.parametrize("name", _arch_params(["gemma2-2b", "rwkv6-3b",
                                               "zamba2-7b"]))
def test_multi_step_decode_consistency(name):
    """Decode 4 tokens sequentially == prefill over the extended prompt."""
    cfg, batch = _setup(name)
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = [3, 11, 5, 2]
    logits_p, cache = prefill(params, batch, cfg, s_max=S + 8, remat=False)
    last = None
    for i, t in enumerate(toks):
        tok = jnp.full((B, 1), t, jnp.int32)
        last, cache = decode_step(params, cache, tok,
                                  jnp.asarray(S + i, jnp.int32), cfg)
    ext = jnp.concatenate(
        [batch["tokens"], jnp.tile(jnp.asarray(toks, jnp.int32), (B, 1))],
        axis=1)
    fb = dict(batch)
    fb["tokens"] = ext
    logits_ref, _ = prefill(params, fb, cfg, s_max=S + 8, remat=False)
    np.testing.assert_allclose(np.asarray(last[:, 0], np.float32),
                               np.asarray(logits_ref[:, 0], np.float32),
                               rtol=5e-2, atol=3e-2)


def test_reduced_configs_respect_limits():
    for name in ARCH_NAMES:
        r = get_config(name).reduced()
        assert r.d_model <= 512
        assert r.num_periods <= 1
        if r.moe:
            assert r.moe.num_experts <= 4
