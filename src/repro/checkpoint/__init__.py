from repro.checkpoint.checkpoint import (CheckpointCorruptError, latest_step,
                                         latest_valid_step, record_steps,
                                         restore, save, verify)

__all__ = ["save", "restore", "latest_step", "latest_valid_step",
           "record_steps", "verify", "CheckpointCorruptError"]
