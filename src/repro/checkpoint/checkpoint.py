"""Dependency-free pytree checkpointing (npz per step, path-flattened).

Arrays are pulled to host (fully addressable on this container; on a real
pod each host would write its shard — the layout keeps one file per step
so that extension is local).  Restore rebuilds the exact pytree structure
and re-places leaves with an optional sharding tree.
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import numpy as np

_SEP = "||"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out[key] = np.asarray(leaf)
    return out


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp, path)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any = None) -> Any:
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    data = np.load(path)
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    sh_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None else None)
    for i, (kpath, leaf) in enumerate(flat_like[0]):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kpath)
        arr = data[key]
        if sh_leaves is not None:
            leaves.append(jax.device_put(arr, sh_leaves[i]))
        else:
            leaves.append(jax.numpy.asarray(arr).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(flat_like[1], leaves)
