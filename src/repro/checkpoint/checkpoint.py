"""Dependency-free pytree checkpointing (npz per step, path-flattened).

Arrays are pulled to host (fully addressable on this container; on a real
pod each host would write its shard — the layout keeps one file per step
so that extension is local).  Restore rebuilds the exact pytree structure
and re-places leaves with an optional sharding tree; values round-trip
bitwise.  Both model params and the AMTL engine-session states
(`make_engine(...).init(...)`, any engine, sharded included) go through
here: restore with `like=engine.init(...)` and the next `engine.run`
resumes the event stream bitwise.  A record whose key set, shapes, or
dtypes disagree with `like` fails loudly, naming the drifted entries — a
layout change in a state NamedTuple cannot silently misload a checkpoint.
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional

import jax
import numpy as np

_SEP = "||"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out[key] = np.asarray(leaf)
    return out


def save(ckpt_dir: str, step: int, tree: Any,
         keep_last: Optional[int] = None) -> str:
    """Write `tree` as `step_<step>.npz`; optionally rotate old steps.

    `keep_last=k` deletes `step_*.npz` records beyond the k newest (by
    step number) AFTER the write lands — a failed save never eats
    existing checkpoints, and the record just written is never rotated
    away (so the returned path always exists on return, even when an
    out-of-order re-save of an old step falls outside the retention
    window).  The default (None) keeps everything, unchanged from the
    historical behaviour; long sharded sessions pass k to bound disk
    growth.  Only `step_*.npz` files are ever touched.
    """
    if keep_last is not None and keep_last < 1:
        raise ValueError(f"keep_last must be >= 1 (got {keep_last}); "
                         "use keep_last=None to keep every checkpoint")
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    np.savez(tmp, **_flatten(tree))
    os.replace(tmp, path)
    if keep_last is not None:
        # Rank records by parsed step but delete the FILENAME that
        # matched: a record written with different zero padding (e.g.
        # step_5.npz) still rotates out instead of surviving forever
        # because its re-formatted name step_00000005.npz never existed.
        # The file just written ranks newest among equal steps and is
        # never deleted, so the returned path always exists on return.
        just_written = os.path.basename(path)
        records = sorted(((int(m.group(1)), f) for f in os.listdir(ckpt_dir)
                          if (m := re.match(r"step_(\d+)\.npz$", f))),
                         key=lambda r: (r[0], r[1] == just_written))
        for _, fname in records[:-keep_last]:
            if fname != just_written:
                os.remove(os.path.join(ckpt_dir, fname))
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def _resolve_step_path(ckpt_dir: str, step: int) -> str:
    """The on-disk filename for `step`, whatever its zero padding.

    `latest_step` parses ANY `step_(\\d+).npz` record, so `restore`
    must accept the same set: re-formatting the parsed step as
    `step_{step:08d}.npz` raised FileNotFoundError on a record written
    with different padding (e.g. `step_5.npz`) — a directory the
    rotation path deliberately tolerates.  Prefers the canonically
    padded name on ties (it is the one `save` writes), then the
    lexicographically first match for determinism; a step with no
    record at all resolves to the canonical name so the caller's
    FileNotFoundError names the expected file.
    """
    padded = f"step_{step:08d}.npz"
    if os.path.isdir(ckpt_dir):
        matches = sorted(
            f for f in os.listdir(ckpt_dir)
            if (m := re.match(r"step_(\d+)\.npz$", f))
            and int(m.group(1)) == step)
        if matches and padded not in matches:
            return os.path.join(ckpt_dir, matches[0])
    return os.path.join(ckpt_dir, padded)


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any = None) -> Any:
    path = _resolve_step_path(ckpt_dir, step)
    data = np.load(path)
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    want_keys = [_SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in kpath)
                 for kpath, _ in flat_like[0]]
    missing = [k for k in want_keys if k not in data]
    extra = sorted(set(data.files) - set(want_keys))
    if missing or extra:
        raise ValueError(
            f"checkpoint {path} does not match the `like` pytree layout: "
            f"missing keys {missing}, unexpected keys {extra} — was the "
            "state's structure changed since this checkpoint was saved?")
    leaves = []
    sh_leaves = (jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: hasattr(x, "spec"))
        if shardings is not None else None)
    for i, ((kpath, leaf), key) in enumerate(zip(flat_like[0], want_keys)):
        arr = data[key]
        if arr.shape != tuple(getattr(leaf, "shape", arr.shape)):
            raise ValueError(
                f"checkpoint {path}: leaf {key!r} has shape {arr.shape} "
                f"but `like` expects {leaf.shape}")
        want_dtype = getattr(leaf, "dtype", None)
        if want_dtype is not None and arr.dtype != want_dtype:
            raise ValueError(
                f"checkpoint {path}: leaf {key!r} has dtype {arr.dtype} "
                f"but `like` expects {want_dtype} — dtype drift would "
                "silently change the resumed computation")
        if sh_leaves is not None:
            leaves.append(jax.device_put(arr, sh_leaves[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(flat_like[1], leaves)
