"""Dependency-free pytree checkpointing (npz per step, path-flattened).

Arrays are pulled to host (fully addressable on this container; on a real
pod each host would write its shard — the layout keeps one file per step
so that extension is local).  Restore rebuilds the exact pytree structure
and re-places leaves with an optional sharding tree; values round-trip
bitwise.  Both model params and the AMTL engine-session states
(`make_engine(...).init(...)`, any engine, sharded included) go through
here: restore with `like=engine.init(...)` and the next `engine.run`
resumes the event stream bitwise.  A record whose key set, shapes, or
dtypes disagree with `like` fails loudly, naming the drifted entries — a
layout change in a state NamedTuple cannot silently misload a checkpoint.

Integrity: `save` embeds a per-leaf CRC32 manifest under the reserved
`__manifest__` key and fsyncs the record before the `os.replace`, so a
record either lands whole or not at all.  `verify` checks one record
against its manifest without rebuilding the pytree; `restore` runs the
same check and raises `CheckpointCorruptError` (naming the damaged
leaves) instead of surfacing an opaque zip error; `latest_valid_step`
walks records newest-first and returns the newest one that verifies —
a torn write or bit rot on the newest record costs at most one
checkpoint interval, never the session.  Records written before the
manifest existed still `restore` (no CRC cover) but fail `verify`.
"""
from __future__ import annotations

import json
import os
import re
import zlib
from typing import Any, Optional

import jax
import numpy as np

_SEP = "||"
MANIFEST_KEY = "__manifest__"
_TMP_RE = re.compile(r"step_\d+\.npz\.tmp\.npz$")
_STEP_RE = re.compile(r"step_(\d+)\.npz$")


class CheckpointCorruptError(RuntimeError):
    """A checkpoint record failed integrity verification.

    `path` is the offending record; `damaged` lists the flattened leaf
    keys whose bytes disagree with the manifest (empty when the record
    is unreadable as a whole — torn zip, missing manifest).
    """

    def __init__(self, path: str, damaged: list[str], detail: str):
        self.path = path
        self.damaged = list(damaged)
        suffix = f" (damaged leaves: {self.damaged})" if self.damaged else ""
        super().__init__(f"corrupt checkpoint {path}: {detail}{suffix}")


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out[key] = np.asarray(leaf)
    return out


def _manifest_array(flat: dict[str, np.ndarray]) -> np.ndarray:
    crcs = {k: zlib.crc32(np.ascontiguousarray(v).tobytes())
            for k, v in flat.items()}
    blob = json.dumps(crcs, sort_keys=True).encode("utf-8")
    return np.frombuffer(blob, dtype=np.uint8)


def _sweep_tmp_litter(ckpt_dir: str, keep: str) -> None:
    # A process that died between np.savez and os.replace leaves its
    # step_*.npz.tmp.npz behind forever; the next save in the same
    # directory sweeps it.  Saves within one directory are serialized
    # by the callers (the server checkpoints under its state lock), so
    # the only matching tmp file not ours is litter.
    for fname in os.listdir(ckpt_dir):
        if _TMP_RE.match(fname) and fname != keep:
            try:
                os.remove(os.path.join(ckpt_dir, fname))
            except OSError:
                pass  # racing sweeper or permissions: litter, not data


def save(ckpt_dir: str, step: int, tree: Any,
         keep_last: Optional[int] = None) -> str:
    """Write `tree` as `step_<step>.npz`; optionally rotate old steps.

    The record embeds a per-leaf CRC32 manifest (`__manifest__`) and is
    flushed + fsynced before the atomic `os.replace`, so a crash at any
    point leaves either the previous record set or the new one — never
    a half-written `step_*.npz`.  Stale `step_*.npz.tmp.npz` litter from
    an earlier crash is swept first.

    `keep_last=k` deletes `step_*.npz` records beyond the k newest (by
    step number) AFTER the write lands — a failed save never eats
    existing checkpoints, and the record just written is never rotated
    away (so the returned path always exists on return, even when an
    out-of-order re-save of an old step falls outside the retention
    window).  The default (None) keeps everything, unchanged from the
    historical behaviour; long sharded sessions pass k to bound disk
    growth.  Only `step_*.npz` files are ever touched.
    """
    if keep_last is not None and keep_last < 1:
        raise ValueError(f"keep_last must be >= 1 (got {keep_last}); "
                         "use keep_last=None to keep every checkpoint")
    os.makedirs(ckpt_dir, exist_ok=True)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    tmp = path + ".tmp.npz"
    _sweep_tmp_litter(ckpt_dir, keep=os.path.basename(tmp))
    flat = _flatten(tree)
    payload = dict(flat)
    payload[MANIFEST_KEY] = _manifest_array(flat)
    with open(tmp, "wb") as f:
        np.savez(f, **payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    if keep_last is not None:
        # Rank records by parsed step but delete the FILENAME that
        # matched: a record written with different zero padding (e.g.
        # step_5.npz) still rotates out instead of surviving forever
        # because its re-formatted name step_00000005.npz never existed.
        # The file just written ranks newest among equal steps and is
        # never deleted, so the returned path always exists on return.
        just_written = os.path.basename(path)
        records = sorted(((int(m.group(1)), f) for f in os.listdir(ckpt_dir)
                          if (m := _STEP_RE.match(f))),
                         key=lambda r: (r[0], r[1] == just_written))
        for _, fname in records[:-keep_last]:
            if fname != just_written:
                os.remove(os.path.join(ckpt_dir, fname))
    return path


def record_steps(ckpt_dir: str) -> list[int]:
    """Distinct recorded steps, newest first ([] for no/absent dir)."""
    if not os.path.isdir(ckpt_dir):
        return []
    steps = {int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := _STEP_RE.match(f))}
    return sorted(steps, reverse=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = record_steps(ckpt_dir)
    return steps[0] if steps else None


def verify(path: str) -> dict[str, int]:
    """Check one record's per-leaf CRC32 manifest without unflattening.

    Returns the verified manifest (flat leaf key -> CRC32).  Raises
    `CheckpointCorruptError` when the record is unreadable (torn zip),
    carries no manifest (pre-manifest record or truncated write), names
    leaves absent from the manifest or vice versa, or any leaf's bytes
    disagree with its recorded CRC.  FileNotFoundError passes through
    untouched — a missing record is not a corrupt one.
    """
    try:
        with np.load(path) as data:
            if MANIFEST_KEY not in data.files:
                raise CheckpointCorruptError(
                    path, [], "record carries no integrity manifest "
                    "(pre-manifest save or truncated write)")
            manifest = json.loads(bytes(data[MANIFEST_KEY]).decode("utf-8"))
            keys = [k for k in data.files if k != MANIFEST_KEY]
            drifted = (sorted(set(keys) - set(manifest))
                       + sorted(set(manifest) - set(keys)))
            if drifted:
                raise CheckpointCorruptError(
                    path, drifted, "leaf set disagrees with the manifest")
            damaged = []
            for key in keys:
                try:
                    arr = data[key]
                    ok = (zlib.crc32(np.ascontiguousarray(arr).tobytes())
                          == manifest[key])
                except Exception:  # zip's own CRC / truncation mid-entry
                    ok = False
                if not ok:
                    damaged.append(key)
            if damaged:
                raise CheckpointCorruptError(
                    path, damaged, "leaf bytes fail their CRC32")
            return manifest
    except (CheckpointCorruptError, FileNotFoundError):
        raise
    except Exception as e:  # bad zip, json rot, short central directory
        raise CheckpointCorruptError(path, [], f"unreadable record: {e!r}")


def latest_valid_step(ckpt_dir: str,
                      like: Any = None) -> Optional[int]:
    """Newest step whose record verifies; None when no record does.

    Walks records newest-first, skipping any that fail `verify` (torn
    write, bit rot, missing manifest).  With `like`, a record whose
    manifest key set disagrees with `like`'s flattened layout is also
    skipped — a foreign record can't be mistaken for a resumable one.
    """
    want = set(_flatten(like)) if like is not None else None
    for step in record_steps(ckpt_dir):
        try:
            manifest = verify(_resolve_step_path(ckpt_dir, step))
        except (CheckpointCorruptError, FileNotFoundError):
            continue
        if want is not None and set(manifest) != want:
            continue
        return step
    return None


def _resolve_step_path(ckpt_dir: str, step: int) -> str:
    """The on-disk filename for `step`, whatever its zero padding.

    `latest_step` parses ANY `step_(\\d+).npz` record, so `restore`
    must accept the same set: re-formatting the parsed step as
    `step_{step:08d}.npz` raised FileNotFoundError on a record written
    with different padding (e.g. `step_5.npz`) — a directory the
    rotation path deliberately tolerates.  Prefers the canonically
    padded name on ties (it is the one `save` writes), then the
    lexicographically first match for determinism; a step with no
    record at all resolves to the canonical name so the caller's
    FileNotFoundError names the expected file.
    """
    padded = f"step_{step:08d}.npz"
    if os.path.isdir(ckpt_dir):
        matches = sorted(
            f for f in os.listdir(ckpt_dir)
            if (m := _STEP_RE.match(f))
            and int(m.group(1)) == step)
        if matches and padded not in matches:
            return os.path.join(ckpt_dir, matches[0])
    return os.path.join(ckpt_dir, padded)


def restore(ckpt_dir: str, step: int, like: Any,
            shardings: Any = None) -> Any:
    path = _resolve_step_path(ckpt_dir, step)
    try:
        data = np.load(path)
    except FileNotFoundError:
        raise
    except Exception as e:
        raise CheckpointCorruptError(path, [], f"unreadable record: {e!r}")
    with data:
        manifest = None
        if MANIFEST_KEY in data.files:
            try:
                manifest = json.loads(
                    bytes(data[MANIFEST_KEY]).decode("utf-8"))
            except Exception as e:
                raise CheckpointCorruptError(
                    path, [MANIFEST_KEY], f"unreadable manifest: {e!r}")
        flat_like = jax.tree_util.tree_flatten_with_path(like)
        want_keys = [_SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                               for k in kpath)
                     for kpath, _ in flat_like[0]]
        missing = [k for k in want_keys if k not in data]
        extra = sorted(set(data.files) - set(want_keys) - {MANIFEST_KEY})
        if missing or extra:
            raise ValueError(
                f"checkpoint {path} does not match the `like` pytree layout: "
                f"missing keys {missing}, unexpected keys {extra} — was the "
                "state's structure changed since this checkpoint was saved?")
        leaves = []
        sh_leaves = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
            if shardings is not None else None)
        damaged = []
        for i, ((kpath, leaf), key) in enumerate(zip(flat_like[0],
                                                     want_keys)):
            try:
                arr = data[key]
            except Exception:  # zip-level CRC failure / truncated entry
                damaged.append(key)
                continue
            if manifest is not None and (
                    key not in manifest
                    or zlib.crc32(np.ascontiguousarray(arr).tobytes())
                    != manifest[key]):
                damaged.append(key)
                continue
            if arr.shape != tuple(getattr(leaf, "shape", arr.shape)):
                raise ValueError(
                    f"checkpoint {path}: leaf {key!r} has shape {arr.shape} "
                    f"but `like` expects {leaf.shape}")
            want_dtype = getattr(leaf, "dtype", None)
            if want_dtype is not None and arr.dtype != want_dtype:
                raise ValueError(
                    f"checkpoint {path}: leaf {key!r} has dtype {arr.dtype} "
                    f"but `like` expects {want_dtype} — dtype drift would "
                    "silently change the resumed computation")
            if sh_leaves is not None:
                leaves.append(jax.device_put(arr, sh_leaves[i]))
            else:
                leaves.append(jax.numpy.asarray(arr))
        if damaged:
            raise CheckpointCorruptError(
                path, damaged, "leaf bytes fail their CRC32")
    return jax.tree_util.tree_unflatten(flat_like[1], leaves)
