"""Generic multi-family transformer assembly.

A model is a sequence of scan groups; each group is a repeating period of
block kinds (DESIGN.md §4's layer patterns), scanned with stacked params
and per-layer remat.  The same machinery expresses all 10 assigned
architectures:

    deepseek : [(attn,)x3] + [(moe,)x58]            (MLA everywhere, MTP)
    gemma2   : [(local, global) x 13]
    gemma3   : [(local x5, global) x 8]
    zamba2   : [(mamba x5, shared_attn) x 13] + [(mamba,)x3]
    llama-v  : [(attn x4, cross) x 8]
    rwkv6    : [(rwkv,) x 32]           ... etc.

Three entry points per model: `forward` (train / full-sequence),
`prefill` (forward + cache materialization), `decode_step` (one token).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, BlockKind
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import KVCache, MLACache
from repro.models.layers import (apply_ffn, apply_norm, dense_init,
                                 embed_init, init_ffn, init_norm, softcap)
from repro.models.moe import ParallelCtx
from repro.models.rwkv import RWKVState
from repro.models.ssm import SSMState

Array = jax.Array

LORA_RANK = 64   # Zamba2 shared-attention per-invocation LoRA rank


class ScanGroup(NamedTuple):
    period: tuple[BlockKind, ...]
    n: int


def scan_groups(cfg: ArchConfig) -> list[ScanGroup]:
    groups: list[ScanGroup] = []
    for blocks in (cfg.head_blocks,):
        if blocks:
            if len(set(blocks)) == 1:
                groups.append(ScanGroup((blocks[0],), len(blocks)))
            else:
                groups.append(ScanGroup(tuple(blocks), 1))
    if cfg.num_periods:
        groups.append(ScanGroup(tuple(cfg.period), cfg.num_periods))
    if cfg.tail_blocks:
        if len(set(cfg.tail_blocks)) == 1:
            groups.append(ScanGroup((cfg.tail_blocks[0],),
                                    len(cfg.tail_blocks)))
        else:
            groups.append(ScanGroup(tuple(cfg.tail_blocks), 1))
    return groups


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key: Array, kind: BlockKind, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    if kind in ("attn", "local", "global", "moe", "cross"):
        p = {"norm1": init_norm(cfg.norm, d, dtype)}
        if cfg.mla is not None:
            p["attn"] = attn_lib.init_mla(ks[0], cfg, dtype)
        else:
            p["attn"] = attn_lib.init_attn(ks[0], cfg, dtype)
        p["norm2"] = init_norm(cfg.norm, d, dtype)
        if kind == "moe":
            p["moe"] = moe_lib.init_moe(ks[1], cfg, dtype)
        else:
            p["ffn"] = init_ffn(ks[1], d, cfg.d_ff, cfg.activation, dtype)
        if kind == "cross":
            p["norm_x"] = init_norm(cfg.norm, d, dtype)
            p["xattn"] = attn_lib.init_cross_attn(ks[2], cfg, dtype)
        return p
    if kind == "mamba":
        return {"norm1": init_norm(cfg.norm, d, dtype),
                "mamba": ssm_lib.init_mamba(ks[0], cfg, dtype)}
    if kind == "rwkv":
        return {"norm1": init_norm(cfg.norm, d, dtype),
                "norm2": init_norm(cfg.norm, d, dtype),
                "rwkv": rwkv_lib.init_rwkv(ks[0], cfg, dtype)}
    if kind == "shared_attn":
        # per-invocation params only: LoRA deltas on wq / wo + norms
        h, hd = cfg.num_heads, cfg.head_dim
        return {"norm1": init_norm(cfg.norm, d, dtype),
                "norm2": init_norm(cfg.norm, d, dtype),
                "lora_q_a": dense_init(ks[0], (d, LORA_RANK), dtype),
                "lora_q_b": dense_init(ks[1], (LORA_RANK, h * hd), dtype,
                                       scale=0.0),
                "lora_o_a": dense_init(ks[2], (h * hd, LORA_RANK), dtype),
                "lora_o_b": dense_init(ks[3], (LORA_RANK, d), dtype,
                                       scale=0.0)}
    raise ValueError(kind)


def init_params(key: Array, cfg: ArchConfig) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {}
    if cfg.feature_dim:
        params["feat_proj"] = dense_init(keys[0], (cfg.feature_dim,
                                                   cfg.d_model), dtype)
        params["mask_emb"] = jnp.zeros((cfg.d_model,), dtype)
    params["embed"] = embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[1], (cfg.d_model,
                                                 cfg.vocab_size), dtype)
    params["final_norm"] = init_norm(cfg.norm, cfg.d_model, dtype)

    if any(k == "shared_attn" for k in cfg.layer_kinds):
        sk = jax.random.split(keys[2], 2)
        params["shared_attn"] = {
            "attn": attn_lib.init_attn(sk[0], cfg, dtype),
            "ffn": init_ffn(sk[1], cfg.d_model, cfg.d_ff, cfg.activation,
                            dtype),
        }

    for gi, group in enumerate(scan_groups(cfg)):
        gkeys = jax.random.split(keys[3 + gi % 5], group.n)

        def init_period(k):
            pks = jax.random.split(k, len(group.period))
            return {f"b{i}": _init_block(pks[i], kind, cfg, dtype)
                    for i, kind in enumerate(group.period)}

        params[f"group{gi}"] = jax.vmap(init_period)(gkeys)

    if cfg.mtp:
        mk = jax.random.split(keys[7], 2)
        params["mtp"] = {
            "proj": dense_init(mk[0], (2 * cfg.d_model, cfg.d_model), dtype),
            "block": _init_block(mk[1], "attn", cfg, dtype),
            "norm": init_norm(cfg.norm, cfg.d_model, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# full-sequence block application (train / prefill)
# ---------------------------------------------------------------------------

class Extras(NamedTuple):
    vision_embeds: Optional[Array] = None
    shared_attn: Optional[dict] = None
    moe_token_spec: Optional[Any] = None


def _attn_flavor(p, x, cfg, kind, *, return_cache=False, cache_len=None,
                 ctx=None):
    window = cfg.sliding_window if kind == "local" else None
    theta = cfg.rope_theta
    if cfg.mla is not None:
        return attn_lib.mla_forward(p, x, cfg, ctx=ctx,
                                    return_cache=return_cache,
                                    cache_len=cache_len)
    return attn_lib.attn_forward(p, x, cfg, window=window, theta=theta,
                                 return_cache=return_cache,
                                 cache_len=cache_len, ctx=ctx)


def _apply_shared_attn(p: dict, shared: dict, x: Array, cfg: ArchConfig,
                       *, return_cache=False, cache_len=None):
    """Weight-tied attention with per-invocation LoRA on wq / wo."""
    sp = dict(shared["attn"])
    dt = x.dtype
    sp["wq"] = sp["wq"] + (p["lora_q_a"] @ p["lora_q_b"]).astype(sp["wq"].dtype)
    sp["wo"] = sp["wo"] + (p["lora_o_a"] @ p["lora_o_b"]).astype(sp["wo"].dtype)
    del dt
    return attn_lib.attn_forward(sp, x, cfg, window=None,
                                 return_cache=return_cache,
                                 cache_len=cache_len)


def apply_block(kind: BlockKind, p: dict, x: Array, cfg: ArchConfig,
                ctx: ParallelCtx, extras: Extras) -> tuple[Array, Array]:
    """Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "local", "global", "moe", "cross"):
        h = apply_norm(cfg.norm, p["norm1"], x)
        x = x + _attn_flavor(p["attn"], h, cfg, kind, ctx=ctx)
        if kind == "cross":
            h = apply_norm(cfg.norm, p["norm_x"], x)
            x = x + attn_lib.cross_attn_forward(p["xattn"], h,
                                                extras.vision_embeds, cfg)
        h = apply_norm(cfg.norm, p["norm2"], x)
        if kind == "moe":
            y, aux = moe_lib.moe_apply(p["moe"], h, cfg, ctx,
                                       extras.moe_token_spec)
            x = x + y
        else:
            x = x + apply_ffn(p["ffn"], h, cfg.activation)
        return x, aux
    if kind == "mamba":
        h = apply_norm(cfg.norm, p["norm1"], x)
        return x + ssm_lib.mamba_forward(p["mamba"], h, cfg), aux
    if kind == "rwkv":
        h = apply_norm(cfg.norm, p["norm1"], x)
        x = x + rwkv_lib.rwkv_time_mix(p["rwkv"], h, cfg)
        h = apply_norm(cfg.norm, p["norm2"], x)
        x = x + rwkv_lib.rwkv_channel_mix(p["rwkv"], h)
        return x, aux
    if kind == "shared_attn":
        h = apply_norm(cfg.norm, p["norm1"], x)
        x = x + _apply_shared_attn(p, extras.shared_attn, h, cfg)
        h = apply_norm(cfg.norm, p["norm2"], x)
        x = x + apply_ffn(extras.shared_attn["ffn"], h, cfg.activation)
        return x, aux
    raise ValueError(kind)


REMAT_POLICIES = {
    "full": None,   # save only the layer boundary, recompute everything
    "dots": "dots_saveable",
    "dots_no_batch": "dots_with_no_batch_dims_saveable",
}


def _remat_wrap(body, remat):
    """remat: False | True ('full') | policy name in REMAT_POLICIES."""
    if remat is False or remat is None:
        return body
    name = "full" if remat is True else remat
    pol = REMAT_POLICIES[name]
    if pol is None:
        return jax.checkpoint(body)
    return jax.checkpoint(body, policy=getattr(jax.checkpoint_policies,
                                               pol))


def backbone_forward(params: dict, x: Array, cfg: ArchConfig,
                     ctx: ParallelCtx, extras: Extras,
                     remat: bool | str = True,
                     unroll: bool | int = 1) -> tuple[Array, Array]:
    """Run all scan groups.  x: (B, S, D) embedded input.

    remat: False, True (full per-layer recompute) or a REMAT_POLICIES name
    — 'dots' saves matmul outputs so the backward pass does not replay the
    forward collectives (MoE all_to_alls) or the attention inner loop, at
    the price of more live activation memory (EXPERIMENTS.md §Perf).

    unroll: passed to lax.scan.  The dry-run lowers with unroll=True because
    XLA's cost_analysis counts a while-loop body ONCE (not x trip count), so
    rooflines from a scanned module would undercount flops/bytes/collectives
    by ~num_layers (verified; see EXPERIMENTS.md §Dry-run).
    """
    aux = jnp.zeros((), jnp.float32)
    groups = scan_groups(cfg)
    for gi, group in enumerate(groups):
        stacked = params[f"group{gi}"]

        def body(carry, layer_params, _group=group):
            xx, aa = carry
            for i, kind in enumerate(_group.period):
                xx, a = apply_block(kind, layer_params[f"b{i}"], xx, cfg,
                                    ctx, extras)
                aa = aa + a
            return (xx, aa), None

        body = _remat_wrap(body, remat)
        (x, aux), _ = jax.lax.scan(body, (x, aux), stacked, unroll=unroll)
    return x, aux


# ---------------------------------------------------------------------------
# embedding / heads
# ---------------------------------------------------------------------------

def embed_tokens(params: dict, tokens: Array, cfg: ArchConfig) -> Array:
    e = params["embed"]
    x = e[tokens]
    if cfg.tie_embeddings:   # gemma-style scaling
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def embed_audio(params: dict, features: Array, mask: Array,
                cfg: ArchConfig) -> Array:
    """features: (B, S, feat); mask: (B, S) — masked-prediction input."""
    x = features.astype(params["feat_proj"].dtype) @ params["feat_proj"]
    m = params["mask_emb"].astype(x.dtype)
    return jnp.where(mask[..., None], m[None, None], x)


def lm_logits(params: dict, h: Array, cfg: ArchConfig) -> Array:
    h = apply_norm(cfg.norm, params["final_norm"], h)
    if cfg.tie_embeddings:
        logits = h @ params["embed"].astype(h.dtype).T
    else:
        logits = h @ params["unembed"].astype(h.dtype)
    return softcap(logits.astype(jnp.float32), cfg.logit_softcap)


def cross_entropy(logits: Array, targets: Array,
                  weights: Optional[Array] = None) -> Array:
    """Mean CE over weighted positions.  logits fp32 (B, S, V)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None],
                               axis=-1)[..., 0]
    nll = lse - gold
    if weights is None:
        return jnp.mean(nll)
    w = weights.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


# ---------------------------------------------------------------------------
# public forward (training)
# ---------------------------------------------------------------------------

def forward(params: dict, batch: dict, cfg: ArchConfig,
            ctx: ParallelCtx = ParallelCtx(), remat: bool = True,
            moe_token_spec=None, unroll: bool | int = 1):
    """Training forward.  Returns (loss, metrics dict incl. 'pooled')."""
    extras = Extras(vision_embeds=batch.get("vision_embeds"),
                    shared_attn=params.get("shared_attn"),
                    moe_token_spec=moe_token_spec)
    if cfg.family == "audio":
        x = embed_audio(params, batch["features"], batch["mask"], cfg)
    else:
        x = embed_tokens(params, batch["tokens"], cfg)
    h, aux = backbone_forward(params, x, cfg, ctx, extras, remat,
                              unroll=unroll)
    logits = lm_logits(params, h, cfg)
    if cfg.family == "audio":
        loss = cross_entropy(logits, batch["targets"],
                             weights=batch["mask"])
    else:
        loss = cross_entropy(logits, batch["targets"])

    metrics = {"lm_loss": loss, "aux_loss": aux}
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_weight * aux
    if cfg.mtp and "mtp" in params:
        mtp_loss = _mtp_loss(params, h, batch, cfg, ctx, extras)
        metrics["mtp_loss"] = mtp_loss
        loss = loss + cfg.mtp_weight * mtp_loss
    # mean-pooled hidden state for the MTL probe heads (paper integration)
    pooled = jnp.mean(h.astype(jnp.float32), axis=1)
    metrics["pooled"] = pooled
    return loss, metrics


def _mtp_loss(params: dict, h: Array, batch: dict, cfg: ArchConfig,
              ctx: ParallelCtx, extras: Extras) -> Array:
    """DeepSeek multi-token prediction (depth 1, simplified): combine h_t
    with emb(token_{t+1}) and predict target_{t+1} (= token t+2)."""
    mp = params["mtp"]
    tokens, targets = batch["tokens"], batch["targets"]
    emb_next = embed_tokens(params, tokens[:, 1:], cfg)        # (B,S-1,D)
    hh = jnp.concatenate([h[:, :-1].astype(emb_next.dtype), emb_next],
                         axis=-1)
    hh = hh @ mp["proj"].astype(hh.dtype)
    hh, _ = apply_block("attn", mp["block"], hh, cfg, ctx, extras)
    hh = apply_norm(cfg.norm, mp["norm"], hh)
    if cfg.tie_embeddings:
        logits = hh @ params["embed"].astype(hh.dtype).T
    else:
        logits = hh @ params["unembed"].astype(hh.dtype)
    return cross_entropy(softcap(logits.astype(jnp.float32),
                                 cfg.logit_softcap), targets[:, 1:])
