"""Mamba2 block (SSD — state-space duality, chunked) for Zamba2.

Chunked SSD algorithm (Dao & Gu 2024) in pure jnp: within-chunk interactions
are masked matmuls (MXU-friendly), across-chunk state is a short `lax.scan`
over L/chunk steps carrying h in (H, P, N).  Decode is the O(1) recurrent
step on (conv_state, ssm_state).  TPU adaptation note (DESIGN.md §3): the
CUDA kernel's warp-level scan becomes chunk matmuls sized for the MXU
(chunk=128) — same math, hardware-native blocking.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import apply_norm, dense_init, init_norm

Array = jax.Array


class SSMState(NamedTuple):
    conv: Array   # (B, conv_width-1, conv_dim) rolling conv inputs
    ssm: Array    # (B, H, P, N) recurrent state


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.state_dim
    return d_inner, n_heads, conv_dim


def init_mamba(key: Array, cfg: ArchConfig, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, n_heads, conv_dim = _dims(cfg)
    ks = jax.random.split(key, 5)
    # in_proj emits [z (gate), x, B, C, dt]
    out_dim = d_inner + conv_dim + n_heads
    p = {
        "in_proj": dense_init(ks[0], (d, out_dim), dtype),
        "conv_w": dense_init(ks[1], (s.conv_width, conv_dim), dtype,
                             scale=1.0 / s.conv_width),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((n_heads,), jnp.float32),          # A = -exp(A_log)
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": init_norm("rmsnorm", d_inner, dtype),
        "out_proj": dense_init(ks[2], (d_inner, d), dtype),
    }
    return p


def _split_proj(cfg: ArchConfig, proj: Array):
    s = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    gn = s.n_groups * s.state_dim
    z, xbc, dt = jnp.split(proj, [d_inner, d_inner + conv_dim], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over (B, L, C) with window len(w)."""
    kw = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (kw - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * w[i] for i in range(kw))
    return jax.nn.silu(out + b)


def _ssd_chunked(x: Array, dt: Array, a_log: Array, b_mat: Array,
                 c_mat: Array, d_skip: Array, chunk: int,
                 h0: Array | None = None):
    """SSD scan.  x: (B,L,H,P); dt: (B,L,H); b,c: (B,L,G,N).

    Returns y (B,L,H,P) and final state (B,H,P,N).
    """
    bsz, ell0, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    q = min(chunk, ell0)
    pad = (-ell0) % q
    if pad:   # neutral padding: dt=0 => decay exp(0)=1 and zero input
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    ell = ell0 + pad
    nc = ell // q

    a = -jnp.exp(a_log)                                    # (H,)
    dta = dt * a                                           # (B,L,H) log-decay
    xb = x * dt[..., None]                                 # discretized input

    # reshape into chunks
    r = lambda t: t.reshape(bsz, nc, q, *t.shape[2:])
    xc, dtac = r(xb), r(dta)
    bc = jnp.repeat(r(b_mat), rep, axis=3)                 # (B,nc,Q,H,N)
    cc = jnp.repeat(r(c_mat), rep, axis=3)

    la = jnp.cumsum(dtac, axis=2)                          # (B,nc,Q,H)
    # within-chunk: att[s,t] = exp(la_s - la_t) * (C_s . B_t), s >= t
    seg = la[:, :, :, None, :] - la[:, :, None, :, :]      # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("bcshn,bcthn->bcsth", cc.astype(jnp.float32),
                        bc.astype(jnp.float32))            # (B,nc,Q,Q,H)
    y_diag = jnp.einsum("bcsth,bcsth,bcthp->bcshp",
                        scores, decay, xc.astype(jnp.float32))

    # chunk states: sum_t exp(la_last - la_t) B_t x_t
    last = la[:, :, -1:, :]                                # (B,nc,1,H)
    w_t = jnp.exp(last - la)                               # (B,nc,Q,H)
    states = jnp.einsum("bcthn,bcth,bcthp->bchpn",
                        bc.astype(jnp.float32), w_t,
                        xc.astype(jnp.float32))            # (B,nc,H,P,N)
    chunk_decay = jnp.exp(last[:, :, 0])                   # (B,nc,H)

    def scan_fn(h_prev, inp):
        st, dec = inp                                      # (B,H,P,N),(B,H)
        h_new = h_prev * dec[:, :, None, None] + st
        return h_new, h_prev

    init = (jnp.zeros((bsz, h, p, n), jnp.float32) if h0 is None
            else h0.astype(jnp.float32))
    h_last, h_prevs = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)             # (B,nc,H,P,N)

    # inter-chunk: y_s += exp(la_s) C_s . h_prev
    y_inter = jnp.einsum("bcshn,bcsh,bchpn->bcshp",
                         cc.astype(jnp.float32), jnp.exp(la), h_prevs)
    y = (y_diag + y_inter).reshape(bsz, ell, h, p)
    y = y + d_skip[None, None, :, None] * x.astype(jnp.float32)
    return y[:, :ell0].astype(x.dtype), h_last


def mamba_forward(p: dict, x: Array, cfg: ArchConfig, *,
                  return_state: bool = False):
    """Training/prefill forward.  x: (B, L, D)."""
    s = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    bsz, ell, _ = x.shape
    proj = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = _split_proj(cfg, proj)
    xbc = _causal_conv(xbc, p["conv_w"].astype(x.dtype),
                       p["conv_b"].astype(x.dtype))
    xi, b_mat, c_mat = jnp.split(
        xbc, [d_inner, d_inner + s.n_groups * s.state_dim], axis=-1)
    xi = xi.reshape(bsz, ell, n_heads, s.head_dim)
    b_mat = b_mat.reshape(bsz, ell, s.n_groups, s.state_dim)
    c_mat = c_mat.reshape(bsz, ell, s.n_groups, s.state_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    y, h_last = _ssd_chunked(xi, dt, p["A_log"], b_mat, c_mat, p["D"],
                             s.chunk)
    y = y.reshape(bsz, ell, d_inner)
    y = apply_norm("rmsnorm", p["norm"], y * jax.nn.silu(z))
    out = y @ p["out_proj"].astype(x.dtype)
    if not return_state:
        return out
    kw = s.conv_width - 1
    _, xbc_raw, _ = _split_proj(cfg, proj)       # pre-conv inputs
    conv_state = xbc_raw[:, -kw:] if ell >= kw else jnp.pad(
        xbc_raw, ((0, 0), (kw - ell, 0), (0, 0)))
    return out, SSMState(conv=conv_state, ssm=h_last.astype(jnp.float32))


def mamba_decode(p: dict, x: Array, state: SSMState, cfg: ArchConfig):
    """Single-token recurrent step.  x: (B, 1, D)."""
    s = cfg.ssm
    d_inner, n_heads, conv_dim = _dims(cfg)
    bsz = x.shape[0]
    proj = x @ p["in_proj"].astype(x.dtype)                # (B,1,out)
    z, xbc_new, dt = _split_proj(cfg, proj)

    window = jnp.concatenate([state.conv.astype(x.dtype), xbc_new], axis=1)
    w = p["conv_w"].astype(x.dtype)
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"].astype(x.dtype)
    xbc = jax.nn.silu(conv_out)[:, None]                   # (B,1,conv_dim)
    new_conv = window[:, 1:]

    xi, b_mat, c_mat = jnp.split(
        xbc, [d_inner, d_inner + s.n_groups * s.state_dim], axis=-1)
    xi = xi.reshape(bsz, n_heads, s.head_dim)
    rep = n_heads // s.n_groups
    b_mat = jnp.repeat(b_mat.reshape(bsz, s.n_groups, s.state_dim), rep, 1)
    c_mat = jnp.repeat(c_mat.reshape(bsz, s.n_groups, s.state_dim), rep, 1)
    dt1 = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt1 * a)                                 # (B,H)
    xb = xi.astype(jnp.float32) * dt1[..., None]
    h = (state.ssm * dec[:, :, None, None]
         + xb[:, :, :, None] * b_mat.astype(jnp.float32)[:, :, None, :])
    y = jnp.einsum("bhpn,bhn->bhp", h, c_mat.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xi.astype(jnp.float32)
    y = y.reshape(bsz, 1, d_inner).astype(x.dtype)
    y = apply_norm("rmsnorm", p["norm"], y * jax.nn.silu(z))
    out = y @ p["out_proj"].astype(x.dtype)
    return out, SSMState(conv=new_conv, ssm=h)
