"""Prefill & single-token decode across all families.

Cache layout mirrors the scan-group structure: for each group, a pytree of
per-period-position caches stacked over the group's repeat count, carried
through `lax.scan` as xs/ys.  Cache kinds:

  attn/global/moe/cross : KVCache (B, S_max, Hkv, hd)   [+ static CrossKV]
  local                 : KVCache ring buffer (B, window, Hkv, hd)
  MLA archs             : MLACache (B, S_max, kv_lora) + (B, S_max, rope)
  mamba                 : SSMState — O(1) in S_max (the long_500k win)
  rwkv                  : RWKVState — O(1) in S_max
  shared_attn           : KVCache per invocation

`pos` is a scalar int32: batched serving with aligned positions
(per-sequence positions are a straightforward extension, DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, BlockKind
from repro.models import attention as attn_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import KVCache, MLACache
from repro.models.layers import apply_ffn, apply_norm
from repro.models.moe import ParallelCtx, moe_apply
from repro.models.rwkv import RWKVState
from repro.models.ssm import SSMState
from repro.models.transformer import (Extras, _apply_shared_attn,
                                      _attn_flavor, apply_block,
                                      embed_tokens, lm_logits, scan_groups)

Array = jax.Array


class CrossKV(NamedTuple):
    k: Array
    v: Array


def _cache_len(kind: BlockKind, cfg: ArchConfig, s_max: int) -> int:
    if kind == "local" and cfg.sliding_window:
        return min(cfg.sliding_window, s_max)
    return s_max


def init_cache(cfg: ArchConfig, batch: int, s_max: int, dtype=None) -> dict:
    """Zero-initialized cache pytree (used by decode-only dry runs)."""
    dtype = jnp.dtype(cfg.dtype) if dtype is None else dtype
    cache: dict[str, Any] = {}
    for gi, group in enumerate(scan_groups(cfg)):
        entry = {}
        for i, kind in enumerate(group.period):
            entry[f"b{i}"] = _init_block_cache(kind, cfg, batch, s_max,
                                               group.n, dtype)
        cache[f"group{gi}"] = entry
    return cache


def _init_block_cache(kind: BlockKind, cfg: ArchConfig, b: int, s_max: int,
                      n: int, dtype):
    cl = _cache_len(kind, cfg, s_max)
    if kind in ("attn", "local", "global", "moe", "cross", "shared_attn"):
        if cfg.mla is not None:
            m = cfg.mla
            base = MLACache(
                c_kv=jnp.zeros((n, b, cl, m.kv_lora_rank), dtype),
                k_rope=jnp.zeros((n, b, cl, m.qk_rope_head_dim), dtype))
        else:
            hkv, hd = cfg.num_kv_heads, cfg.head_dim
            if cfg.kv_cache_dtype == "int8":
                base = KVCache(
                    k=jnp.zeros((n, b, cl, hkv, hd), jnp.int8),
                    v=jnp.zeros((n, b, cl, hkv, hd), jnp.int8),
                    k_scale=jnp.zeros((n, b, cl, hkv), jnp.float32),
                    v_scale=jnp.zeros((n, b, cl, hkv), jnp.float32))
            else:
                base = KVCache(k=jnp.zeros((n, b, cl, hkv, hd), dtype),
                               v=jnp.zeros((n, b, cl, hkv, hd), dtype))
        if kind == "cross":
            hkv, hd = cfg.num_kv_heads, cfg.head_dim
            xkv = CrossKV(
                k=jnp.zeros((n, b, cfg.vision_seq, hkv, hd), dtype),
                v=jnp.zeros((n, b, cfg.vision_seq, hkv, hd), dtype))
            return {"self": base, "cross": xkv}
        return base
    if kind == "mamba":
        d_inner, n_heads, conv_dim = ssm_lib._dims(cfg)
        s = cfg.ssm
        return SSMState(
            conv=jnp.zeros((n, b, s.conv_width - 1, conv_dim), dtype),
            ssm=jnp.zeros((n, b, n_heads, s.head_dim, s.state_dim),
                          jnp.float32))
    if kind == "rwkv":
        h = cfg.d_model // cfg.rwkv.head_size
        return RWKVState(
            x_prev_att=jnp.zeros((n, b, cfg.d_model), dtype),
            x_prev_ffn=jnp.zeros((n, b, cfg.d_model), dtype),
            wkv=jnp.zeros((n, b, h, cfg.rwkv.head_size, cfg.rwkv.head_size),
                          jnp.float32))
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def _prefill_block(kind: BlockKind, p: dict, x: Array, cfg: ArchConfig,
                   ctx: ParallelCtx, extras: Extras, s_max: int):
    """Full-seq forward that also materializes the block's cache."""
    cl = _cache_len(kind, cfg, s_max)
    if kind in ("attn", "local", "global", "moe", "cross"):
        h = apply_norm(cfg.norm, p["norm1"], x)
        out, cache = _attn_flavor(p["attn"], h, cfg, kind,
                                  return_cache=True, cache_len=cl, ctx=ctx)
        x = x + out
        if kind == "cross":
            h = apply_norm(cfg.norm, p["norm_x"], x)
            x = x + attn_lib.cross_attn_forward(p["xattn"], h,
                                                extras.vision_embeds, cfg)
            xkv = _cross_kv(p["xattn"], extras.vision_embeds, cfg, x.dtype)
            cache = {"self": cache, "cross": xkv}
        h = apply_norm(cfg.norm, p["norm2"], x)
        if kind == "moe":
            y, _ = moe_apply(p["moe"], h, cfg, ctx, extras.moe_token_spec)
            x = x + y
        else:
            x = x + apply_ffn(p["ffn"], h, cfg.activation)
        return x, cache
    if kind == "mamba":
        h = apply_norm(cfg.norm, p["norm1"], x)
        out, state = ssm_lib.mamba_forward(p["mamba"], h, cfg,
                                           return_state=True)
        return x + out, state
    if kind == "rwkv":
        h = apply_norm(cfg.norm, p["norm1"], x)
        out, wkv, x_att = rwkv_lib.rwkv_time_mix(p["rwkv"], h, cfg,
                                                 return_state=True)
        x = x + out
        h = apply_norm(cfg.norm, p["norm2"], x)
        out, x_ffn = rwkv_lib.rwkv_channel_mix(p["rwkv"], h,
                                               return_state=True)
        return x + out, RWKVState(x_att, x_ffn, wkv)
    if kind == "shared_attn":
        h = apply_norm(cfg.norm, p["norm1"], x)
        out, cache = _apply_shared_attn(p, extras.shared_attn, h, cfg,
                                        return_cache=True, cache_len=cl)
        x = x + out
        h = apply_norm(cfg.norm, p["norm2"], x)
        x = x + apply_ffn(extras.shared_attn["ffn"], h, cfg.activation)
        return x, cache
    raise ValueError(kind)


def _cross_kv(p: dict, kv_src: Array, cfg: ArchConfig, dtype) -> CrossKV:
    b, sv, _ = kv_src.shape
    hkv, hd = cfg.num_kv_heads, cfg.head_dim
    k = (kv_src.astype(dtype) @ p["wk"].astype(dtype)).reshape(b, sv, hkv, hd)
    v = (kv_src.astype(dtype) @ p["wv"].astype(dtype)).reshape(b, sv, hkv, hd)
    return CrossKV(k=k, v=v)


def prefill(params: dict, batch: dict, cfg: ArchConfig,
            ctx: ParallelCtx = ParallelCtx(), s_max: Optional[int] = None,
            remat: bool = True, moe_token_spec=None,
            unroll: bool | int = 1):
    """Run the prompt; returns (last-position logits, cache)."""
    extras = Extras(vision_embeds=batch.get("vision_embeds"),
                    shared_attn=params.get("shared_attn"),
                    moe_token_spec=moe_token_spec)
    if cfg.family == "audio":      # encoder inference: no masking, no cache
        from repro.models.transformer import embed_audio
        feats = batch["features"]
        mask = batch.get("mask", jnp.zeros(feats.shape[:2], bool))
        x = embed_audio(params, feats, mask, cfg)
        s_max = s_max if s_max is not None else feats.shape[1]
    else:
        tokens = batch["tokens"]
        s_max = s_max if s_max is not None else tokens.shape[1]
        x = embed_tokens(params, tokens, cfg)
    cache: dict[str, Any] = {}
    for gi, group in enumerate(scan_groups(cfg)):
        stacked = params[f"group{gi}"]

        def body(xx, layer_params, _group=group):
            caches = {}
            for i, kind in enumerate(_group.period):
                xx, c = _prefill_block(kind, layer_params[f"b{i}"], xx, cfg,
                                       ctx, extras, s_max)
                caches[f"b{i}"] = c
            return xx, caches

        if remat:
            body = jax.checkpoint(body)
        x, group_cache = jax.lax.scan(body, x, stacked, unroll=unroll)
        cache[f"group{gi}"] = group_cache
    logits = lm_logits(params, x[:, -1:], cfg)
    return logits, cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _decode_block(kind: BlockKind, p: dict, x: Array, cache, pos: Array,
                  cfg: ArchConfig, ctx: ParallelCtx, extras: Extras):
    if kind in ("attn", "local", "global", "moe", "cross"):
        self_cache = cache["self"] if kind == "cross" else cache
        window = cfg.sliding_window if kind == "local" else None
        h = apply_norm(cfg.norm, p["norm1"], x)
        if cfg.mla is not None:
            if ctx.mesh is not None:
                out, new_cache = attn_lib.mla_decode_sharded(
                    p["attn"], h, self_cache, pos, cfg, ctx)
            else:
                out, new_cache = attn_lib.mla_decode(p["attn"], h,
                                                     self_cache, pos, cfg)
        elif ctx.mesh is not None:
            out, new_cache = attn_lib.attn_decode_sharded(
                p["attn"], h, self_cache, pos, cfg, ctx, window=window)
        else:
            out, new_cache = attn_lib.attn_decode(p["attn"], h, self_cache,
                                                  pos, cfg, window=window)
        x = x + out
        if kind == "cross":
            h = apply_norm(cfg.norm, p["norm_x"], x)
            x = x + _cross_decode(p["xattn"], h, cache["cross"], cfg)
            new_cache = {"self": new_cache, "cross": cache["cross"]}
        h = apply_norm(cfg.norm, p["norm2"], x)
        if kind == "moe":
            y, _ = moe_apply(p["moe"], h, cfg, ctx, extras.moe_token_spec)
            x = x + y
        else:
            x = x + apply_ffn(p["ffn"], h, cfg.activation)
        return x, new_cache
    if kind == "mamba":
        h = apply_norm(cfg.norm, p["norm1"], x)
        out, state = ssm_lib.mamba_decode(p["mamba"], h, cache, cfg)
        return x + out, state
    if kind == "rwkv":
        h = apply_norm(cfg.norm, p["norm1"], x)
        out, wkv, x_att = rwkv_lib.rwkv_decode_time_mix(p["rwkv"], h, cache,
                                                        cfg)
        x = x + out
        h = apply_norm(cfg.norm, p["norm2"], x)
        out, x_ffn = rwkv_lib.rwkv_channel_mix(
            p["rwkv"], h, x_prev=cache.x_prev_ffn, return_state=True)
        return x + out, RWKVState(x_att, x_ffn, wkv)
    if kind == "shared_attn":
        sp = dict(extras.shared_attn["attn"])
        sp["wq"] = sp["wq"] + (p["lora_q_a"] @ p["lora_q_b"]).astype(
            sp["wq"].dtype)
        sp["wo"] = sp["wo"] + (p["lora_o_a"] @ p["lora_o_b"]).astype(
            sp["wo"].dtype)
        h = apply_norm(cfg.norm, p["norm1"], x)
        if ctx.mesh is not None:
            out, new_cache = attn_lib.attn_decode_sharded(sp, h, cache, pos,
                                                          cfg, ctx)
        else:
            out, new_cache = attn_lib.attn_decode(sp, h, cache, pos, cfg)
        x = x + out
        h = apply_norm(cfg.norm, p["norm2"], x)
        x = x + apply_ffn(extras.shared_attn["ffn"], h, cfg.activation)
        return x, new_cache
    raise ValueError(kind)


def _cross_decode(p: dict, x: Array, xkv: CrossKV, cfg: ArchConfig) -> Array:
    b = x.shape[0]
    h, hd = cfg.num_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, 1, h, hd)
    out = attn_lib.mha(q, xkv.k.astype(x.dtype), xkv.v.astype(x.dtype),
                       causal=False)
    out = out.reshape(b, 1, -1) @ p["wo"].astype(x.dtype)
    return jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype) * out


def decode_step(params: dict, cache: dict, token: Array, pos: Array,
                cfg: ArchConfig, ctx: ParallelCtx = ParallelCtx(),
                moe_token_spec=None, unroll: bool | int = 1):
    """One decode step.  token: (B, 1) int32; pos: scalar int32.
    Returns (logits (B, 1, V) fp32, new_cache)."""
    extras = Extras(shared_attn=params.get("shared_attn"),
                    moe_token_spec=moe_token_spec)
    x = embed_tokens(params, token, cfg)
    new_cache: dict[str, Any] = {}
    for gi, group in enumerate(scan_groups(cfg)):
        stacked = params[f"group{gi}"]
        gcache = cache[f"group{gi}"]

        def body(xx, scanned, _group=group):
            layer_params, layer_cache = scanned
            new_caches = {}
            for i, kind in enumerate(_group.period):
                xx, c = _decode_block(kind, layer_params[f"b{i}"], xx,
                                      layer_cache[f"b{i}"], pos, cfg, ctx,
                                      extras)
                new_caches[f"b{i}"] = c
            return xx, new_caches

        x, group_cache = jax.lax.scan(body, x, (stacked, gcache),
                                      unroll=unroll)
        new_cache[f"group{gi}"] = group_cache
    logits = lm_logits(params, x, cfg)
    return logits, new_cache
