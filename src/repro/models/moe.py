"""Mixture-of-Experts FFN with expert-parallel all_to_all dispatch.

Two execution paths sharing identical routing/capacity semantics:

* `dense` — dropless reference: every expert runs on every token, combined
  with the top-k mask.  Exact; used for smoke tests / single-host runs and
  as the oracle the EP path is tested against.

* `ep` (shard_map) — production path, two sharding modes:

  - full-EP (DeepSeek: 256 experts on a 16x16 pod slice): experts spread
    over ('data','model'); tokens are capacity-dispatched into per-chip
    buffers and exchanged with one `all_to_all` spanning both axes — the
    paper's server<->node star topology reincarnated as an ICI collective.
    Across pods, experts are replicated and gradients sync over the DCN
    'pod' axis.

  - model-EP + FSDP gather (DBRX: 16 experts, 16-wide model axis): the
    expert dim shards over 'model' and the expert FFN dim over 'data'
    (ZeRO-3 style); each chip all-gathers its resident experts' FFN shards
    over 'data' just-in-time, and the token all_to_all stays inside the
    'model' axis (zero cross-row token traffic).

Capacity-based dropping (cf=1.25 default): tokens over per-expert capacity
fall back to the shared-expert/residual path only.  The router is softmax
top-k with a Switch-style load-balance auxiliary loss (separable per task
shard => AMTL-compatible, DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard_map_compat
from repro.models.layers import activate, dense_init, is_gated

Array = jax.Array


class ParallelCtx(NamedTuple):
    """Mesh context threaded through model apply fns."""
    mesh: Optional[jax.sharding.Mesh] = None
    data_axes: tuple[str, ...] = ("data",)   # batch axes (may include 'pod')
    model_axis: str = "model"
    ep_data_axis: str = "data"               # intra-pod data axis for EP

    @property
    def axis_sizes(self) -> dict[str, int]:
        if self.mesh is None:
            return {}
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

    def model_size(self) -> int:
        return self.axis_sizes.get(self.model_axis, 1)

    def ep_data_size(self) -> int:
        return self.axis_sizes.get(self.ep_data_axis, 1)


def moe_mode(cfg: ArchConfig, ctx: ParallelCtx) -> str:
    """'full' (experts over data+model) or 'model' (model-EP, FSDP over data)."""
    e = cfg.moe.num_experts
    n_full = ctx.ep_data_size() * ctx.model_size()
    if e % n_full == 0:
        return "full"
    if e % ctx.model_size() == 0:
        return "model"
    raise ValueError(f"{e} experts incompatible with mesh {ctx.axis_sizes}")


def init_moe(key: Array, cfg: ArchConfig, dtype) -> dict:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_expert, m.num_experts
    ks = jax.random.split(key, 7)
    p = {"router": dense_init(ks[0], (d, e), jnp.float32),
         "w_in": dense_init(ks[1], (e, d, f), dtype),
         "w_out": dense_init(ks[2], (e, f, d), dtype)}
    if is_gated(cfg.activation):
        p["w_gate"] = dense_init(ks[3], (e, d, f), dtype)
    if m.num_shared:
        fs = f * m.num_shared
        p["shared_in"] = dense_init(ks[4], (d, fs), dtype)
        p["shared_out"] = dense_init(ks[5], (fs, d), dtype)
        if is_gated(cfg.activation):
            p["shared_gate"] = dense_init(ks[6], (d, fs), dtype)
    return p


def _router(router_w: Array, x2: Array, m) -> tuple[Array, Array, Array]:
    """x2: (N, D) -> (topk weights (N,k), topk idx (N,k), aux loss)."""
    logits = x2.astype(jnp.float32) @ router_w.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                   # (N, E)
    w, idx = jax.lax.top_k(probs, m.top_k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)                # renormalize
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    e = probs.shape[-1]
    occupancy = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, e, dtype=jnp.float32), axis=1), axis=0)
    importance = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(occupancy * importance)
    return w.astype(x2.dtype), idx, aux


def _expert_ffn(x: Array, w_in: Array, w_out: Array,
                w_gate: Optional[Array], activation: str) -> Array:
    """x: (E, C, D) tokens grouped per expert; weights (E, D, F)/(E, F, D)."""
    up = jnp.einsum("ecd,edf->ecf", x, w_in.astype(x.dtype))
    gate = (jnp.einsum("ecd,edf->ecf", x, w_gate.astype(x.dtype))
            if w_gate is not None else None)
    h = activate(activation, up, gate)
    return jnp.einsum("ecf,efd->ecd", h, w_out.astype(x.dtype))


def _shared_expert(sh_in: Array, sh_out: Array, sh_gate: Optional[Array],
                   x: Array, activation: str) -> Array:
    up = x @ sh_in.astype(x.dtype)
    gate = x @ sh_gate.astype(x.dtype) if sh_gate is not None else None
    h = activate(activation, up, gate)
    return h @ sh_out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense (dropless oracle) path
# ---------------------------------------------------------------------------

def moe_dense(p: dict, x: Array, cfg: ArchConfig) -> tuple[Array, Array]:
    """x: (B, S, D).  Every expert runs on every token (smoke/oracle)."""
    m = cfg.moe
    b, s, d = x.shape
    x2 = x.reshape(b * s, d)
    w, idx, aux = _router(p["router"], x2, m)
    gates = jnp.zeros((b * s, m.num_experts), x.dtype)
    gates = jax.vmap(lambda g, i, ww: g.at[i].set(ww))(gates, idx, w)
    all_out = _expert_ffn(jnp.broadcast_to(x2[None], (m.num_experts, b * s, d)),
                          p["w_in"], p["w_out"], p.get("w_gate"),
                          cfg.activation)                     # (E, N, D)
    y = jnp.einsum("ne,end->nd", gates, all_out)
    if m.num_shared:
        y = y + _shared_expert(p["shared_in"], p["shared_out"],
                               p.get("shared_gate"), x2, cfg.activation)
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# capacity dispatch helpers
# ---------------------------------------------------------------------------

def _capacity(n_tokens: int, top_k: int, n_dest: int, cf: float) -> int:
    cap = int(math.ceil(n_tokens * top_k / n_dest * cf))
    return max(cap, 1)


def _dispatch_indices(dest: Array, n_dest: int, cap: int):
    """Slot assignment with capacity dropping.  dest: (N*k,) chip ids.
    Returns (slot (N*k,), keep (N*k,))."""
    onehot = jax.nn.one_hot(dest, n_dest, dtype=jnp.int32)    # (N*k, n_dest)
    ranks = jnp.cumsum(onehot, axis=0) - 1
    slot = jnp.take_along_axis(ranks, dest[:, None], axis=1)[:, 0]
    keep = slot < cap
    return slot, keep


# ---------------------------------------------------------------------------
# expert-parallel shard_map path
# ---------------------------------------------------------------------------

def moe_ep(p: dict, x: Array, cfg: ArchConfig, ctx: ParallelCtx,
           token_spec: P) -> tuple[Array, Array]:
    """Expert-parallel MoE via all_to_all.  x: (B, S, D) global view."""
    m = cfg.moe
    mesh = ctx.mesh
    sizes = ctx.axis_sizes
    msize = ctx.model_size()
    dsize = ctx.ep_data_size()
    mode = moe_mode(cfg, ctx)

    if mode == "full":
        ep_axes: tuple[str, ...] = (ctx.ep_data_axis, ctx.model_axis)
        n_chips = dsize * msize
        expert_spec = P((ctx.ep_data_axis, ctx.model_axis), None, None)
        fsdp_gather = False
    else:  # model-EP, FFN dim FSDP'd over data, gathered just-in-time
        ep_axes = (ctx.model_axis,)
        n_chips = msize
        fsdp_gather = dsize > 1 and m.d_expert % dsize == 0
        expert_spec = (P(ctx.model_axis, None, ctx.ep_data_axis)
                       if fsdp_gather else P(ctx.model_axis, None, None))
    e_loc = m.num_experts // n_chips
    out_fsdp_spec = (P(ctx.model_axis, ctx.ep_data_axis, None)
                     if (mode == "model" and fsdp_gather)
                     else expert_spec)

    has_gate = "w_gate" in p
    has_shared = bool(m.num_shared)
    has_shared_gate = "shared_gate" in p
    # shared expert: keep the model-axis TP sharding INSIDE the kernel
    # (partial FFN + psum) — a replicated in_spec would make GSPMD
    # all-gather the shared weights on every layer (57 ms/step of pure
    # weight gather on deepseek decode; EXPERIMENTS.md §Perf).
    fs = m.d_expert * m.num_shared if has_shared else 0
    # TP-psum is only valid when every model shard sees the SAME tokens —
    # with seq-sharded dispatch (train) each shard holds different tokens
    # and the partial-sum would mix them; fall back to the weight gather.
    toks_model_sharded = any(
        ctx.model_axis in ((e,) if not isinstance(e, tuple) else e)
        for e in (token_spec or ()) if e)
    shared_tp = (has_shared and msize > 1 and fs % msize == 0
                 and not toks_model_sharded)
    sh_in_spec = P(None, ctx.model_axis) if shared_tp else P(None, None)
    sh_out_spec = P(ctx.model_axis, None) if shared_tp else P(None, None)

    in_specs = [expert_spec, out_fsdp_spec, P(None, None), token_spec]
    args = [p["w_in"], p["w_out"], p["router"], x]
    if has_gate:
        in_specs.insert(1, expert_spec)
        args.insert(1, p["w_gate"])
    if has_shared:
        in_specs += [sh_in_spec, sh_out_spec]
        args += [p["shared_in"], p["shared_out"]]
        if has_shared_gate:
            in_specs.append(sh_in_spec)
            args.append(p["shared_gate"])

    def kernel(*ops):
        it = iter(ops)
        w_in = next(it)
        w_gate = next(it) if has_gate else None
        w_out = next(it)
        router = next(it)
        x_loc = next(it)
        sh_in = next(it) if has_shared else None
        sh_out = next(it) if has_shared else None
        sh_gate = next(it) if has_shared_gate else None

        bl, sl, d = x_loc.shape
        n_loc = bl * sl
        x2 = x_loc.reshape(n_loc, d)
        w, idx, aux = _router(router, x2, m)

        dest = (idx // e_loc).reshape(-1)                   # (N*k,) chip ids
        cap = _capacity(n_loc, m.top_k, n_chips, m.capacity_factor)

        # With FFN-dim-FSDP'd experts there are two ways to apply an
        # expert (EXPERIMENTS.md §Perf, dbrx decode):
        #   gather-weights: all_gather the (E_loc, D, F) shards, compute
        #     locally — right when the token batch outweighs the weights
        #     (training);
        #   tp-compute: keep the F-shard, compute the partial FFN, psum
        #     the (tokens, D) output over the data axis — right when the
        #     tokens are tiny (decode: ~3 MB of activations vs ~400 MB of
        #     gathered dbrx expert weights per layer).
        tp_compute = False
        if fsdp_gather:
            # tp-compute ships ~3x the data-gathered token set; weight
            # gather materializes the FULL (E_loc, D, F) weights on every
            # device — compare against that result, not the shard
            tok_bytes = n_chips * cap * d * 2
            wfull_bytes = (e_loc * d * m.d_expert
                           * (3 if has_gate else 2) * 2)
            tp_compute = 3 * dsize * tok_bytes < wfull_bytes
        if fsdp_gather and not tp_compute:  # ZeRO-3 weight gather
            w_in = jax.lax.all_gather(w_in, ctx.ep_data_axis, axis=2,
                                      tiled=True)
            if w_gate is not None:
                w_gate = jax.lax.all_gather(w_gate, ctx.ep_data_axis, axis=2,
                                            tiled=True)
            w_out = jax.lax.all_gather(w_out, ctx.ep_data_axis, axis=1,
                                       tiled=True)
        slot, keep = _dispatch_indices(dest, n_chips, cap)
        tok_idx = jnp.repeat(jnp.arange(n_loc), m.top_k)
        safe_slot = jnp.where(keep, slot, cap - 1)

        send = jnp.zeros((n_chips, cap, d), x2.dtype)
        send = send.at[dest, safe_slot].add(
            jnp.where(keep[:, None], x2[tok_idx], 0.0))
        local_eid = (idx % e_loc).reshape(-1)
        eid_send = jnp.zeros((n_chips, cap), jnp.int32)
        eid_send = eid_send.at[dest, safe_slot].max(
            jnp.where(keep, local_eid, 0))

        recv = jax.lax.all_to_all(send, ep_axes, split_axis=0,
                                  concat_axis=0, tiled=True)
        eid_recv = jax.lax.all_to_all(
            eid_send[..., None].astype(jnp.float32), ep_axes,
            split_axis=0, concat_axis=0, tiled=True)[..., 0]
        recv2 = recv.reshape(n_chips * cap, d)
        eid2 = eid_recv.reshape(n_chips * cap).astype(jnp.int32)

        if e_loc > 1:
            sel = jax.nn.one_hot(eid2, e_loc, dtype=recv2.dtype)
            grouped = jnp.einsum("md,me->emd", recv2, sel)
        else:
            grouped = recv2[None]
        if tp_compute:
            # tokens differ per data row in "model" mode: gather the
            # union over data, compute the F-shard partial FFN on it,
            # psum, then slice back this row's tokens.
            g2 = jax.lax.all_gather(grouped, ctx.ep_data_axis, axis=1,
                                    tiled=True)     # (E, dsize*M, D)
            o2 = _expert_ffn(g2, w_in, w_out, w_gate, cfg.activation)
            o2 = jax.lax.psum(o2, ctx.ep_data_axis)
            mstart = jax.lax.axis_index(ctx.ep_data_axis) \
                * grouped.shape[1]
            out_g = jax.lax.dynamic_slice_in_dim(o2, mstart,
                                                 grouped.shape[1], axis=1)
        else:
            out_g = _expert_ffn(grouped, w_in, w_out, w_gate,
                                cfg.activation)
        back2 = (jnp.einsum("emd,me->md", out_g, sel) if e_loc > 1
                 else out_g[0])

        ret = jax.lax.all_to_all(back2.reshape(n_chips, cap, d), ep_axes,
                                 split_axis=0, concat_axis=0, tiled=True)
        flat = ret.reshape(n_chips * cap, d)
        lin = dest * cap + safe_slot
        contrib = jnp.where(keep[:, None], flat[lin], 0.0)
        y = jnp.zeros((n_loc, d), x2.dtype)
        y = y.at[tok_idx].add(contrib * w.reshape(-1)[:, None])
        if has_shared:
            y_sh = _shared_expert(sh_in, sh_out, sh_gate, x2,
                                  cfg.activation)
            if shared_tp:   # partial over the F cut -> full output
                y_sh = jax.lax.psum(y_sh, ctx.model_axis)
            y = y + y_sh
        aux = jax.lax.pmean(aux, tuple(mesh.axis_names))
        return y.reshape(bl, sl, d), aux

    y, aux = shard_map_compat(kernel, mesh=mesh, in_specs=tuple(in_specs),
                           out_specs=(token_spec, P()), check_vma=False)(*args)
    return y, aux


def moe_apply(p: dict, x: Array, cfg: ArchConfig, ctx: ParallelCtx,
              token_spec: Optional[P] = None) -> tuple[Array, Array]:
    """Dispatch to dense oracle (no mesh) or EP shard_map path."""
    if ctx.mesh is None:
        return moe_dense(p, x, cfg)
    return moe_ep(p, x, cfg, ctx, token_spec if token_spec is not None
                  else P(ctx.data_axes, None, None))
