"""Model substrate: layers, attention, MoE, SSM, RWKV, transformer assembly."""
from repro.models.moe import ParallelCtx
from repro.models.transformer import forward, init_params, scan_groups
from repro.models.serving import decode_step, init_cache, prefill

__all__ = ["ParallelCtx", "forward", "init_params", "scan_groups",
           "decode_step", "init_cache", "prefill"]
