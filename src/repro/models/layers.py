"""Shared layer primitives: norms, activations, RoPE, embeddings, dense FFN.

Params are plain nested dicts of jnp arrays (no flax); init fns return the
dict, apply fns are pure.  Compute dtype follows the input; params are cast
at the call site by `astype` on the matmul operand so fp32 master / bf16
compute policies compose.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


# ----------------------------------------------------------------- init ----

def dense_init(key: Array, shape: tuple[int, ...], dtype,
               scale: float | None = None) -> Array:
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    if scale is None:
        scale = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key: Array, vocab: int, dim: int, dtype) -> Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32)
            / math.sqrt(dim)).astype(dtype)


# ----------------------------------------------------------------- norms ---

def init_norm(kind: str, dim: int, dtype) -> dict:
    p = {"scale": jnp.ones((dim,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def apply_norm(kind: str, p: dict, x: Array, eps: float = 1e-6) -> Array:
    x32 = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
        out = x32 * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    elif kind == "layernorm":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        out = ((x32 - mu) * jax.lax.rsqrt(var + eps)
               * p["scale"].astype(jnp.float32)
               + p["bias"].astype(jnp.float32))
    else:
        raise ValueError(kind)
    return out.astype(x.dtype)


# ------------------------------------------------------------ activations --

def activate(name: str, up: Array, gate: Optional[Array]) -> Array:
    if name == "swiglu":
        return jax.nn.silu(gate) * up
    if name == "geglu":
        return jax.nn.gelu(gate) * up
    if name == "relu2":
        r = jax.nn.relu(up)
        return r * r
    if name == "gelu":
        return jax.nn.gelu(up)
    raise ValueError(name)


def is_gated(name: str) -> bool:
    return name in ("swiglu", "geglu")


# ----------------------------------------------------------------- FFN -----

def init_ffn(key: Array, d_model: int, d_ff: int, activation: str,
             dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], (d_model, d_ff), dtype),
         "w_out": dense_init(ks[1], (d_ff, d_model), dtype)}
    if is_gated(activation):
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype)
    return p


def apply_ffn(p: dict, x: Array, activation: str) -> Array:
    up = x @ p["w_in"].astype(x.dtype)
    gate = x @ p["w_gate"].astype(x.dtype) if "w_gate" in p else None
    h = activate(activation, up, gate)
    return h @ p["w_out"].astype(x.dtype)


# ----------------------------------------------------------------- RoPE ----

def rope_frequencies(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, hd), positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                    # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------- softcap ----

def softcap(x: Array, cap: Optional[float]) -> Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)
