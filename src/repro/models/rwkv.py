"""RWKV-6 (Finch) block: time-mix with data-dependent decay + channel-mix.

The WKV recurrence is computed in chunked (matmul) form for training and
prefill — the TPU adaptation of the CUDA wkv6 kernel: per-channel decays
are carried in log-space cumulative sums within a chunk, intra-chunk
interactions become two MXU matmuls, and a short `lax.scan` carries the
(H, D, D) state across chunks.  Decode is the exact O(1) recurrence.

Simplification vs. the full Finch ddlerp (DESIGN.md §4): static per-channel
token-shift mixing coefficients for r/k/v/g, LoRA data-dependence on the
decay w only (the part the paper highlights as "data-dependent decay").
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import apply_norm, dense_init, init_norm

Array = jax.Array


class RWKVState(NamedTuple):
    x_prev_att: Array   # (B, D) previous token (time-mix shift)
    x_prev_ffn: Array   # (B, D) previous token (channel-mix shift)
    wkv: Array          # (B, H, D_head, D_head) fp32 state


def init_rwkv(key: Array, cfg: ArchConfig, dtype) -> dict:
    r = cfg.rwkv
    d = cfg.d_model
    h = d // r.head_size
    ks = jax.random.split(key, 10)
    return {
        "mix": 0.5 * jnp.ones((5, d), dtype),        # r,k,v,w,g shift mixes
        "wr": dense_init(ks[0], (d, d), dtype),
        "wk": dense_init(ks[1], (d, d), dtype),
        "wv": dense_init(ks[2], (d, d), dtype),
        "wg": dense_init(ks[3], (d, d), dtype),
        "wo": dense_init(ks[4], (d, d), dtype),
        "w0": -6.0 * jnp.ones((d,), jnp.float32),    # base decay (large)
        "w_lora_a": dense_init(ks[5], (d, r.decay_lora), dtype),
        "w_lora_b": dense_init(ks[6], (r.decay_lora, d), dtype, scale=0.01),
        "u": jnp.zeros((h, r.head_size), jnp.float32),   # bonus
        "ln_x": init_norm("layernorm", d, dtype),    # per-head group norm
        # channel mix
        "mix_ffn": 0.5 * jnp.ones((d,), dtype),
        "ck": dense_init(ks[7], (d, cfg.d_ff), dtype),
        "cv": dense_init(ks[8], (cfg.d_ff, d), dtype),
        "cr": dense_init(ks[9], (d, d), dtype),
    }


def _shift(x: Array, x_prev: Array | None = None) -> Array:
    """Token shift: x[t-1] (zeros / provided state at t=0).  x: (B,L,D)."""
    if x_prev is None:
        return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _decays(p: dict, xw: Array) -> Array:
    """Data-dependent per-channel decay in (0,1): exp(-exp(w0 + lora))."""
    lora = jnp.tanh(xw @ p["w_lora_a"].astype(xw.dtype)) @ \
        p["w_lora_b"].astype(xw.dtype)
    logw = p["w0"] + lora.astype(jnp.float32)
    return jnp.exp(-jnp.exp(logw))                        # (B,L,D)


def _wkv_chunked(r: Array, k: Array, v: Array, w: Array, u: Array,
                 chunk: int, state0: Array | None = None):
    """Chunked WKV.  r,k,v,w: (B,L,H,D); u: (H,D).  Returns (out, state).

    out_t = r_t . (S_t + u k_t v_t^T);  S_{t+1} = diag(w_t) S_t + k_t v_t^T
    (S_t is the state BEFORE absorbing token t.)
    """
    b, ell0, h, d = r.shape
    q = min(chunk, ell0)
    pad = (-ell0) % q
    if pad:   # decay-neutral padding: k=0 (no contribution), w=1 (no decay)
        zf = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zf(r), zf(k), zf(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
    ell = ell0 + pad
    nc = ell // q
    rs = lambda t: t.reshape(b, nc, q, h, d).astype(jnp.float32)
    rc, kc, vc, wc = rs(r), rs(k), rs(v), rs(w)

    logw = jnp.log(jnp.maximum(wc, 1e-30))
    cum = jnp.cumsum(logw, axis=2)                        # inclusive cumsum
    cum_excl = cum - logw                                 # exclusive

    # intra-chunk: out_s += sum_{t<s} r_s*prod_{j in [t+1, s)} w_j k_t v_t
    # att[s,t] = sum_d r_s[d] k_t[d] exp(cum_excl[s,d] - cum[t,d]) for t < s
    # Factored form exp(cum_excl_s)*exp(-cum_t) can overflow for strong
    # decay; re-center both factors at half the chunk-total log-decay.
    mid = 0.5 * cum[:, :, -1:, :, :]                      # (B,nc,1,H,D)
    r_intra = rc * jnp.exp(cum_excl - mid)                # (B,nc,Q,H,D)
    k_intra = kc * jnp.exp(mid - cum)
    att = jnp.einsum("bcshd,bcthd->bchst", r_intra, k_intra)
    causal = jnp.tril(jnp.ones((q, q), bool), k=-1)       # strictly lower
    att = jnp.where(causal[None, None, None], att, 0.0)
    y_intra = jnp.einsum("bchst,bcthd->bcshd", att, vc)
    # bonus diagonal term: r_s . (u * k_s) v_s
    bonus = jnp.einsum("bcshd,hd,bcshd->bcsh", rc, u.astype(jnp.float32), kc)
    y_intra = y_intra + bonus[..., None] * vc

    # chunk state contribution: sum_t (prod_{j>t} w_j) k_t v_t^T
    k_tail = kc * jnp.exp(cum[:, :, -1:, :, :] - cum)     # (B,nc,Q,H,D)
    chunk_kv = jnp.einsum("bcthd,bcthe->bchde", k_tail, vc)
    chunk_decay = jnp.exp(cum[:, :, -1])                  # (B,nc,H,D)

    def scan_fn(s_prev, inp):
        ckv, dec = inp                                    # (B,H,D,D),(B,H,D)
        s_new = s_prev * dec[..., None] + ckv
        return s_new, s_prev

    init = (jnp.zeros((b, h, d, d), jnp.float32) if state0 is None
            else state0.astype(jnp.float32))
    s_last, s_prevs = jax.lax.scan(
        scan_fn, init, (chunk_kv.transpose(1, 0, 2, 3, 4),
                        chunk_decay.transpose(1, 0, 2, 3)))
    s_prevs = s_prevs.transpose(1, 0, 2, 3, 4)            # (B,nc,H,D,D)

    # inter-chunk factor exp(cum_excl) <= 1 (log-decays are negative): safe.
    y_inter = jnp.einsum("bcshd,bchde->bcshe", rc * jnp.exp(cum_excl),
                         s_prevs)
    out = (y_intra + y_inter).reshape(b, ell, h, d)[:, :ell0]
    return out, s_last


def rwkv_time_mix(p: dict, x: Array, cfg: ArchConfig, *,
                  state: RWKVState | None = None, return_state: bool = False):
    """Time-mix (the attention replacement).  x: (B, L, D)."""
    r_cfg = cfg.rwkv
    b, ell, d = x.shape
    h = d // r_cfg.head_size
    xx = _shift(x, state.x_prev_att if state is not None else None)
    mix = p["mix"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + (xx - x) * mix[i] for i in range(5))
    r = (xr @ p["wr"].astype(x.dtype)).reshape(b, ell, h, r_cfg.head_size)
    k = (xk @ p["wk"].astype(x.dtype)).reshape(b, ell, h, r_cfg.head_size)
    v = (xv @ p["wv"].astype(x.dtype)).reshape(b, ell, h, r_cfg.head_size)
    g = jax.nn.silu(xg @ p["wg"].astype(x.dtype))
    w = _decays(p, xw).reshape(b, ell, h, r_cfg.head_size)

    wkv0 = state.wkv if state is not None else None
    out, s_last = _wkv_chunked(r, k, v, w, p["u"], r_cfg.chunk, wkv0)
    out = out.reshape(b, ell, d).astype(x.dtype)
    out = apply_norm("layernorm", p["ln_x"], out)
    out = (out * g) @ p["wo"].astype(x.dtype)
    if not return_state:
        return out
    return out, s_last, x[:, -1]


def rwkv_channel_mix(p: dict, x: Array, *, x_prev: Array | None = None,
                     return_state: bool = False):
    """Channel mix (squared-ReLU FFN with token shift)."""
    xx = _shift(x, x_prev)
    mix = p["mix_ffn"].astype(x.dtype)
    xk = x + (xx - x) * mix
    kk = jax.nn.relu(xk @ p["ck"].astype(x.dtype)) ** 2
    out = jax.nn.sigmoid(xk @ p["cr"].astype(x.dtype)) * \
        (kk @ p["cv"].astype(x.dtype))
    if not return_state:
        return out
    return out, x[:, -1]


def rwkv_decode_time_mix(p: dict, x1: Array, state: RWKVState,
                         cfg: ArchConfig):
    """O(1) decode for time-mix.  x1: (B, 1, D)."""
    r_cfg = cfg.rwkv
    b, _, d = x1.shape
    h = d // r_cfg.head_size
    xx = state.x_prev_att[:, None]
    mix = p["mix"].astype(x1.dtype)
    xr, xk, xv, xw, xg = (x1 + (xx - x1) * mix[i] for i in range(5))
    r = (xr @ p["wr"].astype(x1.dtype)).reshape(b, h, r_cfg.head_size)
    k = (xk @ p["wk"].astype(x1.dtype)).reshape(b, h, r_cfg.head_size)
    v = (xv @ p["wv"].astype(x1.dtype)).reshape(b, h, r_cfg.head_size)
    g = jax.nn.silu(xg @ p["wg"].astype(x1.dtype))[:, 0]
    w = _decays(p, xw).reshape(b, h, r_cfg.head_size)

    r32, k32, v32 = (t.astype(jnp.float32) for t in (r, k, v))
    kv = k32[..., :, None] * v32[..., None, :]            # (B,H,D,D)
    s = state.wkv
    out = jnp.einsum("bhd,bhde->bhe", r32,
                     s + p["u"][None, :, :, None] * kv)
    s_new = w.astype(jnp.float32)[..., None] * s + kv
    out = out.reshape(b, d).astype(x1.dtype)
    out = apply_norm("layernorm", p["ln_x"], out)
    out = ((out * g) @ p["wo"].astype(x1.dtype))[:, None]
    return out, s_new, x1[:, 0]
