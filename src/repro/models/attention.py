"""Attention: GQA (full / sliding-window / softcap / encoder), gated
cross-attention (VLM), and DeepSeek MLA with an absorbed decode path.

Full-sequence attention is flash-style in pure jnp: an online-softmax
`lax.scan` over KV chunks, so the (S, S) logit matrix is never materialized
(required for prefill_32k to fit HBM).  The Pallas `flash_attention` kernel
mirrors this algorithm for TPU; the jnp path here is what the CPU dry-run
lowers (DESIGN.md §8).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import shard_map_compat
from repro.models.layers import apply_norm, apply_rope, dense_init, init_norm

Array = jax.Array

NEG_INF = -1e30


class KVCache(NamedTuple):
    """Per-layer KV cache.  k/v: (B, S_max, Hkv, hd).

    With cfg.kv_cache_dtype == "int8", k/v are int8 and k_scale/v_scale
    hold per-(position, head) absmax dequant scales (B, S_max, Hkv) f32 —
    0.8% storage overhead for a 2x traffic cut; scores/outputs use
    q.(k_int*s) == (q.k_int)*s so the dot itself runs on int8 operands
    (MXU-native on TPU)."""
    k: Array
    v: Array
    k_scale: Optional[Array] = None
    v_scale: Optional[Array] = None


def quantize_kv(t: Array) -> tuple[Array, Array]:
    """t: (..., hd) -> int8 values + f32 absmax scale over hd."""
    amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.round(t.astype(jnp.float32) / scale[..., None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


# ---------------------------------------------------------------------------
# core flash-style multi-head attention
# ---------------------------------------------------------------------------

def mha(q: Array, k: Array, v: Array, *, causal: bool,
        window: Optional[int] = None, softcap: Optional[float] = None,
        q_offset: Array | int = 0, kv_valid_len: Optional[Array] = None,
        kv_chunk: int = 1024) -> Array:
    """Online-softmax attention.

    q: (B, Sq, H, hd); k, v: (B, Skv, Hkv, hd); GQA via head grouping.
    q_offset: absolute position of q[0] (decode: current position).
    kv_valid_len: number of valid cache entries (decode with static cache).
    """
    b, sq, h, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, hd)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))

    kc = min(kv_chunk, skv)
    n_chunks = (skv + kc - 1) // kc
    pad = n_chunks * kc - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    k = k.reshape(b, n_chunks, kc, hkv, hd).transpose(1, 0, 2, 3, 4)
    v = v.reshape(b, n_chunks, kc, hkv, hd).transpose(1, 0, 2, 3, 4)

    q_pos = jnp.asarray(q_offset) + jnp.arange(sq)          # (Sq,)

    def body(carry, inp):
        m, l, acc = carry
        k_c, v_c, c_idx = inp                               # (B,kc,Hkv,hd)
        kv_pos = c_idx * kc + jnp.arange(kc)                # (kc,)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                            k_c.astype(jnp.float32)) * scale
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        mask = jnp.ones((sq, kc), bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        mask &= kv_pos[None, :] < (skv if kv_valid_len is None
                                   else kv_valid_len)
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)

        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = (acc * corr[..., None]
                   + jnp.einsum("bhgqk,bkhd->bhgqd", p,
                                v_c.astype(jnp.float32)))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (k, v, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def init_attn(key: Array, cfg: ArchConfig, dtype) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], (d, h * hd), dtype),
         "wk": dense_init(ks[1], (d, hkv * hd), dtype),
         "wv": dense_init(ks[2], (d, hkv * hd), dtype),
         "wo": dense_init(ks[3], (h * hd, d), dtype)}
    if cfg.qk_norm:
        p["q_norm"] = init_norm("rmsnorm", hd, dtype)
        p["k_norm"] = init_norm("rmsnorm", hd, dtype)
    return p


def _project_qkv(p: dict, x: Array, cfg: ArchConfig,
                 positions: Array, theta: float):
    b, s, _ = x.shape
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(b, s, hkv, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = apply_norm("rmsnorm", p["q_norm"], q)
        k = apply_norm("rmsnorm", p["k_norm"], k)
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    return q, k, v


def attn_forward(p: dict, x: Array, cfg: ArchConfig, *,
                 window: Optional[int] = None,
                 theta: Optional[float] = None,
                 return_cache: bool = False,
                 cache_len: Optional[int] = None, ctx=None):
    """Full-sequence attention (train / prefill).  x: (B, S, D)."""
    b, s, _ = x.shape
    theta = cfg.rope_theta if theta is None else theta
    positions = jnp.arange(s)[None, :]
    q, k, v = _project_qkv(p, x, cfg, positions, theta)
    # pin head-sharded TP when both H and Hkv divide the model axis
    if ctx is not None and ctx.mesh is not None:
        msize = ctx.axis_sizes.get(ctx.model_axis, 1)
        if msize > 1 and q.shape[2] % msize == 0 \
                and k.shape[2] % msize == 0:
            q, k, v = (_head_shard(q, ctx), _head_shard(k, ctx),
                       _head_shard(v, ctx))
    out = mha(q, k, v, causal=cfg.causal, window=window,
              softcap=cfg.attn_softcap)
    out = out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)
    if not return_cache:
        return out
    cl = cache_len if cache_len is not None else s
    if window is not None:
        cl = min(cl, window)
    kf, vf = _fit_cache(k, cl), _fit_cache(v, cl)
    if cfg.kv_cache_dtype == "int8":
        kq, ks = quantize_kv(kf)
        vq, vs = quantize_kv(vf)
        return out, KVCache(k=kq, v=vq, k_scale=ks, v_scale=vs)
    return out, KVCache(k=kf, v=vf)


def _fit_cache(k: Array, cache_len: int) -> Array:
    """Keep the last `cache_len` positions (ring semantics for local attn)."""
    s = k.shape[1]
    if s >= cache_len:
        return k[:, s - cache_len:]
    pad = cache_len - s
    return jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))


def attn_decode(p: dict, x: Array, cache: KVCache, pos: Array,
                cfg: ArchConfig, *, window: Optional[int] = None,
                theta: Optional[float] = None):
    """One-token decode.  x: (B, 1, D); pos: scalar int32 absolute position.

    Local (sliding-window) layers keep a ring cache of size `window`; global
    layers keep the full-length cache.  Returns (out, new_cache).
    """
    b = x.shape[0]
    theta = cfg.rope_theta if theta is None else theta
    positions = jnp.full((1, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions, theta)

    s_max = cache.k.shape[1]
    if window is None:
        slot = jnp.minimum(pos, s_max - 1)
    else:
        slot = pos % s_max                     # ring cache for local layers
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(cache.k.dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(cache.v.dtype), slot, axis=1)

    if window is None:
        out = mha(q, k, v, causal=False, softcap=cfg.attn_softcap,
                  kv_valid_len=pos + 1, kv_chunk=4096)
    else:
        # Ring cache: all resident entries are within the window by
        # construction; mask only the unwritten tail early on.
        valid = jnp.minimum(pos + 1, s_max)
        out = mha(q, k, v, causal=False, softcap=cfg.attn_softcap,
                  kv_valid_len=valid, kv_chunk=4096)
    out = out.reshape(b, 1, -1) @ p["wo"].astype(x.dtype)
    return out, KVCache(k=k, v=v)


def attn_decode_sharded(p: dict, x: Array, cache: KVCache, pos: Array,
                        cfg: ArchConfig, ctx, *,
                        window: Optional[int] = None,
                        theta: Optional[float] = None):
    """One-token decode with the KV cache left sharded over `model`.

    Plain attn_decode performs a dynamic_update_slice at a runtime slot on
    the model-sharded seq dim; GSPMD cannot partition that and falls back
    to "involuntary full rematerialization" — it all-gathers the WHOLE
    cache every step (31 GB/device/step for gemma2 decode_32k; see
    EXPERIMENTS.md §Perf).  Here both the cache update and the attention
    run inside shard_map: the owning shard writes exactly ONE slot, every
    shard computes flash-decode partial stats over its local seq chunk,
    and only (B,H,hd)-sized stats cross the ICI via psum.
    """
    b = x.shape[0]
    theta = cfg.rope_theta if theta is None else theta
    positions = jnp.full((1, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, cfg, positions, theta)

    s_max = cache.k.shape[1]
    if window is None:
        slot = jnp.minimum(pos, s_max - 1)
        valid = pos + 1
    else:
        slot = pos % s_max                     # ring cache for local layers
        valid = jnp.minimum(pos + 1, s_max)

    maxis = ctx.model_axis
    sizes = ctx.axis_sizes
    msize = sizes.get(maxis, 1)
    dsize = 1
    for a in ctx.data_axes:
        dsize *= sizes.get(a, 1)
    quant = cache.k.dtype == jnp.int8
    if quant:
        kq_new, ks_new = quantize_kv(k_new)    # (B,1,Hkv,hd), (B,1,Hkv)
        vq_new, vs_new = quantize_kv(v_new)

    if ctx.mesh is None or msize <= 1 or s_max % msize != 0:
        # degenerate mesh: the plain path has no resharding to avoid
        if quant:
            nk = jax.lax.dynamic_update_slice_in_dim(cache.k, kq_new, slot,
                                                     axis=1)
            nv = jax.lax.dynamic_update_slice_in_dim(cache.v, vq_new, slot,
                                                     axis=1)
            nks = jax.lax.dynamic_update_slice_in_dim(cache.k_scale, ks_new,
                                                      slot, axis=1)
            nvs = jax.lax.dynamic_update_slice_in_dim(cache.v_scale, vs_new,
                                                      slot, axis=1)
            k_f = nk.astype(jnp.float32) * nks[..., None]
            v_f = nv.astype(jnp.float32) * nvs[..., None]
            out = mha(q, k_f.astype(q.dtype), v_f.astype(q.dtype),
                      causal=False, softcap=cfg.attn_softcap,
                      kv_valid_len=valid, kv_chunk=4096)
            out = out.reshape(b, 1, -1) @ p["wo"].astype(x.dtype)
            return out, KVCache(k=nk, v=nv, k_scale=nks, v_scale=nvs)
        out = mha(q, cache_k := jax.lax.dynamic_update_slice_in_dim(
                      cache.k, k_new.astype(cache.k.dtype), slot, axis=1),
                  cache_v := jax.lax.dynamic_update_slice_in_dim(
                      cache.v, v_new.astype(cache.v.dtype), slot, axis=1),
                  causal=False, softcap=cfg.attn_softcap,
                  kv_valid_len=valid, kv_chunk=4096)
        out = out.reshape(b, 1, -1) @ p["wo"].astype(x.dtype)
        return out, KVCache(k=cache_k, v=cache_v)

    from jax.sharding import PartitionSpec as P
    dax = ctx.data_axes if len(ctx.data_axes) > 1 else ctx.data_axes[0]
    bspec = dax if (dsize > 1 and b % dsize == 0) else None
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // hkv
    softcap = cfg.attn_softcap

    def _one_slot_update(buf, new, safe, inb):
        """Write exactly one slot; keep the old value when not the owner."""
        cur = jax.lax.dynamic_slice_in_dim(buf, safe, 1, axis=1)
        up = jnp.where(jnp.reshape(inb, (1,) * cur.ndim),
                       new.astype(buf.dtype), cur)
        return jax.lax.dynamic_update_slice_in_dim(buf, up, safe, axis=1)

    def _flash(qg, kf, vf, kv_pos, valid_):
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf,
                            preferred_element_type=jnp.float32) / jnp.sqrt(
                                jnp.asarray(hd, jnp.float32))
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        logits = jnp.where((kv_pos < valid_)[None, None, None, None],
                           logits, NEG_INF)
        m = jnp.max(logits, axis=-1)                      # (B,hkv,g,1)
        gm = jax.lax.pmax(m, maxis)
        pr = jnp.exp(logits - gm[..., None])
        l_tot = jax.lax.psum(jnp.sum(pr, axis=-1), maxis)
        acc = jnp.einsum("bhgqk,bkhd->bhgqd", pr.astype(vf.dtype), vf,
                         preferred_element_type=jnp.float32)
        return jax.lax.psum(acc, maxis), l_tot

    def kernel(q_l, kn, vn, kc, vc, slot_, valid_):
        bl = q_l.shape[0]
        s_l = kc.shape[1]
        start = jax.lax.axis_index(maxis) * s_l
        loc = slot_ - start
        inb = (loc >= 0) & (loc < s_l)
        safe = jnp.clip(loc, 0, s_l - 1)
        kc = _one_slot_update(kc, kn, safe, inb)
        vc = _one_slot_update(vc, vn, safe, inb)
        # flash-decode over the local chunk (positions are slot indices).
        # bf16 caches feed the MXU directly (preferred_element_type=f32)
        # instead of materializing an fp32 copy of the whole chunk.
        kv_pos = start + jnp.arange(s_l)
        qg = q_l.reshape(bl, 1, hkv, g, hd).astype(kc.dtype)
        acc_tot, l_tot = _flash(qg, kc, vc, kv_pos, valid_)
        out = acc_tot / jnp.maximum(l_tot, 1e-30)[..., None]
        out = out.transpose(0, 3, 1, 2, 4).reshape(bl, 1, h, hd)
        return out.astype(q_l.dtype), kc, vc

    def kernel_q(q_l, kn, vn, ksn, vsn, kc, vc, ks, vs, slot_, valid_):
        """int8 cache: scores = (q . k_int) * s_k, acc = (p * s_v) . v_int —
        the dot operands stay int8 (MXU-native), scales applied on the
        (B,H,1,S)-sized score/prob tensors."""
        bl = q_l.shape[0]
        s_l = kc.shape[1]
        start = jax.lax.axis_index(maxis) * s_l
        loc = slot_ - start
        inb = (loc >= 0) & (loc < s_l)
        safe = jnp.clip(loc, 0, s_l - 1)
        kc = _one_slot_update(kc, kn, safe, inb)
        vc = _one_slot_update(vc, vn, safe, inb)
        ks = _one_slot_update(ks, ksn, safe, inb)
        vs = _one_slot_update(vs, vsn, safe, inb)

        kv_pos = start + jnp.arange(s_l)
        qg = q_l.reshape(bl, 1, hkv, g, hd).astype(jnp.float32)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                            kc.astype(jnp.float32),
                            preferred_element_type=jnp.float32)
        logits = logits * ks.transpose(0, 2, 1)[:, :, None, None, :] \
            / jnp.sqrt(jnp.asarray(hd, jnp.float32))
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        logits = jnp.where((kv_pos < valid_)[None, None, None, None],
                           logits, NEG_INF)
        m = jnp.max(logits, axis=-1)
        gm = jax.lax.pmax(m, maxis)
        pr = jnp.exp(logits - gm[..., None])
        l_tot = jax.lax.psum(jnp.sum(pr, axis=-1), maxis)
        pv = pr * vs.transpose(0, 2, 1)[:, :, None, None, :]
        acc = jnp.einsum("bhgqk,bkhd->bhgqd", pv, vc.astype(jnp.float32),
                         preferred_element_type=jnp.float32)
        acc_tot = jax.lax.psum(acc, maxis)
        out = acc_tot / jnp.maximum(l_tot, 1e-30)[..., None]
        out = out.transpose(0, 3, 1, 2, 4).reshape(bl, 1, h, hd)
        return out.astype(q_l.dtype), kc, vc, ks, vs

    rep = P(bspec, None, None, None)
    cspec = P(bspec, maxis, None, None)
    if quant:
        rep3 = P(bspec, None, None)
        sspec = P(bspec, maxis, None)
        out, k, v, ks, vs = shard_map_compat(
            kernel_q, mesh=ctx.mesh,
            in_specs=(rep, rep, rep, rep3, rep3, cspec, cspec, sspec,
                      sspec, P(), P()),
            out_specs=(rep, cspec, cspec, sspec, sspec),
            check_vma=False)(
            q, kq_new, vq_new, ks_new, vs_new, cache.k, cache.v,
            cache.k_scale, cache.v_scale, slot, valid)
        out = out.reshape(b, 1, -1) @ p["wo"].astype(x.dtype)
        return out, KVCache(k=k, v=v, k_scale=ks, v_scale=vs)

    out, k, v = shard_map_compat(
        kernel, mesh=ctx.mesh,
        in_specs=(rep, rep, rep, cspec, cspec, P(), P()),
        out_specs=(rep, cspec, cspec), check_vma=False)(
        q, k_new, v_new, cache.k, cache.v, slot, valid)
    out = out.reshape(b, 1, -1) @ p["wo"].astype(x.dtype)
    return out, KVCache(k=k, v=v)


# ---------------------------------------------------------------------------
# gated cross-attention (Llama-3.2-Vision style)
# ---------------------------------------------------------------------------

def init_cross_attn(key: Array, cfg: ArchConfig, dtype) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 5)
    return {"wq": dense_init(ks[0], (d, h * hd), dtype),
            "wk": dense_init(ks[1], (d, hkv * hd), dtype),
            "wv": dense_init(ks[2], (d, hkv * hd), dtype),
            "wo": dense_init(ks[3], (h * hd, d), dtype),
            "gate": jnp.zeros((1,), dtype)}


def cross_attn_forward(p: dict, x: Array, kv_src: Array,
                       cfg: ArchConfig) -> Array:
    """x: (B, S, D) queries; kv_src: (B, Sv, D) vision embeddings."""
    b, s, _ = x.shape
    sv = kv_src.shape[1]
    h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, h, hd)
    k = (kv_src.astype(x.dtype) @ p["wk"].astype(x.dtype)).reshape(b, sv, hkv, hd)
    v = (kv_src.astype(x.dtype) @ p["wv"].astype(x.dtype)).reshape(b, sv, hkv, hd)
    out = mha(q, k, v, causal=False)
    out = out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)
    return jnp.tanh(p["gate"].astype(jnp.float32)).astype(x.dtype) * out


# ---------------------------------------------------------------------------
# DeepSeek MLA (multi-head latent attention) + absorbed decode
# ---------------------------------------------------------------------------

class MLACache(NamedTuple):
    """Compressed cache: c_kv (B, S, kv_lora), k_rope (B, S, rope_dim)."""
    c_kv: Array
    k_rope: Array


def init_mla(key: Array, cfg: ArchConfig, dtype) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 7)
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "q_norm": init_norm("rmsnorm", m.q_lora_rank, dtype),
        "wq_b": dense_init(ks[1], (m.q_lora_rank, h * qk), dtype),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                            dtype),
        "kv_norm": init_norm("rmsnorm", m.kv_lora_rank, dtype),
        "wk_b": dense_init(ks[3], (m.kv_lora_rank, h * m.qk_nope_head_dim),
                           dtype),
        "wv_b": dense_init(ks[4], (m.kv_lora_rank, h * m.v_head_dim), dtype),
        "wo": dense_init(ks[5], (h * m.v_head_dim, d), dtype),
    }


def _mla_q(p: dict, x: Array, cfg: ArchConfig, positions: Array):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    q_lat = apply_norm("rmsnorm", p["q_norm"], x @ p["wq_a"].astype(x.dtype))
    q = (q_lat @ p["wq_b"].astype(x.dtype)).reshape(b, s, h, qk)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(p: dict, x: Array, cfg: ArchConfig, positions: Array):
    m = cfg.mla
    kv = x @ p["wkv_a"].astype(x.dtype)
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = apply_norm("rmsnorm", p["kv_norm"], c_kv)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0]           # shared head
    return c_kv, k_rope


def _head_shard(t: Array, ctx, head_axis: int = 2) -> Array:
    """Pin a (B,S,H,hd) tensor to head-sharded TP (Megatron attention).

    Without this GSPMD may let a downstream seq-sharding constraint (the
    MoE dispatch spec) propagate back into attention, and then all-gathers
    the fully head-EXPANDED k/v every layer — for deepseek-v3 that is the
    difference between resharding the 576-dim latent (75 MB) and the
    128-head 192-dim expansion (6.4 GB) per layer (EXPERIMENTS.md §Perf).
    """
    if ctx is None or ctx.mesh is None:
        return t
    sizes = ctx.axis_sizes
    msize = sizes.get(ctx.model_axis, 1)
    if msize <= 1 or t.shape[head_axis] % msize != 0:
        return t
    dsize = 1
    for a in ctx.data_axes:
        dsize *= sizes.get(a, 1)
    dax = ctx.data_axes if len(ctx.data_axes) > 1 else ctx.data_axes[0]
    spec = [None] * t.ndim
    if t.shape[0] % dsize == 0 and dsize > 1:
        spec[0] = dax
    spec[head_axis] = ctx.model_axis
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(t, P(*spec))


def mla_forward(p: dict, x: Array, cfg: ArchConfig, *, ctx=None,
                return_cache: bool = False, cache_len: Optional[int] = None):
    """Training / prefill MLA: decompress K,V and run standard attention."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    positions = jnp.arange(s)[None, :]
    q_nope, q_rope = _mla_q(p, x, cfg, positions)
    c_kv, k_rope = _mla_kv_latent(p, x, cfg, positions)
    k_nope = (c_kv @ p["wk_b"].astype(x.dtype)).reshape(
        b, s, h, m.qk_nope_head_dim)
    v = (c_kv @ p["wv_b"].astype(x.dtype)).reshape(b, s, h, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, h, m.qk_rope_head_dim))], axis=-1)
    q, k, v = (_head_shard(q, ctx), _head_shard(k, ctx),
               _head_shard(v, ctx))
    # v_head_dim may differ from qk dim; mha handles hd from q/k, v dims own.
    out = _mha_mixed_dims(q, k, v, causal=cfg.causal)
    out = out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)
    if not return_cache:
        return out
    cl = cache_len if cache_len is not None else s
    return out, MLACache(c_kv=_fit2(c_kv, cl), k_rope=_fit2(k_rope, cl))


def _fit2(a: Array, cl: int) -> Array:
    s = a.shape[1]
    if s >= cl:
        return a[:, s - cl:]
    return jnp.pad(a, ((0, 0), (0, cl - s), (0, 0)))


def _mha_mixed_dims(q, k, v, *, causal):
    """mha wrapper when v head_dim != qk head_dim (MLA)."""
    b, s, h, dq = q.shape
    dv = v.shape[-1]
    if dv == dq:
        return mha(q, k, v, causal=causal)
    pad = dq - dv
    v_p = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
    out = mha(q, k, v_p, causal=causal)
    return out[..., :dv]


def mla_decode(p: dict, x: Array, cache: MLACache, pos: Array,
               cfg: ArchConfig):
    """Absorbed MLA decode: attend directly in the compressed latent space.

    score_t = q_nope^T (wk_b c_t) + q_rope^T kr_t
            = (wk_b^T q_nope)^T c_t + q_rope^T kr_t
    so K never needs decompression; output is combined in latent space and
    decompressed once through wv_b.  This is the TPU-native adaptation of
    DeepSeek's MLA serving optimization (MXU-friendly einsums).
    """
    m = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    positions = jnp.full((1, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, x, cfg, positions)          # (B,1,H,*)
    c_new, kr_new = _mla_kv_latent(p, x, cfg, positions)   # (B,1,lora/rope)

    s_max = cache.c_kv.shape[1]
    slot = jnp.minimum(pos, s_max - 1)
    c = jax.lax.dynamic_update_slice_in_dim(cache.c_kv, c_new.astype(cache.c_kv.dtype), slot, axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(cache.k_rope, kr_new.astype(cache.k_rope.dtype), slot, axis=1)

    wk_b = p["wk_b"].astype(x.dtype).reshape(m.kv_lora_rank, h,
                                             m.qk_nope_head_dim)
    q_lat = jnp.einsum("bqhn,lhn->bqhl", q_nope, wk_b)     # (B,1,H,lora)
    scale = 1.0 / jnp.sqrt(jnp.asarray(
        m.qk_nope_head_dim + m.qk_rope_head_dim, jnp.float32))
    logits = (jnp.einsum("bqhl,bsl->bhqs", q_lat.astype(jnp.float32),
                         c.astype(jnp.float32))
              + jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                           kr.astype(jnp.float32))) * scale
    mask = jnp.arange(s_max)[None, None, None, :] <= pos
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out_lat = jnp.einsum("bhqs,bsl->bqhl", probs,
                         c.astype(jnp.float32))            # (B,1,H,lora)
    wv_b = p["wv_b"].astype(x.dtype).reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bqhl,lhv->bqhv", out_lat.astype(x.dtype), wv_b)
    out = out.reshape(b, 1, -1) @ p["wo"].astype(x.dtype)
    return out, MLACache(c_kv=c, k_rope=kr)


def mla_decode_sharded(p: dict, x: Array, cache: MLACache, pos: Array,
                       cfg: ArchConfig, ctx):
    """Absorbed MLA decode with the latent cache left seq-sharded over
    `model` — the MLA analogue of attn_decode_sharded: one-slot owner
    write + flash partial stats in LATENT space (so the psum payload is
    (B,H,kv_lora), never the decompressed per-head K/V)."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    positions = jnp.full((1, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, x, cfg, positions)          # (B,1,H,*)
    c_new, kr_new = _mla_kv_latent(p, x, cfg, positions)   # (B,1,lora/rope)
    s_max = cache.c_kv.shape[1]
    slot = jnp.minimum(pos, s_max - 1)

    maxis = ctx.model_axis
    sizes = ctx.axis_sizes
    msize = sizes.get(maxis, 1)
    if ctx.mesh is None or msize <= 1 or s_max % msize != 0:
        return mla_decode(p, x, cache, pos, cfg)

    wk_b = p["wk_b"].astype(x.dtype).reshape(m.kv_lora_rank, h,
                                             m.qk_nope_head_dim)
    q_lat = jnp.einsum("bqhn,lhn->bqhl", q_nope, wk_b)     # (B,1,H,lora)
    scale = 1.0 / jnp.sqrt(jnp.asarray(
        m.qk_nope_head_dim + m.qk_rope_head_dim, jnp.float32))

    from jax.sharding import PartitionSpec as P
    dsize = 1
    for a in ctx.data_axes:
        dsize *= sizes.get(a, 1)
    dax = ctx.data_axes if len(ctx.data_axes) > 1 else ctx.data_axes[0]
    bspec = dax if (dsize > 1 and b % dsize == 0) else None

    def kernel(ql, qr, cn, krn, cc, krc, slot_, pos_):
        bl, s_l = cc.shape[0], cc.shape[1]
        start = jax.lax.axis_index(maxis) * s_l
        loc = slot_ - start
        inb = (loc >= 0) & (loc < s_l)
        safe = jnp.clip(loc, 0, s_l - 1)
        cur_c = jax.lax.dynamic_slice_in_dim(cc, safe, 1, axis=1)
        cur_k = jax.lax.dynamic_slice_in_dim(krc, safe, 1, axis=1)
        cc = jax.lax.dynamic_update_slice_in_dim(
            cc, jnp.where(inb, cn.astype(cc.dtype), cur_c), safe, axis=1)
        krc = jax.lax.dynamic_update_slice_in_dim(
            krc, jnp.where(inb, krn.astype(krc.dtype), cur_k), safe, axis=1)

        kv_pos = start + jnp.arange(s_l)
        logits = (jnp.einsum("bqhl,bsl->bhqs", ql.astype(cc.dtype), cc,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bqhr,bsr->bhqs", qr.astype(krc.dtype), krc,
                               preferred_element_type=jnp.float32)) * scale
        logits = jnp.where((kv_pos <= pos_)[None, None, None, :],
                           logits, NEG_INF)
        mx = jnp.max(logits, axis=-1)                      # (B,H,1)
        gm = jax.lax.pmax(mx, maxis)
        pr = jnp.exp(logits - gm[..., None])
        l_tot = jax.lax.psum(jnp.sum(pr, axis=-1), maxis)
        acc = jnp.einsum("bhqs,bsl->bqhl", pr.astype(cc.dtype), cc,
                         preferred_element_type=jnp.float32)
        acc_tot = jax.lax.psum(acc, maxis)
        out_lat = acc_tot / jnp.maximum(l_tot, 1e-30).transpose(
            0, 2, 1)[..., None]
        return out_lat.astype(ql.dtype), cc, krc

    q4 = P(bspec, None, None, None)
    c3 = P(bspec, maxis, None)
    out_lat, c, kr = shard_map_compat(
        kernel, mesh=ctx.mesh,
        in_specs=(q4, q4, P(bspec, None, None), P(bspec, None, None),
                  c3, c3, P(), P()),
        out_specs=(q4, c3, c3), check_vma=False)(
        q_lat, q_rope, c_new, kr_new, cache.c_kv, cache.k_rope, slot, pos)

    wv_b = p["wv_b"].astype(x.dtype).reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bqhl,lhv->bqhv", out_lat.astype(x.dtype), wv_b)
    out = out.reshape(b, 1, -1) @ p["wo"].astype(x.dtype)
    return out, MLACache(c_kv=c, k_rope=kr)
