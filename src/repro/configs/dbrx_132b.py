"""dbrx-132b [moe] — 16 experts top-4, fine-grained MoE.

Source: model card hf:databricks/dbrx-base.
40 layers, d_model 6144, 48 heads (GQA kv=8), expert FFN 10752,
16 experts top-4, vocab 100 352, GLU activation, RoPE theta 5e5.
"""
from repro.configs.base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    citation="hf:databricks/dbrx-base",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    period=("moe",),
    num_periods=40,
    rope_theta=500000.0,
    activation="swiglu",
    moe=MoECfg(num_experts=16, top_k=4, d_expert=10752),
)
