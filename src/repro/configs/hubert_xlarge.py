"""hubert-xlarge [audio] — encoder-only masked-cluster prediction.

Source: HuBERT [arXiv:2106.07447] (X-Large: same arch as wav2vec2 XL).
48 layers, d_model 1280, 16 heads (MHA), d_ff 5120, 504 cluster targets.
The conv/mel frontend is a STUB (sanctioned carve-out): input_specs()
provides precomputed frame embeddings (B, S, 1280).
Encoder-only => no decode shapes (DESIGN.md §4); HuBERT's masked
multi-cluster prediction is itself an MTL objective — the natural fit for
the paper's technique.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    citation="arXiv:2106.07447",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    period=("attn",),
    num_periods=48,
    causal=False,
    activation="gelu",
    norm="layernorm",
    feature_dim=1280,
    has_decode=False,
)
