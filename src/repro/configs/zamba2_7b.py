"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.

Source: Zamba2 suite [arXiv:2411.15242].
81 layers = 13 x (5 mamba + 1 shared-attn) + 3 mamba, d_model 3584,
shared attention 32 heads (kv=32, head_dim 112) with per-invocation LoRA,
attn-block FFN 14336, Mamba2 state 64, vocab 32 000.
Simplification (DESIGN.md §4): one weight-tied attention block (the real
model alternates two) with rank-64 LoRA deltas per invocation.
Linear-scan backbone => long_500k eligible.
"""
from repro.configs.base import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    citation="arXiv:2411.15242",
    num_layers=81,
    d_model=3584,
    num_heads=32,
    num_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    period=("mamba",) * 5 + ("shared_attn",),
    num_periods=13,
    tail_blocks=("mamba",) * 3,
    rope_theta=10000.0,
    activation="geglu",
    ssm=SSMCfg(state_dim=64, head_dim=64, expand=2, conv_width=4),
    subquadratic=True,
)
