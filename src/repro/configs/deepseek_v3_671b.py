"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.

Source: DeepSeek-V3 Technical Report [arXiv:2412.19437].
61 layers (first 3 dense, 58 MoE), d_model 7168, 128 heads (MLA),
256 routed experts top-8 with d_expert 2048 (the assignment's d_ff=2048),
1 shared expert, vocab 129 280.  Dense-layer FFN is 18432 per the report.
Simplifications (DESIGN.md §4): softmax+aux-loss routing instead of
aux-loss-free bias routing; 1 MTP block.
"""
from repro.configs.base import ArchConfig, MLACfg, MoECfg

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    citation="arXiv:2412.19437",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=18432,                      # dense (non-MoE) layers
    vocab_size=129280,
    head_blocks=("attn",) * 3,
    period=("moe",),
    num_periods=58,
    rope_theta=10000.0,
    activation="swiglu",
    moe=MoECfg(num_experts=256, top_k=8, d_expert=2048, num_shared=1),
    mla=MLACfg(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
               qk_rope_head_dim=64, v_head_dim=128),
    mtp=True,
    subquadratic=False,              # full (MLA) attention
)
