"""llama-3.2-vision-11b [vlm] — cross-attention image layers.

Source: model card hf:meta-llama/Llama-3.2-11B-Vision.
40 layers, d_model 4096, 32 heads (GQA kv=8), d_ff 14336, vocab 128 256;
gated cross-attention every 5th layer (8 of 40).  The ViT vision encoder +
projector are a STUB (sanctioned carve-out): input_specs() provides patch
embeddings (B, 1601, 4096) already projected to d_model.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    citation="hf:meta-llama/Llama-3.2-11B-Vision",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    period=("attn", "attn", "attn", "attn", "cross"),
    num_periods=8,
    rope_theta=500000.0,
    activation="swiglu",
    cross_every=5,
    vision_seq=1601,
)
