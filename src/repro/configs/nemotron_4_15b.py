"""nemotron-4-15b [dense] — GQA, squared-ReLU MLP.

Source: Nemotron-4 15B Technical Report [arXiv:2402.16819].
32 layers, d_model 6144, 48 heads (GQA kv=8), d_ff 24576, vocab 256 000,
squared-ReLU activation, RoPE, LayerNorm.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    citation="arXiv:2402.16819",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    period=("attn",),
    num_periods=32,
    rope_theta=10000.0,
    activation="relu2",
    norm="layernorm",
)
