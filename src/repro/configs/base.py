"""Architecture config schema for the 10 assigned architectures.

Every field that differs between archs is explicit; every config file cites
its source paper/model card.  `reduced()` produces the CPU smoke-test
variant (<=2 layers, d_model<=512, <=4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Optional, Sequence

BlockKind = Literal[
    "attn",          # full self-attention + dense FFN
    "local",         # sliding-window self-attention + dense FFN
    "global",        # full self-attention + dense FFN (local/global mixes)
    "moe",           # self-attention + MoE FFN
    "mamba",         # Mamba2 SSD block
    "rwkv",          # RWKV6 time-mix + channel-mix
    "shared_attn",   # weight-tied global attention (Zamba2) + LoRA delta
    "cross",         # self-attention + gated cross-attention + FFN (VLM)
]


@dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_expert: int                  # per-expert FFN hidden size
    num_shared: int = 0            # always-on shared experts (DeepSeek)
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class MLACfg:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMCfg:
    state_dim: int = 64            # N
    head_dim: int = 64             # P
    expand: int = 2
    conv_width: int = 4
    n_groups: int = 1
    chunk: int = 128


@dataclass(frozen=True)
class RWKVCfg:
    head_size: int = 64
    decay_lora: int = 64
    chunk: int = 128


@dataclass(frozen=True)
class MTLCfg:
    """The paper's technique attached to the backbone (see DESIGN.md §3)."""
    num_tasks: int = 16
    reg_name: str = "nuclear"
    lam: float = 0.01
    tau: int = 4                   # bounded staleness of the head updates
    activation_rate: float = 0.5   # Bernoulli thinning of Assumption 1
    dynamic_step: bool = True
    eta: float = 0.1
    km_relax: float = 0.9
    probe_weight: float = 0.1      # weight of the probe loss in the backbone


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "audio", "vlm", "hybrid"]
    citation: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # layer layout: head (unscanned prefix) + period x num_periods + tail
    head_blocks: tuple[BlockKind, ...] = ()
    period: tuple[BlockKind, ...] = ("attn",)
    num_periods: int = 0
    tail_blocks: tuple[BlockKind, ...] = ()

    # attention details
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None       # window for "local" layers
    local_global_pattern: bool = False         # period mixes local/global
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    causal: bool = True                        # False => encoder-only
    qk_norm: bool = False

    activation: Literal["swiglu", "geglu", "relu2", "gelu"] = "swiglu"
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    tie_embeddings: bool = False

    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    rwkv: Optional[RWKVCfg] = None

    # vlm
    cross_every: int = 0                       # cross-attn layer cadence
    vision_seq: int = 1601                     # stubbed patch embeddings
    # audio
    feature_dim: int = 0                       # stubbed frame-embedding dim
    # deepseek multi-token prediction
    mtp: bool = False
    mtp_weight: float = 0.3

    # serving: KV cache storage ("model" = cfg.dtype, or "int8" for
    # absmax-quantized caches with per-(position, head) f32 scales)
    kv_cache_dtype: str = "model"

    # the paper's technique
    mtl: MTLCfg = field(default_factory=MTLCfg)

    # capability flags for shape selection
    subquadratic: bool = False                 # eligible for long_500k
    has_decode: bool = True                    # False for encoder-only

    dtype: str = "bfloat16"

    def __post_init__(self):
        n_pattern = (len(self.head_blocks) + len(self.period) * self.num_periods
                     + len(self.tail_blocks))
        if n_pattern != self.num_layers:
            raise ValueError(
                f"{self.name}: pattern covers {n_pattern} layers, "
                f"declared num_layers={self.num_layers}")

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family/block kinds, tiny dims."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.num_heads, 4)
        head_dim = max(32, d_model // n_heads)
        n_kv = min(self.num_kv_heads, n_heads)
        period = self.period
        head = self.head_blocks[:1]
        tail = self.tail_blocks[:1]
        num_periods = 1 if self.num_periods else 0
        num_layers = len(head) + len(period) * num_periods + len(tail)
        changes = dict(
            num_layers=num_layers, d_model=d_model, num_heads=n_heads,
            num_kv_heads=n_kv, head_dim=head_dim, d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            head_blocks=head, num_periods=num_periods, tail_blocks=tail,
            sliding_window=min(self.sliding_window, 64)
            if self.sliding_window else None,
            dtype="float32",
            mtl=dataclasses.replace(self.mtl, num_tasks=4),
        )
        if self.moe:
            changes["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=2, d_expert=128,
                capacity_factor=2.0)
        if self.mla:
            changes["mla"] = MLACfg(q_lora_rank=64, kv_lora_rank=32,
                                    qk_nope_head_dim=32, qk_rope_head_dim=16,
                                    v_head_dim=32)
        if self.ssm:
            changes["ssm"] = dataclasses.replace(self.ssm, state_dim=16,
                                                 head_dim=32, chunk=16)
        if self.rwkv:
            changes["rwkv"] = dataclasses.replace(self.rwkv, head_size=32,
                                                  decay_lora=16, chunk=16)
        if self.feature_dim:
            changes["feature_dim"] = 64
        if self.cross_every:
            changes["vision_seq"] = 16
        return dataclasses.replace(self, **changes)

    @property
    def layer_kinds(self) -> tuple[BlockKind, ...]:
        return (self.head_blocks + self.period * self.num_periods
                + self.tail_blocks)
