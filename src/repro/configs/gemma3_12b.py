"""gemma3-12b [dense] — 5:1 local:global attention, 128k context.

Source: Gemma 3 family, model card hf:google/gemma-3-1b-pt (12B variant).
48 layers = 8 x (5 local + 1 global), d_model 3840, 16 heads (GQA kv=8,
head_dim 256), d_ff 15360, vocab 262 144, sliding window 1024, qk-norm,
GeGLU, tied embeddings.  5:1 sliding-window => long_500k eligible.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    family="dense",
    citation="hf:google/gemma-3-1b-pt",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    period=("local",) * 5 + ("global",),
    num_periods=8,
    rope_theta=1000000.0,
    sliding_window=1024,
    local_global_pattern=True,
    qk_norm=True,
    activation="geglu",
    tie_embeddings=True,
    subquadratic=True,
)
