"""rwkv6-3b [ssm] — Finch: attention-free, data-dependent decay.

Source: Eagle & Finch [arXiv:2404.05892].
32 layers, d_model 2560 (40 heads of size 64), channel-mix FFN 8960,
vocab 65 536.  Linear-time WKV recurrence => long_500k eligible.
"""
from repro.configs.base import ArchConfig, RWKVCfg

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    citation="arXiv:2404.05892",
    num_layers=32,
    d_model=2560,
    num_heads=40,                    # d_model / head_size
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    period=("rwkv",),
    num_periods=32,
    activation="relu2",              # RWKV channel-mix uses squared ReLU
    norm="layernorm",
    rwkv=RWKVCfg(head_size=64, decay_lora=64),
    subquadratic=True,
)
