"""Architecture registry: the 10 assigned architectures + the paper's own
MTL workload config (amtl_paper)."""
from __future__ import annotations

import importlib

from repro.configs.base import (ArchConfig, MLACfg, MoECfg, MTLCfg, RWKVCfg,
                                SSMCfg)

_MODULES = {
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "llama-3.2-vision-11b": "repro.configs.llama_3_2_vision_11b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "granite-8b": "repro.configs.granite_8b",
    "zamba2-7b": "repro.configs.zamba2_7b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {name: get_config(name) for name in ARCH_NAMES}


__all__ = ["ArchConfig", "MoECfg", "MLACfg", "SSMCfg", "RWKVCfg", "MTLCfg",
           "ARCH_NAMES", "get_config", "all_configs"]
