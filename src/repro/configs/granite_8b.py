"""granite-8b [dense] — llama-arch code model.

Source: Granite Code Models [arXiv:2405.04324] (granite-8b-code).
36 layers, d_model 4096, 32 heads (GQA kv=8), d_ff 14336, vocab 49 152,
SwiGLU, RoPE.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    citation="arXiv:2405.04324",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    period=("attn",),
    num_periods=36,
    rope_theta=10000000.0,
    activation="swiglu",
)
