"""gemma2-2b [dense] — alternating local/global attention, logit softcap.

Source: Gemma 2 [arXiv:2408.00118].
26 layers = 13 x (local, global), d_model 2304, 8 heads (GQA kv=4,
head_dim 256), d_ff 9216, vocab 256 000, sliding window 4096,
attention softcap 50, final-logit softcap 30, GeGLU, tied embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    citation="arXiv:2408.00118",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    period=("local", "global"),
    num_periods=13,
    rope_theta=10000.0,
    sliding_window=4096,
    local_global_pattern=True,
    attn_softcap=50.0,
    logit_softcap=30.0,
    activation="geglu",
    tie_embeddings=True,
    subquadratic=True,
)
