"""AMTL — asynchronous backward-forward coordinate updates (Algorithm 1).

SPMD execution of the ARock semantics: the physical asynchrony of the paper
(threads racing on shared memory) is replayed as a *sequential consistency
simulation* inside `lax.scan`/`fori_loop`:

  event k:  a task t_k is activated (uniform — Poisson thinning under
            Assumption 1);  it reads the server state at staleness nu_k <= tau
            (stale AND inconsistent reads: every block but its own comes from
            an older iterate);  the server computes the backward step
            prox_{eta*lam*g} on that stale copy;  the node applies the forward
            step on its block and writes back with KM relaxation eta_k
            (Eq. III.4), optionally scaled by the delay-adaptive multiplier
            (Eq. III.5/III.6).

Four engines implement the same mathematics:

  engine="delta" (default) — the delta ring.  Only ONE full iterate V is kept;
      each event appends `(task_id, pre-write column)` to a `(tau+1, d)` undo
      log, and the stale read at staleness nu is reconstructed lazily by
      rolling back the nu newest log entries (O(tau*d) work, O(tau*d) memory).
      Per-event state writes are O(d): one column of V plus one ring slot.
      The fused column math (forward step + KM relaxation + undo-log emit)
      is the `amtl_event` kernel (`repro.kernels.ops.amtl_event`).
      The server-side prox can be amortized (`prox_every` — paper §III-C:
      "the proximal mapping can be also applied after several gradient
      updates"), with `svt_randomized` as the refresh for the nuclear norm
      at large d x T (`prox_rank`).

  engine="dense" — the seed engine: a `(tau+1, d, T)` ring of full iterates,
      O(d*T) HBM writes per event.  Kept as the equivalence baseline; the
      delta engine reproduces its iterates bitwise under the same PRNG key
      when `prox_every == 1` and both engines run the same arithmetic
      dispatch (the CPU oracle path, where `ops.amtl_event` lowers to the
      same jnp expression as `km_block_update`; on TPU the Pallas kernel
      may contract FMAs differently, so expect ulp-level, not bitwise,
      agreement there).

  engine="batch" — the delta ring, `event_batch` events per loop step.
      Each step replays `event_batch` draws of the serial PRNG chain (so
      the (task, staleness) event stream is identical to the one-event
      engines by construction), refreshes the server prox only at batch
      boundaries, and applies all column updates through
      `ops.amtl_event_batch` (gather -> fused forward/KM/undo-emit ->
      scatter).  Within-batch conflicts — duplicate tasks — are serialized
      in event order: a later event reads the column as left by the
      earlier in-batch write, and its undo-log entry records that
      pre-write column, so the ring replays exactly as if the events had
      been applied one at a time.  The prox cadence is decoupled from the
      batch size: `prox_every = k * event_batch` refreshes the prox at
      every k-th batch's first event and carries the result in a (d, T)
      prox cache between batches (k == 1 refreshes every batch and carries
      no cache).  For matched cadences (same `prox_every`, same key) the
      batch engine reproduces the delta engine's iterates bitwise on the
      CPU oracle path.

  engine="sharded" — the batch engine with the T task columns partitioned
      over a 1-D "tasks" mesh axis (shard_map).  Each shard owns a (d,
      T/n_shards) block of V, a private (tau+1, d) undo ring, and its
      tasks' data; the task ring records GLOBAL task ids and the scalar
      chain state (PRNG key, ring pointer, event counter) is replicated.
      Every shard replays the FULL serial PRNG chain and masks events to
      their owner, so the (task, staleness) event stream is invariant to
      shard count by construction.  Collectives are paid only at prox
      cadence.  With prox_mode="replicated", one `all_gather` per refresh
      assembles the stale iterate for the server prox (SVT / randomized
      SVT), whose replicated result is the broadcast back; with
      prox_mode="distributed" (prox_rank required) the refresh is the
      rank-distributed randomized SVT — a (d, p) `psum` of per-shard
      sketch partials plus a (p, T/n) `all_gather` of the projected core,
      the thresholded reconstruction applied shard-locally — cutting
      per-refresh communication from O(d*T) to O(d*p + p*T) and dividing
      the sketch flops over the shards.  Gradients, column updates, and
      ring writes stay shard-local in both modes.  With the decoupled
      cadence (`prox_every = k * event_batch`) the collectives are paid
      only every k batches — the true "communication only at prox cadence"
      limit.  This is exactly the paper's server/worker communication
      pattern: task nodes hold their data locally, the central server runs
      the prox.  On a 1-device mesh the engine reproduces engine="batch"
      bitwise on the CPU oracle path, and per-shard `delay_offsets` skews
      model the paper's slow-node regime (a lagging shard's tasks read at
      high staleness without stalling the other shards' event stream).

SGD-AMTL (paper §V): with `AMTLConfig(batch_size=b)` the delta, batch, and
sharded engines replace every forward-step gradient by an unbiased
(n_t/bsz)-scaled seeded minibatch gradient (bsz = min(b, n_t), the
simulator's convention).  The per-event sampling seed is folded off the
main PRNG chain (`_minibatch_seed`), so the (task, staleness) event stream
— and with batch_size=None the engines' every bit — is unchanged; the
selection itself is generated in-kernel from counter hashes
(`repro.kernels.ops.lstsq_grad_sampled`), with no gather and no
materialized index array.

Ragged task cohorts: an `MTLProblem` with `row_counts` set (the
`repro.data.TaskStore` layout — per-task valid-row counts over a shared
padded buffer) runs unchanged through the delta, batch, and sharded
engines; every loss/gradient/minibatch expression masks rows >= n_t
inside `repro.core.losses`, the sharded engine ships row_counts as one
more per_task shard_map input, and uniform row_counts reproduce the
unmasked engines bitwise on the CPU oracle path.  engine="dense" is the
exact uniform seed baseline and rejects ragged problems.

This is bit-faithful to Algorithm 1's mathematics while being jit-compiled,
deterministic under a PRNG key, and mesh-shardable.  Wall-clock behaviour
(Tables I/III) is studied separately by `repro.core.simulator`.

The public surface is the *session* API — the paper's deployment story is
a long-lived asynchronous system, so the solver is a resumable session
over a streaming event source rather than a one-shot batch call:

    engine = make_engine(problem, cfg, mesh=None)   # -> AMTLEngine
    state  = engine.init(v0, key)
    state  = engine.run(state, delay_offsets, num_events)   # resumable
    v      = engine.iterate(state)

`run` is jitted (one compile per distinct `num_events`), advances the
state by any multiple of `engine.events_per_step` events, and composes
bitwise: `run(·, n + m)` == `run(run(·, n), m)` for every engine.  Engine
states are plain pytrees of arrays and round-trip through
`repro.checkpoint.save/restore`, resuming bitwise — including the sharded
state under a mesh.  `amtl_solve` (epoch metrics) and `amtl_events_only`
(bench path) are thin wrappers over the session API, and the online
learning-while-serving platform (`repro.serve.AMTLServer`) holds one of
these sessions long-lived behind a double-buffered prediction path.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.dynamic_step import DelayHistory, dynamic_multiplier
from repro.core.losses import MTLProblem
from repro.core.operators import (amtl_max_step, backward,
                                  fixed_point_residual, km_block_update,
                                  rollback_columns, rollback_columns_batch,
                                  rollback_columns_shard)
from repro.core.prox import ProxPlan, svt_randomized, svt_randomized_dist
from repro.distributed.sharding import (TASK_AXIS, prox_cache_spec,
                                        shard_map_compat, task_shard_specs)

Array = jax.Array


class AMTLConfig(NamedTuple):
    eta: float                 # inner forward/backward step, in (0, 2/L)
    eta_k: float               # KM relaxation, <= amtl_max_step(tau, T)
    tau: int                   # max staleness (ring-buffer depth - 1)
    dynamic_step: bool = False
    delay_window: int = 5      # paper averages the last 5 delays
    # Per-task mean staleness (in events). The sampled delay is
    # min(round(offset_t + U[0,1) * jitter), tau). offsets=None => all zero.
    delay_jitter: float = 1.0
    # "delta": O(d) per-event state with an undo-log ring (default).
    # "dense": the seed (tau+1, d, T) full-iterate ring, for equivalence.
    # "batch": the delta ring, event_batch events per loop step with
    #          batch-boundary prox refreshes and conflict-aware updates.
    # "sharded": the batch engine with task columns partitioned over a
    #          "tasks" mesh axis; one all_gather per prox refresh.
    engine: str = "delta"
    # Server prox amortization (paper §III-C): refresh the backward step
    # every K events, reuse the cached prox in between.  K=1 == exact AMTL.
    # For engine="batch"/"sharded" K must be a multiple of event_batch
    # (refreshes happen at batch boundaries); K = k*event_batch with k > 1
    # carries the refreshed prox in a (d, T) cache across batches — the
    # sharded engine then pays its all_gather only every k batches.
    prox_every: int = 1
    # If set (nuclear reg only), prox refreshes use the randomized SVT
    # sketch at this rank instead of the dense SVD — the large-d*T regime.
    prox_rank: int | None = None
    # engine="batch"/"sharded" only: activations applied per loop step.
    event_batch: int = 1
    # engine="sharded" only: how the server prox is executed at a refresh.
    # "replicated": ONE all_gather assembles the (d, T) stale iterate and
    #   every shard runs the same SVT / randomized SVT on it (the
    #   replicated result is the broadcast back) — O(d*T) communication
    #   and the prox work duplicated n_shards times.
    # "distributed" (requires prox_rank): the rank-distributed randomized
    #   SVT — each shard sketches only its own (d, T/n) column block (one
    #   (d, p) psum), the projected core is assembled with a (p, T/n)
    #   all_gather, and the thresholded reconstruction is applied
    #   shard-locally: O(d*p + p*T) communication, sketch flops divided
    #   by the shard count, no shard ever holds the full iterate.
    prox_mode: str = "replicated"
    # SGD-AMTL (paper §V): if set, every forward step uses an unbiased
    # (n_t/bsz)-scaled seeded minibatch gradient with bsz =
    # min(batch_size, n_t) — the simulator's convention.  The per-event
    # sampling seed is folded off the main PRNG chain (fold_in constant
    # 11, the sketch-key pattern), so the (task, staleness) event stream
    # is untouched and every shard of the sharded engine re-derives the
    # identical seed, sampling shard-locally.  None = exact full
    # gradients, bitwise-identical to the pre-SGD engines.  Supported by
    # the delta, batch, and sharded engines (dense is the exact seed
    # baseline).
    batch_size: int | None = None


class AMTLState(NamedTuple):
    """Dense-engine state: the seed full-iterate staleness ring."""
    ring: Array            # (tau+1, d, T) past iterates, ring[ptr] = newest
    ptr: Array             # int32 index of newest iterate
    event: Array           # int32 global event counter
    history: DelayHistory  # per-task recent delays (for dynamic step)
    key: Array             # PRNG


class DeltaAMTLState(NamedTuple):
    """Delta-engine state: one iterate + an O(tau*d) undo log."""
    v: Array               # (d, T) current iterate (the only full copy)
    delta_ring: Array      # (tau+1, d) pre-write column per event (undo log)
    task_ring: Array       # (tau+1,) int32 task written at each event
    ptr: Array             # int32 slot of the newest event
    event: Array           # int32 global event counter
    p_cache: Array         # (d, T) cached server prox (prox_every > 1)
    history: DelayHistory
    key: Array


class BatchAMTLState(NamedTuple):
    """Batch-engine state: the delta ring with a per-cadence prox cache.

    At the aligned cadence (prox_every == event_batch) the prox is
    refreshed unconditionally at each batch's first event, so no (d, T)
    cache is carried between loop steps (`p_cache` stays a (0, 0) stub) —
    the per-event `lax.cond` copy of that cache is the delta engine's
    dominant non-prox cost.  With the decoupled cadence (prox_every =
    k*event_batch, k > 1) `p_cache` holds the last refreshed prox and is
    reused by the k-1 batches between refreshes.
    """
    v: Array               # (d, T) current iterate (the only full copy)
    delta_ring: Array      # (tau+1, d) pre-write column per event (undo log)
    task_ring: Array       # (tau+1,) int32 task written at each event
    ptr: Array             # int32 slot of the newest event
    event: Array           # int32 global event counter
    p_cache: Array         # (d, T) cached prox (prox_every > event_batch)
    history: DelayHistory
    key: Array


class ShardedAMTLState(NamedTuple):
    """Sharded-engine state, global view (engine='sharded').

    The T task columns live on a 1-D "tasks" mesh axis.  Each shard runs
    the batch engine's conflict-aware column updates on its own block and
    keeps a private undo ring; the task ring holds GLOBAL task ids and —
    like the scalar chain state — is replicated, because every shard
    replays the full serial PRNG chain and masks events to their owner.
    """
    v: Array               # (d, T) iterate, columns sharded over "tasks"
    delta_ring: Array      # (n_shards, tau+1, d) per-shard undo rings
    task_ring: Array       # (tau+1,) int32 GLOBAL task id per event slot
    ptr: Array             # int32 slot of the newest event (replicated)
    event: Array           # int32 global event counter (replicated)
    p_cache: Array         # (d, T) cached prox, replicated (k > 1 cadence)
    history: DelayHistory  # per-task delays, rows sharded over "tasks"
    key: Array             # PRNG (replicated serial chain)


class AMTLResult(NamedTuple):
    v: Array               # final auxiliary iterate V (d, T)
    w: Array               # final primal W = prox(V) (one extra backward)
    objectives: Array      # objective of prox(V) per recorded epoch
    residuals: Array       # BF fixed-point residual per recorded epoch


def init_state(cfg: AMTLConfig, v0: Array, num_tasks: int,
               key: Array) -> AMTLState:
    ring = jnp.broadcast_to(v0, (cfg.tau + 1, *v0.shape)).astype(v0.dtype)
    return AMTLState(
        ring=ring,
        ptr=jnp.zeros((), jnp.int32),
        event=jnp.zeros((), jnp.int32),
        history=DelayHistory.create(num_tasks, cfg.delay_window),
        key=key,
    )


def init_delta_state(cfg: AMTLConfig, v0: Array, num_tasks: int,
                     key: Array) -> DeltaAMTLState:
    depth = cfg.tau + 1
    return DeltaAMTLState(
        v=v0,
        delta_ring=jnp.zeros((depth, v0.shape[0]), v0.dtype),
        task_ring=jnp.zeros((depth,), jnp.int32),
        ptr=jnp.zeros((), jnp.int32),
        event=jnp.zeros((), jnp.int32),
        p_cache=_prox_cache_init(cfg, v0),
        history=DelayHistory.create(num_tasks, cfg.delay_window),
        key=key,
    )


def _prox_cache_init(cfg: AMTLConfig, v0: Array) -> Array:
    """(d, T) zeros when a cache is actually carried, else a (0, 0) stub.

    The aligned cadence (prox_every <= event_batch for the batch engines,
    prox_every == 1 for delta) refreshes before every read and never
    consults the cache, so no dead (d, T) buffer rides the loop carry;
    with amortization, event 0 always refreshes before the first read.
    """
    carried = cfg.prox_every > (cfg.event_batch
                                if cfg.engine in ("batch", "sharded") else 1)
    return jnp.zeros_like(v0) if carried else jnp.zeros((0, 0), v0.dtype)


def init_batch_state(cfg: AMTLConfig, v0: Array, num_tasks: int,
                     key: Array) -> BatchAMTLState:
    depth = cfg.tau + 1
    return BatchAMTLState(
        v=v0,
        delta_ring=jnp.zeros((depth, v0.shape[0]), v0.dtype),
        task_ring=jnp.zeros((depth,), jnp.int32),
        ptr=jnp.zeros((), jnp.int32),
        event=jnp.zeros((), jnp.int32),
        p_cache=_prox_cache_init(cfg, v0),
        history=DelayHistory.create(num_tasks, cfg.delay_window),
        key=key,
    )


def init_sharded_state(cfg: AMTLConfig, v0: Array, num_tasks: int,
                       key: Array, n_shards: int) -> ShardedAMTLState:
    depth = cfg.tau + 1
    return ShardedAMTLState(
        v=v0,
        delta_ring=jnp.zeros((n_shards, depth, v0.shape[0]), v0.dtype),
        task_ring=jnp.zeros((depth,), jnp.int32),
        ptr=jnp.zeros((), jnp.int32),
        event=jnp.zeros((), jnp.int32),
        p_cache=_prox_cache_init(cfg, v0),
        history=DelayHistory.create(num_tasks, cfg.delay_window),
        key=key,
    )


def _sample_activation(cfg: AMTLConfig, delay_offsets: Array, key: Array,
                       num_tasks: int, event: Array):
    """Shared event sampling: (next key, activated task, staleness nu).

    Identical PRNG consumption in both engines => bitwise-reproducible
    event sequences across `engine=` choices.
    """
    key, k_task, k_delay = jax.random.split(key, 3)
    # Assumption 1: same-rate independent Poisson processes => the next
    # activated node is uniform over tasks.
    t = jax.random.randint(k_task, (), 0, num_tasks)
    # Staleness of this node's read (network delay in iterate space).
    raw = delay_offsets[t] + cfg.delay_jitter * jax.random.uniform(k_delay)
    nu = jnp.minimum(jnp.round(raw).astype(jnp.int32),
                     jnp.minimum(cfg.tau, event))
    return key, t, nu


def _minibatch_seed(key: Array) -> Array:
    """Per-event uint32 sampling seed, folded off the pre-event chain key.

    fold_in (constant 11, distinct from the sketch key's 7) does not
    advance the chain, so deriving the seed leaves the (task, staleness)
    event stream bit-identical to the full-gradient engines; and because
    the chain key is replicated on the sharded engine, every shard
    derives the SAME seed for an event and re-creates its selection bits
    locally.
    """
    return jax.random.bits(jax.random.fold_in(key, 11), dtype=jnp.uint32)


def _sample_activation_batch(cfg: AMTLConfig, delay_offsets: Array,
                             key: Array, num_tasks: int, event: Array,
                             batch: int):
    """Replay `batch` steps of the serial PRNG chain in one scan.

    Same splits, same draws, same staleness clamp (`event + i`) as `batch`
    consecutive calls of `_sample_activation` — the event stream is
    identical to the one-event engines by construction.  Returns
    (next key, tasks (batch,), stalenesses (batch,), minibatch seeds
    (batch,) uint32).  Each seed is `_minibatch_seed` of the chain key
    the serial delta engine would hold at that event, so the one-event
    and batched SGD engines sample identical minibatches; when
    batch_size is None the seeds are unused (and dead-code-eliminated).
    """
    def one(k, i):
        seed = _minibatch_seed(k)
        k, t, nu = _sample_activation(cfg, delay_offsets, k, num_tasks,
                                      event + i)
        return k, (t, nu, seed)

    key, (ts, nus, seeds) = jax.lax.scan(one, key, jnp.arange(batch))
    return key, ts, nus, seeds


def _km_relaxation(cfg: AMTLConfig, history: DelayHistory, t: Array,
                   nu: Array):
    """Record the delay and return (updated history, eta_k for this event)."""
    history = history.record(t, nu.astype(jnp.float32))
    if cfg.dynamic_step:
        eta_k = cfg.eta_k * dynamic_multiplier(history.mean_delay(t))
    else:
        eta_k = jnp.asarray(cfg.eta_k, jnp.float32)
    return history, eta_k


def _one_event_dense(problem: MTLProblem, cfg: AMTLConfig,
                     delay_offsets: Array, state: AMTLState) -> AMTLState:
    """One ARock activation on the seed full-iterate ring (O(d*T)/event)."""
    depth = cfg.tau + 1
    key, t, nu = _sample_activation(cfg, delay_offsets, state.key,
                                    problem.num_tasks, state.event)

    # Stale/inconsistent read: all blocks from iterate (k - nu); the node's
    # own block is current (only node t ever writes block t).
    v_cur = state.ring[state.ptr]
    idx = (state.ptr - nu) % depth
    v_hat = state.ring[idx]
    v_hat = v_hat.at[:, t].set(v_cur[:, t])

    # Backward step at the server on the stale copy.
    p = backward(problem, v_hat, cfg.eta)

    # Forward step on the node's block only (separability of I - eta*grad f).
    p_t = p[:, t]
    g_t = problem.task_grad(t, p_t)

    # KM relaxation, optionally delay-adaptive (Eq. III.5/III.6).
    history, eta_k = _km_relaxation(cfg, state.history, t, nu)

    v_t_new = km_block_update(v_cur[:, t], p_t, g_t,
                              jnp.asarray(cfg.eta, p_t.dtype),
                              eta_k.astype(p_t.dtype))
    v_new = v_cur.at[:, t].set(v_t_new)

    ptr = (state.ptr + 1) % depth
    ring = state.ring.at[ptr].set(v_new)
    return AMTLState(ring, ptr, state.event + 1, history, key)


def _one_event_delta(problem: MTLProblem, cfg: AMTLConfig,
                     delay_offsets: Array,
                     state: DeltaAMTLState) -> DeltaAMTLState:
    """One ARock activation on the delta ring (O(d) state writes/event)."""
    from repro.kernels.ops import amtl_event

    depth = cfg.tau + 1
    use_randomized = cfg.prox_rank is not None and problem.reg_name == "nuclear"
    key, t, nu = _sample_activation(cfg, delay_offsets, state.key,
                                    problem.num_tasks, state.event)
    # The sketch key is folded off the pre-event key instead of split from
    # the main chain, so the task/staleness event stream stays identical to
    # the dense engine even when the randomized refresh is enabled.  The
    # minibatch sampling seed follows the same pattern at a different fold
    # constant.
    k_prox = jax.random.fold_in(state.key, 7) if use_randomized else None
    mb_seed = _minibatch_seed(state.key) if cfg.batch_size is not None \
        else None
    v = state.v

    def refresh(_):
        # Lazy stale read: roll back the nu newest undo-log entries, then
        # patch the node's own (always-current) column.  Only paid when the
        # server actually recomputes the prox.
        v_hat = rollback_columns(v, state.delta_ring, state.task_ring,
                                 state.ptr, nu, cfg.tau)
        v_hat = v_hat.at[:, t].set(v[:, t])
        if use_randomized:
            return svt_randomized(
                v_hat, jnp.asarray(cfg.eta * problem.lam, v_hat.dtype),
                rank=cfg.prox_rank, key=k_prox)
        return backward(problem, v_hat, cfg.eta)

    if cfg.prox_every <= 1:
        p = refresh(None)
        p_cache = state.p_cache      # untouched loop carry: no copy
    else:
        do_prox = (state.event % cfg.prox_every) == 0
        p = jax.lax.cond(do_prox, refresh, lambda _: state.p_cache, None)
        p_cache = p

    p_t = p[:, t]
    if cfg.batch_size is None:
        g_t = problem.task_grad(t, p_t)
    else:
        g_t = problem.task_grad_sampled(t, p_t, mb_seed, cfg.batch_size)

    history, eta_k = _km_relaxation(cfg, state.history, t, nu)

    # Fused column event: forward step + KM relaxation + undo-log emit.
    v_t_new, old_col = amtl_event(v[:, t], p_t, g_t,
                                  jnp.asarray(cfg.eta, p_t.dtype),
                                  eta_k.astype(p_t.dtype))

    ptr = (state.ptr + 1) % depth
    return DeltaAMTLState(
        v=v.at[:, t].set(v_t_new),
        delta_ring=state.delta_ring.at[ptr].set(old_col),
        task_ring=state.task_ring.at[ptr].set(t),
        ptr=ptr,
        event=state.event + 1,
        p_cache=p_cache,
        history=history,
        key=key,
    )


def _one_batch(problem: MTLProblem, cfg: AMTLConfig, delay_offsets: Array,
               state: BatchAMTLState) -> BatchAMTLState:
    """`event_batch` ARock activations in one step (batch engine).

    Serial-replay equivalent: the PRNG chain, the amortized prox schedule
    (refresh at batch-first events that are multiples of prox_every), the
    per-event KM arithmetic, and the undo-log contents all match
    `event_batch` consecutive `_one_event_delta` steps bitwise on the CPU
    oracle path — at the aligned cadence (prox_every == event_batch) and
    the decoupled one (prox_every = k*event_batch, refresh every k-th
    batch via the carried prox cache).
    """
    from repro.kernels.ops import amtl_event_batch

    depth = cfg.tau + 1
    bsz = cfg.event_batch
    use_randomized = cfg.prox_rank is not None and problem.reg_name == "nuclear"
    # Folded off the batch-start key — the key the serial engine would hold
    # at its refresh event (a refresh batch's first event).
    k_prox = jax.random.fold_in(state.key, 7) if use_randomized else None
    key, ts, nus, mb_seeds = _sample_activation_batch(
        cfg, delay_offsets, state.key, problem.num_tasks, state.event, bsz)
    v = state.v

    # Server prox at the batch's first event: stale read at staleness nu_0
    # (vectorized rollback — one masked scatter), own column patched
    # current, then the exact or sketched backward step.
    def refresh(_):
        v_hat = rollback_columns_batch(v, state.delta_ring, state.task_ring,
                                       state.ptr, nus[0], cfg.tau)
        v_hat = v_hat.at[:, ts[0]].set(v[:, ts[0]])
        if use_randomized:
            return svt_randomized(v_hat, jnp.asarray(cfg.eta * problem.lam,
                                                     v_hat.dtype),
                                  rank=cfg.prox_rank, key=k_prox)
        return backward(problem, v_hat, cfg.eta)

    if cfg.prox_every <= bsz:
        # Aligned cadence: refresh unconditionally every batch; the (0, 0)
        # cache stub rides the carry untouched (no copy).
        p = refresh(None)
        p_cache = state.p_cache
    else:
        # Decoupled cadence: refresh only at every k-th batch's first
        # event — exactly the events where the serial delta engine at the
        # same prox_every refreshes — else reuse the carried cache.
        do_prox = (state.event % cfg.prox_every) == 0
        p = jax.lax.cond(do_prox, refresh, lambda _: state.p_cache, None)
        p_cache = p

    # Per-event forward-step gradients at the batch-constant prox.  g_t
    # depends only on (t, p[:, t]) — not on v — so duplicates need no
    # serialization here; the scan body issues the same per-event ops as
    # the serial engine, keeping the bits identical.  With batch_size set
    # each event samples its minibatch from the seed the serial delta
    # engine would derive at that chain position.
    p_cols = p[:, ts]                                        # (d, bsz)

    if cfg.batch_size is None:
        def grad_one(_, inp):
            t, p_t = inp
            return None, problem.task_grad(t, p_t)

        _, g_rows = jax.lax.scan(grad_one, None, (ts, p_cols.T))  # (bsz, d)
    else:
        def grad_one(_, inp):
            t, p_t, s = inp
            return None, problem.task_grad_sampled(t, p_t, s,
                                                   cfg.batch_size)

        _, g_rows = jax.lax.scan(grad_one, None, (ts, p_cols.T, mb_seeds))

    # Delay recording / KM relaxation factors, in event order.
    def relax_one(h, inp):
        t, nu = inp
        h, eta_k = _km_relaxation(cfg, h, t, nu)
        return h, eta_k

    history, eta_ks = jax.lax.scan(relax_one, state.history, (ts, nus))

    # Batched column updates: gather -> fused forward/KM/undo-emit ->
    # scatter, duplicates serialized in event order inside the op.
    v_new, undo_cols = amtl_event_batch(
        v, p_cols, g_rows.T, ts, jnp.asarray(cfg.eta, v.dtype),
        eta_ks.astype(v.dtype))

    # Ring append, batched.  Only the newest `depth` events can ever be
    # rolled back (nu <= tau < depth), so when bsz > depth the overwritten
    # head of the batch is dropped; the surviving slots are distinct and
    # the scatter is deterministic.
    keep = min(bsz, depth)
    slots = (state.ptr + 1 + jnp.arange(bsz - keep, bsz)) % depth
    return BatchAMTLState(
        v=v_new,
        delta_ring=state.delta_ring.at[slots].set(undo_cols[bsz - keep:]),
        task_ring=state.task_ring.at[slots].set(ts[bsz - keep:]),
        ptr=(state.ptr + bsz) % depth,
        event=state.event + bsz,
        p_cache=p_cache,
        history=history,
        key=key,
    )


def _sharded_state_specs(cfg: AMTLConfig,
                         axis: str = TASK_AXIS) -> ShardedAMTLState:
    """PartitionSpec tree mirroring ShardedAMTLState's placement classes.

    The prox cache is the one cfg-dependent placement: replicated for the
    broadcast-back replicated prox, column-sharded like the iterate when
    the rank-distributed prox carries its shard-local reconstruction
    across decoupled-cadence batches (see `prox_cache_spec`).
    """
    sp = task_shard_specs(axis)
    carried = cfg.prox_every > cfg.event_batch
    return ShardedAMTLState(
        v=sp["columns"],
        delta_ring=sp["per_shard"],
        task_ring=sp["replicated"],
        ptr=sp["replicated"],
        event=sp["replicated"],
        p_cache=prox_cache_spec(cfg.prox_mode, carried, axis),
        history=DelayHistory(buf=sp["per_task"], count=sp["per_task"]),
        key=sp["replicated"],
    )


def _one_batch_sharded(problem: MTLProblem, cfg: AMTLConfig,
                       delay_offsets: Array, state: ShardedAMTLState, *,
                       mesh) -> ShardedAMTLState:
    """`event_batch` activations with task columns sharded over "tasks".

    Communication schedule — the paper's server/worker pattern, collectives
    only at prox cadence: each shard reconstructs the stale bits of ITS
    columns from its private undo ring, then per refresh (every k-th batch
    under the decoupled cadence prox_every = k*event_batch) either

      prox_mode="replicated": ONE `all_gather` assembles the (d, T) stale
        iterate and every shard runs the same server prox on it (the
        replicated result is the broadcast back, carried in the replicated
        prox cache between refreshes), or
      prox_mode="distributed": the rank-distributed randomized SVT
        (`svt_randomized_dist`) — one (d, p) `psum` of partial sketches +
        one (p, T/n) `all_gather` of projected-core blocks, thresholded
        reconstruction shard-local, cache column-sharded — O(d*p + p*T)
        bytes instead of O(d*T) and the sketch flops divided over shards;

    gradients, column updates, and ring writes stay shard-local either way.

    Every shard replays the full serial PRNG chain and masks events to
    their owner (sentinel column ids drop foreign events inside the batch
    op), so per-shard execution is a masked replay of `_one_batch`: on a
    1-device mesh every expression below degenerates to the batch engine's
    and the iterates match bitwise on the CPU oracle path; at any shard
    count the event stream and the per-column arithmetic are unchanged.
    """
    from repro.kernels.ops import amtl_event_batch_sharded
    from repro.kernels.ref import shard_local_tasks

    axis = TASK_AXIS
    n_shards = mesh.shape[axis]
    num_tasks = problem.num_tasks
    n_local = num_tasks // n_shards
    depth = cfg.tau + 1
    bsz = cfg.event_batch
    use_randomized = cfg.prox_rank is not None and problem.reg_name == "nuclear"
    distributed = cfg.prox_mode == "distributed"
    plan = ProxPlan(axis=axis, num_tasks=num_tasks, n_local=n_local)

    def local_body(problem_l, offs, st):
        t_off = jax.lax.axis_index(axis) * n_local
        # Folded off the batch-start key, replicated — identical to the
        # serial engines' sketch key.
        k_prox = jax.random.fold_in(st.key, 7) if use_randomized else None
        key, ts, nus, mb_seeds = _sample_activation_batch(
            cfg, offs, st.key, num_tasks, st.event, bsz)
        lts, owned = shard_local_tasks(ts, t_off, n_local)
        lts_clamped = jnp.where(owned, lts, 0)
        v = st.v                                   # (d, n_local)
        ring = st.delta_ring[0]                    # (depth, d) private ring

        # Shard-local stale reconstruction at the batch's first event, then
        # patch that event's column current on its owner shard.  Then the
        # refresh collectives, mode-dependent: replicated assembles the
        # global stale iterate with ONE (d, T) all_gather and runs the
        # identical server prox on every shard (result = broadcast);
        # distributed hands the LOCAL stale block to the rank-distributed
        # SVT, which psums a (d, p) sketch partial, gathers the (p, T/n)
        # projected core, and reconstructs only this shard's columns.
        # With the decoupled cadence this whole branch — collectives
        # included — runs only at every k-th batch; the predicate is
        # replicated, so every shard takes the same branch and the
        # collectives stay SPMD-safe.
        def refresh(_):
            v_hat_loc = rollback_columns_shard(v, ring, st.task_ring,
                                               st.ptr, nus[0], cfg.tau,
                                               t_off)
            c0 = jnp.clip(ts[0] - t_off, 0, n_local - 1)
            own0 = (ts[0] >= t_off) & (ts[0] < t_off + n_local)
            v_hat_loc2 = v_hat_loc.at[:, c0].set(
                jnp.where(own0, v[:, c0], v_hat_loc[:, c0]))
            thresh = jnp.asarray(cfg.eta * problem.lam, v_hat_loc2.dtype)
            if distributed:
                return svt_randomized_dist(v_hat_loc2, thresh,
                                           rank=cfg.prox_rank, key=k_prox,
                                           plan=plan)
            v_hat = jax.lax.all_gather(v_hat_loc2, axis, axis=1, tiled=True)
            if use_randomized:
                return svt_randomized(v_hat, thresh, rank=cfg.prox_rank,
                                      key=k_prox)
            return backward(problem_l, v_hat, cfg.eta)

        if cfg.prox_every <= bsz:
            p = refresh(None)
            p_cache = st.p_cache
        else:
            do_prox = (st.event % cfg.prox_every) == 0
            p = jax.lax.cond(do_prox, refresh, lambda _: st.p_cache, None)
            p_cache = p

        # Per-event prox columns.  The replicated prox yields the global
        # (d, T) result, indexed by global task id; the distributed prox
        # yields only this shard's (d, n_local) block, indexed by local
        # column id (foreign events read the clamped column 0 — their
        # whole pipeline is dropped at the scatter).  On the owner shard
        # both index the same bits of the same reconstruction.
        p_cols = p[:, lts_clamped] if distributed else p[:, ts]  # (d, bsz)

        # Forward-step gradients from the shard-local task data.  Foreign
        # events run on clamped inputs and are dropped at the scatter; the
        # owner's expression is the serial engines', on the same bits.
        # Minibatch seeds come from the replicated chain replay, so the
        # owner samples the same rows of its task's (shard-local) data the
        # unsharded engine would at any shard count.
        if cfg.batch_size is None:
            def grad_one(_, inp):
                t_l, p_t = inp
                return None, problem_l.task_grad(t_l, p_t)

            _, g_rows = jax.lax.scan(grad_one, None,
                                     (lts_clamped, p_cols.T))
        else:
            def grad_one(_, inp):
                t_l, p_t, s = inp
                return None, problem_l.task_grad_sampled(t_l, p_t, s,
                                                         cfg.batch_size)

            _, g_rows = jax.lax.scan(grad_one, None,
                                     (lts_clamped, p_cols.T, mb_seeds))

        # Delay recording / KM relaxation in event order; only the owner
        # keeps each event's history write.
        def relax_one(h, inp):
            t_l, nu, own = inp
            h2, eta_k = _km_relaxation(cfg, h, t_l, nu)
            h = jax.tree.map(lambda a, b: jnp.where(own, a, b), h2, h)
            return h, eta_k

        history, eta_ks = jax.lax.scan(relax_one, st.history,
                                       (lts_clamped, nus, owned))

        # Shard-local batched column updates (foreign events -> sentinel
        # column, dropped inside the op) and private-ring append; the task
        # ring records global ids so later rollbacks can re-mask ownership.
        v_new, undo_cols = amtl_event_batch_sharded(
            v, p_cols, g_rows.T, lts, jnp.asarray(cfg.eta, v.dtype),
            eta_ks.astype(v.dtype))

        keep = min(bsz, depth)
        slots = (st.ptr + 1 + jnp.arange(bsz - keep, bsz)) % depth
        return ShardedAMTLState(
            v=v_new,
            delta_ring=ring.at[slots].set(undo_cols[bsz - keep:])[None],
            task_ring=st.task_ring.at[slots].set(ts[bsz - keep:]),
            ptr=(st.ptr + bsz) % depth,
            event=st.event + bsz,
            p_cache=p_cache,
            history=history,
            key=key,
        )

    sp = task_shard_specs(axis)
    state_specs = _sharded_state_specs(cfg, axis)
    if problem.row_counts is None:
        # Uniform problems keep the exact pre-ragged shard_map signature
        # (and therefore the exact trace/bits of the PR-8 engine).
        def local_step(xs, ys, offs, st):
            problem_l = MTLProblem(xs, ys, problem.loss_name,
                                   problem.reg_name, problem.lam)
            return local_body(problem_l, offs, st)

        step = shard_map_compat(
            local_step, mesh=mesh,
            in_specs=(sp["per_task"], sp["per_task"], sp["replicated"],
                      state_specs),
            out_specs=state_specs)
        return step(problem.xs, problem.ys, delay_offsets, state)

    # Ragged: row_counts ride along as one more per_task input — each
    # shard's local problem masks its own tasks' padded rows, everything
    # else (chain replay, ownership masking, collectives) is unchanged.
    def local_step_ragged(xs, ys, rcs, offs, st):
        problem_l = MTLProblem(xs, ys, problem.loss_name,
                               problem.reg_name, problem.lam, rcs)
        return local_body(problem_l, offs, st)

    step = shard_map_compat(
        local_step_ragged, mesh=mesh,
        in_specs=(sp["per_task"], sp["per_task"], sp["per_task"],
                  sp["replicated"], state_specs),
        out_specs=state_specs)
    return step(problem.xs, problem.ys, problem.row_counts, delay_offsets,
                state)


def validate_config(cfg: AMTLConfig, reg_name: str | None = None) -> None:
    """The one config-validation path, shared by `make_engine` (and thus
    `amtl_solve`/`amtl_events_only`) and `default_config`.

    `reg_name` enables the problem-dependent prox_rank check when the
    caller knows the regularizer.
    """
    if cfg.engine not in ("delta", "dense", "batch", "sharded"):
        raise ValueError(f"unknown AMTL engine {cfg.engine!r}; "
                         "expected 'delta', 'dense', 'batch', or 'sharded'")
    if cfg.prox_every < 1:
        raise ValueError(f"prox_every must be >= 1, got {cfg.prox_every} "
                         "(1 = exact prox every event)")
    if cfg.event_batch < 1:
        raise ValueError(f"event_batch must be >= 1, got {cfg.event_batch}")
    if cfg.engine in ("dense", "delta") and cfg.event_batch != 1:
        raise ValueError(
            f"engine={cfg.engine!r} processes one event per step; "
            f"event_batch={cfg.event_batch} requires engine='batch' or "
            "engine='sharded'")
    if cfg.prox_rank is not None and reg_name is not None \
            and reg_name != "nuclear":
        raise ValueError(
            "prox_rank selects the randomized SVT refresh, which only "
            f"exists for reg_name='nuclear' (got {reg_name!r})")
    if cfg.engine == "dense" and (cfg.prox_every != 1
                                  or cfg.prox_rank is not None):
        raise ValueError("engine='dense' is the exact seed baseline; "
                         "prox_every>1 / prox_rank require "
                         "engine='delta', 'batch', or 'sharded'")
    if cfg.batch_size is not None:
        if cfg.batch_size < 1:
            raise ValueError(
                f"batch_size must be >= 1 (or None for exact full "
                f"gradients), got {cfg.batch_size}")
        if cfg.engine == "dense":
            raise ValueError(
                "engine='dense' is the exact seed baseline and computes "
                "full gradients only; batch_size requires engine='delta', "
                "'batch', or 'sharded'")
    if cfg.engine in ("batch", "sharded") \
            and cfg.prox_every % cfg.event_batch != 0:
        raise ValueError(
            f"engine={cfg.engine!r} refreshes the server prox only at "
            f"batch boundaries, so prox_every ({cfg.prox_every}) must be a "
            f"multiple of event_batch ({cfg.event_batch})")
    if cfg.prox_mode not in ("replicated", "distributed"):
        raise ValueError(f"unknown prox_mode {cfg.prox_mode!r}; "
                         "expected 'replicated' or 'distributed'")
    if cfg.prox_mode == "distributed":
        if cfg.engine != "sharded":
            raise ValueError(
                "prox_mode='distributed' is the sharded engine's "
                "rank-distributed server prox; "
                f"engine={cfg.engine!r} has no shards to distribute over")
        if cfg.prox_rank is None:
            raise ValueError(
                "prox_mode='distributed' distributes the RANDOMIZED SVT "
                "sketch, so prox_rank must be set (the exact dense SVD "
                "has no column-separable decomposition to distribute)")


def _resolve_mesh(problem: MTLProblem, cfg: AMTLConfig, mesh):
    """Validate/default the mesh; returns (mesh or None, n_shards or None)."""
    if cfg.engine != "sharded":
        if mesh is not None:
            raise ValueError(
                f"mesh is only meaningful for engine='sharded' "
                f"(got engine={cfg.engine!r})")
        return None, None
    if mesh is None:
        from repro.launch.mesh import make_task_mesh
        mesh = make_task_mesh()
    if TASK_AXIS not in mesh.axis_names:
        raise ValueError(
            f"engine='sharded' needs a mesh with a {TASK_AXIS!r} axis; "
            f"got axes {mesh.axis_names}")
    n_shards = mesh.shape[TASK_AXIS]
    if problem.num_tasks % n_shards != 0:
        raise ValueError(
            f"num_tasks ({problem.num_tasks}) must be divisible by the "
            f"{TASK_AXIS!r} mesh axis size ({n_shards})")
    return mesh, n_shards


def _step_fn(cfg: AMTLConfig, mesh):
    if cfg.engine == "dense":
        return _one_event_dense
    if cfg.engine == "delta":
        return _one_event_delta
    if cfg.engine == "batch":
        return _one_batch
    return functools.partial(_one_batch_sharded, mesh=mesh)


@functools.partial(jax.jit, static_argnames=("cfg", "num_events", "mesh"))
def _run_events(problem: MTLProblem, cfg: AMTLConfig, state,
                delay_offsets: Array, num_events: int, mesh=None):
    """Advance any engine state by `num_events` activations (jitted).

    Module-level so the compile cache is shared across every AMTLEngine
    built for the same (cfg, mesh, num_events) — `make_engine` is cheap to
    call repeatedly.
    """
    step = _step_fn(cfg, mesh)
    per_step = cfg.event_batch if cfg.engine in ("batch", "sharded") else 1
    return jax.lax.fori_loop(
        0, num_events // per_step,
        lambda _, s: step(problem, cfg, delay_offsets, s), state)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _iterate_metrics(problem: MTLProblem, cfg: AMTLConfig, v: Array):
    """(W, objective, BF residual) of the current iterate V."""
    w = backward(problem, v, cfg.eta)
    return w, problem.objective(w), fixed_point_residual(problem, v, cfg.eta)


class AMTLEngine(NamedTuple):
    """A resumable AMTL session: pure jittable functions over an engine
    state (the public stepwise API; `make_engine` builds one).

    init(v0, key) -> state
        Fresh engine state for a (d, T) initial iterate and a PRNG key.
    run(state, delay_offsets, num_events) -> state
        Advance the session by `num_events` activations (jitted; one
        compile per distinct num_events).  `delay_offsets` may be None
        (all-zero mean staleness).  num_events must be a multiple of
        `events_per_step`; run composes bitwise across any such split,
        and a state that round-tripped through `repro.checkpoint`
        resumes bitwise.
    iterate(state) -> V
        The newest (d, T) iterate held by the state (any engine).
    events_per_step
        Step granularity: `event_batch` for the batch/sharded engines,
        1 for dense/delta.
    num_tasks
        T, the problem's task count — so session consumers (the
        learning-while-serving platform in `repro.serve`, examples)
        can validate task ids / size event streams without carrying
        the problem alongside the engine.
    """
    init: Callable[[Array, Array], Any]
    run: Callable[[Any, Array | None, int], Any]
    iterate: Callable[[Any], Array]
    events_per_step: int
    num_tasks: int


def make_engine(problem: MTLProblem, cfg: AMTLConfig,
                mesh=None) -> AMTLEngine:
    """Build the resumable session engine for `cfg` (the public API).

    `mesh` (engine='sharded' only) is the 1-D "tasks" mesh to partition
    the task columns over; default is all visible devices
    (`make_task_mesh`).  Validation runs here, eagerly — `run` never
    raises on a well-formed event count.
    """
    validate_config(cfg, problem.reg_name)
    if cfg.engine == "dense" and problem.row_counts is not None:
        raise ValueError(
            "engine='dense' is the exact uniform seed baseline; ragged "
            "problems (row_counts set) require engine='delta', 'batch', "
            "or 'sharded'")
    mesh, n_shards = _resolve_mesh(problem, cfg, mesh)
    num_tasks = problem.num_tasks
    per_step = cfg.event_batch if cfg.engine in ("batch", "sharded") else 1

    def init(v0: Array, key: Array):
        if cfg.engine == "dense":
            return init_state(cfg, v0, num_tasks, key)
        if cfg.engine == "delta":
            return init_delta_state(cfg, v0, num_tasks, key)
        if cfg.engine == "batch":
            return init_batch_state(cfg, v0, num_tasks, key)
        return init_sharded_state(cfg, v0, num_tasks, key, n_shards)

    def run(state, delay_offsets, num_events: int):
        if num_events % per_step != 0:
            raise ValueError(
                f"num_events ({num_events}) must be a multiple of "
                f"event_batch ({per_step}) for engine={cfg.engine!r}")
        if delay_offsets is None:
            delay_offsets = jnp.zeros((num_tasks,), jnp.float32)
        return _run_events(problem, cfg, state, delay_offsets,
                           int(num_events), mesh)

    return AMTLEngine(init=init, run=run, iterate=current_iterate,
                      events_per_step=per_step, num_tasks=num_tasks)


def amtl_solve(problem: MTLProblem, cfg: AMTLConfig, v0: Array, key: Array,
               num_epochs: int, events_per_epoch: int | None = None,
               delay_offsets: Array | None = None, mesh=None) -> AMTLResult:
    """Run AMTL for num_epochs * events_per_epoch activations.

    One "epoch" defaults to T events (each node activated once in
    expectation), matching the paper's per-iteration accounting ("every task
    node updates one forward step for each iteration").

    Thin wrapper over the session API: each epoch is one `engine.run`
    advance followed by the (full-SVD) objective/residual metric tail.
    `mesh` (engine='sharded' only) is the 1-D "tasks" mesh to partition the
    task columns over; default is all visible devices (`make_task_mesh`).
    """
    engine = make_engine(problem, cfg, mesh)
    if events_per_epoch is None:
        events_per_epoch = problem.num_tasks
    if events_per_epoch % engine.events_per_step != 0:
        raise ValueError(
            f"events_per_epoch ({events_per_epoch}) must be a multiple of "
            f"event_batch ({engine.events_per_step}) for "
            f"engine={cfg.engine!r}")

    state = engine.init(v0, key)
    objs, ress, w = [], [], None
    for _ in range(num_epochs):
        state = engine.run(state, delay_offsets, events_per_epoch)
        w, obj, res = _iterate_metrics(problem, cfg, engine.iterate(state))
        objs.append(obj)
        ress.append(res)
    v = engine.iterate(state)
    if w is None:                      # num_epochs == 0
        w = _iterate_metrics(problem, cfg, v)[0]
    empty = jnp.zeros((0,), jnp.float32)
    return AMTLResult(v, w,
                      jnp.stack(objs) if objs else empty,
                      jnp.stack(ress) if ress else empty)


def amtl_events_only(problem: MTLProblem, cfg: AMTLConfig, v0: Array,
                     key: Array, num_events: int,
                     delay_offsets: Array | None = None, mesh=None):
    """Run `num_events` activations with NO per-epoch metric tail.

    Returns the final engine state (AMTLState, DeltaAMTLState,
    BatchAMTLState, or ShardedAMTLState, matching `cfg.engine`).  This is
    the events/sec benchmark path: it isolates the per-event engine cost
    from the (full-SVD) objective/residual instrumentation of `amtl_solve`.
    Thin wrapper over the session API (init + one `run`).
    """
    engine = make_engine(problem, cfg, mesh)
    return engine.run(engine.init(v0, key), delay_offsets, num_events)


def current_iterate(state) -> Array:
    """The newest iterate V held by any engine's state."""
    if isinstance(state, (DeltaAMTLState, BatchAMTLState, ShardedAMTLState)):
        return state.v
    return state.ring[state.ptr]


def default_config(problem: MTLProblem, tau: int = 4, c: float = 0.9,
                   dynamic_step: bool = False, safety: float = 1.0, *,
                   engine: str = "delta", prox_every: int = 1,
                   prox_rank: int | None = None, event_batch: int = 1,
                   prox_mode: str = "replicated",
                   batch_size: int | None = None) -> AMTLConfig:
    """Step sizes from Theorem 1: eta < 2/L, eta_k <= c/(2 tau/sqrt(T)+1).

    Engine-selection kwargs (`engine`, `prox_every`, `prox_rank`,
    `event_batch`, `prox_mode`, `batch_size`) go through
    `validate_config` — the same path `make_engine` runs — so an invalid
    combination fails here, not at the first solve.
    """
    lip = problem.lipschitz()
    cfg = AMTLConfig(
        eta=safety / lip,
        eta_k=amtl_max_step(tau, problem.num_tasks, c),
        tau=tau,
        dynamic_step=dynamic_step,
        engine=engine,
        prox_every=prox_every,
        prox_rank=prox_rank,
        event_batch=event_batch,
        prox_mode=prox_mode,
        batch_size=batch_size,
    )
    validate_config(cfg, problem.reg_name)
    return cfg
