"""AMTL — asynchronous backward-forward coordinate updates (Algorithm 1).

SPMD execution of the ARock semantics: the physical asynchrony of the paper
(threads racing on shared memory) is replayed as a *sequential consistency
simulation* inside `lax.scan`/`fori_loop`:

  event k:  a task t_k is activated (uniform — Poisson thinning under
            Assumption 1);  it reads the server state at staleness nu_k <= tau
            from a ring buffer of past iterates (stale AND inconsistent reads:
            every block but its own comes from an older iterate);  the server
            computes the backward step prox_{eta*lam*g} on that stale copy;
            the node applies the forward step on its block and writes back
            with KM relaxation eta_k (Eq. III.4), optionally scaled by the
            delay-adaptive multiplier (Eq. III.5/III.6).

This is bit-faithful to Algorithm 1's mathematics while being jit-compiled,
deterministic under a PRNG key, and mesh-shardable.  Wall-clock behaviour
(Tables I/III) is studied separately by `repro.core.simulator`.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.dynamic_step import DelayHistory, dynamic_multiplier
from repro.core.losses import MTLProblem
from repro.core.operators import amtl_max_step, backward, km_block_update
from repro.core.prox import get_regularizer

Array = jax.Array


class AMTLConfig(NamedTuple):
    eta: float                 # inner forward/backward step, in (0, 2/L)
    eta_k: float               # KM relaxation, <= amtl_max_step(tau, T)
    tau: int                   # max staleness (ring-buffer depth - 1)
    dynamic_step: bool = False
    delay_window: int = 5      # paper averages the last 5 delays
    # Per-task mean staleness (in events). The sampled delay is
    # min(round(offset_t + U[0,1) * jitter), tau). offsets=None => all zero.
    delay_jitter: float = 1.0


class AMTLState(NamedTuple):
    ring: Array            # (tau+1, d, T) past iterates, ring[ptr] = newest
    ptr: Array             # int32 index of newest iterate
    event: Array           # int32 global event counter
    history: DelayHistory  # per-task recent delays (for dynamic step)
    key: Array             # PRNG


class AMTLResult(NamedTuple):
    v: Array               # final auxiliary iterate V (d, T)
    w: Array               # final primal W = prox(V) (one extra backward)
    objectives: Array      # objective of prox(V) per recorded epoch
    residuals: Array       # BF fixed-point residual per recorded epoch


def init_state(cfg: AMTLConfig, v0: Array, num_tasks: int,
               key: Array) -> AMTLState:
    ring = jnp.broadcast_to(v0, (cfg.tau + 1, *v0.shape)).astype(v0.dtype)
    return AMTLState(
        ring=ring,
        ptr=jnp.zeros((), jnp.int32),
        event=jnp.zeros((), jnp.int32),
        history=DelayHistory.create(num_tasks, cfg.delay_window),
        key=key,
    )


def _one_event(problem: MTLProblem, cfg: AMTLConfig,
               delay_offsets: Array, state: AMTLState) -> AMTLState:
    """One ARock activation (one line of Algorithm 1's while-loop)."""
    depth = cfg.tau + 1
    num_tasks = problem.num_tasks
    key, k_task, k_delay = jax.random.split(state.key, 3)

    # Assumption 1: same-rate independent Poisson processes => the next
    # activated node is uniform over tasks.
    t = jax.random.randint(k_task, (), 0, num_tasks)

    # Staleness of this node's read (network delay in iterate space).
    raw = delay_offsets[t] + cfg.delay_jitter * jax.random.uniform(k_delay)
    nu = jnp.minimum(jnp.round(raw).astype(jnp.int32),
                     jnp.minimum(cfg.tau, state.event))

    # Stale/inconsistent read: all blocks from iterate (k - nu); the node's
    # own block is current (only node t ever writes block t).
    v_cur = state.ring[state.ptr]
    idx = (state.ptr - nu) % depth
    v_hat = state.ring[idx]
    v_hat = v_hat.at[:, t].set(v_cur[:, t])

    # Backward step at the server on the stale copy.
    p = backward(problem, v_hat, cfg.eta)

    # Forward step on the node's block only (separability of I - eta*grad f).
    p_t = p[:, t]
    g_t = problem.task_grad(t, p_t)

    # KM relaxation, optionally delay-adaptive (Eq. III.5/III.6).
    history = state.history.record(t, nu.astype(jnp.float32))
    if cfg.dynamic_step:
        eta_k = cfg.eta_k * dynamic_multiplier(history.mean_delay(t))
    else:
        eta_k = jnp.asarray(cfg.eta_k, jnp.float32)

    v_t_new = km_block_update(v_cur[:, t], p_t, g_t,
                              jnp.asarray(cfg.eta, p_t.dtype),
                              eta_k.astype(p_t.dtype))
    v_new = v_cur.at[:, t].set(v_t_new)

    ptr = (state.ptr + 1) % depth
    ring = state.ring.at[ptr].set(v_new)
    return AMTLState(ring, ptr, state.event + 1, history, key)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "num_epochs", "events_per_epoch"))
def amtl_solve(problem: MTLProblem, cfg: AMTLConfig, v0: Array, key: Array,
               num_epochs: int, events_per_epoch: int | None = None,
               delay_offsets: Array | None = None) -> AMTLResult:
    """Run AMTL for num_epochs * events_per_epoch activations.

    One "epoch" defaults to T events (each node activated once in
    expectation), matching the paper's per-iteration accounting ("every task
    node updates one forward step for each iteration").
    """
    num_tasks = problem.num_tasks
    if events_per_epoch is None:
        events_per_epoch = num_tasks
    if delay_offsets is None:
        delay_offsets = jnp.zeros((num_tasks,), jnp.float32)

    state0 = init_state(cfg, v0, num_tasks, key)

    def epoch(state, _):
        state = jax.lax.fori_loop(
            0, events_per_epoch,
            lambda _, s: _one_event(problem, cfg, delay_offsets, s), state)
        v = state.ring[state.ptr]
        w = backward(problem, v, cfg.eta)
        obj = problem.objective(w)
        from repro.core.operators import fixed_point_residual
        res = fixed_point_residual(problem, v, cfg.eta)
        return state, (obj, res)

    state, (objs, ress) = jax.lax.scan(epoch, state0, None, length=num_epochs)
    v = state.ring[state.ptr]
    w = backward(problem, v, cfg.eta)
    return AMTLResult(v, w, objs, ress)


def default_config(problem: MTLProblem, tau: int = 4, c: float = 0.9,
                   dynamic_step: bool = False,
                   safety: float = 1.0) -> AMTLConfig:
    """Step sizes from Theorem 1: eta < 2/L, eta_k <= c/(2 tau/sqrt(T)+1)."""
    lip = problem.lipschitz()
    return AMTLConfig(
        eta=safety / lip,
        eta_k=amtl_max_step(tau, problem.num_tasks, c),
        tau=tau,
        dynamic_step=dynamic_step,
    )
