"""AMTL core: the paper's contribution as composable JAX modules."""
from repro.core.amtl import (AMTLConfig, AMTLEngine, AMTLResult,
                             amtl_events_only, amtl_solve, current_iterate,
                             default_config, make_engine, validate_config)
from repro.core.dynamic_step import DelayHistory, dynamic_multiplier
from repro.core.losses import MTLProblem, get_loss
from repro.core.operators import (amtl_max_step, backward, backward_forward,
                                  fixed_point_residual, forward,
                                  forward_backward, km_block_update, km_step,
                                  rollback_columns, rollback_columns_batch,
                                  rollback_columns_shard)
from repro.core.prox import (ProxPlan, apply_prox, get_regularizer,
                             sketch_width, svt_randomized,
                             svt_randomized_dist)
from repro.core.simulator import (NetworkModel, SimProblem, SimResult,
                                  make_synthetic, simulate_amtl,
                                  simulate_smtl)
from repro.core.smtl import fista_solve, reference_optimum, smtl_solve

__all__ = [
    "AMTLConfig", "AMTLEngine", "AMTLResult", "amtl_events_only",
    "amtl_solve", "make_engine", "validate_config",
    "current_iterate", "default_config", "rollback_columns",
    "rollback_columns_batch", "rollback_columns_shard",
    "DelayHistory", "dynamic_multiplier", "MTLProblem", "get_loss",
    "amtl_max_step", "backward", "backward_forward", "fixed_point_residual",
    "forward", "forward_backward", "km_block_update", "km_step",
    "ProxPlan", "sketch_width", "svt_randomized", "svt_randomized_dist",
    "apply_prox", "get_regularizer", "NetworkModel", "SimProblem",
    "SimResult", "make_synthetic", "simulate_amtl", "simulate_smtl",
    "fista_solve", "reference_optimum", "smtl_solve",
]
