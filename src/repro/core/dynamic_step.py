"""Delay-adaptive dynamic step size (paper Sec. III-D, Eq. III.5/III.6).

The KM relaxation of task t at event k is scaled by

    c_(t,k) = log(max(nu_bar_{t,k}, 10))

where nu_bar is the mean of the node's recent communication delays (the
paper averages the last 5).  Longer historical delay => larger step, to
compensate the lower effective activation rate (Remark 1).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class DelayHistory(NamedTuple):
    """Per-task ring buffer of recent delays."""

    buf: Array     # (T, window) float32, initialized to zero
    count: Array   # (T,) int32 — number of delays recorded so far

    @staticmethod
    def create(num_tasks: int, window: int = 5) -> "DelayHistory":
        return DelayHistory(
            jnp.zeros((num_tasks, window), jnp.float32),
            jnp.zeros((num_tasks,), jnp.int32),
        )

    def record(self, task: Array, delay: Array) -> "DelayHistory":
        """Record `delay` for `task` (scalar int32 index)."""
        window = self.buf.shape[1]
        slot = self.count[task] % window
        buf = self.buf.at[task, slot].set(delay.astype(jnp.float32))
        count = self.count.at[task].add(1)
        return DelayHistory(buf, count)

    def mean_delay(self, task: Array) -> Array:
        """Mean of the recorded delays for `task` (0 if none yet)."""
        window = self.buf.shape[1]
        n = jnp.minimum(self.count[task], window)
        total = jnp.sum(self.buf[task])
        return jnp.where(n > 0, total / jnp.maximum(n, 1), 0.0)

    def mean_delay_all(self) -> Array:
        """(T,) vector of per-task mean recent delays."""
        window = self.buf.shape[1]
        n = jnp.minimum(self.count, window)
        total = jnp.sum(self.buf, axis=1)
        return jnp.where(n > 0, total / jnp.maximum(n, 1), 0.0)


def dynamic_multiplier(mean_delay: Array) -> Array:
    """c = log(max(nu_bar, 10)) — Eq. III.6 (natural log, >= log 10)."""
    return jnp.log(jnp.maximum(mean_delay, 10.0))
