"""Mesh-AMTL: the paper's technique as a first-class train_step feature.

T task-specific linear probes W = [w_1..w_T] in R^{d_model x T} sit on the
backbone's pooled hidden state and are coupled by a non-smooth regularizer
(nuclear norm by default — shared-subspace MTL, paper Sec. IV).  They are
NOT updated by the smooth optimizer; instead each train step performs one
mesh-adapted AMTL round (DESIGN.md §3, mode 3):

  * activation mask  m ~ Bernoulli(rate)^T      (Poisson thinning, Asm. 1)
  * per-task stale read from a ring buffer of the last tau+1 iterates
    (nu_t sampled <= tau — ICI-delay in iterate space)
  * backward step: p = prox_{eta lam g}(v_hat) at the "server" (an
    all-gather of the task-sharded head on real hardware)
  * forward step on active blocks only, with the analytic least-squares
    probe gradient (the probe IS the paper's per-task linear model)
  * KM write-back with the delay-adaptive step of Eq. III.5/III.6

The probe loss also flows into the backbone (inductive transfer to the
representation), but W itself sees only AMTL updates.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import MTLCfg
from repro.core.dynamic_step import dynamic_multiplier
from repro.core.operators import amtl_max_step
from repro.core.prox import get_regularizer

Array = jax.Array


class MTLHeadState(NamedTuple):
    ring: Array          # (tau+1, d, T) fp32 — past iterates of V
    ptr: Array           # () int32 newest slot
    step: Array          # () int32 events so far
    delay_buf: Array     # (T, window) fp32 recent staleness per task
    delay_cnt: Array     # (T,) int32


def init_mtl_state(d_model: int, cfg: MTLCfg, window: int = 5
                   ) -> MTLHeadState:
    t = cfg.num_tasks
    return MTLHeadState(
        ring=jnp.zeros((cfg.tau + 1, d_model, t), jnp.float32),
        ptr=jnp.zeros((), jnp.int32),
        step=jnp.zeros((), jnp.int32),
        delay_buf=jnp.zeros((t, window), jnp.float32),
        delay_cnt=jnp.zeros((t,), jnp.int32),
    )


def stale_read(state: MTLHeadState, cfg: MTLCfg, key: Array
               ) -> tuple[Array, Array]:
    """Per-task stale read v_hat (d, T) and the sampled staleness (T,)."""
    depth = cfg.tau + 1
    t = state.ring.shape[-1]
    nu = jax.random.randint(key, (t,), 0, cfg.tau + 1)
    nu = jnp.minimum(nu, state.step)                     # can't pre-date t=0
    idx = (state.ptr - nu) % depth                       # (T,)
    # Column t comes from iterate (k - nu_t): a stale AND inconsistent read
    # (different columns from different pasts) — exactly the read model the
    # ARock analysis covers.  The own-block term of Eq. III.4 uses the
    # current iterate (see amtl_head_update: delta is computed vs v_cur).
    v_hat = state.ring[idx, :, jnp.arange(t)].T          # (d, T)
    return v_hat, nu


def probe_predictions(p_cols: Array, pooled: Array, task_ids: Array
                      ) -> Array:
    """y_hat_i = pooled_i . p[:, task_i].  pooled: (B, d) fp32."""
    w_per_ex = p_cols.T[task_ids]                        # (B, d)
    return jnp.sum(pooled * w_per_ex, axis=-1)


def probe_loss(p_cols: Array, pooled: Array, task_ids: Array,
               targets: Array) -> Array:
    """Least-squares probe loss (the paper's regression tasks)."""
    r = probe_predictions(p_cols, pooled, task_ids) - targets
    return jnp.mean(r * r)


def probe_task_grads(p_cols: Array, pooled: Array, task_ids: Array,
                     targets: Array) -> Array:
    """Analytic d loss_t / d p_t, column-stacked (d, T).

    loss_t = sum_{i in task t} (pooled_i . p_t - y_i)^2  (paper Eq. III.2's
    separable gradient, computed without a second autodiff pass).
    """
    t = p_cols.shape[1]
    r = probe_predictions(p_cols, pooled, task_ids) - targets   # (B,)
    onehot = jax.nn.one_hot(task_ids, t, dtype=pooled.dtype)    # (B, T)
    g = 2.0 * jnp.einsum("bd,b,bt->dt", pooled, r, onehot)
    counts = jnp.maximum(jnp.sum(onehot, axis=0), 1.0)
    return g / counts                                   # mean per task


def amtl_head_update(state: MTLHeadState, pooled: Array, task_ids: Array,
                     targets: Array, cfg: MTLCfg, key: Array,
                     read: tuple[Array, Array] | None = None
                     ) -> tuple[MTLHeadState, dict]:
    """One mesh-AMTL round.  Returns (new state, metrics).

    `read` may carry a precomputed (p, nu) pair so train_step can reuse the
    same backward-step output for the probe loss and the head update.
    """
    reg = get_regularizer(cfg.reg_name)
    k_read, k_act = jax.random.split(key)
    t = state.ring.shape[-1]
    depth = cfg.tau + 1

    v_cur = state.ring[state.ptr]                        # (d, T)
    if read is None:
        v_hat, nu = stale_read(state, cfg, k_read)
        # backward step (server prox) on the stale read
        p = reg.prox(v_hat, jnp.asarray(cfg.eta * cfg.lam, jnp.float32))
    else:
        p, nu = read

    # forward step: analytic probe gradient at p
    g = probe_task_grads(p, pooled.astype(jnp.float32), task_ids,
                         targets.astype(jnp.float32))

    # delay-adaptive KM relaxation (Eq. III.5/III.6), capped by Theorem 1
    window = state.delay_buf.shape[1]
    slot = state.delay_cnt % window
    delay_buf = state.delay_buf.at[jnp.arange(t), slot].set(
        nu.astype(jnp.float32))
    delay_cnt = state.delay_cnt + 1
    n_recent = jnp.minimum(delay_cnt, window)
    mean_delay = jnp.sum(delay_buf, axis=1) / jnp.maximum(n_recent, 1)
    base = min(cfg.km_relax, amtl_max_step(cfg.tau, t, 0.99) * 3.0)
    mult = jnp.where(cfg.dynamic_step, dynamic_multiplier(mean_delay) /
                     dynamic_multiplier(jnp.zeros_like(mean_delay)), 1.0)
    eta_k = base * mult                                  # (T,)

    # Poisson-thinned activation mask (Assumption 1)
    m = jax.random.bernoulli(k_act, cfg.activation_rate, (t,))

    delta = p - cfg.eta * g - v_cur                      # fused Eq. III.4
    v_new = v_cur + jnp.where(m[None, :], eta_k[None, :] * delta, 0.0)

    ptr = (state.ptr + 1) % depth
    ring = state.ring.at[ptr].set(v_new)
    new_state = MTLHeadState(ring, ptr, state.step + 1, delay_buf, delay_cnt)
    metrics = {
        "mtl_active_frac": jnp.mean(m.astype(jnp.float32)),
        "mtl_mean_staleness": jnp.mean(nu.astype(jnp.float32)),
        "mtl_v_norm": jnp.linalg.norm(v_new),
    }
    return new_state, metrics


def head_weights(state: MTLHeadState, cfg: MTLCfg) -> Array:
    """W = prox(V) — the deployable multi-task head (one extra backward)."""
    reg = get_regularizer(cfg.reg_name)
    v = state.ring[state.ptr]
    return reg.prox(v, jnp.asarray(cfg.eta * cfg.lam, jnp.float32))
