"""Synchronous distributed MTL baselines (paper Sec. III-B).

SMTL = synchronized proximal gradient: every iteration gathers all T task
gradients (the map-reduce round the paper criticizes), then the server
applies the proximal mapping.  Also provides FISTA acceleration [20] as the
centralized reference solver used to compute ground-truth optima in tests.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.losses import MTLProblem
from repro.core.operators import backward, forward_backward

Array = jax.Array


class SolveResult(NamedTuple):
    w: Array               # final model matrix (d, T)
    objectives: Array      # objective after each iteration (num_iters,)
    residuals: Array       # ||W_{k+1} - W_k||_F per iteration


@functools.partial(jax.jit, static_argnames=("num_iters",))
def smtl_solve(problem: MTLProblem, w0: Array, eta: float,
               num_iters: int) -> SolveResult:
    """Synchronous proximal gradient descent (ISTA form of SMTL)."""

    def body(w, _):
        w_next = forward_backward(problem, w, eta)
        obj = problem.objective(w_next)
        res = jnp.linalg.norm(w_next - w)
        return w_next, (obj, res)

    w_final, (objs, ress) = jax.lax.scan(body, w0, None, length=num_iters)
    return SolveResult(w_final, objs, ress)


@functools.partial(jax.jit, static_argnames=("num_iters",))
def fista_solve(problem: MTLProblem, w0: Array, eta: float,
                num_iters: int) -> SolveResult:
    """FISTA [20] — accelerated centralized reference solver."""

    def body(carry, _):
        w, z, t = carry
        w_next = forward_backward(problem, z, eta)
        t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        z_next = w_next + ((t - 1.0) / t_next) * (w_next - w)
        obj = problem.objective(w_next)
        res = jnp.linalg.norm(w_next - w)
        return (w_next, z_next, t_next), (obj, res)

    (w_final, _, _), (objs, ress) = jax.lax.scan(
        body, (w0, w0, jnp.asarray(1.0, w0.dtype)), None, length=num_iters)
    return SolveResult(w_final, objs, ress)


def reference_optimum(problem: MTLProblem, eta: float | None = None,
                      num_iters: int = 2000) -> tuple[Array, Array]:
    """High-accuracy (W*, obj*) via FISTA, for convergence assertions."""
    if eta is None:
        eta = 1.0 / problem.lipschitz()
    d, T = problem.dim, problem.num_tasks
    w0 = jnp.zeros((d, T), dtype=jnp.float32)
    res = fista_solve(problem, w0, eta, num_iters)
    return res.w, res.objectives[-1]
