"""Discrete-event simulator for AMTL vs SMTL wall-clock behaviour.

Reproduces the paper's experimental protocol (Sec. IV): task nodes are kept
idle for `offset + U(0,1)` seconds after each forward step to simulate
network delay; the server serializes proximal mappings.  Unlike the paper's
C++/threads implementation, this is a deterministic discrete-event simulation
— node clocks, stale snapshot reads, and server serialization are explicit —
so Tables I/III/IV-VI and Figs 3-4 are reproducible bit-for-bit under a seed.

The optimization mathematics executed at each event is the *real* AMTL
update (Eq. III.4) on the real data, so objective-vs-iteration curves
(Fig. 4) come out of the same run as the timing.

Supports ragged task sizes and heterogeneous losses (regression +
classification mixed), like the paper's School/MNIST/MTFL setups.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, Literal, Sequence

import numpy as np


# ---------------------------------------------------------------------------
# Numpy problem container (ragged, heterogeneous)
# ---------------------------------------------------------------------------

def _lstsq_grad(x, y, w):
    return 2.0 * x.T @ (x @ w - y)


def _lstsq_val(x, y, w):
    r = x @ w - y
    return float(r @ r)


def _logistic_grad(x, y, w):
    z = y * (x @ w)
    s = 1.0 / (1.0 + np.exp(np.clip(z, -60, 60)))
    return -(x.T @ (s * y))


def _logistic_val(x, y, w):
    z = y * (x @ w)
    return float(np.sum(np.logaddexp(0.0, -z)))


_NP_LOSSES = {
    "lstsq": (_lstsq_val, _lstsq_grad),
    "logistic": (_logistic_val, _logistic_grad),
}


def _svt(w, t):
    u, s, vt = np.linalg.svd(w, full_matrices=False)
    return (u * np.maximum(s - t, 0.0)) @ vt


def _l21_prox(w, t):
    norms = np.linalg.norm(w, axis=1, keepdims=True)
    return w * np.maximum(0.0, 1.0 - t / np.maximum(norms, 1e-12))


def _nuclear_val(w):
    return float(np.sum(np.linalg.svd(w, compute_uv=False)))


def _l21_val(w):
    return float(np.sum(np.linalg.norm(w, axis=1)))


_NP_REGS = {
    "nuclear": (_nuclear_val, _svt),
    "l21": (_l21_val, _l21_prox),
    "none": (lambda w: 0.0, lambda w, t: w),
}


@dataclass
class SimProblem:
    """Ragged multi-task problem held in host memory."""

    xs: Sequence[np.ndarray]          # T arrays (n_t, d)
    ys: Sequence[np.ndarray]          # T arrays (n_t,)
    losses: Sequence[str]             # per-task loss name (heterogeneous ok)
    reg_name: str = "nuclear"
    lam: float = 0.1

    def __post_init__(self):
        self.xs = [np.asarray(x, np.float64) for x in self.xs]
        self.ys = [np.asarray(y, np.float64) for y in self.ys]
        if isinstance(self.losses, str):
            self.losses = [self.losses] * len(self.xs)

    @property
    def num_tasks(self) -> int:
        return len(self.xs)

    @property
    def dim(self) -> int:
        return self.xs[0].shape[1]

    def task_grad(self, t: int, w_t: np.ndarray) -> np.ndarray:
        return _NP_LOSSES[self.losses[t]][1](self.xs[t], self.ys[t], w_t)

    def prox(self, v: np.ndarray, t: float) -> np.ndarray:
        return _NP_REGS[self.reg_name][1](v, t)

    def objective(self, w: np.ndarray) -> float:
        f = sum(_NP_LOSSES[self.losses[t]][0](self.xs[t], self.ys[t], w[:, t])
                for t in range(self.num_tasks))
        return f + self.lam * _NP_REGS[self.reg_name][0](w)

    def lipschitz(self) -> float:
        out = 0.0
        for t in range(self.num_tasks):
            s = np.linalg.svd(self.xs[t], compute_uv=False)
            smax = s[0] ** 2 if s.size else 1.0
            out = max(out, 2.0 * smax if self.losses[t] == "lstsq"
                      else 0.25 * smax)
        return out


@dataclass
class NetworkModel:
    """Per-cycle node cost: compute + (offset + U[0,1)) network delay.

    Matches the paper's protocol: AMTL-5/10/30 <=> delay_offset 5/10/30 s.
    """

    delay_offset: float = 5.0
    delay_jitter: float = 1.0
    compute_time: float | Sequence[float] = 0.1   # gradient cost per node
    prox_time: float = 0.05                       # server SVT cost

    def node_compute(self, t: int) -> float:
        if np.isscalar(self.compute_time):
            return float(self.compute_time)
        return float(self.compute_time[t])


@dataclass
class SimResult:
    total_time: float
    event_times: list[float] = field(default_factory=list)
    objectives: list[float] = field(default_factory=list)
    w: np.ndarray | None = None
    iterations: int = 0


# ---------------------------------------------------------------------------
# AMTL (asynchronous) event loop
# ---------------------------------------------------------------------------

def simulate_amtl(problem: SimProblem, net: NetworkModel, num_epochs: int,
                  eta: float | None = None, eta_k: float | None = None,
                  tau: int | None = None, dynamic_step: bool = False,
                  delay_window: int = 5, seed: int = 0,
                  record_objective: bool = True,
                  batch_size: int | None = None,
                  prox_every: int = 1) -> SimResult:
    """Event-driven AMTL: each node performs `num_epochs` cycles.

    cycle(t):  snapshot <- server V (stale read at cycle start)
               p = prox(snapshot);  g = grad_t(p_t)        [compute c_t]
               idle for offset + U(0,1)                    [network delay]
               server applies KM write of block t (serialized prox slot)

    batch_size: SGD-AMTL (the paper's §V future work) — each activation
    uses an unbiased (n_t/b)-scaled minibatch gradient and the node's
    compute time shrinks proportionally, so a node completes ~n_t/b more
    asynchronous cycles in the same wall-clock.  `num_epochs` then counts
    minibatch cycles; callers normalize for equal data passes.

    prox_every: server-side prox batching (paper §III-C: "the proximal
    mapping can be also applied after several gradient updates") — the
    server pays `prox_time` only on every K-th write, amortizing the SVT
    when T is large relative to the network delay (the School regime of
    Table III).  Writes between proxes read a cached prox of V.
    """
    rng = np.random.default_rng(seed)
    # separate stream for minibatch sampling: keeps the event/delay
    # sequence identical across batch sizes (including batch == n == full)
    data_rng = np.random.default_rng((seed + 1) * 7919)
    T, d = problem.num_tasks, problem.dim
    lip = problem.lipschitz()
    if eta is None:
        eta = 1.0 / lip
    if tau is None:
        tau = T  # every other node may write once between read and write
    if eta_k is None:
        c = 0.9
        eta_k = c / (2.0 * tau / np.sqrt(T) + 1.0)

    v = np.zeros((d, T))
    delays_hist: list[list[float]] = [[] for _ in range(T)]
    result = SimResult(0.0)

    # Event queue holds (write_time, seq, task, snapshot-at-read).
    # Each node immediately starts its next cycle after its write completes.
    heap: list[tuple[float, int, int, np.ndarray]] = []
    seq = 0
    cycles_left = [num_epochs] * T
    server_free = 0.0

    def compute_cost(t: int) -> float:
        c = net.node_compute(t)
        if batch_size is not None:
            n_t = problem.xs[t].shape[0]
            c *= min(1.0, batch_size / max(n_t, 1))
        return c

    def schedule(t: int, start: float):
        nonlocal seq
        delay = net.delay_offset + net.delay_jitter * rng.random()
        delays_hist[t].append(delay)
        write_time = start + compute_cost(t) + delay
        heapq.heappush(heap, (write_time, seq, t, v.copy()))
        seq += 1

    for t in range(T):
        schedule(t, 0.0)

    events = 0
    cached_prox: np.ndarray | None = None
    while heap:
        write_time, _, t, snapshot = heapq.heappop(heap)
        # Server serializes proximal mappings; with prox_every > 1 the
        # server only pays the SVT on every K-th write (paper §III-C).
        do_prox = (events % prox_every == 0) or cached_prox is None
        start_srv = max(write_time, server_free)
        server_free = start_srv + (net.prox_time if do_prox else 0.0)
        now = server_free

        # Math of Eq. III.4 on the stale snapshot (own block is current).
        snapshot[:, t] = v[:, t]
        if do_prox:
            p = problem.prox(snapshot, eta * problem.lam)
            cached_prox = p
        else:
            p = cached_prox
        if batch_size is None:
            g = problem.task_grad(t, p[:, t])
        else:  # unbiased minibatch gradient (SGD-AMTL)
            n_t = problem.xs[t].shape[0]
            bsz = min(batch_size, n_t)
            idx = data_rng.choice(n_t, size=bsz, replace=False)
            sub_grad = _NP_LOSSES[problem.losses[t]][1](
                problem.xs[t][idx], problem.ys[t][idx], p[:, t])
            g = (n_t / bsz) * sub_grad
        if dynamic_step:
            recent = delays_hist[t][-delay_window:]
            mult = np.log(max(np.mean(recent), 10.0))
        else:
            mult = 1.0
        v[:, t] = v[:, t] + eta_k * mult * (p[:, t] - eta * g - v[:, t])

        events += 1
        if record_objective:
            w = problem.prox(v, eta * problem.lam)
            result.event_times.append(now)
            result.objectives.append(problem.objective(w))

        cycles_left[t] -= 1
        if cycles_left[t] > 0:
            schedule(t, now)
        result.total_time = now

    result.w = problem.prox(v, eta * problem.lam)
    result.iterations = events
    return result


# ---------------------------------------------------------------------------
# SMTL (synchronous) loop
# ---------------------------------------------------------------------------

def simulate_smtl(problem: SimProblem, net: NetworkModel, num_epochs: int,
                  eta: float | None = None, seed: int = 0,
                  record_objective: bool = True) -> SimResult:
    """Synchronous proximal gradient: every round waits for the slowest node.

    round time = max_t (compute_t + delay_t) + prox_time  (paper Sec. III-B)
    """
    rng = np.random.default_rng(seed)
    T, d = problem.num_tasks, problem.dim
    if eta is None:
        eta = 1.0 / problem.lipschitz()

    w = np.zeros((d, T))
    result = SimResult(0.0)
    now = 0.0
    for _ in range(num_epochs):
        round_costs = [net.node_compute(t) + net.delay_offset
                       + net.delay_jitter * rng.random() for t in range(T)]
        now += max(round_costs) + net.prox_time
        grads = np.stack([problem.task_grad(t, w[:, t]) for t in range(T)],
                         axis=1)
        w = problem.prox(w - eta * grads, eta * problem.lam)
        if record_objective:
            result.event_times.append(now)
            result.objectives.append(problem.objective(w))
    result.total_time = now
    result.w = w
    result.iterations = num_epochs
    return result


# ---------------------------------------------------------------------------
# Synthetic data matching the paper's setup (Sec. IV-B.1)
# ---------------------------------------------------------------------------

def make_synthetic(num_tasks: int = 5, samples: int = 100, dim: int = 50,
                   rank: int = 3, noise: float = 0.1,
                   seed: int = 0, loss: str = "lstsq") -> SimProblem:
    """Random low-rank multi-task regression (shared subspace ground truth)."""
    rng = np.random.default_rng(seed)
    basis = rng.standard_normal((dim, rank))
    coef = rng.standard_normal((rank, num_tasks))
    w_true = basis @ coef / np.sqrt(rank)
    xs, ys = [], []
    for t in range(num_tasks):
        x = rng.standard_normal((samples, dim)) / np.sqrt(dim)
        y = x @ w_true[:, t] + noise * rng.standard_normal(samples)
        if loss == "logistic":
            y = np.where(y > 0, 1.0, -1.0)
        xs.append(x)
        ys.append(y)
    return SimProblem(xs, ys, loss, "nuclear", 0.1)
