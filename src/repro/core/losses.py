"""Per-task loss functions for regularized MTL.

The paper assumes each task t has data (x_t, y_t) and a convex, L-Lipschitz-
differentiable loss ell_t (least squares for regression, logistic for binary
classification; tasks may be heterogeneous, Sec. III-A / ref [12]).

Two dataset layouts are supported:

  * "stacked": all tasks share n and d -> X (T, n, d), Y (T, n).  Fully
    jit/vmap-friendly; used by the SPMD engines and property tests.
  * python lists of per-task (x_t, y_t) arrays with ragged n_t; used by the
    event-driven simulator (each node jits its own gradient).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


class TaskLoss(NamedTuple):
    name: str
    value: Callable[[Array, Array, Array], Array]   # (x, y, w) -> scalar
    grad: Callable[[Array, Array, Array], Array]    # (x, y, w) -> (d,)
    lipschitz: Callable[[Array], float]             # (x,) -> L bound
    predict: Callable[[Array], Array]               # linear score -> output
    # ragged variants over a padded row buffer: rows >= n_t (traced) are
    # masked out of the per-row loss/residual.  With n_t == n the all-true
    # mask passes bits through, so the uniform case stays bitwise equal to
    # the unmasked expressions — the ragged path's equivalence anchor.
    value_masked: Callable[[Array, Array, Array, Array], Array]
    grad_masked: Callable[[Array, Array, Array, Array], Array]


# -- least squares:  ||x w - y||_2^2  (paper Eq. IV.1 uses the unnormalized
#    squared loss; gradient 2 x^T (x w - y), L = 2*sigma_max(x^T x)) ---------

def lstsq_value(x: Array, y: Array, w: Array) -> Array:
    r = x @ w - y
    return jnp.sum(r * r)


def lstsq_grad(x: Array, y: Array, w: Array) -> Array:
    return 2.0 * (x.T @ (x @ w - y))


def lstsq_lipschitz(x: Array) -> float:
    s = np.linalg.svd(np.asarray(x, dtype=np.float64), compute_uv=False)
    return float(2.0 * s[0] ** 2) if s.size else 1.0


def lstsq_predict(score: Array) -> Array:
    """Regression serves the raw linear score x·w."""
    return score


def _row_mask(x: Array, n_t: Array) -> Array:
    """(n,) bool: row index < n_t (traced valid-row count)."""
    return jnp.arange(x.shape[0]) < n_t


def lstsq_value_masked(x: Array, y: Array, w: Array, n_t: Array) -> Array:
    r = jnp.where(_row_mask(x, n_t), x @ w - y, 0.0)
    return jnp.sum(r * r)


def lstsq_grad_masked(x: Array, y: Array, w: Array, n_t: Array) -> Array:
    r = jnp.where(_row_mask(x, n_t), x @ w - y, 0.0)
    return 2.0 * (x.T @ r)


# -- logistic: sum log(1 + exp(-y x w)), y in {-1, +1} ----------------------

def logistic_value(x: Array, y: Array, w: Array) -> Array:
    z = y * (x @ w)
    return jnp.sum(jnp.logaddexp(0.0, -z))


def logistic_grad(x: Array, y: Array, w: Array) -> Array:
    z = y * (x @ w)
    s = jax.nn.sigmoid(-z)          # = 1 - sigmoid(z)
    return -(x.T @ (s * y))


def logistic_lipschitz(x: Array) -> float:
    s = np.linalg.svd(np.asarray(x, dtype=np.float64), compute_uv=False)
    return float(0.25 * s[0] ** 2) if s.size else 1.0


def logistic_predict(score: Array) -> Array:
    """Classification serves P(y = +1) = sigmoid(x·w)."""
    return jax.nn.sigmoid(score)


def logistic_value_masked(x: Array, y: Array, w: Array, n_t: Array) -> Array:
    # A zero row is NOT neutral for the logistic value (logaddexp(0, 0) =
    # log 2), so the per-row loss itself is masked, not the data.
    z = y * (x @ w)
    per_row = jnp.logaddexp(0.0, -z)
    return jnp.sum(jnp.where(_row_mask(x, n_t), per_row, 0.0))


def logistic_grad_masked(x: Array, y: Array, w: Array, n_t: Array) -> Array:
    z = y * (x @ w)
    s = jax.nn.sigmoid(-z)          # = 1 - sigmoid(z)
    return -(x.T @ jnp.where(_row_mask(x, n_t), s * y, 0.0))


LOSSES: dict[str, TaskLoss] = {
    "lstsq": TaskLoss("lstsq", lstsq_value, lstsq_grad, lstsq_lipschitz,
                      lstsq_predict, lstsq_value_masked, lstsq_grad_masked),
    "logistic": TaskLoss("logistic", logistic_value, logistic_grad,
                         logistic_lipschitz, logistic_predict,
                         logistic_value_masked, logistic_grad_masked),
}


def get_loss(name: str) -> TaskLoss:
    return LOSSES[name]


class MTLProblem(NamedTuple):
    """A stacked multi-task problem: T padded equal-capacity tasks.

    xs: (T, n, d)  ys: (T, n)  loss: one of LOSSES (homogeneous stacked case;
    heterogeneous losses are handled by the simulator's list layout).

    `row_counts` (optional, (T,) int32) makes the problem RAGGED: task t
    owns only its first row_counts[t] rows of the shared n-row buffer;
    rows past n_t are padding (or data appended to a `TaskStore` buffer
    but not yet published) and are masked out of every loss, gradient,
    and minibatch selection.  row_counts=None means every row is valid —
    the layout and every bitwise contract of the uniform problem are
    preserved (None is an empty pytree subtree, so existing 5-field
    constructions and jit traces are untouched).
    """

    xs: Array
    ys: Array
    loss_name: str
    reg_name: str
    lam: float
    row_counts: Array | None = None

    @property
    def num_tasks(self) -> int:
        return self.xs.shape[0]

    @property
    def dim(self) -> int:
        return self.xs.shape[2]

    def loss_value(self, w_cols: Array) -> Array:
        """f(W) = sum_t ell_t(w_t); w_cols is (d, T)."""
        loss = get_loss(self.loss_name)
        if self.row_counts is None:
            per_task = jax.vmap(loss.value, in_axes=(0, 0, 1))(
                self.xs, self.ys, w_cols)
        else:
            per_task = jax.vmap(loss.value_masked, in_axes=(0, 0, 1, 0))(
                self.xs, self.ys, w_cols, self.row_counts)
        return jnp.sum(per_task)

    def task_grad(self, t: Array, w_t: Array) -> Array:
        """grad of task t's loss at w_t (dynamic task index)."""
        loss = get_loss(self.loss_name)
        x_t = jax.lax.dynamic_index_in_dim(self.xs, t, axis=0, keepdims=False)
        y_t = jax.lax.dynamic_index_in_dim(self.ys, t, axis=0, keepdims=False)
        if self.row_counts is None:
            return loss.grad(x_t, y_t, w_t)
        n_t = jax.lax.dynamic_index_in_dim(self.row_counts, t, axis=0,
                                           keepdims=False)
        return loss.grad_masked(x_t, y_t, w_t, n_t)

    def task_grad_sampled(self, t: Array, w_t: Array, seed: Array,
                          batch_size: int) -> Array:
        """Unbiased seeded-minibatch gradient of task t's loss at w_t.

        SGD-AMTL's forward step: the exactly-`bsz` minibatch (bsz =
        min(batch_size, n_t), the simulator's clamp) of smallest counter
        hashes of (seed, row), scaled by (n_t/bsz).  For lstsq this is the
        fused `ops.lstsq_grad_sampled` (in-kernel selection on TPU, a
        static-size O(bsz d) gather on the CPU oracle path); other losses
        mask the dropped rows of x to zero — a zero row contributes
        nothing to any x^T(...) gradient — and scale the same way.
        batch_size >= n_t reproduces `task_grad` (bitwise for lstsq on a
        fixed backend).  Ragged problems restrict the selection to rows
        < row_counts[t]; uniform row_counts keep the selection, scale,
        and contraction bits of the unmasked path.
        """
        from repro.kernels.ops import lstsq_grad_sampled
        from repro.kernels.ref import sample_mask_masked_ref, sample_mask_ref

        x_t = jax.lax.dynamic_index_in_dim(self.xs, t, axis=0, keepdims=False)
        y_t = jax.lax.dynamic_index_in_dim(self.ys, t, axis=0, keepdims=False)
        n_t = None
        if self.row_counts is not None:
            n_t = jax.lax.dynamic_index_in_dim(self.row_counts, t, axis=0,
                                               keepdims=False)
        if self.loss_name == "lstsq":
            return lstsq_grad_sampled(x_t, w_t, y_t, seed,
                                      batch_size=batch_size, n_t=n_t)
        n = self.xs.shape[1]
        if n_t is None:
            bsz = min(batch_size, n)
            mask = sample_mask_ref(n, batch_size, seed)
            x_s = jnp.where(mask[:, None], x_t, 0.0)
            return (n / bsz) * get_loss(self.loss_name).grad(x_s, y_t, w_t)
        bsz = jnp.minimum(jnp.int32(batch_size), n_t.astype(jnp.int32))
        mask = sample_mask_masked_ref(n, batch_size, seed, n_t)
        x_s = jnp.where(mask[:, None], x_t, 0.0)
        scale = (n_t.astype(jnp.float32)
                 / jnp.maximum(bsz, 1).astype(jnp.float32))
        return scale * get_loss(self.loss_name).grad(x_s, y_t, w_t)

    def full_grad(self, w_cols: Array) -> Array:
        """nabla f(W) column-stacked, (d, T) — paper Eq. III.2."""
        loss = get_loss(self.loss_name)
        if self.row_counts is None:
            g = jax.vmap(loss.grad, in_axes=(0, 0, 1))(
                self.xs, self.ys, w_cols)
        else:
            g = jax.vmap(loss.grad_masked, in_axes=(0, 0, 1, 0))(
                self.xs, self.ys, w_cols, self.row_counts)
        return g.T  # (T, d) -> (d, T)

    def objective(self, w_cols: Array) -> Array:
        from repro.core.prox import get_regularizer
        reg = get_regularizer(self.reg_name)
        return self.loss_value(w_cols) + self.lam * reg.value(w_cols)

    def lipschitz(self) -> float:
        """max_t L_t — the coordinate-wise Lipschitz bound used for eta.

        Ragged problems bound each task over its VALID rows only (padding
        rows are zero or unpublished data and must not inflate L_t).
        """
        loss = get_loss(self.loss_name)
        if self.row_counts is None:
            return max(loss.lipschitz(np.asarray(self.xs[t]))
                       for t in range(self.num_tasks))
        counts = np.asarray(self.row_counts)
        return max(loss.lipschitz(np.asarray(self.xs[t])[:int(counts[t])])
                   for t in range(self.num_tasks))


# row_counts is a pytree CHILD: None flattens to an empty subtree, so the
# uniform problem's treedef/leaves — and every jit trace keyed on them —
# are identical to the pre-ragged 5-field registration.
jax.tree_util.register_pytree_node(
    MTLProblem,
    lambda p: ((p.xs, p.ys, p.row_counts),
               (p.loss_name, p.reg_name, p.lam)),
    lambda aux, ch: MTLProblem(ch[0], ch[1], aux[0], aux[1], aux[2], ch[2]),
)
