"""Per-task loss functions for regularized MTL.

The paper assumes each task t has data (x_t, y_t) and a convex, L-Lipschitz-
differentiable loss ell_t (least squares for regression, logistic for binary
classification; tasks may be heterogeneous, Sec. III-A / ref [12]).

Two dataset layouts are supported:

  * "stacked": all tasks share n and d -> X (T, n, d), Y (T, n).  Fully
    jit/vmap-friendly; used by the SPMD engines and property tests.
  * python lists of per-task (x_t, y_t) arrays with ragged n_t; used by the
    event-driven simulator (each node jits its own gradient).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


class TaskLoss(NamedTuple):
    name: str
    value: Callable[[Array, Array, Array], Array]   # (x, y, w) -> scalar
    grad: Callable[[Array, Array, Array], Array]    # (x, y, w) -> (d,)
    lipschitz: Callable[[Array], float]             # (x,) -> L bound
    predict: Callable[[Array], Array]               # linear score -> output


# -- least squares:  ||x w - y||_2^2  (paper Eq. IV.1 uses the unnormalized
#    squared loss; gradient 2 x^T (x w - y), L = 2*sigma_max(x^T x)) ---------

def lstsq_value(x: Array, y: Array, w: Array) -> Array:
    r = x @ w - y
    return jnp.sum(r * r)


def lstsq_grad(x: Array, y: Array, w: Array) -> Array:
    return 2.0 * (x.T @ (x @ w - y))


def lstsq_lipschitz(x: Array) -> float:
    s = np.linalg.svd(np.asarray(x, dtype=np.float64), compute_uv=False)
    return float(2.0 * s[0] ** 2) if s.size else 1.0


def lstsq_predict(score: Array) -> Array:
    """Regression serves the raw linear score x·w."""
    return score


# -- logistic: sum log(1 + exp(-y x w)), y in {-1, +1} ----------------------

def logistic_value(x: Array, y: Array, w: Array) -> Array:
    z = y * (x @ w)
    return jnp.sum(jnp.logaddexp(0.0, -z))


def logistic_grad(x: Array, y: Array, w: Array) -> Array:
    z = y * (x @ w)
    s = jax.nn.sigmoid(-z)          # = 1 - sigmoid(z)
    return -(x.T @ (s * y))


def logistic_lipschitz(x: Array) -> float:
    s = np.linalg.svd(np.asarray(x, dtype=np.float64), compute_uv=False)
    return float(0.25 * s[0] ** 2) if s.size else 1.0


def logistic_predict(score: Array) -> Array:
    """Classification serves P(y = +1) = sigmoid(x·w)."""
    return jax.nn.sigmoid(score)


LOSSES: dict[str, TaskLoss] = {
    "lstsq": TaskLoss("lstsq", lstsq_value, lstsq_grad, lstsq_lipschitz,
                      lstsq_predict),
    "logistic": TaskLoss("logistic", logistic_value, logistic_grad,
                         logistic_lipschitz, logistic_predict),
}


def get_loss(name: str) -> TaskLoss:
    return LOSSES[name]


class MTLProblem(NamedTuple):
    """A stacked multi-task problem: T equal-sized tasks.

    xs: (T, n, d)  ys: (T, n)  loss: one of LOSSES (homogeneous stacked case;
    heterogeneous losses are handled by the simulator's list layout).
    """

    xs: Array
    ys: Array
    loss_name: str
    reg_name: str
    lam: float

    @property
    def num_tasks(self) -> int:
        return self.xs.shape[0]

    @property
    def dim(self) -> int:
        return self.xs.shape[2]

    def loss_value(self, w_cols: Array) -> Array:
        """f(W) = sum_t ell_t(w_t); w_cols is (d, T)."""
        loss = get_loss(self.loss_name)
        per_task = jax.vmap(loss.value, in_axes=(0, 0, 1))(self.xs, self.ys, w_cols)
        return jnp.sum(per_task)

    def task_grad(self, t: Array, w_t: Array) -> Array:
        """grad of task t's loss at w_t (dynamic task index)."""
        loss = get_loss(self.loss_name)
        x_t = jax.lax.dynamic_index_in_dim(self.xs, t, axis=0, keepdims=False)
        y_t = jax.lax.dynamic_index_in_dim(self.ys, t, axis=0, keepdims=False)
        return loss.grad(x_t, y_t, w_t)

    def task_grad_sampled(self, t: Array, w_t: Array, seed: Array,
                          batch_size: int) -> Array:
        """Unbiased seeded-minibatch gradient of task t's loss at w_t.

        SGD-AMTL's forward step: the exactly-`bsz` minibatch (bsz =
        min(batch_size, n), the simulator's clamp) of smallest counter
        hashes of (seed, row), scaled by (n/bsz).  For lstsq this is the
        fused `ops.lstsq_grad_sampled` (in-kernel selection on TPU, a
        static-size O(bsz d) gather on the CPU oracle path); other losses
        mask the dropped rows of x to zero — a zero row contributes
        nothing to any x^T(...) gradient — and scale the same way.
        batch_size >= n reproduces `task_grad` (bitwise for lstsq on a
        fixed backend).
        """
        from repro.kernels.ops import lstsq_grad_sampled
        from repro.kernels.ref import sample_mask_ref

        x_t = jax.lax.dynamic_index_in_dim(self.xs, t, axis=0, keepdims=False)
        y_t = jax.lax.dynamic_index_in_dim(self.ys, t, axis=0, keepdims=False)
        if self.loss_name == "lstsq":
            return lstsq_grad_sampled(x_t, w_t, y_t, seed,
                                      batch_size=batch_size)
        n = self.xs.shape[1]
        bsz = min(batch_size, n)
        mask = sample_mask_ref(n, batch_size, seed)
        x_s = jnp.where(mask[:, None], x_t, 0.0)
        return (n / bsz) * get_loss(self.loss_name).grad(x_s, y_t, w_t)

    def full_grad(self, w_cols: Array) -> Array:
        """nabla f(W) column-stacked, (d, T) — paper Eq. III.2."""
        loss = get_loss(self.loss_name)
        g = jax.vmap(loss.grad, in_axes=(0, 0, 1))(self.xs, self.ys, w_cols)
        return g.T  # (T, d) -> (d, T)

    def objective(self, w_cols: Array) -> Array:
        from repro.core.prox import get_regularizer
        reg = get_regularizer(self.reg_name)
        return self.loss_value(w_cols) + self.lam * reg.value(w_cols)

    def lipschitz(self) -> float:
        """max_t L_t — the coordinate-wise Lipschitz bound used for eta."""
        loss = get_loss(self.loss_name)
        return max(loss.lipschitz(np.asarray(self.xs[t]))
                   for t in range(self.num_tasks))


jax.tree_util.register_pytree_node(
    MTLProblem,
    lambda p: ((p.xs, p.ys), (p.loss_name, p.reg_name, p.lam)),
    lambda aux, ch: MTLProblem(ch[0], ch[1], *aux),
)
