"""Operator-splitting building blocks (paper Sec. III-B/III-C).

Forward operator   F = I - eta * grad(f)          (separable across tasks)
Backward operator  B = (I + eta*lam*dg)^{-1}      (= prox, NOT separable)

Forward-backward:   W+ = B(F(W))     — classic proximal gradient (SMTL)
Backward-forward:   V+ = F(B(V))     — the paper's reordering: the *outer*
                                       operator is separable, so a single task
                                       block of V can be updated (Eq. III.4).
W* is recovered from V* with one extra backward step: W* = B(V*).

Both compositions are nonexpansive for eta in (0, 2/L), so the KM iteration
   v <- v + eta_k (Op(v) - v)
converges (Theorem 1 via ARock [6]).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.losses import MTLProblem
from repro.core.prox import get_regularizer

Array = jax.Array


class SplittingConfig(NamedTuple):
    eta: float        # gradient / prox step (0, 2/L)
    lam: float        # regularization weight
    reg_name: str


def backward(problem: MTLProblem, v: Array, eta: float) -> Array:
    """prox_{eta*lam*g}(V)."""
    reg = get_regularizer(problem.reg_name)
    return reg.prox(v, jnp.asarray(eta * problem.lam, v.dtype))


def forward(problem: MTLProblem, w: Array, eta: float) -> Array:
    """(I - eta * grad f)(W) — separable per task column."""
    return w - eta * problem.full_grad(w)


def forward_backward(problem: MTLProblem, w: Array, eta: float) -> Array:
    """One synchronous proximal-gradient step (SMTL inner map)."""
    return backward(problem, forward(problem, w, eta), eta)


def backward_forward(problem: MTLProblem, v: Array, eta: float) -> Array:
    """V+ = (I - eta grad f)(prox(V)) — the paper's reordered iteration."""
    return forward(problem, backward(problem, v, eta), eta)


def km_step(v: Array, op_v: Array, eta_k: Array) -> Array:
    """Krasnosel'skii-Mann relaxation: v + eta_k (Op(v) - v)."""
    return v + eta_k * (op_v - v)


def km_block_update(v_t: Array, prox_t: Array, grad_t: Array,
                    eta: Array, eta_k: Array) -> Array:
    """Paper Eq. III.4 — the fused per-task-block AMTL update.

    v_t^{k+1} = v_t + eta_k * ( prox(v_hat)_t - eta * grad_t(prox(v_hat)_t) - v_t )

    This is the op the `km_update` Pallas kernel fuses.
    """
    return v_t + eta_k * (prox_t - eta * grad_t - v_t)


def rollback_columns(v: Array, delta_ring: Array, task_ring: Array,
                     ptr: Array, nu: Array, tau: int) -> Array:
    """Reconstruct the iterate from `nu` events ago out of an undo log.

    `delta_ring[s]` holds the exact pre-write bits of column `task_ring[s]`
    at the event written to slot `s`; `ptr` is the newest event's slot.
    Restoring the `nu` newest entries newest-first replays each overwritten
    column back to its stored value, so the result is bitwise identical to
    the dense ring's `ring[ptr - nu]` — in O(tau*d) work instead of
    materializing a (tau+1, d, T) ring.

    `tau` is static (loop trip count); `nu <= min(tau, event)` is dynamic
    and masks which entries actually restore.  A masked-out step writes a
    column back onto itself, which is a bitwise no-op.
    """
    if tau == 0:
        return v
    depth = tau + 1

    def undo(j, vh):
        slot = (ptr - j) % depth          # j=0 -> newest event
        t_j = task_ring[slot]
        col = jnp.where(j < nu, delta_ring[slot], vh[:, t_j])
        return vh.at[:, t_j].set(col)

    return jax.lax.fori_loop(0, tau, undo, v)


def rollback_columns_batch(v: Array, delta_ring: Array, task_ring: Array,
                           ptr: Array, nu: Array, tau: int) -> Array:
    """Vectorized multi-column rollback: one masked scatter, no fori_loop.

    Bitwise-equal to `rollback_columns`: the newest-first sequential replay
    ends with the OLDEST restored entry per column winning, so it suffices
    to select, for each column touched within the rollback window, the
    entry with the largest offset j < nu and scatter all winners at once.
    Losers and masked-out slots scatter to column index T, which is out of
    bounds and dropped (`mode="drop"`).  Winners have distinct column
    indices, so the scatter is deterministic; the written bits are the
    stored pre-write bits verbatim.

    The batch engine uses this at its per-batch prox refresh, where the
    fori_loop's tau sequential (d,)-column writes would serialize for no
    reason; `rollback_columns` stays as the one-event engines' path and the
    semantic reference.  The winner selection lives in
    `rollback_columns_shard`; this is the t_offset=0 case, where every
    task is owned.
    """
    return rollback_columns_shard(v, delta_ring, task_ring, ptr, nu, tau,
                                  jnp.zeros((), jnp.int32))


def rollback_columns_shard(v: Array, delta_ring: Array, task_ring: Array,
                           ptr: Array, nu: Array, tau: int,
                           t_offset: Array) -> Array:
    """Shard-local rollback: `task_ring` holds GLOBAL task ids, `v` is the
    shard's (d, T_local) column block covering global columns
    [t_offset, t_offset + T_local).

    Same winner selection as the sequential replay — the oldest active
    entry per column wins — but entries whose task lives on another shard
    are dropped alongside the masked-out slots (their restore happens on
    the owner, which holds the stored pre-write bits).  Concatenating the
    per-shard results in shard order is therefore bitwise-equal to the
    global `rollback_columns_batch` — which is this function at
    t_offset=0, every task owned.
    """
    if tau == 0:
        return v
    depth = tau + 1
    n_local = v.shape[1]
    j = jnp.arange(tau)                              # j=0 -> newest event
    slots = (ptr - j) % depth
    tasks = task_ring[slots]                         # (tau,) global ids
    active = j < nu
    # shadowed[j]: an older active entry (j' > j) touches the same column,
    # so entry j's restore would be overwritten in the sequential replay.
    same = tasks[None, :] == tasks[:, None]
    older = j[None, :] > j[:, None]
    shadowed = jnp.any(same & older & active[None, :], axis=1)
    local = tasks - t_offset
    owned = (local >= 0) & (local < n_local)
    win = active & ~shadowed & owned
    cols = jnp.where(win, local, n_local)            # n_local => dropped
    return v.at[:, cols].set(delta_ring[slots].T, mode="drop")


def fixed_point_residual(problem: MTLProblem, v: Array, eta: float) -> Array:
    """||BF(v) - v||_F — zero exactly at a fixed point of the BF operator."""
    return jnp.linalg.norm(backward_forward(problem, v, eta) - v)


def amtl_max_step(tau: int, num_tasks: int, c: float = 0.9) -> float:
    """Theorem 1 step-size cap: eta_k <= c / (2*tau/sqrt(T) + 1), 0<c<1."""
    if not 0.0 < c < 1.0:
        raise ValueError("c must be in (0,1)")
    return c / (2.0 * tau / (num_tasks ** 0.5) + 1.0)
