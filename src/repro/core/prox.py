"""Proximal operators for regularized multi-task learning.

The paper couples T task models W = [w_1 ... w_T] in R^{d x T} through a
non-smooth regularizer g(W).  The central server's "backward" step is
prox_{eta*lambda*g}.  All operators here are pure jnp, jit- and vmap-safe,
and differentiable where the math allows.

Registry keys match the MALSAR formulations cited in the paper:
  nuclear      - shared subspace learning, ||W||_*           (paper Eq. IV.2)
  l21          - joint feature learning, sum_i ||w^i||_2     (paper Sec. III-A)
  l1           - elementwise sparsity
  elastic_net  - l1 + ridge (paper's strict-convexity trick, ref [25])
  ridge        - squared Frobenius
  none         - identity (independent single-task learning)
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class Regularizer(NamedTuple):
    """A non-smooth penalty g with its proximal mapping.

    value(W)            -> scalar g(W)
    prox(W, t)          -> argmin_Z  (1/2t)||Z - W||_F^2 + g(Z)
    """

    name: str
    value: Callable[[Array], Array]
    prox: Callable[[Array, Array], Array]
    separable_rows: bool  # prox decomposes over rows of W
    separable_cols: bool  # prox decomposes over columns (tasks)


# ---------------------------------------------------------------------------
# nuclear norm: singular value thresholding (paper Eq. IV.2)
# ---------------------------------------------------------------------------

def nuclear_value(w: Array) -> Array:
    return jnp.sum(jnp.linalg.svd(w.astype(jnp.float32), compute_uv=False))


def svt(w: Array, t: Array) -> Array:
    """Singular value thresholding: U (Sigma - t)_+ V^T."""
    dtype = w.dtype
    u, s, vt = jnp.linalg.svd(w.astype(jnp.float32), full_matrices=False)
    s = jnp.maximum(s - t, 0.0)
    return (u * s[None, :] @ vt).astype(dtype)


def sketch_width(rank: int, d: int, num_tasks: int) -> int:
    """Columns of the Halko sketch: `rank` + oversampling, clipped to the
    matrix.  One definition shared by the serial and distributed SVT (and
    the bench's communication-volume accounting)."""
    return min(rank + 8, min(d, num_tasks))


def _sketch_seed(key: Array) -> Array:
    """uint32 counter seed of one refresh's sketch, from the folded key."""
    return jax.random.bits(key, dtype=jnp.uint32)


def svt_randomized(w: Array, t: Array, *, rank: int, key: Array) -> Array:
    """Randomized SVT for very large (d x T): project to `rank` + oversampling.

    Halko et al. range finder; exact when rank >= true rank.  Used when
    d_model * T makes the dense SVD the server-side bottleneck (the paper's
    online-SVD concern, adapted: on TPU a small randomized sketch keeps the
    backward step MXU-friendly instead of sequential Brand updates).

    The (T, p) test matrix Omega is never materialized per refresh: its
    entries are counter-generated from a uint32 seed drawn off `key`, and
    `ops.gauss_sketch` contracts W against Omega tiles generated in-kernel
    (VMEM-resident on TPU; the jnp oracle materializes the same bits on
    the CPU path).
    """
    from repro.kernels.ops import gauss_sketch, svt_reconstruct

    d, T = w.shape
    p = sketch_width(rank, d, T)
    y = gauss_sketch(w, _sketch_seed(key), jnp.zeros((), jnp.int32),
                     p=p)                                    # (d, p)
    q, _ = jnp.linalg.qr(y)                                  # (d, p)
    b = q.T @ w.astype(jnp.float32)                          # (p, T)
    ub, s, vt = jnp.linalg.svd(b, full_matrices=False)
    s = jnp.maximum(s - t, 0.0)
    return svt_reconstruct(q @ ub, s, vt).astype(w.dtype)


class ProxPlan(NamedTuple):
    """Collective schedule of the rank-distributed randomized SVT.

    The T task columns of the iterate live on a 1-D `axis` mesh
    (`n_local = T / n_shards` columns per shard).  One refresh moves

      psum        (d, p)        partial sketches  y = sum_s W_s @ Omega_s
      all_gather  (p, n_local)  projected-core blocks  b_s = Q^T W_s

    i.e. O(d*p + p*T) bytes instead of the O(d*T) iterate all_gather of
    the replicated prox; the QR of the (d, p) sketch and the SVD of the
    (p, T) core are cheap and replicated, the thresholded reconstruction
    `(Q U) * sigma @ V^T_s` is shard-local.
    """
    axis: str          # mesh axis the task columns are sharded over
    num_tasks: int     # global T
    n_local: int       # T // n_shards columns owned per shard

    def comm_bytes_per_refresh(self, d: int, rank: int,
                               itemsize: int = 4) -> int:
        """Collective payload per refresh: the (d, p) psum'd partial plus
        the gathered (p, T) projected core."""
        p = sketch_width(rank, d, self.num_tasks)
        return (d * p + p * self.num_tasks) * itemsize


def svt_randomized_dist(w_local: Array, t: Array, *, rank: int, key: Array,
                        plan: ProxPlan) -> Array:
    """Rank-distributed randomized SVT (inside shard_map over `plan.axis`).

    `w_local` is this shard's (d, n_local) column block of the global
    (d, T) iterate; the return is the thresholded reconstruction of the
    SAME columns — no shard ever materializes the full iterate.  `key`
    must be the replicated folded sketch key every shard holds: Omega's
    entries are counter-generated from the seed drawn off that key
    (position-determined, never materialized as a full (T, p) array), so
    each shard generates exactly ITS row block of the serial
    `svt_randomized`'s Omega — `row_offset = t_off` into the same global
    counters — and the psum'd sketch equals the serial contraction
    `W @ Omega`.

    Equivalence contract: on a 1-shard mesh every collective degenerates
    to the identity and each expression below is the serial path's, so the
    result is bitwise `svt_randomized(w, t)` on the CPU oracle path.  At
    n > 1 shards the psum regroups the sum over T (and hence Q, the core,
    and the reconstruction) relative to the serial matmul, so agreement is
    ulp-level, not bitwise — shard-count-invariance of the *engine* is
    asserted at that tolerance (tests/test_amtl_sharded_multidevice.py).
    """
    from repro.kernels.ops import gauss_sketch, svt_reconstruct

    d = w_local.shape[0]
    p = sketch_width(rank, d, plan.num_tasks)
    t_off = jax.lax.axis_index(plan.axis) * plan.n_local
    # y = sum_s W_s @ Omega_s — ONE (d, p) psum; each shard's sketch flops
    # drop from O(d*T*p) to O(d*T*p / n_shards), and each shard only ever
    # generates its own (n_local, p) rows of Omega (in-kernel on TPU).
    y = jax.lax.psum(
        gauss_sketch(w_local, _sketch_seed(key), t_off, p=p), plan.axis)
    q, _ = jnp.linalg.qr(y)                                  # replicated
    b_loc = q.T @ w_local.astype(jnp.float32)                # (p, n_local)
    # Assemble the projected core with a tiny (p, n_local) all_gather; the
    # per-column contraction over d is shard-local, so given Q the gathered
    # core carries the serial `Q^T W` bits.
    b = jax.lax.all_gather(b_loc, plan.axis, axis=1, tiled=True)
    ub, s, vt = jnp.linalg.svd(b, full_matrices=False)       # replicated
    s = jnp.maximum(s - t, 0.0)
    vt_loc = jax.lax.dynamic_slice_in_dim(vt, t_off, plan.n_local, 1)
    return svt_reconstruct(q @ ub, s, vt_loc).astype(w_local.dtype)


# ---------------------------------------------------------------------------
# l2,1 row-group soft threshold (joint feature learning)
# ---------------------------------------------------------------------------

def l21_value(w: Array) -> Array:
    return jnp.sum(jnp.linalg.norm(w.astype(jnp.float32), axis=1))


def l21_prox(w: Array, t: Array) -> Array:
    """Row-wise group soft-threshold: w^i * max(0, 1 - t/||w^i||_2)."""
    w32 = w.astype(jnp.float32)
    norms = jnp.linalg.norm(w32, axis=1, keepdims=True)
    scale = jnp.maximum(0.0, 1.0 - t / jnp.maximum(norms, 1e-12))
    return (w32 * scale).astype(w.dtype)


# ---------------------------------------------------------------------------
# l1 / elastic net / ridge
# ---------------------------------------------------------------------------

def l1_value(w: Array) -> Array:
    return jnp.sum(jnp.abs(w.astype(jnp.float32)))


def l1_prox(w: Array, t: Array) -> Array:
    w32 = w.astype(jnp.float32)
    return (jnp.sign(w32) * jnp.maximum(jnp.abs(w32) - t, 0.0)).astype(w.dtype)


def make_elastic_net(alpha: float = 1.0) -> Regularizer:
    """g(W) = ||W||_1 + (alpha/2)||W||_F^2 — the paper's strict-convexity fix."""

    def value(w: Array) -> Array:
        w32 = w.astype(jnp.float32)
        return jnp.sum(jnp.abs(w32)) + 0.5 * alpha * jnp.sum(w32 * w32)

    def prox(w: Array, t: Array) -> Array:
        return (l1_prox(w, t).astype(jnp.float32) / (1.0 + t * alpha)).astype(w.dtype)

    return Regularizer("elastic_net", value, prox, True, True)


def ridge_value(w: Array) -> Array:
    w32 = w.astype(jnp.float32)
    return 0.5 * jnp.sum(w32 * w32)


def ridge_prox(w: Array, t: Array) -> Array:
    return (w.astype(jnp.float32) / (1.0 + t)).astype(w.dtype)


def none_value(w: Array) -> Array:
    return jnp.zeros((), dtype=jnp.float32)


def none_prox(w: Array, t: Array) -> Array:
    del t
    return w


REGISTRY: dict[str, Regularizer] = {
    "nuclear": Regularizer("nuclear", nuclear_value, svt, False, False),
    "l21": Regularizer("l21", l21_value, l21_prox, True, False),
    "l1": Regularizer("l1", l1_value, l1_prox, True, True),
    "elastic_net": make_elastic_net(),
    "ridge": Regularizer("ridge", ridge_value, ridge_prox, True, True),
    "none": Regularizer("none", none_value, none_prox, True, True),
}


def get_regularizer(name: str, **kwargs) -> Regularizer:
    if name == "elastic_net" and kwargs:
        return make_elastic_net(**kwargs)
    if name not in REGISTRY:
        raise KeyError(f"unknown regularizer {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


@functools.partial(jax.jit, static_argnames=("name",))
def apply_prox(name: str, w: Array, t: Array) -> Array:
    return get_regularizer(name).prox(w, t)
