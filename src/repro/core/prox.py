"""Proximal operators for regularized multi-task learning.

The paper couples T task models W = [w_1 ... w_T] in R^{d x T} through a
non-smooth regularizer g(W).  The central server's "backward" step is
prox_{eta*lambda*g}.  All operators here are pure jnp, jit- and vmap-safe,
and differentiable where the math allows.

Registry keys match the MALSAR formulations cited in the paper:
  nuclear      - shared subspace learning, ||W||_*           (paper Eq. IV.2)
  l21          - joint feature learning, sum_i ||w^i||_2     (paper Sec. III-A)
  l1           - elementwise sparsity
  elastic_net  - l1 + ridge (paper's strict-convexity trick, ref [25])
  ridge        - squared Frobenius
  none         - identity (independent single-task learning)
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class Regularizer(NamedTuple):
    """A non-smooth penalty g with its proximal mapping.

    value(W)            -> scalar g(W)
    prox(W, t)          -> argmin_Z  (1/2t)||Z - W||_F^2 + g(Z)
    """

    name: str
    value: Callable[[Array], Array]
    prox: Callable[[Array, Array], Array]
    separable_rows: bool  # prox decomposes over rows of W
    separable_cols: bool  # prox decomposes over columns (tasks)


# ---------------------------------------------------------------------------
# nuclear norm: singular value thresholding (paper Eq. IV.2)
# ---------------------------------------------------------------------------

def nuclear_value(w: Array) -> Array:
    return jnp.sum(jnp.linalg.svd(w.astype(jnp.float32), compute_uv=False))


def svt(w: Array, t: Array) -> Array:
    """Singular value thresholding: U (Sigma - t)_+ V^T."""
    dtype = w.dtype
    u, s, vt = jnp.linalg.svd(w.astype(jnp.float32), full_matrices=False)
    s = jnp.maximum(s - t, 0.0)
    return (u * s[None, :] @ vt).astype(dtype)


def svt_randomized(w: Array, t: Array, *, rank: int, key: Array) -> Array:
    """Randomized SVT for very large (d x T): project to `rank` + oversampling.

    Halko et al. range finder; exact when rank >= true rank.  Used when
    d_model * T makes the dense SVD the server-side bottleneck (the paper's
    online-SVD concern, adapted: on TPU a small randomized sketch keeps the
    backward step MXU-friendly instead of sequential Brand updates).
    """
    d, T = w.shape
    p = min(rank + 8, min(d, T))
    omega = jax.random.normal(key, (T, p), dtype=jnp.float32)
    y = w.astype(jnp.float32) @ omega                       # (d, p)
    q, _ = jnp.linalg.qr(y)                                  # (d, p)
    b = q.T @ w.astype(jnp.float32)                          # (p, T)
    ub, s, vt = jnp.linalg.svd(b, full_matrices=False)
    s = jnp.maximum(s - t, 0.0)
    return ((q @ ub) * s[None, :] @ vt).astype(w.dtype)


# ---------------------------------------------------------------------------
# l2,1 row-group soft threshold (joint feature learning)
# ---------------------------------------------------------------------------

def l21_value(w: Array) -> Array:
    return jnp.sum(jnp.linalg.norm(w.astype(jnp.float32), axis=1))


def l21_prox(w: Array, t: Array) -> Array:
    """Row-wise group soft-threshold: w^i * max(0, 1 - t/||w^i||_2)."""
    w32 = w.astype(jnp.float32)
    norms = jnp.linalg.norm(w32, axis=1, keepdims=True)
    scale = jnp.maximum(0.0, 1.0 - t / jnp.maximum(norms, 1e-12))
    return (w32 * scale).astype(w.dtype)


# ---------------------------------------------------------------------------
# l1 / elastic net / ridge
# ---------------------------------------------------------------------------

def l1_value(w: Array) -> Array:
    return jnp.sum(jnp.abs(w.astype(jnp.float32)))


def l1_prox(w: Array, t: Array) -> Array:
    w32 = w.astype(jnp.float32)
    return (jnp.sign(w32) * jnp.maximum(jnp.abs(w32) - t, 0.0)).astype(w.dtype)


def make_elastic_net(alpha: float = 1.0) -> Regularizer:
    """g(W) = ||W||_1 + (alpha/2)||W||_F^2 — the paper's strict-convexity fix."""

    def value(w: Array) -> Array:
        w32 = w.astype(jnp.float32)
        return jnp.sum(jnp.abs(w32)) + 0.5 * alpha * jnp.sum(w32 * w32)

    def prox(w: Array, t: Array) -> Array:
        return (l1_prox(w, t).astype(jnp.float32) / (1.0 + t * alpha)).astype(w.dtype)

    return Regularizer("elastic_net", value, prox, True, True)


def ridge_value(w: Array) -> Array:
    w32 = w.astype(jnp.float32)
    return 0.5 * jnp.sum(w32 * w32)


def ridge_prox(w: Array, t: Array) -> Array:
    return (w.astype(jnp.float32) / (1.0 + t)).astype(w.dtype)


def none_value(w: Array) -> Array:
    return jnp.zeros((), dtype=jnp.float32)


def none_prox(w: Array, t: Array) -> Array:
    del t
    return w


REGISTRY: dict[str, Regularizer] = {
    "nuclear": Regularizer("nuclear", nuclear_value, svt, False, False),
    "l21": Regularizer("l21", l21_value, l21_prox, True, False),
    "l1": Regularizer("l1", l1_value, l1_prox, True, True),
    "elastic_net": make_elastic_net(),
    "ridge": Regularizer("ridge", ridge_value, ridge_prox, True, True),
    "none": Regularizer("none", none_value, none_prox, True, True),
}


def get_regularizer(name: str, **kwargs) -> Regularizer:
    if name == "elastic_net" and kwargs:
        return make_elastic_net(**kwargs)
    if name not in REGISTRY:
        raise KeyError(f"unknown regularizer {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


@functools.partial(jax.jit, static_argnames=("name",))
def apply_prox(name: str, w: Array, t: Array) -> Array:
    return get_regularizer(name).prox(w, t)
