"""Proximal composition: any smooth optimizer + a prox on selected leaves.

Generalizes the paper's backward step to arbitrary parameter subsets —
e.g. nuclear-norm-coupled multi-task heads inside an AdamW-trained
transformer (the Mesh-AMTL integration), or l2,1 feature selection on an
embedding table.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.prox import get_regularizer
from repro.optim.optimizers import Optimizer


def proximal_wrap(opt: Optimizer, reg_name: str, lam: float,
                  select: Callable[[tuple], bool],
                  eta_ref: float = 1.0) -> Optimizer:
    """After each smooth update, apply prox_{lr*lam*g} to selected leaves.

    select(path) -> True for leaves the regularizer couples (path is the
    jax.tree_util key path tuple).
    """
    reg = get_regularizer(reg_name)

    def update(grads, state, params, step):
        new_params, new_state = opt.update(grads, state, params, step)

        def maybe_prox(path, leaf):
            if not select(tuple(str(getattr(k, "key", getattr(k, "name", k))) for k in path)):
                return leaf
            t = jnp.asarray(eta_ref * lam, jnp.float32)
            mat = leaf if leaf.ndim == 2 else leaf.reshape(leaf.shape[0], -1)
            out = reg.prox(mat, t)
            return out.reshape(leaf.shape).astype(leaf.dtype)

        new_params = jax.tree_util.tree_map_with_path(maybe_prox, new_params)
        # keep the master copy consistent with the projected params
        if isinstance(new_state, dict) and "master" in new_state:
            new_master = jax.tree_util.tree_map_with_path(
                lambda path, m, p: p.astype(jnp.float32)
                if select(tuple(str(getattr(k, "key", getattr(k, "name", k))) for k in path)) else m,
                new_state["master"], new_params)
            new_state = dict(new_state)
            new_state["master"] = new_master
        return new_params, new_state

    return Optimizer(f"prox_{opt.name}", opt.init, update)
