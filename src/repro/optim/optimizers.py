"""Functional optimizers with mixed-precision master weights.

Policy: model params may be bf16 (compute copy); optimizer state carries an
fp32 master plus moments.  State sharding (ZeRO-1) is applied by the rule
engine in `repro.distributed.sharding`, not here.

`adafactor` (factored second moments, no first moment by default) exists so
deepseek-v3-671b's optimizer state fits a 256-chip pod (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class Optimizer(NamedTuple):
    name: str
    init: Callable        # params -> opt_state (pytree)
    update: Callable      # (grads, opt_state, params, step) -> (params, st)


def _cast_like(x32, ref):
    return x32.astype(ref.dtype)


# ----------------------------------------------------------------- AdamW ---

def adamw(lr_fn, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, grad_clip: float = 1.0) -> Optimizer:
    def init(params):
        f32 = lambda p: jnp.array(p, jnp.float32)   # real copy even if fp32
        return {
            "master": jax.tree.map(f32, params),
            "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                               params),
            "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                               params),
        }

    def update(grads, state, params, step):
        lr = lr_fn(step)
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-12))
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, m, mu, nu):
            g = g.astype(jnp.float32) * scale
            mu = b1 * mu + (1 - b1) * g
            nu = b2 * nu + (1 - b2) * g * g
            upd_ = (mu / bc1) / (jnp.sqrt(nu / bc2) + eps)
            m_new = m - lr * (upd_ + weight_decay * m)
            return m_new, mu, nu

        out = jax.tree.map(upd, grads, state["master"], state["mu"],
                           state["nu"])
        master = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        nu = jax.tree.map(lambda o: o[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        new_params = jax.tree.map(_cast_like, master, params)
        return new_params, {"master": master, "mu": mu, "nu": nu}

    return Optimizer("adamw", init, update)


# -------------------------------------------------------------- Adafactor --

def adafactor(lr_fn, eps: float = 1e-30, decay: float = 0.8,
              weight_decay: float = 0.0, clip_threshold: float = 1.0
              ) -> Optimizer:
    """Factored second moments for >=2D leaves; no first moment."""

    def _factored(shape) -> bool:
        return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1

    def init(params):
        def st(p):
            entry = {"master": jnp.array(p, jnp.float32)}
            if _factored(p.shape):
                entry["vr"] = jnp.zeros(p.shape[:-1], jnp.float32)
                entry["vc"] = jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)
            else:
                entry["v"] = jnp.zeros(p.shape, jnp.float32)
            return entry
        return jax.tree.map(st, params,
                            is_leaf=lambda x: isinstance(x, jax.Array))

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)
        flat_g, treedef = jax.tree.flatten(grads)
        flat_p = treedef.flatten_up_to(params)
        # state has the same outer structure as params with dict leaves:
        st_leaves = treedef.flatten_up_to(state)
        new_params, new_states = [], []
        for g, p, st in zip(flat_g, flat_p, st_leaves):
            g32 = g.astype(jnp.float32)
            m = st["master"]
            if "vr" in st:
                vr = beta * st["vr"] + (1 - beta) * jnp.mean(
                    g32 * g32 + eps, axis=-1)
                vc = beta * st["vc"] + (1 - beta) * jnp.mean(
                    g32 * g32 + eps, axis=-2)
                row_mean = jnp.maximum(
                    jnp.mean(vr, axis=-1, keepdims=True), eps)
                denom = jnp.sqrt((vr / row_mean)[..., None]
                                 * vc[..., None, :])
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta * st["v"] + (1 - beta) * (g32 * g32 + eps)
                denom = jnp.sqrt(v)
                new_st = {"v": v}
            u = g32 / jnp.maximum(denom, eps)
            rms_u = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            m_new = m - lr * (u + weight_decay * m)
            new_st["master"] = m_new
            new_states.append(new_st)
            new_params.append(m_new.astype(p.dtype))
        return (jax.tree.unflatten(treedef, new_params),
                jax.tree.unflatten(treedef, new_states))

    return Optimizer("adafactor", init, update)


# ------------------------------------------------------------------ SGDM ---

def sgdm(lr_fn, momentum: float = 0.9, weight_decay: float = 0.0
         ) -> Optimizer:
    def init(params):
        return {"master": jax.tree.map(lambda p: jnp.array(p, jnp.float32),
                                       params),
                "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                   params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)

        def upd(g, m, mu):
            g = g.astype(jnp.float32) + weight_decay * m
            mu = momentum * mu + g
            return m - lr * mu, mu

        out = jax.tree.map(upd, grads, state["master"], state["mu"])
        master = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        mu = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
        return (jax.tree.map(_cast_like, master, params),
                {"master": master, "mu": mu})

    return Optimizer("sgdm", init, update)


def global_norm(tree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                        for l in leaves))


def make_optimizer(name: str, lr_fn, **kwargs) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor, "sgdm": sgdm}[name](
        lr_fn, **kwargs)
