from repro.optim.optimizers import (Optimizer, adafactor, adamw, sgdm,
                                    make_optimizer)
from repro.optim.schedules import constant, cosine_warmup, linear_warmup
from repro.optim.prox_wrapper import proximal_wrap

__all__ = ["Optimizer", "adamw", "adafactor", "sgdm", "make_optimizer",
           "constant", "cosine_warmup", "linear_warmup", "proximal_wrap"]
