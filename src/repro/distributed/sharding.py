"""Param/activation/cache -> PartitionSpec rule engine.

Conventions (DESIGN.md §5):
  * tensor parallelism over 'model': projections feature-sharded, FFN
    hidden sharded, embeddings vocab-sharded, MoE experts expert-sharded
    (full-EP over ('data','model') when divisible, else model-EP with the
    expert FFN dim FSDP'd over 'data');
  * ZeRO-1: optimizer-state leaves additionally sharded over the data axes
    on the largest free divisible dim;
  * scanned groups carry a leading stack dim that is never sharded;
  * KV caches: batch over data axes, sequence over 'model';
  * recurrent states: heads over 'model' when divisible, else batch-only.

Every rule validates divisibility against the actual mesh and falls back to
replication per-dim, so the same engine serves the 1-CPU smoke tests and
the 512-chip dry run.
"""
from __future__ import annotations

import inspect
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig


def shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma=False):
    """`jax.shard_map` across jax versions.

    Newer jax exposes top-level `jax.shard_map`; 0.4.x only has
    `jax.experimental.shard_map.shard_map`.  The replication-check kwarg
    was also renamed `check_rep` -> `check_vma` (same switch), not
    necessarily in the same release — so detect the accepted kwarg from
    the signature rather than guessing from the module layout.  All
    shard_map call sites in this repo go through here.
    """
    if hasattr(jax, "shard_map"):
        sm = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as sm
    params = inspect.signature(sm).parameters
    kw = "check_vma" if "check_vma" in params else "check_rep"
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{kw: check_vma})

# ------------------------------------------------------- AMTL task axis ---

TASK_AXIS = "tasks"


def task_shard_specs(axis: str = TASK_AXIS) -> dict[str, P]:
    """PartitionSpecs for the task-sharded AMTL engine (engine='sharded').

    The engine partitions the T task columns of the (d, T) iterate over a
    1-D `axis` mesh; everything it touches falls into four placement
    classes (keys of the returned dict):

      per_task   — leading-dim-T leaves: xs (T, n, d), ys (T, n), the
                   delay-history rows (T, window)/(T,)
      columns    — (d, T) iterates: tasks on the trailing dim
      per_shard  — (n_shards, ...) leaves: each shard's private undo ring
      replicated — the serial PRNG chain state (key, ptr, event counter)
                   and the global-task-id ring every shard replays

    The rank-distributed randomized SVT (prox_mode='distributed',
    `prox.svt_randomized_dist`) adds no new placement class: its (d, p)
    sketch partial is psum'd to replicated INSIDE shard_map, its (p,
    n_local) projected-core block is gathered to replicated, and its
    reconstruction — like the prox cache that carries it between decoupled
    refreshes — is `columns` (see `prox_cache_spec`).
    """
    return {
        "per_task": P(axis),
        "columns": P(None, axis),
        "per_shard": P(axis),
        "replicated": P(),
    }


def prox_cache_spec(prox_mode: str, carried: bool,
                    axis: str = TASK_AXIS) -> P:
    """Placement of the sharded engine's prox cache (`p_cache`).

    The replicated prox broadcasts one (d, T) result to every shard, so
    its cache is replicated.  The rank-distributed prox never materializes
    the full result — each shard reconstructs only its own (d, n_local)
    columns — so a CARRIED cache (decoupled cadence, prox_every >
    event_batch) is column-sharded like the iterate.  At the aligned
    cadence nothing is carried and the (0, 0) stub stays replicated in
    either mode (sharding a 0-width stub buys nothing and the stub rides
    the loop carry untouched).
    """
    if prox_mode == "distributed" and carried:
        return P(None, axis)
    return P()


# leaf-name -> raw spec (for the *unstacked* trailing dims)
_COL = ("wq", "wk", "wv", "wg", "wr", "ck", "w_in", "w_gate", "shared_in",
        "shared_gate", "wq_a", "wq_b", "wkv_a", "wk_b", "wv_b", "in_proj",
        "conv_w", "feat_proj", "unembed", "proj")
_ROW = ("wo", "out_proj", "cv", "w_out", "shared_out")
_REPL = ("router", "scale", "bias", "mask_emb", "A_log", "D", "dt_bias",
         "u", "mix", "mix_ffn", "w0", "w_lora_a", "w_lora_b", "gate",
         "lora_q_a", "lora_q_b", "lora_o_a", "lora_o_b", "cr")


def _moe_specs(name: str, mode: str, fsdp: bool) -> tuple:
    """Expert-stacked weights (E, D, F) / (E, F, D)."""
    if mode == "full":
        return (("data", "model"), None, None)
    if name in ("w_in", "w_gate"):
        return ("model", None, "data" if fsdp else None)
    return ("model", "data" if fsdp else None, None)   # w_out


def moe_fsdp(cfg: ArchConfig, axis_sizes: dict[str, int]) -> bool:
    dsize = axis_sizes.get("data", 1)
    return (cfg.moe is not None and dsize > 1
            and cfg.moe.d_expert % dsize == 0)


def moe_sharding_mode(cfg: ArchConfig, axis_sizes: dict[str, int]) -> str:
    e = cfg.moe.num_experts
    n_full = axis_sizes.get("data", 1) * axis_sizes.get("model", 1)
    if e % n_full == 0:
        return "full"
    if e % axis_sizes.get("model", 1) == 0:
        return "model"
    raise ValueError(f"experts={e} incompatible with mesh {axis_sizes}")


def _validate(spec: tuple, shape: tuple[int, ...],
              axis_sizes: dict[str, int]) -> P:
    """Drop any axis assignment that does not divide the dim."""
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = math.prod(axis_sizes.get(a, 1) for a in axes)
        out.append(ax if (size > 1 and dim % size == 0) else None)
    # pad spec if shorter than shape
    out += [None] * (len(shape) - len(out))
    return P(*out)


def param_pspec(path: tuple[str, ...], shape: tuple[int, ...],
                cfg: ArchConfig, axis_sizes: dict[str, int]) -> P:
    stacked = bool(path) and path[0].startswith("group")
    names = set(path)
    leaf = path[-1]
    trailing = shape[1:] if stacked else shape

    if leaf == "embed":
        raw = ("model", None)
    elif "moe" in names and leaf in ("w_in", "w_gate", "w_out") \
            and len(trailing) == 3:
        mode = moe_sharding_mode(cfg, axis_sizes)
        raw = _moe_specs(leaf, mode, moe_fsdp(cfg, axis_sizes))
    elif leaf in _REPL or len(trailing) <= 1:
        raw = tuple(None for _ in trailing)
    elif leaf in _ROW:
        raw = ("model",) + (None,) * (len(trailing) - 1)
    elif leaf in _COL:
        raw = (None,) * (len(trailing) - 1) + ("model",)
    else:
        raw = tuple(None for _ in trailing)

    if stacked:
        raw = (None,) + tuple(raw)
    return _validate(raw, shape, axis_sizes)


def param_pspecs(params: Any, cfg: ArchConfig,
                 axis_sizes: dict[str, int]) -> Any:
    def rule(path, leaf):
        names = tuple(_key_str(k) for k in path)
        return param_pspec(names, leaf.shape, cfg, axis_sizes)
    return jax.tree_util.tree_map_with_path(rule, params)


def _key_str(k) -> str:
    return getattr(k, "key", getattr(k, "name", str(k)))


# ---------------------------------------------------------------- ZeRO-1 ---

def with_zero(spec: P, shape: tuple[int, ...], axis_sizes: dict[str, int],
              zero_axes: tuple[str, ...] = ("data",)) -> P:
    """Shard the largest free divisible dim over the data axes (ZeRO-1)."""
    zsize = math.prod(axis_sizes.get(a, 1) for a in zero_axes)
    if zsize <= 1:
        return spec
    used = set()
    for e in spec:
        for a in (e if isinstance(e, tuple) else (e,)):
            if a:
                used.add(a)
    if any(a in used for a in zero_axes):
        return spec
    best, best_dim = -1, -1
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (dim, e) in enumerate(zip(shape, entries)):
        if e is None and dim % zsize == 0 and dim > best_dim:
            best, best_dim = i, dim
    if best < 0:
        return spec
    entries[best] = zero_axes if len(zero_axes) > 1 else zero_axes[0]
    return P(*entries)


def opt_state_pspecs(opt_state: Any, params: Any, cfg: ArchConfig,
                     axis_sizes: dict[str, int],
                     zero_axes: tuple[str, ...] = ("data",)) -> Any:
    """Derive optimizer-state pspecs from the param rules + ZeRO-1.

    Handles {master,mu,nu} (same shape as param) and adafactor {vr,vc}
    (row/col reductions of the param shape).
    """
    pspecs = param_pspecs(params, cfg, axis_sizes)
    flat_p = dict(_flatten_with_paths(pspecs))

    def rule(path, leaf):
        names = tuple(_key_str(k) for k in path)
        # first component is the optimizer-state kind for dict-of-trees
        # layouts ({master: {...}}); for adafactor it's the param path with
        # the kind as the LAST component.
        if names[0] in ("master", "mu", "nu"):
            base = flat_p.get(names[1:])
            kind = names[0]
        else:
            base = flat_p.get(names[:-1])
            kind = names[-1]
        if base is None:
            return P()
        entries = list(base) + [None] * (len(leaf.shape) - len(base))
        if kind in ("master", "mu", "nu", "v"):
            spec = P(*entries[:len(leaf.shape)])
            return with_zero(spec, leaf.shape, axis_sizes, zero_axes)
        if kind == "vr":       # param.shape[:-1]
            return P(*entries[:len(leaf.shape)])
        if kind == "vc":       # param.shape[:-2] + param.shape[-1:]
            ent = entries[:max(len(leaf.shape) - 1, 0)] + [entries[-1]] \
                if len(entries) >= 2 else entries
            ent = (ent + [None] * len(leaf.shape))[:len(leaf.shape)]
            return P(*ent)
        return P(*entries[:len(leaf.shape)])

    return jax.tree_util.tree_map_with_path(rule, opt_state)


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(
        tree, is_leaf=lambda x: isinstance(x, P))[0]
    return [(tuple(_key_str(k) for k in path), leaf) for path, leaf in flat]


# ----------------------------------------------------------- batch/caches --

def batch_pspec(name: str, shape: tuple[int, ...],
                axis_sizes: dict[str, int],
                data_axes: tuple[str, ...] = ("data",)) -> P:
    dsize = math.prod(axis_sizes.get(a, 1) for a in data_axes)
    daxis = data_axes if len(data_axes) > 1 else data_axes[0]
    b_ok = shape and shape[0] % dsize == 0 and dsize > 1
    first = daxis if b_ok else None
    return P(first, *([None] * (len(shape) - 1)))


def cache_pspec(path: tuple[str, ...], shape: tuple[int, ...],
                axis_sizes: dict[str, int],
                data_axes: tuple[str, ...] = ("data",)) -> P:
    """KV caches (n,B,S,H,hd)/(n,B,S,r): B->data, S->model.
    States conv/ssm/wkv/x_prev: B->data, heads->model when divisible."""
    leaf = path[-1]
    msize = axis_sizes.get("model", 1)
    dsize = math.prod(axis_sizes.get(a, 1) for a in data_axes)
    daxis = data_axes if len(data_axes) > 1 else data_axes[0]

    def dshard(dim):
        return daxis if (dsize > 1 and dim % dsize == 0) else None

    def mshard(dim):
        return "model" if (msize > 1 and dim % msize == 0) else None

    if leaf in ("k", "v", "c_kv", "k_rope",
                "k_scale", "v_scale"):             # (n, B, S, ...) stacked
        spec = [None, dshard(shape[1]), mshard(shape[2])]
        spec += [None] * (len(shape) - 3)
        return P(*spec)
    if leaf in ("ssm", "wkv"):                     # (n, B, H, ...)
        spec = [None, dshard(shape[1]), mshard(shape[2])]
        spec += [None] * (len(shape) - 3)
        return P(*spec)
    if leaf == "conv":                             # (n, B, kw, conv_dim)
        return P(None, dshard(shape[1]), None, mshard(shape[3]))
    if leaf.startswith("x_prev"):                  # (n, B, D)
        return P(None, dshard(shape[1]), mshard(shape[2]))
    return P(*([None] * len(shape)))


def cache_pspecs(cache: Any, axis_sizes: dict[str, int],
                 data_axes: tuple[str, ...] = ("data",)) -> Any:
    def rule(path, leaf):
        names = tuple(_key_str(k) for k in path)
        return cache_pspec(names, leaf.shape, axis_sizes, data_axes)
    return jax.tree_util.tree_map_with_path(rule, cache)


def to_named(tree_of_pspecs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_pspecs,
                        is_leaf=lambda x: isinstance(x, P))
