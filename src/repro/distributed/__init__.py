from repro.distributed.sharding import (batch_pspec, cache_pspec,
                                        opt_state_pspecs, param_pspec,
                                        param_pspecs, with_zero)

__all__ = ["batch_pspec", "cache_pspec", "opt_state_pspecs", "param_pspec",
           "param_pspecs", "with_zero"]
