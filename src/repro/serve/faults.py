"""Deterministic fault injection for the learn-while-serve platform.

Every recovery path PR 10 adds — supervised learner restart, checkpoint
fallback restore, the non-finite guard's quarantine-and-rollback — is a
claim about behaviour under failure, and timing-based chaos cannot test
such claims bitwise.  A `FaultPlan` scripts the failure points instead:
the server calls the plan's hooks at fixed places in its control flow
(chunk runner, checkpoint writer, feedback admission), the plan counts
those calls, and fires exactly at the scripted indices.  Recovery is
then a pure function of (traffic, plan) — the fault suite replays the
surviving chunk log through one `engine.run` and asserts bitwise
equality, exactly like the no-fault tests do.

The default plan is a no-op: hooks still run (an integer compare each),
so the guarded code path is IDENTICAL with and without faults armed —
there is no "fault build" whose timing or jit keys differ from prod.

Scripted points (all 0-based call indices, deterministic given the
single-threaded chunk runner):

  * `crash_on_chunks`: raise `InjectedFault` in the chunk runner just
    before the k-th runnable chunk's `engine.run`.  The chunk's
    coalesced events are lost — the platform's documented at-most-once
    crash window — and a supervised learner auto-restarts past it.
  * `poison_iterate_on_chunks`: overwrite the k-th chunk's materialized
    iterate with NaN before the snapshot flip, exercising the
    non-finite guard (quarantine + state/store rollback).
  * `nan_feedback`: `(call, row)` pairs; NaN the feature row `row` of
    the call-th LABELED `submit_feedback` before admission, exercising
    the admission-side non-finite reject.
  * `fail_checkpoint_calls`: raise `InjectedFault` inside the k-th
    `checkpoint()` call AFTER the store record lands but BEFORE the
    engine record is written — the documented crash-split window that
    `resume`'s newest-valid-record scans must bridge.

On-disk damage (bit rot, torn writes) is not a server control-flow
event, so it lives in module functions instead of the plan:
`truncate_record` tears a record's tail off (unreadable zip);
`corrupt_leaf` flips payload bytes behind a VALID zip container — the
damage only the `__manifest__` CRC layer can see.
"""
from __future__ import annotations

import os
import zipfile
from typing import Collection, Iterable, Optional, Tuple

import jax.numpy as jnp
import numpy as np


class InjectedFault(RuntimeError):
    """A scripted failure fired by a `FaultPlan` hook."""


class FaultPlan:
    """Scripted failure points for one `AMTLServer`; see module doc.

    Stateful (each hook advances a call counter), so one plan drives
    one server — build a fresh plan per server, and identical plans
    against identical traffic reproduce identical failures.
    """

    def __init__(self, *,
                 crash_on_chunks: Collection[int] = (),
                 poison_iterate_on_chunks: Collection[int] = (),
                 nan_feedback: Iterable[Tuple[int, int]] = (),
                 fail_checkpoint_calls: Collection[int] = ()):
        self._crash = frozenset(int(c) for c in crash_on_chunks)
        self._poison = frozenset(int(c) for c in poison_iterate_on_chunks)
        self._nan_rows: dict[int, list[int]] = {}
        for call, row in nan_feedback:
            self._nan_rows.setdefault(int(call), []).append(int(row))
        self._fail_ckpt = frozenset(int(c) for c in fail_checkpoint_calls)
        self._chunk_i = 0
        self._fb_i = 0
        self._ckpt_i = 0

    # ------------------------------------------------------ server hooks --

    def begin_chunk(self) -> int:
        """Called once per runnable chunk (after coalescing found
        events); returns this chunk's 0-based index."""
        idx = self._chunk_i
        self._chunk_i += 1
        return idx

    def crash_point(self, chunk_idx: int) -> None:
        """Raise if chunk `chunk_idx` is scripted to crash the runner."""
        if chunk_idx in self._crash:
            raise InjectedFault(
                f"scripted learner crash at chunk {chunk_idx}")

    def poison(self, chunk_idx: int, iterate):
        """NaN the materialized iterate when scripted, else pass it."""
        if chunk_idx in self._poison:
            return jnp.full_like(iterate, jnp.nan)
        return iterate

    def feedback(self, features: np.ndarray,
                 labels: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Called once per LABELED submit_feedback, before admission;
        returns (features, labels), NaN-poisoned when scripted."""
        call = self._fb_i
        self._fb_i += 1
        rows = self._nan_rows.get(call)
        if rows:
            features = np.array(features, np.float32, copy=True)
            for r in rows:
                features[r, 0] = np.nan
        return features, labels

    def checkpoint_point(self) -> None:
        """Called between the store record write and the engine record
        write; raises when this checkpoint call is scripted to die."""
        call = self._ckpt_i
        self._ckpt_i += 1
        if call in self._fail_ckpt:
            raise InjectedFault(
                f"scripted crash in checkpoint call {call} (store record "
                "written, engine record not)")


# ------------------------------------------------------- on-disk damage --

def truncate_record(path: str, keep_bytes: Optional[int] = None) -> int:
    """Tear the tail off a record (default: keep the first half).

    Models a crash mid-write or a short copy: the zip central directory
    lives at the end of the file, so the result is unreadable as a
    whole — `verify`/`restore` raise `CheckpointCorruptError` with no
    damaged-leaf attribution.  Returns the bytes kept.
    """
    size = os.path.getsize(path)
    keep = size // 2 if keep_bytes is None else int(keep_bytes)
    keep = max(0, min(keep, size))
    with open(path, "r+b") as f:
        f.truncate(keep)
    return keep


def corrupt_leaf(path: str, key: Optional[str] = None) -> str:
    """Flip a payload byte of one leaf behind a VALID zip container.

    The zip member is rewritten (container CRC recomputed over the
    flipped bytes), so only the embedded `__manifest__` CRC layer can
    see the damage — this models silent bit rot that the file format
    does not catch.  `key` is the flattened leaf key (without the
    `.npy` suffix); default is the first non-manifest leaf.  Returns
    the damaged member name.
    """
    with zipfile.ZipFile(path) as z:
        members = {n: z.read(n) for n in z.namelist()}
    if key is not None:
        name = key if key in members else key + ".npy"
        if name not in members:
            raise KeyError(f"no member {key!r} in {path}: "
                           f"{sorted(members)}")
    else:
        name = next(n for n in sorted(members)
                    if not n.startswith("__manifest__"))
    blob = bytearray(members[name])
    blob[-1] ^= 0xFF  # last byte = array payload, well past the npy header
    members[name] = bytes(blob)
    with zipfile.ZipFile(path, "w", zipfile.ZIP_STORED) as z:
        for n, data in members.items():
            z.writestr(n, data)
    return name
