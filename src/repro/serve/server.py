"""Learning-while-serving platform over the AMTL session API.

`AMTLServer` holds a long-lived `AMTLEngine` (`core.amtl.make_engine`) —
the paper's central server, kept learning while task nodes stream events
at it — and splits its two duties onto two CONCURRENT paths:

  * request path — `predict(task_ids, features)` micro-batches incoming
    (task_id, features) rows (bucketed padding, so distinct batch sizes
    reuse a handful of jit traces) and scores them off the committed
    serving snapshot.  The snapshot is read with ONE atomic reference
    load; the request path never takes the learner's state lock, so a
    prediction never waits on an in-flight `run` chunk or the server
    prox refresh inside it.
  * feedback path — `submit_feedback(task_ids, features=None,
    labels=None)` enqueues labeled feedback, now actually CARRYING the
    labels: an accepted item with `(features, labels)` is both one
    future engine event and one new data row for its task.  The chunk
    runner (the background learner thread via `start_learner()`, or
    the cooperative `step()`) first folds the accepted rows into the
    server's `TaskStore` (`data.store`) AT THE CHUNK BOUNDARY — the
    published ragged problem snapshot, and with it the rebuilt engine,
    changes only between chunks, never under a running one — then
    coalesces the queue into ONE engine chunk (a multiple of
    `engine.events_per_step`), advances the session with `engine.run`,
    and flips the serving snapshot at the chunk boundary.

Label-free feedback (`features=None`) is the PR-8 path unchanged: no
store is ever created, the problem and engine objects are never
rebuilt, and every PR-8 bitwise contract holds verbatim.  The store is
created lazily (`TaskStore.from_problem`) at the first fold; because
its initial capacity is exactly the problem's row budget, the fold
boundary — not store creation — is what first changes the problem.

Threading model (PR 8; components in `serve.learner` / `serve.admission`):

  * State lock (`_state_lock`, learner-side only): serializes
    coalesce -> `engine.run` -> materialize -> flip, `checkpoint()`, and
    the cooperative `step()`.  Held for the whole chunk.
  * Queue lock (`_queue_lock`): guards the pending-feedback counters,
    shared by `submit_feedback` (any thread) and the coalescer.  Never
    held across engine work.
  * Atomic flip: the serving snapshot is an immutable `(iterate, event)`
    pair reassigned as ONE reference ONLY after
    `jax.block_until_ready` — a reader sees the old committed snapshot
    or the new committed snapshot, never a torn or in-flight one.
  * Lifecycle: `start_learner()` / `stop_learner(drain=...)`; learner
    exceptions are captured and re-raised on stop/join; the
    auto-checkpoint cadence runs on the learner thread unchanged.

Double-buffer equivalence contract (tests/test_serve.py,
tests/test_serve_threaded.py — unchanged from PR 7, now also under a
concurrent predict load):

  * Zero feedback: the served iterate is BITWISE
    `engine.iterate(engine.init(v0, key))` — a frozen server serves
    exactly the frozen engine.
  * With feedback: after any sequence of chunk boundaries (cooperative
    OR on the learner thread) the engine state is BITWISE
    `engine.run(engine.init(v0, key), offs, sum(chunk_log))` over the
    same coalesced chunk sizes, every served snapshot is bitwise some
    chunk-boundary `engine.iterate`, and draining the learner with no
    concurrent submissions reproduces the cooperative `step()` loop's
    chunk log exactly (coalescing is deterministic in the queue).
  * With label-carrying feedback: after any sequence of chunk
    boundaries the engine state is BITWISE the replay of the same
    coalesced chunk log with the same rows folded at the same
    boundaries — fold, rebuild, `engine.run` — over ONE engine
    session; the store snapshot at every boundary is itself bitwise
    the replayed `TaskStore.append` sequence.
  * Restart: `AMTLServer.resume(...)` from a rotated checkpoint is
    invisible to subsequent predictions (pending, not-yet-run feedback
    is the one thing a crash loses; clients re-submit — the standard
    at-most-once queue contract).  `checkpoint()` writes the store
    (when one exists) FIRST under `<ckpt_dir>/store/` at the same
    step, then the engine state: resume restores the engine at its
    newest step and the store record paired with it, so the rebuilt
    problem, engine, and state — and therefore every subsequent
    prediction and chunk — are bitwise the uninterrupted server's.

Latency-SLO-driven admission (`ServeConfig.slo_ms`): the request path
records per-batch predict latency into a `LatencySLOController`
(`serve.admission`), which deterministically shrinks the admitted chunk
budget while the rolling p95 violates the SLO and restores it while the
tail is healthy — the chunk-size trace is a pure function of the
recorded latency sequence, logged in `stats()["slo"]`.  With
`slo_shed=True` a degraded controller also sheds NEW feedback at
admission (predictions always flow).

Per-task admission/QoS (`max_pending_per_task`, `task_chunk_quota`)
bounds what one bursty task can inject: excess queue depth is rejected
at admission, and each chunk consumes at most `task_chunk_quota` events
per task — drained round-robin from a rotating start offset — so a
flood on one task can neither evict other tasks' pending feedback nor
starve the per-chunk event budget.

Fault tolerance (PR 10):

  * Supervised learner: with `ServeConfig.restart_limit` set,
    `start_learner()` wraps the thread in a `LearnerSupervisor`
    (`serve.learner`) — a crashed learner auto-restarts under
    exponential backoff, re-serving the last committed snapshot; once
    the budget is exhausted the server's circuit breaker latches it
    into frozen-serving mode (predictions flow, feedback rejected with
    receipt reason "breaker") and the terminal exception surfaces on
    `stop_learner()`.  `restart_limit=None` (default) is the PR-8
    unsupervised learner, byte for byte.
  * Non-finite guard: `submit_feedback` rejects rows with non-finite
    features/labels at admission (reason "nonfinite"); `_step_once`
    checks the freshly materialized iterate with one `isfinite`
    reduction BEFORE the flip — on failure the chunk is discarded, the
    engine state stays at the last committed one, the rows folded at
    that boundary are rolled back out of the store bitwise
    (`TaskStore.rollback`), and the coalesced events are quarantined
    (logged per task in `stats()["health"]`, never re-queued).  The
    served snapshot can never go non-finite, and a poisoned chunk can
    never reach a checkpoint (checkpoints happen after the guard).
  * Deterministic fault injection: a `serve.faults.FaultPlan` threads
    scripted failure points (chunk crash, iterate poison, feedback NaN,
    checkpoint crash-split) through this control flow behind a no-op
    default; `resume` bridges torn/corrupt records via
    `checkpoint.latest_valid_step` and drops to older store records on
    `CheckpointCorruptError`.  Telemetry: `stats()["health"]`.
"""
from __future__ import annotations

import functools
import os
import threading
import time
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.checkpoint import CheckpointCorruptError
from repro.core.amtl import AMTLConfig, make_engine
from repro.core.losses import MTLProblem, get_loss
from repro.data.store import TaskStore
from repro.serve.admission import make_controller
from repro.serve.faults import FaultPlan
from repro.serve.learner import BackgroundLearner, LearnerSupervisor

Array = jax.Array


class ServeConfig(NamedTuple):
    """Serving-side knobs (the engine itself is configured by AMTLConfig).

    chunk_events         per-chunk event budget: at most this many engine
                         events are coalesced per chunk (must be a
                         positive multiple of `engine.events_per_step`).
                         With an SLO set this is the level-0 budget the
                         admission controller degrades from.
    task_chunk_quota     QoS: max events ONE task contributes to a chunk
                         (None = no per-task cap, the budget still caps
                         the chunk).  Drained round-robin from a rotating
                         offset so tied tasks alternate priority.
    max_pending_per_task admission: feedback beyond this per-task queue
                         depth is rejected at `submit_feedback` (None =
                         unbounded queue).
    learning             False freezes the server: feedback is rejected
                         and `step()` is a no-op — the served iterate
                         stays bitwise `engine.iterate(init_state)`.
    ckpt_dir             checkpoint directory (None disables checkpoints).
    checkpoint_every     auto-checkpoint after this many learned events
                         (None = only explicit `checkpoint()` calls).
    keep_last            rotation: keep only the k newest `step_*.npz`
                         records (repro.checkpoint.save semantics).
    max_batch            predict micro-batch ceiling: larger request
                         batches are served in `max_batch` slices;
                         smaller ones are padded to the next power of
                         two, bounding the number of jit traces.
    slo_ms               predict-latency SLO in ms (None disables the
                         admission controller and latency recording).
                         When set, `predict` blocks on its scores and
                         records the per-batch wall latency.
    slo_window           tumbling-window size (latency samples) between
                         controller decisions.
    slo_shed             True: while the controller is degraded, NEW
                         feedback is shed at admission (rejected) so the
                         backlog cannot grow against a violated SLO.
                         Requires slo_ms.
    restart_limit        fault tolerance: number of learner-thread
                         crashes `start_learner()`'s supervisor will
                         auto-restart through before tripping the
                         circuit breaker (frozen-serving mode).  None
                         (default) = unsupervised PR-8 learner: a crash
                         parks until surfaced on stop.
    restart_backoff_s    base of the supervisor's exponential restart
                         backoff: crash k waits backoff * 2**k seconds.
    """
    chunk_events: int = 32
    task_chunk_quota: Optional[int] = None
    max_pending_per_task: Optional[int] = None
    learning: bool = True
    ckpt_dir: Optional[str] = None
    checkpoint_every: Optional[int] = None
    keep_last: Optional[int] = None
    max_batch: int = 256
    slo_ms: Optional[float] = None
    slo_window: int = 32
    slo_shed: bool = False
    restart_limit: Optional[int] = None
    restart_backoff_s: float = 0.05


class FeedbackReceipt(tuple):
    """An (accepted, rejected) pair with a `reason` annotation.

    Still compares and unpacks as the plain 2-tuple it has always been
    (`receipt == (3, 7)`, `a, r = receipt`); `reason` rides along as an
    instance attribute naming why rows were rejected — None, "frozen",
    "breaker" (learner circuit breaker latched), "shed" (SLO),
    "nonfinite" (non-finite features/labels), or "admission" (per-task
    queue cap).  When one call rejects for several reasons the most
    severe wins (breaker > frozen > shed > nonfinite > admission).
    """
    reason: Optional[str]

    def __new__(cls, accepted: int, rejected: int,
                reason: Optional[str] = None):
        self = super().__new__(cls, (int(accepted), int(rejected)))
        self.reason = reason
        return self

    @property
    def accepted(self) -> int:       # enqueued for a future chunk
        return self[0]

    @property
    def rejected(self) -> int:       # capped, shed, frozen, or non-finite
        return self[1]

    def __repr__(self) -> str:
        return (f"FeedbackReceipt(accepted={self[0]}, rejected={self[1]}, "
                f"reason={self.reason!r})")


class ServingSnapshot(NamedTuple):
    """The committed serving state, flipped as one atomic reference:
    `v` is a fully-materialized chunk-boundary `engine.iterate`, `event`
    the engine event count it was committed at."""
    v: Array
    event: int


@functools.partial(jax.jit, static_argnames=("loss_name",))
def _predict_scores(v: Array, task_ids: Array, x: Array,
                    loss_name: str) -> Array:
    """Row scores off the served iterate: loss-specific link of x_i·v[:, t_i]."""
    cols = v[:, task_ids].T                       # (B, d)
    return get_loss(loss_name).predict(jnp.sum(x * cols, axis=-1))


def _bucket(n: int, cap: int) -> int:
    m = 1
    while m < n:
        m <<= 1
    return min(m, cap)


class AMTLServer:
    """A long-lived learning-while-serving AMTL session (see module doc)."""

    def __init__(self, problem: MTLProblem, cfg: AMTLConfig, v0: Array,
                 key: Array, serve_cfg: ServeConfig = ServeConfig(), *,
                 mesh=None, delay_offsets: Array | None = None,
                 fault_plan: Optional[FaultPlan] = None):
        self._configure(problem, cfg, v0, key, serve_cfg, mesh=mesh,
                        delay_offsets=delay_offsets, fault_plan=fault_plan)
        self._install_state(self.engine.init(v0, key))

    def _configure(self, problem: MTLProblem, cfg: AMTLConfig, v0: Array,
                   key: Array, serve_cfg: ServeConfig, *, mesh=None,
                   delay_offsets: Array | None = None,
                   fault_plan: Optional[FaultPlan] = None) -> None:
        """Everything construction-time except building/serving a state
        (shared by `__init__` and `resume`, which install different
        states — the fresh init vs the restored checkpoint)."""
        self.problem = problem
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        self._mesh = mesh
        self.engine = make_engine(problem, cfg, mesh)
        per = self.engine.events_per_step
        if serve_cfg.chunk_events < per \
                or serve_cfg.chunk_events % per != 0:
            raise ValueError(
                f"chunk_events ({serve_cfg.chunk_events}) must be a "
                f"positive multiple of the engine's events_per_step "
                f"({per}) so every coalesced chunk is runnable")
        if serve_cfg.task_chunk_quota is not None \
                and serve_cfg.task_chunk_quota < 1:
            raise ValueError(
                f"task_chunk_quota must be >= 1 or None, got "
                f"{serve_cfg.task_chunk_quota}")
        if serve_cfg.max_pending_per_task is not None \
                and serve_cfg.max_pending_per_task < 1:
            raise ValueError(
                f"max_pending_per_task must be >= 1 or None, got "
                f"{serve_cfg.max_pending_per_task}")
        if serve_cfg.checkpoint_every is not None \
                and serve_cfg.ckpt_dir is None:
            raise ValueError("checkpoint_every is set but ckpt_dir is None "
                             "— there is nowhere to write the checkpoints")
        if serve_cfg.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got "
                             f"{serve_cfg.max_batch}")
        if serve_cfg.slo_shed and serve_cfg.slo_ms is None:
            raise ValueError("slo_shed requires slo_ms — there is no "
                             "controller to decide when to shed")
        if serve_cfg.restart_limit is not None \
                and serve_cfg.restart_limit < 0:
            raise ValueError(
                f"restart_limit must be >= 0 or None, got "
                f"{serve_cfg.restart_limit} (None = unsupervised learner)")
        if serve_cfg.restart_backoff_s < 0:
            raise ValueError(f"restart_backoff_s must be >= 0, got "
                             f"{serve_cfg.restart_backoff_s}")
        self._slo = make_controller(serve_cfg.slo_ms, serve_cfg.chunk_events,
                                    per, serve_cfg.slo_window)
        # Fault injection: a no-op plan unless a scripted one is given,
        # so the guarded control flow is identical with and without
        # faults armed (each hook is an integer compare).
        self._faults = fault_plan if fault_plan is not None else FaultPlan()
        self._delay_offsets = delay_offsets
        self._pending = np.zeros(problem.num_tasks, np.int64)
        # Label-carrying feedback: accepted (task_id, x_row, y) rows in
        # arrival order, folded into the store at the next chunk
        # boundary.  The store itself is created lazily at the first
        # fold — the label-free path never touches it.
        self._pending_rows: list[tuple[int, np.ndarray, np.float32]] = []
        self._store: Optional[TaskStore] = None
        self._rr = 0                       # rotating round-robin offset
        self.chunk_log: list[int] = []     # coalesced chunk sizes, in order
        # Locks, narrowest-scope first (see module doc threading model):
        # the request path takes NONE of them to read the snapshot.
        self._state_lock = threading.RLock()   # chunk run / checkpoint
        self._queue_lock = threading.Lock()    # pending counters + _rr
        self._stats_lock = threading.Lock()    # request-path counters
        self._learner: Optional[BackgroundLearner | LearnerSupervisor] = None
        self._events_since_ckpt = 0
        self._n_requests = 0
        self._n_predictions = 0
        self._n_rejected = 0
        self._n_shed = 0
        # Fault-tolerance telemetry (stats()["health"]):
        self._breaker_exc: Optional[BaseException] = None
        self._n_breaker_rejected = 0
        self._n_nonfinite_fb = 0       # rows rejected at admission
        self._n_nonfinite_chunks = 0   # chunks discarded by the guard
        self._n_quarantined = 0        # events quarantined by the guard
        self._quarantine_log: list[dict[int, int]] = []  # per-task counts

    def _install_state(self, state) -> None:
        """Serve `state`: materialize its iterate and commit the serving
        snapshot (the only place besides `_step_once` that flips it)."""
        self._state = state
        v = jax.block_until_ready(self.engine.iterate(state))
        self._serving = ServingSnapshot(v, int(state.event))

    # ------------------------------------------------------- request path
    def predict(self, task_ids, features) -> Array:
        """Score a micro-batch of (task_id, features) rows.

        Served off the committed snapshot (one atomic reference read):
        never blocks on a running chunk or prox refresh, never takes the
        learner's lock.  Batches above `max_batch` are served in slices;
        smaller ones pad to the next power of two (same trace).  An
        empty request batch returns an empty (0,) score array.  With an
        SLO set, the call blocks on its scores and records the per-batch
        latency into the admission controller.
        """
        t = np.asarray(task_ids, np.int32).reshape(-1)
        x = jnp.asarray(features)
        if x.ndim != 2 or x.shape[0] != t.shape[0] \
                or x.shape[1] != self.problem.dim:
            raise ValueError(
                f"features must be (len(task_ids), d) = "
                f"({t.shape[0]}, {self.problem.dim}), got {x.shape}")
        if t.size and (t.min() < 0 or t.max() >= self.problem.num_tasks):
            raise ValueError(
                f"task_ids must be in [0, {self.problem.num_tasks}), got "
                f"range [{t.min()}, {t.max()}]")
        snap = self._serving                  # ONE atomic reference read
        with self._stats_lock:
            self._n_requests += 1
            self._n_predictions += int(t.shape[0])
        if t.shape[0] == 0:
            # the slice loop below never runs — return the empty score
            # vector in the link's dtype instead of concatenating nothing
            return jnp.zeros((0,), jnp.result_type(x.dtype, snap.v.dtype))
        t0 = time.perf_counter() if self._slo is not None else 0.0
        cap = self.serve_cfg.max_batch
        outs = []
        for lo in range(0, t.shape[0], cap):
            ts = t[lo:lo + cap]
            xs = x[lo:lo + cap]
            m = _bucket(ts.shape[0], cap)
            pad = m - ts.shape[0]
            if pad:
                ts = np.pad(ts, (0, pad))
                xs = jnp.pad(xs, ((0, pad), (0, 0)))
            scores = _predict_scores(snap.v, jnp.asarray(ts), xs,
                                     self.problem.loss_name)
            outs.append(scores[:m - pad] if pad else scores)
        out = outs[0] if len(outs) == 1 else jnp.concatenate(outs)
        if self._slo is not None:
            jax.block_until_ready(out)        # latency = computed scores
            self._slo.record(1e3 * (time.perf_counter() - t0))
        return out

    def iterate(self) -> Array:
        """The committed serving iterate (the snapshot's V)."""
        return self._serving.v

    def serving(self) -> ServingSnapshot:
        """The committed `(iterate, event)` snapshot, read atomically."""
        return self._serving

    # ------------------------------------------------------ feedback path
    def submit_feedback(self, task_ids, features=None,
                        labels=None) -> FeedbackReceipt:
        """Enqueue labeled feedback; each accepted item is one future
        engine event.

        `features` (k, d) and `labels` (k,) optionally carry the actual
        labeled rows (all-or-none: both or neither).  An accepted item
        with a row is folded into the server's `TaskStore` at the next
        chunk boundary — BEFORE that chunk runs — growing its task's
        cohort; a rejected item's row is dropped with its event
        (admission cap hit, SLO shed, non-finite row, latched breaker,
        or server frozen — the receipt's `reason` says which).  A row
        whose features or label are not finite is rejected at admission
        with its event: the engine and the store only ever see finite
        data.  Label-free items (the PR-8 API) remain pure event
        triggers against the standing data.  Thread-safe; wakes a
        running learner."""
        t = np.asarray(task_ids, np.int64).reshape(-1)
        if t.size and (t.min() < 0 or t.max() >= self.problem.num_tasks):
            raise ValueError(
                f"feedback task_ids must be in "
                f"[0, {self.problem.num_tasks}), got range "
                f"[{t.min()}, {t.max()}]")
        if (features is None) != (labels is None):
            raise ValueError("features and labels must be given together "
                             "(a labeled row is (x, y)) or both omitted")
        rows = None
        if features is not None:
            if self.cfg.engine == "dense":
                raise ValueError(
                    "engine='dense' is the exact uniform baseline and "
                    "cannot grow ragged cohorts; use engine='delta', "
                    "'batch', or 'sharded' for label-carrying feedback")
            x = np.asarray(features, np.float32)
            if x.ndim == 1:
                x = x[None, :]
            y = np.atleast_1d(np.asarray(labels, np.float32))
            if x.shape != (t.size, self.problem.dim) \
                    or y.shape != (t.size,):
                raise ValueError(
                    f"features must be ({t.size}, {self.problem.dim}) and "
                    f"labels ({t.size},) for {t.size} task ids; got "
                    f"{x.shape} and {y.shape}")
            x, y = self._faults.feedback(x, y)  # scripted NaN injection
            rows = (x, y)
        if self._breaker_exc is not None:
            with self._stats_lock:
                self._n_rejected += t.size
                self._n_breaker_rejected += t.size
            return FeedbackReceipt(0, int(t.size), reason="breaker")
        if not self.serve_cfg.learning:
            with self._stats_lock:
                self._n_rejected += t.size
            return FeedbackReceipt(0, int(t.size), reason="frozen")
        if self.serve_cfg.slo_shed and self._slo is not None \
                and self._slo.degraded:
            with self._stats_lock:
                self._n_rejected += t.size
                self._n_shed += t.size
            return FeedbackReceipt(0, int(t.size), reason="shed")
        finite = None
        if rows is not None:
            finite = (np.isfinite(rows[0]).all(axis=1)
                      & np.isfinite(rows[1]))
        cap = self.serve_cfg.max_pending_per_task
        accepted = rejected = nonfinite = 0
        with self._queue_lock:
            for i, ti in enumerate(t):
                if finite is not None and not finite[i]:
                    rejected += 1       # the event dies with its row
                    nonfinite += 1
                elif cap is not None and self._pending[ti] >= cap:
                    rejected += 1
                else:
                    self._pending[ti] += 1
                    if rows is not None:
                        self._pending_rows.append(
                            (int(ti), rows[0][i], rows[1][i]))
                    accepted += 1
        with self._stats_lock:
            self._n_rejected += rejected
            self._n_nonfinite_fb += nonfinite
        if accepted and self._learner is not None and self._learner.running:
            self._learner.wake()
        reason = None
        if nonfinite:
            reason = "nonfinite"
        elif rejected:
            reason = "admission"
        return FeedbackReceipt(accepted, rejected, reason=reason)

    def _coalesce(self) -> np.ndarray:
        """Drain the feedback queue into one runnable chunk.

        Round-robin over tasks from the rotating offset, at most
        `task_chunk_quota` events per task, at most the ADMITTED budget
        (`chunk_events`, degraded by the SLO controller when one is
        configured) total, floored to a multiple of `events_per_step`
        (the floored remainder goes back to the queue, reverse
        consumption order).  Deterministic in the queue contents and
        the admitted budget.  Called with the state lock held.
        Returns the per-task taken vector (the chunk size is its sum;
        the non-finite guard quarantines exactly these counts).
        """
        per = self.engine.events_per_step
        budget = (self._slo.chunk_events if self._slo is not None
                  else self.serve_cfg.chunk_events)
        quota = self.serve_cfg.task_chunk_quota
        quota = budget if quota is None else quota
        num_tasks = self.problem.num_tasks
        with self._queue_lock:
            order = [(self._rr + i) % num_tasks for i in range(num_tasks)]
            taken = np.zeros(num_tasks, np.int64)
            total = 0
            for ti in order:
                if total >= budget:
                    break
                k = min(int(self._pending[ti]), quota, budget - total)
                if k > 0:
                    taken[ti] = k
                    total += k
            give_back = total - (total // per) * per
            for ti in reversed(order):
                if give_back == 0:
                    break
                k = min(int(taken[ti]), give_back)
                taken[ti] -= k
                give_back -= k
            self._pending -= taken
            if taken.any():
                self._rr = (self._rr + 1) % num_tasks
        return taken

    def _fold_pending_rows(self) -> Optional[tuple]:
        """Publish the accepted labeled rows into the store (chunk
        boundary only; called with the state lock held).

        Drains `_pending_rows` in arrival order, appends them to the
        store (created lazily from the standing problem at the first
        fold), and rebuilds the published problem and engine against
        the new snapshot — the ragged row_counts grew, and capacity may
        have power-of-two doubled.  The live session STATE is untouched
        (engine state shapes depend on (d, T, tau), never on the row
        budget), so the next `engine.run` continues the same session
        against more data: exactly the paper's nodes streaming new
        local observations at the central server.

        Returns None when nothing folded (no rebuild happened), else an
        undo record `(store_undo, prev_problem, prev_engine, created)`
        the non-finite guard uses to unwind the fold bitwise: rolling
        back the store AND reinstating the exact previous problem and
        engine objects keeps the jit cache keys of the pre-fold session.
        """
        with self._queue_lock:
            rows, self._pending_rows = self._pending_rows, []
        if not rows:
            return None
        created = self._store is None
        if created:
            self._store = TaskStore.from_problem(self.problem)
        tids = np.asarray([r[0] for r in rows], np.int64)
        xs = np.stack([r[1] for r in rows])
        ys = np.asarray([r[2] for r in rows], np.float32)
        prev = (self.problem, self.engine)
        store_undo = self._store.append_undoable(tids, xs, ys)
        self.problem = self._store.problem()
        self.engine = make_engine(self.problem, self.cfg, self._mesh)
        return (store_undo, prev[0], prev[1], created)

    def _unfold_rows(self, fold: Optional[tuple]) -> None:
        """Unwind one `_fold_pending_rows` (state lock held): the store,
        problem, and engine return bitwise to their pre-fold snapshots.
        A store created BY the rolled-back fold is discarded outright —
        the session drops back to the label-free path it was on."""
        if fold is None:
            return
        store_undo, prev_problem, prev_engine, created = fold
        if created:
            self._store = None
        else:
            self._store.rollback(store_undo)
        self.problem = prev_problem
        self.engine = prev_engine

    def _step_once(self) -> int:
        """One chunk boundary: fold rows -> coalesce -> `engine.run` ->
        non-finite guard -> atomic flip.

        The engine-side critical section (state lock): accepted labeled
        rows fold into the store FIRST, so the chunk about to run — and
        every later one — sees them; then the serving snapshot is
        reassigned as ONE reference only after the new iterate fully
        materializes, so a concurrent `predict` reads either the
        previous or the new committed snapshot — never an in-flight
        one.  The guard checks the materialized iterate with one
        `isfinite` reduction BEFORE the flip: a non-finite result
        discards the chunk (state, snapshot, and chunk log untouched),
        unwinds the boundary's fold, and quarantines the coalesced
        events (logged per task, not re-queued) — the committed
        snapshot and every checkpoint stay finite by construction.
        Auto-checkpoints on the `checkpoint_every` cadence.  Runs on
        the learner thread, or inline via `step()`.

        Returns the events CONSUMED at this boundary (committed or
        quarantined), so drain loops always make progress past a
        poisoned chunk.
        """
        with self._state_lock:
            fold = self._fold_pending_rows()
            taken = self._coalesce()
            n = int(taken.sum())
            if n == 0:
                return 0
            chunk_idx = self._faults.begin_chunk()
            self._faults.crash_point(chunk_idx)   # scripted learner crash
            state = self.engine.run(self._state, self._delay_offsets, n)
            v = self.engine.iterate(state)
            v = self._faults.poison(chunk_idx, v)  # scripted NaN iterate
            v = jax.block_until_ready(v)
            if not bool(jnp.isfinite(v).all()):
                # Quarantine: nothing commits.  The last committed
                # snapshot keeps serving, the fold unwinds bitwise, and
                # the chunk's events are logged per task — never
                # re-queued (re-running the same poison forever is the
                # one thing worse than losing it).
                self._unfold_rows(fold)
                with self._stats_lock:
                    self._n_nonfinite_chunks += 1
                    self._n_quarantined += n
                    self._quarantine_log.append(
                        {int(t): int(k) for t, k in enumerate(taken)
                         if k > 0})
                return n
            self._state = state
            self.chunk_log.append(n)
            self._serving = ServingSnapshot(v, int(state.event))  # the flip
            self._events_since_ckpt += n
            every = self.serve_cfg.checkpoint_every
            if every is not None and self._events_since_ckpt >= every:
                self.checkpoint()
            return n

    def step(self) -> int:
        """Cooperative chunk boundary (single-threaded callers).

        Returns the number of events consumed at the boundary — learned,
        or quarantined by the non-finite guard (0 if frozen, breaker
        latched, or nothing runnable yet).  While the background learner
        is running, chunks belong to it — call `stop_learner()` first.
        """
        if not self.serve_cfg.learning or self._breaker_exc is not None:
            return 0
        if self.learner_running:
            raise RuntimeError(
                "the background learner owns the chunk loop; call "
                "stop_learner() before stepping cooperatively")
        return self._step_once()

    # ------------------------------------------------- learner lifecycle
    @property
    def learner_running(self) -> bool:
        return self._learner is not None and self._learner.running

    @property
    def breaker_tripped(self) -> bool:
        """True once the learner circuit breaker latched the server
        into frozen-serving mode (predictions flow, feedback rejected,
        chunks stop).  Latched for the server's lifetime."""
        return self._breaker_exc is not None

    def _trip_breaker(self, exc: BaseException) -> None:
        """Called by the supervisor when the restart budget is spent."""
        with self._stats_lock:
            self._breaker_exc = exc

    def start_learner(self) -> BackgroundLearner | LearnerSupervisor:
        """Start the background chunk runner (`serve.learner`).  The
        request path keeps serving the committed snapshot throughout;
        `submit_feedback` wakes the thread.  With
        `ServeConfig.restart_limit` set the runner is a
        `LearnerSupervisor` (bounded auto-restart + circuit breaker);
        None keeps the PR-8 unsupervised `BackgroundLearner`."""
        if not self.serve_cfg.learning:
            raise RuntimeError("server is frozen (learning=False); there "
                               "is nothing for a learner thread to run")
        if self._breaker_exc is not None:
            raise RuntimeError(
                "learner circuit breaker is latched (restart budget "
                "exhausted); the server is in frozen-serving mode"
            ) from self._breaker_exc
        if self._learner is None:
            limit = self.serve_cfg.restart_limit
            if limit is None:
                self._learner = BackgroundLearner(self)
            else:
                self._learner = LearnerSupervisor(
                    self, limit=limit,
                    backoff_s=self.serve_cfg.restart_backoff_s)
        self._learner.start()
        return self._learner

    def stop_learner(self, drain: bool = True,
                     timeout: Optional[float] = None) -> int:
        """Stop + join the learner; returns events it learned.  With
        drain=True every runnable chunk is finished first (no
        concurrent submissions -> bitwise the cooperative loop).
        Re-raises any exception the learner thread died with."""
        if self._learner is None:
            return 0
        return self._learner.stop(drain=drain, timeout=timeout)

    def serve(self, task_ids, features, feedback_task_ids=None,
              feedback_features=None, feedback_labels=None):
        """One request batch: predict, enqueue feedback, run one chunk.

        Predictions are scored against the CURRENT committed snapshot
        before the chunk runs — this batch's feedback affects the NEXT
        batch's predictions, which is what lets the request path never
        block on learning.  `feedback_features`/`feedback_labels`
        optionally carry the labeled rows (see `submit_feedback`).
        With the background learner running, the chunk step is left to
        it (ran = 0 here).  Returns (predictions, FeedbackReceipt,
        events_learned).
        """
        preds = self.predict(task_ids, features)
        receipt = FeedbackReceipt(0, 0)
        if feedback_task_ids is not None:
            receipt = self.submit_feedback(feedback_task_ids,
                                           feedback_features,
                                           feedback_labels)
        ran = 0 if self.learner_running else self.step()
        return preds, receipt, ran

    # ------------------------------------------------- checkpoint/restart
    def checkpoint(self) -> Optional[str]:
        """Write the engine state as `step_<event>.npz`, rotated to
        `keep_last`.  Returns the written path (None if no ckpt_dir).
        Serialized against the chunk runner by the state lock.

        When a store exists (labeled rows were folded), its buffers are
        written FIRST, under `<ckpt_dir>/store/` at the SAME step: a
        crash between the two writes leaves an unpaired NEWER store
        record — which resume tolerates — never an engine state whose
        data is missing.  A label-free server writes no store subdir
        at all (the PR-8 on-disk layout, byte for byte).  The fault
        plan's checkpoint hook sits exactly in that split window, so
        the crash-split recovery path is testable on demand."""
        if self.serve_cfg.ckpt_dir is None:
            return None
        with self._state_lock:
            if self._store is not None:
                self._store.save(
                    os.path.join(self.serve_cfg.ckpt_dir, "store"),
                    int(self._state.event),
                    keep_last=self.serve_cfg.keep_last)
            self._faults.checkpoint_point()  # scripted crash-split
            path = checkpoint.save(self.serve_cfg.ckpt_dir,
                                   int(self._state.event), self._state,
                                   keep_last=self.serve_cfg.keep_last)
            self._events_since_ckpt = 0
        return path

    @classmethod
    def resume(cls, problem: MTLProblem, cfg: AMTLConfig, v0: Array,
               key: Array, serve_cfg: ServeConfig = ServeConfig(), *,
               mesh=None, delay_offsets: Array | None = None,
               fault_plan: Optional[FaultPlan] = None) -> "AMTLServer":
        """Restart-transparent construction: restore the newest VALID
        rotated checkpoint in `serve_cfg.ckpt_dir` if one exists, else
        a fresh `engine.init(v0, key)` session.  The init state is
        built ONCE (it doubles as `restore`'s `like` layout witness)
        and only the state actually served materializes a serving
        snapshot.  The restored server's snapshot — and therefore every
        subsequent prediction — is bitwise the uninterrupted server's
        at the same chunk boundary.

        Record selection is integrity-checked
        (`checkpoint.latest_valid_step`): a torn or bit-rotted newest
        record is skipped and the session falls back one checkpoint
        interval instead of dying on an opaque zip error.  A directory
        whose records are ALL damaged raises `CheckpointCorruptError` —
        silently restarting a session from scratch is worse than
        failing loudly.

        If the checkpoint has a paired store record (labeled rows had
        been folded), the store is restored FIRST and the problem and
        engine are rebuilt from its snapshot — `problem` then only
        seeds the restored buffers' layout witness — so the resumed
        session continues against exactly the grown cohorts it was
        checkpointed with.  A missing or corrupt paired record drops to
        the remaining store records newest-first (the crash-split and
        bit-rot cases).  Engine state shapes never depend on the row
        budget, so the fresh init state remains a valid `like` layout
        for `restore` either way."""
        server = cls.__new__(cls)
        server._configure(problem, cfg, v0, key, serve_cfg, mesh=mesh,
                          delay_offsets=delay_offsets, fault_plan=fault_plan)
        init_state = server.engine.init(v0, key)
        d = serve_cfg.ckpt_dir
        step = None
        if d is not None:
            step = checkpoint.latest_valid_step(d, like=init_state)
            if step is None and checkpoint.latest_step(d) is not None:
                raise CheckpointCorruptError(
                    d, [], "every engine record in the directory fails "
                    "verification — refusing to silently restart the "
                    "session from scratch")
        if step is None:
            server._install_state(init_state)
            return server
        store_dir = os.path.join(d, "store")

        def _try_store(s: int) -> Optional[TaskStore]:
            try:
                return TaskStore.restore(store_dir, s, problem.loss_name,
                                         problem.reg_name, problem.lam)
            except (FileNotFoundError, CheckpointCorruptError):
                return None

        # Prefer the record paired with the engine step; fall back to
        # the remaining records newest-first.  No record at exactly
        # `step` is either a label-free session (no store subdir — the
        # common case), a crash between the store write and the engine
        # write (one unpaired NEWER record holding a superset of the
        # paired rows — the engine state never saw the extras, appends
        # only affect FUTURE chunks), or a torn/corrupt paired record
        # (drop one interval of rows rather than the session).
        store = _try_store(step)
        if store is None:
            for s in checkpoint.record_steps(store_dir):
                if s == step:
                    continue
                store = _try_store(s)
                if store is not None:
                    break
            if store is None and checkpoint.record_steps(store_dir):
                raise CheckpointCorruptError(
                    store_dir, [], "every store record fails to restore "
                    "— resuming the engine without its folded rows would "
                    "silently change the session")
        if store is not None:
            server._store = store
            server.problem = store.problem()
            server.engine = make_engine(server.problem, cfg, mesh)
        server._install_state(checkpoint.restore(d, step, like=init_state))
        return server

    # ---------------------------------------------------------- telemetry
    @property
    def event_count(self) -> int:
        return int(self._state.event)

    @property
    def pending_feedback(self) -> int:
        return int(self._pending.sum())

    @property
    def store_rows(self) -> Optional[int]:
        """Total rows in the store (None until labeled rows fold)."""
        store = self._store
        return None if store is None else store.num_rows

    def stats(self) -> dict[str, Any]:
        sup = (self._learner
               if isinstance(self._learner, LearnerSupervisor) else None)
        health = {
            "learner_restarts": 0 if sup is None else sup.restarts,
            "learner_crashes": 0 if sup is None else sup.crashes,
            "crash_log": [] if sup is None else list(sup.crash_log),
            "recovery_ms": [] if sup is None else list(sup.recovery_ms),
            "breaker_tripped": self.breaker_tripped,
            "breaker_rejected": self._n_breaker_rejected,
            "nonfinite_feedback": self._n_nonfinite_fb,
            "nonfinite_chunks": self._n_nonfinite_chunks,
            "quarantined_feedback": self._n_quarantined,
            "quarantine_log": [dict(q) for q in self._quarantine_log],
        }
        out = {
            "requests": self._n_requests,
            "predictions": self._n_predictions,
            "events": self.event_count,
            "chunks": len(self.chunk_log),
            "pending_feedback": self.pending_feedback,
            "pending_rows": len(self._pending_rows),
            "store_rows": self.store_rows,
            "rejected_feedback": self._n_rejected,
            "shed_feedback": self._n_shed,
            "learning": self.serve_cfg.learning,
            "learner_running": self.learner_running,
            "learner_chunks": 0 if self._learner is None
                              else self._learner.chunks,
            "slo": None if self._slo is None else self._slo.snapshot(),
            "health": health,
        }
        return out
