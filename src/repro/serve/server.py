"""Learning-while-serving platform over the AMTL session API.

`AMTLServer` holds a long-lived `AMTLEngine` (`core.amtl.make_engine`) —
the paper's central server, kept learning while task nodes stream events
at it — and splits its two duties onto two CONCURRENT paths:

  * request path — `predict(task_ids, features)` micro-batches incoming
    (task_id, features) rows (bucketed padding, so distinct batch sizes
    reuse a handful of jit traces) and scores them off the committed
    serving snapshot.  The snapshot is read with ONE atomic reference
    load; the request path never takes the learner's state lock, so a
    prediction never waits on an in-flight `run` chunk or the server
    prox refresh inside it.
  * feedback path — `submit_feedback(task_ids, features=None,
    labels=None)` enqueues labeled feedback, now actually CARRYING the
    labels: an accepted item with `(features, labels)` is both one
    future engine event and one new data row for its task.  The chunk
    runner (the background learner thread via `start_learner()`, or
    the cooperative `step()`) first folds the accepted rows into the
    server's `TaskStore` (`data.store`) AT THE CHUNK BOUNDARY — the
    published ragged problem snapshot, and with it the rebuilt engine,
    changes only between chunks, never under a running one — then
    coalesces the queue into ONE engine chunk (a multiple of
    `engine.events_per_step`), advances the session with `engine.run`,
    and flips the serving snapshot at the chunk boundary.

Label-free feedback (`features=None`) is the PR-8 path unchanged: no
store is ever created, the problem and engine objects are never
rebuilt, and every PR-8 bitwise contract holds verbatim.  The store is
created lazily (`TaskStore.from_problem`) at the first fold; because
its initial capacity is exactly the problem's row budget, the fold
boundary — not store creation — is what first changes the problem.

Threading model (PR 8; components in `serve.learner` / `serve.admission`):

  * State lock (`_state_lock`, learner-side only): serializes
    coalesce -> `engine.run` -> materialize -> flip, `checkpoint()`, and
    the cooperative `step()`.  Held for the whole chunk.
  * Queue lock (`_queue_lock`): guards the pending-feedback counters,
    shared by `submit_feedback` (any thread) and the coalescer.  Never
    held across engine work.
  * Atomic flip: the serving snapshot is an immutable `(iterate, event)`
    pair reassigned as ONE reference ONLY after
    `jax.block_until_ready` — a reader sees the old committed snapshot
    or the new committed snapshot, never a torn or in-flight one.
  * Lifecycle: `start_learner()` / `stop_learner(drain=...)`; learner
    exceptions are captured and re-raised on stop/join; the
    auto-checkpoint cadence runs on the learner thread unchanged.

Double-buffer equivalence contract (tests/test_serve.py,
tests/test_serve_threaded.py — unchanged from PR 7, now also under a
concurrent predict load):

  * Zero feedback: the served iterate is BITWISE
    `engine.iterate(engine.init(v0, key))` — a frozen server serves
    exactly the frozen engine.
  * With feedback: after any sequence of chunk boundaries (cooperative
    OR on the learner thread) the engine state is BITWISE
    `engine.run(engine.init(v0, key), offs, sum(chunk_log))` over the
    same coalesced chunk sizes, every served snapshot is bitwise some
    chunk-boundary `engine.iterate`, and draining the learner with no
    concurrent submissions reproduces the cooperative `step()` loop's
    chunk log exactly (coalescing is deterministic in the queue).
  * With label-carrying feedback: after any sequence of chunk
    boundaries the engine state is BITWISE the replay of the same
    coalesced chunk log with the same rows folded at the same
    boundaries — fold, rebuild, `engine.run` — over ONE engine
    session; the store snapshot at every boundary is itself bitwise
    the replayed `TaskStore.append` sequence.
  * Restart: `AMTLServer.resume(...)` from a rotated checkpoint is
    invisible to subsequent predictions (pending, not-yet-run feedback
    is the one thing a crash loses; clients re-submit — the standard
    at-most-once queue contract).  `checkpoint()` writes the store
    (when one exists) FIRST under `<ckpt_dir>/store/` at the same
    step, then the engine state: resume restores the engine at its
    newest step and the store record paired with it, so the rebuilt
    problem, engine, and state — and therefore every subsequent
    prediction and chunk — are bitwise the uninterrupted server's.

Latency-SLO-driven admission (`ServeConfig.slo_ms`): the request path
records per-batch predict latency into a `LatencySLOController`
(`serve.admission`), which deterministically shrinks the admitted chunk
budget while the rolling p95 violates the SLO and restores it while the
tail is healthy — the chunk-size trace is a pure function of the
recorded latency sequence, logged in `stats()["slo"]`.  With
`slo_shed=True` a degraded controller also sheds NEW feedback at
admission (predictions always flow).

Per-task admission/QoS (`max_pending_per_task`, `task_chunk_quota`)
bounds what one bursty task can inject: excess queue depth is rejected
at admission, and each chunk consumes at most `task_chunk_quota` events
per task — drained round-robin from a rotating start offset — so a
flood on one task can neither evict other tasks' pending feedback nor
starve the per-chunk event budget.
"""
from __future__ import annotations

import functools
import os
import threading
import time
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.core.amtl import AMTLConfig, make_engine
from repro.core.losses import MTLProblem, get_loss
from repro.data.store import TaskStore
from repro.serve.admission import make_controller
from repro.serve.learner import BackgroundLearner

Array = jax.Array


class ServeConfig(NamedTuple):
    """Serving-side knobs (the engine itself is configured by AMTLConfig).

    chunk_events         per-chunk event budget: at most this many engine
                         events are coalesced per chunk (must be a
                         positive multiple of `engine.events_per_step`).
                         With an SLO set this is the level-0 budget the
                         admission controller degrades from.
    task_chunk_quota     QoS: max events ONE task contributes to a chunk
                         (None = no per-task cap, the budget still caps
                         the chunk).  Drained round-robin from a rotating
                         offset so tied tasks alternate priority.
    max_pending_per_task admission: feedback beyond this per-task queue
                         depth is rejected at `submit_feedback` (None =
                         unbounded queue).
    learning             False freezes the server: feedback is rejected
                         and `step()` is a no-op — the served iterate
                         stays bitwise `engine.iterate(init_state)`.
    ckpt_dir             checkpoint directory (None disables checkpoints).
    checkpoint_every     auto-checkpoint after this many learned events
                         (None = only explicit `checkpoint()` calls).
    keep_last            rotation: keep only the k newest `step_*.npz`
                         records (repro.checkpoint.save semantics).
    max_batch            predict micro-batch ceiling: larger request
                         batches are served in `max_batch` slices;
                         smaller ones are padded to the next power of
                         two, bounding the number of jit traces.
    slo_ms               predict-latency SLO in ms (None disables the
                         admission controller and latency recording).
                         When set, `predict` blocks on its scores and
                         records the per-batch wall latency.
    slo_window           tumbling-window size (latency samples) between
                         controller decisions.
    slo_shed             True: while the controller is degraded, NEW
                         feedback is shed at admission (rejected) so the
                         backlog cannot grow against a violated SLO.
                         Requires slo_ms.
    """
    chunk_events: int = 32
    task_chunk_quota: Optional[int] = None
    max_pending_per_task: Optional[int] = None
    learning: bool = True
    ckpt_dir: Optional[str] = None
    checkpoint_every: Optional[int] = None
    keep_last: Optional[int] = None
    max_batch: int = 256
    slo_ms: Optional[float] = None
    slo_window: int = 32
    slo_shed: bool = False


class FeedbackReceipt(NamedTuple):
    accepted: int          # enqueued for a future chunk
    rejected: int          # admission-capped, SLO-shed, or server frozen


class ServingSnapshot(NamedTuple):
    """The committed serving state, flipped as one atomic reference:
    `v` is a fully-materialized chunk-boundary `engine.iterate`, `event`
    the engine event count it was committed at."""
    v: Array
    event: int


@functools.partial(jax.jit, static_argnames=("loss_name",))
def _predict_scores(v: Array, task_ids: Array, x: Array,
                    loss_name: str) -> Array:
    """Row scores off the served iterate: loss-specific link of x_i·v[:, t_i]."""
    cols = v[:, task_ids].T                       # (B, d)
    return get_loss(loss_name).predict(jnp.sum(x * cols, axis=-1))


def _bucket(n: int, cap: int) -> int:
    m = 1
    while m < n:
        m <<= 1
    return min(m, cap)


class AMTLServer:
    """A long-lived learning-while-serving AMTL session (see module doc)."""

    def __init__(self, problem: MTLProblem, cfg: AMTLConfig, v0: Array,
                 key: Array, serve_cfg: ServeConfig = ServeConfig(), *,
                 mesh=None, delay_offsets: Array | None = None):
        self._configure(problem, cfg, v0, key, serve_cfg, mesh=mesh,
                        delay_offsets=delay_offsets)
        self._install_state(self.engine.init(v0, key))

    def _configure(self, problem: MTLProblem, cfg: AMTLConfig, v0: Array,
                   key: Array, serve_cfg: ServeConfig, *, mesh=None,
                   delay_offsets: Array | None = None) -> None:
        """Everything construction-time except building/serving a state
        (shared by `__init__` and `resume`, which install different
        states — the fresh init vs the restored checkpoint)."""
        self.problem = problem
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        self._mesh = mesh
        self.engine = make_engine(problem, cfg, mesh)
        per = self.engine.events_per_step
        if serve_cfg.chunk_events < per \
                or serve_cfg.chunk_events % per != 0:
            raise ValueError(
                f"chunk_events ({serve_cfg.chunk_events}) must be a "
                f"positive multiple of the engine's events_per_step "
                f"({per}) so every coalesced chunk is runnable")
        if serve_cfg.task_chunk_quota is not None \
                and serve_cfg.task_chunk_quota < 1:
            raise ValueError(
                f"task_chunk_quota must be >= 1 or None, got "
                f"{serve_cfg.task_chunk_quota}")
        if serve_cfg.max_pending_per_task is not None \
                and serve_cfg.max_pending_per_task < 1:
            raise ValueError(
                f"max_pending_per_task must be >= 1 or None, got "
                f"{serve_cfg.max_pending_per_task}")
        if serve_cfg.checkpoint_every is not None \
                and serve_cfg.ckpt_dir is None:
            raise ValueError("checkpoint_every is set but ckpt_dir is None "
                             "— there is nowhere to write the checkpoints")
        if serve_cfg.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got "
                             f"{serve_cfg.max_batch}")
        if serve_cfg.slo_shed and serve_cfg.slo_ms is None:
            raise ValueError("slo_shed requires slo_ms — there is no "
                             "controller to decide when to shed")
        self._slo = make_controller(serve_cfg.slo_ms, serve_cfg.chunk_events,
                                    per, serve_cfg.slo_window)
        self._delay_offsets = delay_offsets
        self._pending = np.zeros(problem.num_tasks, np.int64)
        # Label-carrying feedback: accepted (task_id, x_row, y) rows in
        # arrival order, folded into the store at the next chunk
        # boundary.  The store itself is created lazily at the first
        # fold — the label-free path never touches it.
        self._pending_rows: list[tuple[int, np.ndarray, np.float32]] = []
        self._store: Optional[TaskStore] = None
        self._rr = 0                       # rotating round-robin offset
        self.chunk_log: list[int] = []     # coalesced chunk sizes, in order
        # Locks, narrowest-scope first (see module doc threading model):
        # the request path takes NONE of them to read the snapshot.
        self._state_lock = threading.RLock()   # chunk run / checkpoint
        self._queue_lock = threading.Lock()    # pending counters + _rr
        self._stats_lock = threading.Lock()    # request-path counters
        self._learner: Optional[BackgroundLearner] = None
        self._events_since_ckpt = 0
        self._n_requests = 0
        self._n_predictions = 0
        self._n_rejected = 0
        self._n_shed = 0

    def _install_state(self, state) -> None:
        """Serve `state`: materialize its iterate and commit the serving
        snapshot (the only place besides `_step_once` that flips it)."""
        self._state = state
        v = jax.block_until_ready(self.engine.iterate(state))
        self._serving = ServingSnapshot(v, int(state.event))

    # ------------------------------------------------------- request path
    def predict(self, task_ids, features) -> Array:
        """Score a micro-batch of (task_id, features) rows.

        Served off the committed snapshot (one atomic reference read):
        never blocks on a running chunk or prox refresh, never takes the
        learner's lock.  Batches above `max_batch` are served in slices;
        smaller ones pad to the next power of two (same trace).  An
        empty request batch returns an empty (0,) score array.  With an
        SLO set, the call blocks on its scores and records the per-batch
        latency into the admission controller.
        """
        t = np.asarray(task_ids, np.int32).reshape(-1)
        x = jnp.asarray(features)
        if x.ndim != 2 or x.shape[0] != t.shape[0] \
                or x.shape[1] != self.problem.dim:
            raise ValueError(
                f"features must be (len(task_ids), d) = "
                f"({t.shape[0]}, {self.problem.dim}), got {x.shape}")
        if t.size and (t.min() < 0 or t.max() >= self.problem.num_tasks):
            raise ValueError(
                f"task_ids must be in [0, {self.problem.num_tasks}), got "
                f"range [{t.min()}, {t.max()}]")
        snap = self._serving                  # ONE atomic reference read
        with self._stats_lock:
            self._n_requests += 1
            self._n_predictions += int(t.shape[0])
        if t.shape[0] == 0:
            # the slice loop below never runs — return the empty score
            # vector in the link's dtype instead of concatenating nothing
            return jnp.zeros((0,), jnp.result_type(x.dtype, snap.v.dtype))
        t0 = time.perf_counter() if self._slo is not None else 0.0
        cap = self.serve_cfg.max_batch
        outs = []
        for lo in range(0, t.shape[0], cap):
            ts = t[lo:lo + cap]
            xs = x[lo:lo + cap]
            m = _bucket(ts.shape[0], cap)
            pad = m - ts.shape[0]
            if pad:
                ts = np.pad(ts, (0, pad))
                xs = jnp.pad(xs, ((0, pad), (0, 0)))
            scores = _predict_scores(snap.v, jnp.asarray(ts), xs,
                                     self.problem.loss_name)
            outs.append(scores[:m - pad] if pad else scores)
        out = outs[0] if len(outs) == 1 else jnp.concatenate(outs)
        if self._slo is not None:
            jax.block_until_ready(out)        # latency = computed scores
            self._slo.record(1e3 * (time.perf_counter() - t0))
        return out

    def iterate(self) -> Array:
        """The committed serving iterate (the snapshot's V)."""
        return self._serving.v

    def serving(self) -> ServingSnapshot:
        """The committed `(iterate, event)` snapshot, read atomically."""
        return self._serving

    # ------------------------------------------------------ feedback path
    def submit_feedback(self, task_ids, features=None,
                        labels=None) -> FeedbackReceipt:
        """Enqueue labeled feedback; each accepted item is one future
        engine event.

        `features` (k, d) and `labels` (k,) optionally carry the actual
        labeled rows (all-or-none: both or neither).  An accepted item
        with a row is folded into the server's `TaskStore` at the next
        chunk boundary — BEFORE that chunk runs — growing its task's
        cohort; a rejected item's row is dropped with its event
        (admission cap hit, SLO shed, or server frozen).  Label-free
        items (the PR-8 API) remain pure event triggers against the
        standing data.  Thread-safe; wakes a running learner."""
        t = np.asarray(task_ids, np.int64).reshape(-1)
        if t.size and (t.min() < 0 or t.max() >= self.problem.num_tasks):
            raise ValueError(
                f"feedback task_ids must be in "
                f"[0, {self.problem.num_tasks}), got range "
                f"[{t.min()}, {t.max()}]")
        if (features is None) != (labels is None):
            raise ValueError("features and labels must be given together "
                             "(a labeled row is (x, y)) or both omitted")
        rows = None
        if features is not None:
            if self.cfg.engine == "dense":
                raise ValueError(
                    "engine='dense' is the exact uniform baseline and "
                    "cannot grow ragged cohorts; use engine='delta', "
                    "'batch', or 'sharded' for label-carrying feedback")
            x = np.asarray(features, np.float32)
            if x.ndim == 1:
                x = x[None, :]
            y = np.atleast_1d(np.asarray(labels, np.float32))
            if x.shape != (t.size, self.problem.dim) \
                    or y.shape != (t.size,):
                raise ValueError(
                    f"features must be ({t.size}, {self.problem.dim}) and "
                    f"labels ({t.size},) for {t.size} task ids; got "
                    f"{x.shape} and {y.shape}")
            rows = (x, y)
        if not self.serve_cfg.learning:
            with self._stats_lock:
                self._n_rejected += t.size
            return FeedbackReceipt(0, int(t.size))
        if self.serve_cfg.slo_shed and self._slo is not None \
                and self._slo.degraded:
            with self._stats_lock:
                self._n_rejected += t.size
                self._n_shed += t.size
            return FeedbackReceipt(0, int(t.size))
        cap = self.serve_cfg.max_pending_per_task
        accepted = rejected = 0
        with self._queue_lock:
            for i, ti in enumerate(t):
                if cap is not None and self._pending[ti] >= cap:
                    rejected += 1
                else:
                    self._pending[ti] += 1
                    if rows is not None:
                        self._pending_rows.append(
                            (int(ti), rows[0][i], rows[1][i]))
                    accepted += 1
        with self._stats_lock:
            self._n_rejected += rejected
        if accepted and self._learner is not None and self._learner.running:
            self._learner.wake()
        return FeedbackReceipt(accepted, rejected)

    def _coalesce(self) -> int:
        """Drain the feedback queue into one runnable chunk size.

        Round-robin over tasks from the rotating offset, at most
        `task_chunk_quota` events per task, at most the ADMITTED budget
        (`chunk_events`, degraded by the SLO controller when one is
        configured) total, floored to a multiple of `events_per_step`
        (the floored remainder goes back to the queue, reverse
        consumption order).  Deterministic in the queue contents and
        the admitted budget.  Called with the state lock held.
        """
        per = self.engine.events_per_step
        budget = (self._slo.chunk_events if self._slo is not None
                  else self.serve_cfg.chunk_events)
        quota = self.serve_cfg.task_chunk_quota
        quota = budget if quota is None else quota
        num_tasks = self.problem.num_tasks
        with self._queue_lock:
            order = [(self._rr + i) % num_tasks for i in range(num_tasks)]
            taken = np.zeros(num_tasks, np.int64)
            total = 0
            for ti in order:
                if total >= budget:
                    break
                k = min(int(self._pending[ti]), quota, budget - total)
                if k > 0:
                    taken[ti] = k
                    total += k
            give_back = total - (total // per) * per
            for ti in reversed(order):
                if give_back == 0:
                    break
                k = min(int(taken[ti]), give_back)
                taken[ti] -= k
                give_back -= k
            self._pending -= taken
            if taken.any():
                self._rr = (self._rr + 1) % num_tasks
        return int(taken.sum())

    def _fold_pending_rows(self) -> int:
        """Publish the accepted labeled rows into the store (chunk
        boundary only; called with the state lock held).

        Drains `_pending_rows` in arrival order, appends them to the
        store (created lazily from the standing problem at the first
        fold), and rebuilds the published problem and engine against
        the new snapshot — the ragged row_counts grew, and capacity may
        have power-of-two doubled.  The live session STATE is untouched
        (engine state shapes depend on (d, T, tau), never on the row
        budget), so the next `engine.run` continues the same session
        against more data: exactly the paper's nodes streaming new
        local observations at the central server.  Returns the number
        of rows folded (0 = nothing changed, no rebuild).
        """
        with self._queue_lock:
            rows, self._pending_rows = self._pending_rows, []
        if not rows:
            return 0
        if self._store is None:
            self._store = TaskStore.from_problem(self.problem)
        tids = np.asarray([r[0] for r in rows], np.int64)
        xs = np.stack([r[1] for r in rows])
        ys = np.asarray([r[2] for r in rows], np.float32)
        self._store.append(tids, xs, ys)
        self.problem = self._store.problem()
        self.engine = make_engine(self.problem, self.cfg, self._mesh)
        return len(rows)

    def _step_once(self) -> int:
        """One chunk boundary: fold rows -> coalesce -> `engine.run` ->
        atomic flip.

        The engine-side critical section (state lock): accepted labeled
        rows fold into the store FIRST, so the chunk about to run — and
        every later one — sees them; then the serving snapshot is
        reassigned as ONE reference only after the new iterate fully
        materializes, so a concurrent `predict` reads either the
        previous or the new committed snapshot — never an in-flight
        one.  Auto-checkpoints on the `checkpoint_every` cadence.  Runs
        on the learner thread, or inline via `step()`.
        """
        with self._state_lock:
            self._fold_pending_rows()
            n = self._coalesce()
            if n == 0:
                return 0
            state = self.engine.run(self._state, self._delay_offsets, n)
            v = jax.block_until_ready(self.engine.iterate(state))
            self._state = state
            self.chunk_log.append(n)
            self._serving = ServingSnapshot(v, int(state.event))  # the flip
            self._events_since_ckpt += n
            every = self.serve_cfg.checkpoint_every
            if every is not None and self._events_since_ckpt >= every:
                self.checkpoint()
            return n

    def step(self) -> int:
        """Cooperative chunk boundary (single-threaded callers).

        Returns the number of events learned (0 if frozen or nothing
        runnable yet).  While the background learner is running, chunks
        belong to it — call `stop_learner()` first.
        """
        if not self.serve_cfg.learning:
            return 0
        if self.learner_running:
            raise RuntimeError(
                "the background learner owns the chunk loop; call "
                "stop_learner() before stepping cooperatively")
        return self._step_once()

    # ------------------------------------------------- learner lifecycle
    @property
    def learner_running(self) -> bool:
        return self._learner is not None and self._learner.running

    def start_learner(self) -> BackgroundLearner:
        """Start the background chunk runner (`serve.learner`).  The
        request path keeps serving the committed snapshot throughout;
        `submit_feedback` wakes the thread."""
        if not self.serve_cfg.learning:
            raise RuntimeError("server is frozen (learning=False); there "
                               "is nothing for a learner thread to run")
        if self._learner is None:
            self._learner = BackgroundLearner(self)
        self._learner.start()
        return self._learner

    def stop_learner(self, drain: bool = True,
                     timeout: Optional[float] = None) -> int:
        """Stop + join the learner; returns events it learned.  With
        drain=True every runnable chunk is finished first (no
        concurrent submissions -> bitwise the cooperative loop).
        Re-raises any exception the learner thread died with."""
        if self._learner is None:
            return 0
        return self._learner.stop(drain=drain, timeout=timeout)

    def serve(self, task_ids, features, feedback_task_ids=None,
              feedback_features=None, feedback_labels=None):
        """One request batch: predict, enqueue feedback, run one chunk.

        Predictions are scored against the CURRENT committed snapshot
        before the chunk runs — this batch's feedback affects the NEXT
        batch's predictions, which is what lets the request path never
        block on learning.  `feedback_features`/`feedback_labels`
        optionally carry the labeled rows (see `submit_feedback`).
        With the background learner running, the chunk step is left to
        it (ran = 0 here).  Returns (predictions, FeedbackReceipt,
        events_learned).
        """
        preds = self.predict(task_ids, features)
        receipt = FeedbackReceipt(0, 0)
        if feedback_task_ids is not None:
            receipt = self.submit_feedback(feedback_task_ids,
                                           feedback_features,
                                           feedback_labels)
        ran = 0 if self.learner_running else self.step()
        return preds, receipt, ran

    # ------------------------------------------------- checkpoint/restart
    def checkpoint(self) -> Optional[str]:
        """Write the engine state as `step_<event>.npz`, rotated to
        `keep_last`.  Returns the written path (None if no ckpt_dir).
        Serialized against the chunk runner by the state lock.

        When a store exists (labeled rows were folded), its buffers are
        written FIRST, under `<ckpt_dir>/store/` at the SAME step: a
        crash between the two writes leaves an unpaired NEWER store
        record — which resume tolerates — never an engine state whose
        data is missing.  A label-free server writes no store subdir
        at all (the PR-8 on-disk layout, byte for byte)."""
        if self.serve_cfg.ckpt_dir is None:
            return None
        with self._state_lock:
            if self._store is not None:
                self._store.save(
                    os.path.join(self.serve_cfg.ckpt_dir, "store"),
                    int(self._state.event),
                    keep_last=self.serve_cfg.keep_last)
            path = checkpoint.save(self.serve_cfg.ckpt_dir,
                                   int(self._state.event), self._state,
                                   keep_last=self.serve_cfg.keep_last)
            self._events_since_ckpt = 0
        return path

    @classmethod
    def resume(cls, problem: MTLProblem, cfg: AMTLConfig, v0: Array,
               key: Array, serve_cfg: ServeConfig = ServeConfig(), *,
               mesh=None, delay_offsets: Array | None = None) -> "AMTLServer":
        """Restart-transparent construction: restore the newest rotated
        checkpoint in `serve_cfg.ckpt_dir` if one exists, else a fresh
        `engine.init(v0, key)` session.  The init state is built ONCE
        (it doubles as `restore`'s `like` layout witness) and only the
        state actually served materializes a serving snapshot.  The
        restored server's snapshot — and therefore every subsequent
        prediction — is bitwise the uninterrupted server's at the same
        chunk boundary.

        If the checkpoint has a paired store record (labeled rows had
        been folded), the store is restored FIRST and the problem and
        engine are rebuilt from its snapshot — `problem` then only
        seeds the restored buffers' layout witness — so the resumed
        session continues against exactly the grown cohorts it was
        checkpointed with.  Engine state shapes never depend on the row
        budget, so the fresh init state remains a valid `like` layout
        for `restore` either way."""
        server = cls.__new__(cls)
        server._configure(problem, cfg, v0, key, serve_cfg, mesh=mesh,
                          delay_offsets=delay_offsets)
        init_state = server.engine.init(v0, key)
        d = serve_cfg.ckpt_dir
        step = checkpoint.latest_step(d) if d is not None else None
        if step is None:
            server._install_state(init_state)
            return server
        store_dir = os.path.join(d, "store")
        try:
            store = TaskStore.restore(store_dir, step, problem.loss_name,
                                      problem.reg_name, problem.lam)
        except FileNotFoundError:
            # No record at exactly `step`: either a label-free session
            # (no store subdir — the common case) or a crash landed
            # between the store write and the engine write, leaving one
            # unpaired newer store record.  Take the newest record when
            # one exists — it holds a superset of the paired rows (the
            # engine state at `step` never saw the extras, and appends
            # only ever affect FUTURE chunks).
            newer = checkpoint.latest_step(store_dir)
            store = None if newer is None else TaskStore.restore(
                store_dir, newer, problem.loss_name, problem.reg_name,
                problem.lam)
        if store is not None:
            server._store = store
            server.problem = store.problem()
            server.engine = make_engine(server.problem, cfg, mesh)
        server._install_state(checkpoint.restore(d, step, like=init_state))
        return server

    # ---------------------------------------------------------- telemetry
    @property
    def event_count(self) -> int:
        return int(self._state.event)

    @property
    def pending_feedback(self) -> int:
        return int(self._pending.sum())

    @property
    def store_rows(self) -> Optional[int]:
        """Total rows in the store (None until labeled rows fold)."""
        store = self._store
        return None if store is None else store.num_rows

    def stats(self) -> dict[str, Any]:
        out = {
            "requests": self._n_requests,
            "predictions": self._n_predictions,
            "events": self.event_count,
            "chunks": len(self.chunk_log),
            "pending_feedback": self.pending_feedback,
            "pending_rows": len(self._pending_rows),
            "store_rows": self.store_rows,
            "rejected_feedback": self._n_rejected,
            "shed_feedback": self._n_shed,
            "learning": self.serve_cfg.learning,
            "learner_running": self.learner_running,
            "learner_chunks": 0 if self._learner is None
                              else self._learner.chunks,
            "slo": None if self._slo is None else self._slo.snapshot(),
        }
        return out
