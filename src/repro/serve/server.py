"""Learning-while-serving platform over the AMTL session API.

`AMTLServer` holds a long-lived `AMTLEngine` (`core.amtl.make_engine`) —
the paper's central server, kept learning while task nodes stream events
at it — and splits its two duties onto two paths:

  * request path — `predict(task_ids, features)` micro-batches incoming
    (task_id, features) rows (bucketed padding, so distinct batch sizes
    reuse a handful of jit traces) and scores them off the
    DOUBLE-BUFFERED live iterate.
  * feedback path — `submit_feedback(task_ids)` enqueues labeled
    feedback; `step()` coalesces the queue into ONE engine chunk (a
    multiple of `engine.events_per_step`), advances the session with
    `engine.run`, and swaps the serving buffer at the chunk boundary.

Double-buffer equivalence contract (tests/test_serve.py):

  * The serving buffer is always a COMMITTED (fully materialized)
    snapshot of `engine.iterate(state)`; it swaps only at chunk
    boundaries, so a prediction never waits on an in-flight `run` chunk
    or the server prox refresh inside it.
  * Zero feedback: the served iterate is BITWISE
    `engine.iterate(engine.init(v0, key))` — a frozen server serves
    exactly the frozen engine.
  * With feedback: after any sequence of `step()` boundaries the engine
    state is BITWISE `engine.run(engine.init(v0, key), offs, sum(chunks))`
    over the same coalesced chunk sizes (`run` composes bitwise at any
    step boundary — the PR-4 session contract), and the serving buffer
    is the iterate of that state.
  * Restart: `AMTLServer.resume(...)` from a rotated checkpoint
    (`repro.checkpoint.save(..., keep_last=k)`) is invisible to
    subsequent predictions — the restored server serves bitwise what the
    uninterrupted one would (pending, not-yet-run feedback is the one
    thing a crash loses; clients re-submit, the standard at-most-once
    queue contract).

Per-task admission/QoS (`max_pending_per_task`, `task_chunk_quota`)
bounds what one bursty task can inject: excess queue depth is rejected
at admission, and each chunk consumes at most `task_chunk_quota` events
per task — drained round-robin from a rotating start offset — so a
flood on one task can neither evict other tasks' pending feedback nor
starve the per-chunk event budget.  Coalescing is deterministic (pure
function of the queue contents), which is what makes the chunk-replay
contract above testable.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.core.amtl import AMTLConfig, make_engine
from repro.core.losses import MTLProblem, get_loss

Array = jax.Array


class ServeConfig(NamedTuple):
    """Serving-side knobs (the engine itself is configured by AMTLConfig).

    chunk_events         per-chunk event budget: at most this many engine
                         events are coalesced per `step()` (must be a
                         positive multiple of `engine.events_per_step`).
    task_chunk_quota     QoS: max events ONE task contributes to a chunk
                         (None = no per-task cap, the budget still caps
                         the chunk).  Drained round-robin from a rotating
                         offset so tied tasks alternate priority.
    max_pending_per_task admission: feedback beyond this per-task queue
                         depth is rejected at `submit_feedback` (None =
                         unbounded queue).
    learning             False freezes the server: feedback is rejected
                         and `step()` is a no-op — the served iterate
                         stays bitwise `engine.iterate(init_state)`.
    ckpt_dir             checkpoint directory (None disables checkpoints).
    checkpoint_every     auto-checkpoint after this many learned events
                         (None = only explicit `checkpoint()` calls).
    keep_last            rotation: keep only the k newest `step_*.npz`
                         records (repro.checkpoint.save semantics).
    max_batch            predict micro-batch ceiling: larger request
                         batches are served in `max_batch` slices;
                         smaller ones are padded to the next power of
                         two, bounding the number of jit traces.
    """
    chunk_events: int = 32
    task_chunk_quota: Optional[int] = None
    max_pending_per_task: Optional[int] = None
    learning: bool = True
    ckpt_dir: Optional[str] = None
    checkpoint_every: Optional[int] = None
    keep_last: Optional[int] = None
    max_batch: int = 256


class FeedbackReceipt(NamedTuple):
    accepted: int          # enqueued for a future chunk
    rejected: int          # admission-capped (or server frozen)


@functools.partial(jax.jit, static_argnames=("loss_name",))
def _predict_scores(v: Array, task_ids: Array, x: Array,
                    loss_name: str) -> Array:
    """Row scores off the served iterate: loss-specific link of x_i·v[:, t_i]."""
    cols = v[:, task_ids].T                       # (B, d)
    return get_loss(loss_name).predict(jnp.sum(x * cols, axis=-1))


def _bucket(n: int, cap: int) -> int:
    m = 1
    while m < n:
        m <<= 1
    return min(m, cap)


class AMTLServer:
    """A long-lived learning-while-serving AMTL session (see module doc)."""

    def __init__(self, problem: MTLProblem, cfg: AMTLConfig, v0: Array,
                 key: Array, serve_cfg: ServeConfig = ServeConfig(), *,
                 mesh=None, delay_offsets: Array | None = None):
        self.problem = problem
        self.cfg = cfg
        self.serve_cfg = serve_cfg
        self.engine = make_engine(problem, cfg, mesh)
        per = self.engine.events_per_step
        if serve_cfg.chunk_events < per \
                or serve_cfg.chunk_events % per != 0:
            raise ValueError(
                f"chunk_events ({serve_cfg.chunk_events}) must be a "
                f"positive multiple of the engine's events_per_step "
                f"({per}) so every coalesced chunk is runnable")
        if serve_cfg.task_chunk_quota is not None \
                and serve_cfg.task_chunk_quota < 1:
            raise ValueError(
                f"task_chunk_quota must be >= 1 or None, got "
                f"{serve_cfg.task_chunk_quota}")
        if serve_cfg.max_pending_per_task is not None \
                and serve_cfg.max_pending_per_task < 1:
            raise ValueError(
                f"max_pending_per_task must be >= 1 or None, got "
                f"{serve_cfg.max_pending_per_task}")
        if serve_cfg.checkpoint_every is not None \
                and serve_cfg.ckpt_dir is None:
            raise ValueError("checkpoint_every is set but ckpt_dir is None "
                             "— there is nowhere to write the checkpoints")
        if serve_cfg.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got "
                             f"{serve_cfg.max_batch}")
        self._delay_offsets = delay_offsets
        self._state = self.engine.init(v0, key)
        self._pending = np.zeros(problem.num_tasks, np.int64)
        self._rr = 0                       # rotating round-robin offset
        self.chunk_log: list[int] = []     # coalesced chunk sizes, in order
        # Double buffer: predictions read _buf[_front], which is only ever
        # reassigned at a chunk boundary after the new iterate has fully
        # materialized — never an in-flight value.
        front = jax.block_until_ready(self.engine.iterate(self._state))
        self._buf: list[Array] = [front, front]
        self._front = 0
        self._events_since_ckpt = 0
        self._n_requests = 0
        self._n_predictions = 0
        self._n_rejected = 0

    # ------------------------------------------------------- request path
    def predict(self, task_ids, features) -> Array:
        """Score a micro-batch of (task_id, features) rows.

        Served off the committed front buffer: never blocks on a running
        chunk or prox refresh.  Batches above `max_batch` are served in
        slices; smaller ones pad to the next power of two (same trace).
        """
        t = np.asarray(task_ids, np.int32).reshape(-1)
        x = jnp.asarray(features)
        if x.ndim != 2 or x.shape[0] != t.shape[0] \
                or x.shape[1] != self.problem.dim:
            raise ValueError(
                f"features must be (len(task_ids), d) = "
                f"({t.shape[0]}, {self.problem.dim}), got {x.shape}")
        if t.size and (t.min() < 0 or t.max() >= self.problem.num_tasks):
            raise ValueError(
                f"task_ids must be in [0, {self.problem.num_tasks}), got "
                f"range [{t.min()}, {t.max()}]")
        v = self._buf[self._front]
        cap = self.serve_cfg.max_batch
        outs = []
        for lo in range(0, t.shape[0], cap):
            ts = t[lo:lo + cap]
            xs = x[lo:lo + cap]
            m = _bucket(ts.shape[0], cap)
            pad = m - ts.shape[0]
            if pad:
                ts = np.pad(ts, (0, pad))
                xs = jnp.pad(xs, ((0, pad), (0, 0)))
            scores = _predict_scores(v, jnp.asarray(ts), xs,
                                     self.problem.loss_name)
            outs.append(scores[:m - pad] if pad else scores)
        self._n_requests += 1
        self._n_predictions += int(t.shape[0])
        return outs[0] if len(outs) == 1 else jnp.concatenate(outs)

    def iterate(self) -> Array:
        """The committed serving buffer (the front of the double buffer)."""
        return self._buf[self._front]

    # ------------------------------------------------------ feedback path
    def submit_feedback(self, task_ids) -> FeedbackReceipt:
        """Enqueue labeled feedback; each accepted item is one future
        engine event.  Rejected = admission cap hit (or server frozen)."""
        t = np.asarray(task_ids, np.int64).reshape(-1)
        if t.size and (t.min() < 0 or t.max() >= self.problem.num_tasks):
            raise ValueError(
                f"feedback task_ids must be in "
                f"[0, {self.problem.num_tasks}), got range "
                f"[{t.min()}, {t.max()}]")
        if not self.serve_cfg.learning:
            self._n_rejected += t.size
            return FeedbackReceipt(0, int(t.size))
        cap = self.serve_cfg.max_pending_per_task
        accepted = rejected = 0
        for ti in t:
            if cap is not None and self._pending[ti] >= cap:
                rejected += 1
            else:
                self._pending[ti] += 1
                accepted += 1
        self._n_rejected += rejected
        return FeedbackReceipt(accepted, rejected)

    def _coalesce(self) -> int:
        """Drain the feedback queue into one runnable chunk size.

        Round-robin over tasks from the rotating offset, at most
        `task_chunk_quota` events per task, at most `chunk_events`
        total, floored to a multiple of `events_per_step` (the floored
        remainder goes back to the queue, reverse consumption order).
        Deterministic in the queue contents.
        """
        per = self.engine.events_per_step
        budget = self.serve_cfg.chunk_events
        quota = self.serve_cfg.task_chunk_quota
        quota = budget if quota is None else quota
        num_tasks = self.problem.num_tasks
        order = [(self._rr + i) % num_tasks for i in range(num_tasks)]
        taken = np.zeros(num_tasks, np.int64)
        total = 0
        for ti in order:
            if total >= budget:
                break
            k = min(int(self._pending[ti]), quota, budget - total)
            if k > 0:
                taken[ti] = k
                total += k
        give_back = total - (total // per) * per
        for ti in reversed(order):
            if give_back == 0:
                break
            k = min(int(taken[ti]), give_back)
            taken[ti] -= k
            give_back -= k
        self._pending -= taken
        if taken.any():
            self._rr = (self._rr + 1) % num_tasks
        return int(taken.sum())

    def step(self) -> int:
        """One chunk boundary: coalesce -> `engine.run` -> buffer swap.

        Returns the number of events learned (0 if frozen or nothing
        runnable yet).  This is the ONLY place the serving buffer swaps,
        and the swap happens after the new iterate fully materializes —
        the front buffer a concurrent `predict` reads is never
        in-flight.  Auto-checkpoints on the `checkpoint_every` cadence.
        """
        if not self.serve_cfg.learning:
            return 0
        n = self._coalesce()
        if n == 0:
            return 0
        self._state = self.engine.run(self._state, self._delay_offsets, n)
        self.chunk_log.append(n)
        back = 1 - self._front
        self._buf[back] = jax.block_until_ready(
            self.engine.iterate(self._state))
        self._front = back
        self._events_since_ckpt += n
        every = self.serve_cfg.checkpoint_every
        if every is not None and self._events_since_ckpt >= every:
            self.checkpoint()
        return n

    def serve(self, task_ids, features, feedback_task_ids=None):
        """One request batch: predict, enqueue feedback, run one chunk.

        Predictions are scored against the CURRENT committed buffer
        before the chunk runs — this batch's feedback affects the NEXT
        batch's predictions, which is what lets the request path never
        block on learning.  Returns (predictions, FeedbackReceipt,
        events_learned).
        """
        preds = self.predict(task_ids, features)
        receipt = FeedbackReceipt(0, 0)
        if feedback_task_ids is not None:
            receipt = self.submit_feedback(feedback_task_ids)
        ran = self.step()
        return preds, receipt, ran

    # ------------------------------------------------- checkpoint/restart
    def checkpoint(self) -> Optional[str]:
        """Write the engine state as `step_<event>.npz`, rotated to
        `keep_last`.  Returns the written path (None if no ckpt_dir)."""
        if self.serve_cfg.ckpt_dir is None:
            return None
        path = checkpoint.save(self.serve_cfg.ckpt_dir,
                               int(self._state.event), self._state,
                               keep_last=self.serve_cfg.keep_last)
        self._events_since_ckpt = 0
        return path

    @classmethod
    def resume(cls, problem: MTLProblem, cfg: AMTLConfig, v0: Array,
               key: Array, serve_cfg: ServeConfig = ServeConfig(), *,
               mesh=None, delay_offsets: Array | None = None) -> "AMTLServer":
        """Restart-transparent construction: restore the newest rotated
        checkpoint in `serve_cfg.ckpt_dir` if one exists, else a fresh
        `engine.init(v0, key)` session.  The restored server's serving
        buffer — and therefore every subsequent prediction — is bitwise
        the uninterrupted server's at the same chunk boundary."""
        server = cls(problem, cfg, v0, key, serve_cfg, mesh=mesh,
                     delay_offsets=delay_offsets)
        d = serve_cfg.ckpt_dir
        step = checkpoint.latest_step(d) if d is not None else None
        if step is not None:
            server._state = checkpoint.restore(
                d, step, like=server.engine.init(v0, key))
            back = 1 - server._front
            server._buf[back] = jax.block_until_ready(
                server.engine.iterate(server._state))
            server._front = back
        return server

    # ---------------------------------------------------------- telemetry
    @property
    def event_count(self) -> int:
        return int(self._state.event)

    @property
    def pending_feedback(self) -> int:
        return int(self._pending.sum())

    def stats(self) -> dict[str, Any]:
        return {
            "requests": self._n_requests,
            "predictions": self._n_predictions,
            "events": self.event_count,
            "chunks": len(self.chunk_log),
            "pending_feedback": self.pending_feedback,
            "rejected_feedback": self._n_rejected,
            "learning": self.serve_cfg.learning,
        }
