"""Background learner thread for `AMTLServer` — the concurrent chunk
runner.

The cooperative server interleaves `predict` and `step()` on one
thread, so every coalesce -> `engine.run` -> materialize chunk (and the
server-prox refresh inside it) stalls the request path — exactly the
blocking the asynchronous framework exists to avoid.  `BackgroundLearner`
moves that loop onto its own daemon thread:

  * loop: run one chunk via `AMTLServer._step_once()` (fold accepted
    labeled rows into the TaskStore, coalesce, `engine.run`,
    materialize the new iterate, atomic snapshot flip, auto-checkpoint
    cadence — all under the server's state lock, which the request
    path never takes);
  * idle: when the queue has no runnable chunk, park on a wake event
    that `submit_feedback` sets — no spin, sub-ms reaction to new
    feedback (a short timeout re-polls so a floored remainder that
    becomes runnable is never missed);
  * lifecycle: `start()` / `stop(drain=...)`.  `stop(drain=True)`
    keeps running chunks until the queue cannot produce another
    runnable chunk, then joins — with no concurrent submissions, the
    drained chunk log is exactly the cooperative `while step(): pass`
    loop's (coalescing is deterministic in the queue contents), which
    is the thread-vs-cooperative bitwise contract
    tests/test_serve_threaded.py pins down;
  * failure: an exception on the learner thread is captured, the
    thread exits (the server keeps serving the last committed
    snapshot), and the exception is re-raised on `stop()`/`join()` —
    a dead learner is never silent.
"""
from __future__ import annotations

import threading
from typing import Optional


class BackgroundLearner:
    """Owns the learner thread of one `AMTLServer` (see module doc)."""

    def __init__(self, server, *, idle_wait_s: float = 0.002,
                 name: str = "amtl-learner"):
        self._server = server
        self._idle_wait_s = float(idle_wait_s)
        self._name = name
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._wake = threading.Event()
        self._draining = False
        self._exc: Optional[BaseException] = None
        self.chunks = 0     # chunks run on this thread
        self.events = 0     # events learned on this thread

    # ---------------------------------------------------------- lifecycle
    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> None:
        if self.running:
            raise RuntimeError("learner thread is already running")
        self._maybe_reraise()
        self._stop.clear()
        self._wake.clear()
        self._draining = False
        self._thread = threading.Thread(
            target=self._loop, name=self._name, daemon=True)
        self._thread.start()

    def wake(self) -> None:
        """Called by `submit_feedback`: new work may be runnable."""
        self._wake.set()

    def stop(self, drain: bool = True,
             timeout: Optional[float] = None) -> int:
        """Stop the thread and join it; returns events learned on it.

        drain=True finishes every runnable chunk first (the queue may
        still hold a floored, un-runnable remainder — same as the
        cooperative drain loop).  drain=False exits at the next chunk
        boundary, leaving the rest queued.  Re-raises any exception the
        learner thread died with.
        """
        self._draining = drain
        self._stop.set()
        self._wake.set()
        return self.join(timeout)

    def join(self, timeout: Optional[float] = None) -> int:
        """Join the thread (if any) and surface its exception."""
        if self._thread is not None:
            self._thread.join(timeout)
            if self._thread.is_alive():
                raise TimeoutError(
                    f"learner thread did not stop within {timeout}s")
            self._thread = None
        self._maybe_reraise()
        return self.events

    def _maybe_reraise(self) -> None:
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc

    # --------------------------------------------------------------- loop
    def _loop(self) -> None:
        try:
            while True:
                if self._stop.is_set() and not self._draining:
                    break
                ran = self._server._step_once()
                if ran:
                    self.chunks += 1
                    self.events += ran
                    continue
                if self._stop.is_set():
                    break               # drained: no runnable chunk left
                self._wake.wait(self._idle_wait_s)
                self._wake.clear()
        except BaseException as e:      # surfaced on stop()/join()
            self._exc = e
