"""Background learner thread for `AMTLServer` — the concurrent chunk
runner, and its fault-tolerant supervisor.

The cooperative server interleaves `predict` and `step()` on one
thread, so every coalesce -> `engine.run` -> materialize chunk (and the
server-prox refresh inside it) stalls the request path — exactly the
blocking the asynchronous framework exists to avoid.  `BackgroundLearner`
moves that loop onto its own daemon thread:

  * loop: run one chunk via `AMTLServer._step_once()` (fold accepted
    labeled rows into the TaskStore, coalesce, `engine.run`,
    materialize the new iterate, atomic snapshot flip, auto-checkpoint
    cadence — all under the server's state lock, which the request
    path never takes);
  * idle: when the queue has no runnable chunk, park on a wake event
    that `submit_feedback` sets — no spin, sub-ms reaction to new
    feedback (a short timeout re-polls so a floored remainder that
    becomes runnable is never missed);
  * lifecycle: `start()` / `stop(drain=...)`.  `stop(drain=True)`
    keeps running chunks until the queue cannot produce another
    runnable chunk, then joins — with no concurrent submissions, the
    drained chunk log is exactly the cooperative `while step(): pass`
    loop's (coalescing is deterministic in the queue contents), which
    is the thread-vs-cooperative bitwise contract
    tests/test_serve_threaded.py pins down;
  * failure: an exception on the learner thread is captured, the
    thread exits (the server keeps serving the last committed
    snapshot), and the exception is re-raised on `stop()`/`join()` —
    a dead learner is never silent.  A `join` that times out leaves
    the learner joinable again: a later `stop()`/`join()` retries
    cleanly and still surfaces the captured exception exactly once.

`LearnerSupervisor` (PR 10) wraps a `BackgroundLearner` with the same
start/wake/stop surface plus bounded auto-restart: a monitor thread
waits on the learner's exit event, and on a crash either restarts it
under exponential backoff (the restart re-serves the last committed
snapshot — the atomic-flip contract makes a mid-chunk death lose only
that chunk's coalesced events, the platform's documented crash window)
or, once `restart_limit` crashes have been healed, trips the server's
circuit breaker: the server latches into frozen-serving mode
(predictions keep flowing, feedback is rejected with a "breaker"
receipt reason) and the terminal exception surfaces on `stop()`.  A
dead learner heals or it declares itself down — never silently frozen.
"""
from __future__ import annotations

import threading
import time
from typing import Optional


class BackgroundLearner:
    """Owns the learner thread of one `AMTLServer` (see module doc)."""

    def __init__(self, server, *, idle_wait_s: float = 0.002,
                 name: str = "amtl-learner"):
        self._server = server
        self._idle_wait_s = float(idle_wait_s)
        self._name = name
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._wake = threading.Event()
        self._exit = threading.Event()  # set whenever no loop is running
        self._exit.set()
        self._join_lock = threading.Lock()
        self._draining = False
        self._exc: Optional[BaseException] = None
        self.chunks = 0     # chunks run on this thread (across restarts)
        self.events = 0     # events learned on this thread

    # ---------------------------------------------------------- lifecycle
    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    @property
    def exited(self) -> threading.Event:
        """Set while no learner loop is running (crash, drain, or never
        started); the supervisor's monitor parks on it."""
        return self._exit

    def start(self) -> None:
        if self.running:
            raise RuntimeError("learner thread is already running")
        self._maybe_reraise()
        self._stop.clear()
        self._wake.clear()
        self._draining = False
        self._exit.clear()
        self._thread = threading.Thread(
            target=self._loop, name=self._name, daemon=True)
        self._thread.start()

    def wake(self) -> None:
        """Called by `submit_feedback`: new work may be runnable."""
        self._wake.set()

    def stop(self, drain: bool = True,
             timeout: Optional[float] = None) -> int:
        """Stop the thread and join it; returns events learned on it.

        drain=True finishes every runnable chunk first (the queue may
        still hold a floored, un-runnable remainder — same as the
        cooperative drain loop).  drain=False exits at the next chunk
        boundary, leaving the rest queued.  Re-raises any exception the
        learner thread died with.
        """
        self._draining = drain
        self._stop.set()
        self._wake.set()
        return self.join(timeout)

    def join(self, timeout: Optional[float] = None) -> int:
        """Join the thread (if any) and surface its exception.

        A timed-out join raises TimeoutError but leaves the learner
        joinable: `self._thread` stays set so a later `stop()`/`join()`
        retries the join, and a captured exception stays pending until
        a join completes — it is surfaced exactly once, never lost to
        the timeout path.
        """
        with self._join_lock:
            thread = self._thread
            if thread is not None:
                thread.join(timeout)
                if thread.is_alive():
                    pending = (" (a captured learner exception is still "
                               "pending and will surface on the next "
                               "successful stop/join)"
                               if self._exc is not None else "")
                    raise TimeoutError(
                        f"learner thread did not stop within {timeout}s; "
                        f"retry stop()/join(){pending}")
                self._thread = None
            self._maybe_reraise()
            return self.events

    def take_exception(self) -> Optional[BaseException]:
        """Consume the captured exception (supervisor path); the normal
        stop/join re-raise then stays silent — exactly-once surfacing
        moves to the caller."""
        exc, self._exc = self._exc, None
        return exc

    def _maybe_reraise(self) -> None:
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc

    # --------------------------------------------------------------- loop
    def _loop(self) -> None:
        try:
            while True:
                if self._stop.is_set() and not self._draining:
                    break
                ran = self._server._step_once()
                if ran:
                    self.chunks += 1
                    self.events += ran
                    continue
                if self._stop.is_set():
                    break               # drained: no runnable chunk left
                self._wake.wait(self._idle_wait_s)
                self._wake.clear()
        except BaseException as e:      # surfaced on stop()/join()
            self._exc = e
        finally:
            self._exit.set()


class LearnerSupervisor:
    """Bounded auto-restart around one `BackgroundLearner`.

    Same lifecycle surface as the learner (`start`/`wake`/`stop`/
    `running`/`chunks`/`events`), so `AMTLServer` holds either
    interchangeably.  `limit` is the number of crashes the supervisor
    will heal; crash k restarts after `backoff_s * 2**k`.  Crash
    `limit` + 1 trips the server's circuit breaker instead, and the
    terminal exception is re-raised (once) by `stop()` — as is a crash
    whose backoff was cut short by `stop()`.
    """

    def __init__(self, server, *, limit: int, backoff_s: float,
                 idle_wait_s: float = 0.002):
        self._server = server
        self._learner = BackgroundLearner(server, idle_wait_s=idle_wait_s)
        self.limit = int(limit)
        self.backoff_s = float(backoff_s)
        self._stop_evt = threading.Event()
        self._monitor: Optional[threading.Thread] = None
        self.restarts = 0            # crashes healed by a restart
        self.crashes = 0             # learner-thread deaths observed
        self.crash_log: list = []    # repr of each crash, in order
        self.recovery_ms: list = []  # crash-detect -> re-serving, wall ms
        self.breaker_tripped = False
        self._pending_exc: Optional[BaseException] = None

    # ---------------------------------------------------------- lifecycle
    @property
    def running(self) -> bool:
        # The monitor IS the supervised learner's liveness: it stays up
        # through crash/backoff gaps where the learner thread is dead
        # but the system is still healing.
        return self._monitor is not None and self._monitor.is_alive()

    @property
    def chunks(self) -> int:
        return self._learner.chunks

    @property
    def events(self) -> int:
        return self._learner.events

    def start(self) -> None:
        if self.running:
            raise RuntimeError("learner thread is already running")
        if self.breaker_tripped:
            raise RuntimeError(
                "learner circuit breaker is latched (restart budget "
                "exhausted); the server is in frozen-serving mode")
        self._stop_evt.clear()
        self._learner.start()
        self._monitor = threading.Thread(
            target=self._run, name="amtl-learner-supervisor", daemon=True)
        self._monitor.start()

    def wake(self) -> None:
        self._learner.wake()

    def stop(self, drain: bool = True,
             timeout: Optional[float] = None) -> int:
        """Stop learner + monitor; re-raise an unhealed crash once.

        An unhealed crash is one the monitor never restarted past: it
        either latched the breaker, had its backoff cut short by this
        stop, or happened during the stop-drain itself (the monitor
        stands down once stop is requested).  Healed crashes do not
        re-raise — they are telemetry (`crash_log`), not failures.
        """
        self._stop_evt.set()
        exc: Optional[BaseException] = None
        try:
            events = self._learner.stop(drain=drain, timeout=timeout)
        except TimeoutError:
            raise  # learner still joinable; monitor still standing by
        except BaseException as e:
            exc = e  # crash during the stop-drain window
            events = self._learner.events
        monitor = self._monitor
        if monitor is not None:
            monitor.join(timeout)
            if monitor.is_alive():
                raise TimeoutError(
                    f"learner supervisor did not stop within {timeout}s; "
                    "retry stop()")
            self._monitor = None
        pending, self._pending_exc = self._pending_exc, None
        exc = exc if exc is not None else pending
        if exc is not None:
            raise exc
        return events

    # -------------------------------------------------------------- monitor
    def _run(self) -> None:
        while True:
            self._learner.exited.wait()
            if self._stop_evt.is_set():
                return
            exc = self._learner.take_exception()
            if exc is None:
                return  # clean exit without stop(): nothing to heal
            self.crashes += 1
            self.crash_log.append(repr(exc))
            if self.restarts >= self.limit:
                self._pending_exc = exc
                self.breaker_tripped = True
                self._server._trip_breaker(exc)
                return
            started = time.perf_counter()
            if self._stop_evt.wait(self.backoff_s * (2.0 ** self.restarts)):
                self._pending_exc = exc  # stop cut the heal short
                return
            self.restarts += 1
            self._learner.start()
            self._learner.wake()
            self.recovery_ms.append(
                1e3 * (time.perf_counter() - started))
