"""Online learning-while-serving subsystem (the paper's deployment story).

A central `AMTLServer` keeps an `AMTLEngine` session learning from
asynchronously streamed task feedback while serving predictions off a
double-buffered live iterate.  The double-buffer equivalence contract —
frozen serving is bitwise the frozen engine, feedback-driven serving is
bitwise a plain `engine.run` over the same coalesced chunks, and a
checkpoint restart is invisible to subsequent predictions — is
documented in `repro.serve.server` and enforced by tests/test_serve.py.
"""
from repro.serve.server import (AMTLServer, FeedbackReceipt, ServeConfig)

__all__ = ["AMTLServer", "FeedbackReceipt", "ServeConfig"]
