"""Online learning-while-serving subsystem (the paper's deployment story).

A central `AMTLServer` (`serve.server`) keeps an `AMTLEngine` session
learning from asynchronously streamed task feedback while serving
predictions off a committed, atomically-flipped serving snapshot.  The
chunk runner lives on a background learner thread (`serve.learner`,
start/stop/drain lifecycle, optionally supervised with bounded
auto-restart and a circuit breaker) and a latency-SLO admission
controller (`serve.admission`) deterministically trades the chunk
budget against the request path's p95.  Fault tolerance (`serve.faults`
+ the checkpoint integrity layer) makes failure recovery scriptable and
bitwise-testable: a `FaultPlan` injects deterministic crashes, NaNs,
and torn checkpoints, and the recovery contracts — restart replays the
surviving chunk log, resume bridges corrupt records, the served
snapshot never goes non-finite — are enforced under injection.  The
equivalence contract — frozen serving is bitwise the frozen engine,
feedback-driven serving is bitwise a plain `engine.run` over the same
coalesced chunks (cooperative or threaded), and a checkpoint restart is
invisible to subsequent predictions — is documented in
`repro.serve.server` and enforced by tests/test_serve.py,
tests/test_serve_threaded.py, and tests/test_serve_faults.py.
"""
from repro.serve.admission import (LatencySLOController, SLODecision,
                                   degraded_budget)
from repro.serve.faults import (FaultPlan, InjectedFault, corrupt_leaf,
                                truncate_record)
from repro.serve.learner import BackgroundLearner, LearnerSupervisor
from repro.serve.server import (AMTLServer, FeedbackReceipt, ServeConfig,
                                ServingSnapshot)

__all__ = ["AMTLServer", "FeedbackReceipt", "ServeConfig",
           "ServingSnapshot", "BackgroundLearner", "LearnerSupervisor",
           "LatencySLOController", "SLODecision", "degraded_budget",
           "FaultPlan", "InjectedFault", "corrupt_leaf",
           "truncate_record"]
