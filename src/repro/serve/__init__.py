"""Online learning-while-serving subsystem (the paper's deployment story).

A central `AMTLServer` (`serve.server`) keeps an `AMTLEngine` session
learning from asynchronously streamed task feedback while serving
predictions off a committed, atomically-flipped serving snapshot.  The
chunk runner lives on a background learner thread (`serve.learner`,
start/stop/drain lifecycle) and a latency-SLO admission controller
(`serve.admission`) deterministically trades the chunk budget against
the request path's p95.  The equivalence contract — frozen serving is
bitwise the frozen engine, feedback-driven serving is bitwise a plain
`engine.run` over the same coalesced chunks (cooperative or threaded),
and a checkpoint restart is invisible to subsequent predictions — is
documented in `repro.serve.server` and enforced by tests/test_serve.py
and tests/test_serve_threaded.py.
"""
from repro.serve.admission import (LatencySLOController, SLODecision,
                                   degraded_budget)
from repro.serve.learner import BackgroundLearner
from repro.serve.server import (AMTLServer, FeedbackReceipt, ServeConfig,
                                ServingSnapshot)

__all__ = ["AMTLServer", "FeedbackReceipt", "ServeConfig",
           "ServingSnapshot", "BackgroundLearner", "LatencySLOController",
           "SLODecision", "degraded_budget"]
