"""Latency-SLO-driven admission control for the learning-while-serving
front-end.

A serving system that learns in the background has one global knob that
trades learning throughput for request latency: the per-chunk event
budget (`ServeConfig.chunk_events`).  Bigger chunks amortize the server
prox over more events but hold the engine (and, on a shared host, the
CPU the request path also wants) longer per `engine.run`.
`LatencySLOController` closes that loop: the request path records its
per-batch predict latency, and the controller deterministically shrinks
the admitted chunk budget while the rolling p95 violates the SLO and
restores it while the tail is healthy.

The control law is a PURE FUNCTION of the recorded latency sequence
(tested in tests/test_serve_threaded.py), which is what keeps the
threaded server's chunk-size trace explainable after the fact:

  * Latencies are consumed in TUMBLING windows of `window` samples.
  * At each window close, p95 = percentile(window, 95).
      - p95 >  slo_ms: degrade one level (the admitted budget halves,
        floored to a positive multiple of `events_per_step`; levels
        past the floor are clamped, the violation still counts).
      - p95 <= slo_ms: restore one level (toward the configured budget).
  * Every window close is logged as an `SLODecision`; `snapshot()`
    exposes the full trace plus the per-sample violation count
    (`violations`, the serving bench's `slo_violations` key).

Thread model: `record` takes a controller-private mutex (never the
learner's state lock — a predict is never blocked behind an in-flight
`engine.run` chunk); `chunk_events` is a single int attribute read on
the learner side, so the coalescer sees each level change atomically.
"""
from __future__ import annotations

import threading
from typing import Any, NamedTuple, Optional

import numpy as np


class SLODecision(NamedTuple):
    """One tumbling-window close of the controller (logged in order).

    sample        1-based index of the latency sample that closed the
                  window (== multiples of `window`)
    p95_ms        the window's 95th-percentile latency
    level_before  degradation level entering the decision
    level         degradation level after it (0 = full budget)
    chunk_events  admitted per-chunk event budget after the decision
    """
    sample: int
    p95_ms: float
    level_before: int
    level: int
    chunk_events: int


def degraded_budget(base: int, per: int, level: int) -> int:
    """The admitted chunk budget at a degradation level: halved per
    level, floored to a positive multiple of `per` (the engine's
    events_per_step, the smallest runnable chunk)."""
    if level <= 0:
        return base
    return max(per, (base >> level) // per * per)


class LatencySLOController:
    """Rolling-p95 admission controller (see module doc for the law)."""

    def __init__(self, slo_ms: float, chunk_events: int,
                 events_per_step: int, window: int = 32):
        if slo_ms <= 0:
            raise ValueError(f"slo_ms must be > 0, got {slo_ms}")
        if window < 1:
            raise ValueError(f"slo_window must be >= 1, got {window}")
        self.slo_ms = float(slo_ms)
        self.base_chunk_events = int(chunk_events)
        self.events_per_step = int(events_per_step)
        self.window = int(window)
        # deepest level that still changes the budget; violations at the
        # floor clamp here so one recovery window restores real budget
        self._max_level = 0
        while degraded_budget(chunk_events, events_per_step,
                              self._max_level + 1) \
                < degraded_budget(chunk_events, events_per_step,
                                  self._max_level):
            self._max_level += 1
        self._mutex = threading.Lock()
        self._pending: list[float] = []   # current (open) tumbling window
        self._samples = 0
        self.level = 0
        self.chunk_events = int(chunk_events)   # learner-side atomic read
        self.violations = 0                     # samples over the SLO
        self.decisions: list[SLODecision] = []

    def record(self, latency_ms: float) -> None:
        """Feed one per-batch predict latency; decides at window closes."""
        with self._mutex:
            self._samples += 1
            self.violations += latency_ms > self.slo_ms
            self._pending.append(float(latency_ms))
            if len(self._pending) < self.window:
                return
            p95 = float(np.percentile(self._pending, 95))
            self._pending = []
            before = self.level
            if p95 > self.slo_ms:
                self.level = min(self.level + 1, self._max_level)
            else:
                self.level = max(self.level - 1, 0)
            self.chunk_events = degraded_budget(
                self.base_chunk_events, self.events_per_step, self.level)
            self.decisions.append(SLODecision(
                self._samples, p95, before, self.level, self.chunk_events))

    @property
    def degraded(self) -> bool:
        return self.level > 0

    def snapshot(self) -> dict[str, Any]:
        """Telemetry for `AMTLServer.stats()["slo"]` (decision trace
        included — the controller's choices are part of the record)."""
        with self._mutex:
            return {
                "slo_ms": self.slo_ms,
                "window": self.window,
                "samples": self._samples,
                "violations": int(self.violations),
                "level": self.level,
                "chunk_events": self.chunk_events,
                "base_chunk_events": self.base_chunk_events,
                "decisions": [d._asdict() for d in self.decisions],
            }


def make_controller(slo_ms: Optional[float], chunk_events: int,
                    events_per_step: int,
                    window: int) -> Optional[LatencySLOController]:
    """None when the SLO is unset — the server then never times predicts."""
    if slo_ms is None:
        return None
    return LatencySLOController(slo_ms, chunk_events, events_per_step,
                                window=window)
