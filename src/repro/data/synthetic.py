"""Dataset generators mirroring the paper's experimental workloads.

* `make_mtl_problem` — random low-rank multi-task regression (paper
  Sec. IV-B.1 synthetic data): a shared rank-r subspace generates the task
  models, so nuclear-norm MTL provably helps.
* `make_school_like` — ragged per-task regression shaped like the School
  dataset (139 tasks, 22-251 samples, 28 features; paper Table II).
* `make_mnist_like` — balanced binary classification task packs shaped
  like the paper's 5 MNIST one-vs-one tasks (d=100 after projection).
* `synthetic_lm_batches` — token streams with per-sequence task ids and
  scalar MTL targets for the transformer + mesh-AMTL integration.
"""
from __future__ import annotations

from typing import Iterator

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.losses import MTLProblem
from repro.core.simulator import SimProblem


def make_mtl_problem(num_tasks: int = 16, samples: int = 100, dim: int = 64,
                     rank: int = 4, noise: float = 0.1, lam: float = 0.1,
                     reg: str = "nuclear", seed: int = 0) -> MTLProblem:
    rng = np.random.default_rng(seed)
    basis = rng.standard_normal((dim, rank))
    coef = rng.standard_normal((rank, num_tasks))
    w_true = basis @ coef / np.sqrt(rank)
    xs = rng.standard_normal((num_tasks, samples, dim)) / np.sqrt(dim)
    ys = np.einsum("tnd,dt->tn", xs, w_true)
    ys += noise * rng.standard_normal(ys.shape)
    return MTLProblem(jnp.asarray(xs, jnp.float32),
                      jnp.asarray(ys, jnp.float32), "lstsq", reg, lam)


def make_school_like(seed: int = 0) -> SimProblem:
    rng = np.random.default_rng(seed)
    sizes = rng.integers(22, 252, size=139)
    dim = 28
    w_shared = rng.standard_normal(dim)
    xs, ys = [], []
    for n in sizes:
        x = rng.standard_normal((n, dim)) / np.sqrt(dim)
        w_t = w_shared + 0.3 * rng.standard_normal(dim)
        xs.append(x)
        ys.append(x @ w_t + 0.2 * rng.standard_normal(n))
    return SimProblem(xs, ys, "lstsq", "nuclear", 0.1)


def make_mnist_like(num_tasks: int = 5, samples: int = 2000, dim: int = 100,
                    seed: int = 0) -> SimProblem:
    rng = np.random.default_rng(seed)
    w_shared = rng.standard_normal(dim)
    xs, ys = [], []
    for t in range(num_tasks):
        x = rng.standard_normal((samples, dim)) / np.sqrt(dim)
        w_t = w_shared + 0.5 * rng.standard_normal(dim)
        ys.append(np.where(x @ w_t > 0, 1.0, -1.0))
        xs.append(x)
    return SimProblem(xs, ys, "logistic", "nuclear", 0.05)


def synthetic_lm_batches(vocab: int, seq: int, batch: int, num_tasks: int,
                         seed: int = 0, vision_seq: int = 0,
                         d_model: int = 0, audio_dim: int = 0
                         ) -> Iterator[dict]:
    """Infinite stream of LM batches with MTL task structure.

    Each sequence belongs to a task; the scalar MTL target is a noisy
    linear functional of the task id (so the probes have signal to find).
    """
    rng = np.random.default_rng(seed)
    while True:
        tokens = rng.integers(0, vocab, size=(batch, seq), dtype=np.int32)
        targets = np.roll(tokens, -1, axis=1)
        task_ids = rng.integers(0, num_tasks, size=(batch,), dtype=np.int32)
        mtl_targets = (task_ids.astype(np.float32) / num_tasks
                       + 0.05 * rng.standard_normal(batch).astype(np.float32))
        out = {"tokens": tokens, "targets": targets, "task_ids": task_ids,
               "mtl_targets": mtl_targets}
        if vision_seq:
            out["vision_embeds"] = (0.05 * rng.standard_normal(
                (batch, vision_seq, d_model))).astype(np.float32)
        if audio_dim:
            out.pop("tokens")
            out["features"] = (0.5 * rng.standard_normal(
                (batch, seq, audio_dim))).astype(np.float32)
            out["mask"] = rng.random((batch, seq)) < 0.3
        yield out
