"""Host->device batching with mesh sharding.

`ShardedBatcher` wraps a host-side numpy batch iterator and places each
array on the mesh with the rule-engine batch specs, double-buffering one
batch ahead (overlap host prep with device compute).
"""
from __future__ import annotations

from typing import Any, Iterator, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import batch_pspec


class ShardedBatcher:
    def __init__(self, it: Iterator[dict], mesh: Optional[Mesh] = None,
                 data_axes: tuple[str, ...] = ("data",)):
        self._it = it
        self._mesh = mesh
        self._data_axes = data_axes
        self._next: Optional[dict] = None

    def _place(self, batch: dict) -> dict:
        if self._mesh is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        sizes = dict(zip(self._mesh.axis_names, self._mesh.devices.shape))
        out = {}
        for k, v in batch.items():
            spec = batch_pspec(k, np.shape(v), sizes, self._data_axes)
            out[k] = jax.device_put(v, NamedSharding(self._mesh, spec))
        return out

    def __iter__(self):
        return self

    def __next__(self) -> dict:
        if self._next is None:
            self._next = self._place(next(self._it))
        out = self._next
        try:
            self._next = self._place(next(self._it))
        except StopIteration:
            self._next = None
        return out
