"""TaskStore: padded+masked ragged task data with live row ingestion.

The paper's deployment story is task nodes that each hold a *local,
private, differently-sized* cohort that keeps growing while the central
server learns.  The jitted engines want one stacked (T, n, d) layout; the
TaskStore reconciles the two:

  * Canonical storage is HOST numpy: `(T, cap, d)` feature and `(T, cap)`
    label buffers plus a `(T,)` int32 `row_counts` vector.  Task t owns
    rows [0, row_counts[t]); rows past its count are zero padding (or
    garbage from a previous capacity — they are never read, every
    consumer masks on row_counts).
  * `problem()` publishes the buffers as a ragged `MTLProblem`
    (row_counts set) — a cached device view, rebuilt only after an
    append, so repeated `engine.run` chunks against an unchanged store
    hand jit the SAME arrays (no retrace, no re-upload).
  * `append` writes labeled rows in arrival order and grows `cap` by
    power-of-two doubling when full (the predict micro-batching idiom:
    the number of distinct buffer shapes — and therefore of jit
    retraces of the engine step — is logarithmic in the final size).
    The learning-while-serving platform calls it at chunk boundaries
    only, so every engine chunk runs against one immutable snapshot.
  * `save`/`restore` round-trip the buffers through `repro.checkpoint`
    (strict key/shape/dtype validation), so a store checkpointed next
    to an engine state resumes bitwise: same buffers, same counts, same
    capacity, same jit cache keys.

A store built `from_problem` keeps the problem's exact buffer as its
initial capacity (NOT pow2-rounded): with no appends the published
problem carries the same arrays plus uniform row_counts, which the
engines reproduce bitwise against the row_counts=None baseline.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import (CheckpointCorruptError,
                                         _resolve_step_path, restore, save)
from repro.core.losses import MTLProblem


class TaskStoreState(NamedTuple):
    """The store's checkpoint pytree (host numpy leaves)."""
    xs: np.ndarray          # (T, cap, d) float32
    ys: np.ndarray          # (T, cap)    float32
    row_counts: np.ndarray  # (T,)        int32


class StoreUndo(NamedTuple):
    """Inverse of one `append_undoable` call (see `rollback`).

    Holds the pre-append capacity, the full pre-append row_counts, and
    the prior contents of exactly the slots the append overwrote — O(k)
    in the appended rows, never a full-buffer snapshot.
    """
    capacity: int
    row_counts: np.ndarray
    slots: list  # [(task, row, prev_x_row, prev_y), ...] for rows < old cap


class TaskStore:
    """Ragged task cohorts over a shared padded buffer; see module doc."""

    def __init__(self, xs, ys, row_counts, loss_name: str, reg_name: str,
                 lam: float):
        xs = np.asarray(xs, np.float32)
        ys = np.asarray(ys, np.float32)
        row_counts = np.asarray(row_counts, np.int32)
        if xs.ndim != 3 or ys.shape != xs.shape[:2] \
                or row_counts.shape != (xs.shape[0],):
            raise ValueError(
                f"TaskStore buffers must be xs (T, cap, d), ys (T, cap), "
                f"row_counts (T,); got {xs.shape}, {ys.shape}, "
                f"{row_counts.shape}")
        if (row_counts < 0).any() or (row_counts > xs.shape[1]).any():
            raise ValueError(
                f"row_counts must lie in [0, cap={xs.shape[1]}]; "
                f"got {row_counts.tolist()}")
        self._xs = xs.copy()
        self._ys = ys.copy()
        self._row_counts = row_counts.copy()
        self._loss_name = loss_name
        self._reg_name = reg_name
        self._lam = float(lam)
        self._problem: Optional[MTLProblem] = None

    # ------------------------------------------------------ constructors --

    @classmethod
    def from_problem(cls, problem: MTLProblem) -> "TaskStore":
        """Adopt a problem's buffers as the store's initial contents.

        Capacity is EXACTLY the problem's n (not pow2-rounded): until the
        first overflowing append the published ragged problem keeps the
        adopted buffer shape, and with uniform row_counts its engines are
        bitwise the row_counts=None engines.
        """
        if problem.row_counts is None:
            counts = np.full((problem.num_tasks,), problem.xs.shape[1],
                             np.int32)
        else:
            counts = np.asarray(problem.row_counts, np.int32)
        return cls(np.asarray(problem.xs), np.asarray(problem.ys), counts,
                   problem.loss_name, problem.reg_name, problem.lam)

    @classmethod
    def from_ragged(cls, xs_list: Sequence, ys_list: Sequence,
                    loss_name: str, reg_name: str, lam: float) -> "TaskStore":
        """Pad a list of per-task (x_t (n_t, d), y_t (n_t,)) cohorts.

        Capacity = max_t n_t; shorter cohorts are zero-padded and masked
        by row_counts.  This is how a ragged School/hospital-shaped
        dataset enters the jitted engines without trimming to n_min.
        """
        if len(xs_list) != len(ys_list) or not xs_list:
            raise ValueError("need equal, non-empty xs/ys cohort lists")
        d = np.asarray(xs_list[0]).shape[1]
        counts = np.asarray([len(x) for x in xs_list], np.int32)
        cap = int(counts.max())
        t = len(xs_list)
        xs = np.zeros((t, cap, d), np.float32)
        ys = np.zeros((t, cap), np.float32)
        for i, (x, y) in enumerate(zip(xs_list, ys_list)):
            x = np.asarray(x, np.float32)
            y = np.asarray(y, np.float32)
            if x.shape != (counts[i], d) or y.shape != (counts[i],):
                raise ValueError(
                    f"cohort {i}: expected x ({counts[i]}, {d}) and "
                    f"y ({counts[i]},), got {x.shape} and {y.shape}")
            xs[i, :counts[i]] = x
            ys[i, :counts[i]] = y
        return cls(xs, ys, counts, loss_name, reg_name, lam)

    # -------------------------------------------------------- properties --

    @property
    def num_tasks(self) -> int:
        return self._xs.shape[0]

    @property
    def capacity(self) -> int:
        return self._xs.shape[1]

    @property
    def dim(self) -> int:
        return self._xs.shape[2]

    @property
    def row_counts(self) -> np.ndarray:
        return self._row_counts.copy()

    @property
    def num_rows(self) -> int:
        """Total valid rows across tasks."""
        return int(self._row_counts.sum())

    # ----------------------------------------------------- problem view ---

    def problem(self) -> MTLProblem:
        """The store's current snapshot as a ragged MTLProblem.

        Cached: repeated calls between appends return the SAME device
        arrays, so chunked `engine.run` calls hit one jit trace and never
        re-upload the buffers.  Invalidated by `append`.
        """
        if self._problem is None:
            self._problem = MTLProblem(
                jnp.asarray(self._xs), jnp.asarray(self._ys),
                self._loss_name, self._reg_name, self._lam,
                jnp.asarray(self._row_counts))
        return self._problem

    # ---------------------------------------------------------- appends ---

    def append(self, task_ids, features, labels) -> int:
        """Append labeled rows (one per task_id) in arrival order.

        task_ids (k,) int, features (k, d) float, labels (k,) float.
        Rows land at each task's current row_count; capacity grows by
        power-of-two doubling when any task would overflow (all tasks
        share one capacity — the stacked layout).  Returns k.  Callers
        that feed a live engine (the serving platform) must only append
        at chunk boundaries: the published problem snapshot changes.
        """
        task_ids = np.atleast_1d(np.asarray(task_ids, np.int64))
        features = np.asarray(features, np.float32)
        labels = np.atleast_1d(np.asarray(labels, np.float32))
        if features.ndim == 1:
            features = features[None, :]
        k = task_ids.shape[0]
        if features.shape != (k, self.dim) or labels.shape != (k,):
            raise ValueError(
                f"append expects features ({k}, {self.dim}) and labels "
                f"({k},) for {k} task ids; got {features.shape} and "
                f"{labels.shape}")
        if k == 0:
            return 0
        if (task_ids < 0).any() or (task_ids >= self.num_tasks).any():
            raise ValueError(
                f"task_ids must lie in [0, {self.num_tasks}); "
                f"got {np.unique(task_ids).tolist()}")
        final = self._row_counts.copy()
        np.add.at(final, task_ids, 1)
        need = int(final.max())
        if need > self.capacity:
            self._grow(need)
        for t, x_row, y in zip(task_ids, features, labels):
            r = self._row_counts[t]
            self._xs[t, r] = x_row
            self._ys[t, r] = y
            self._row_counts[t] = r + 1
        self._problem = None
        return k

    def append_undoable(self, task_ids, features, labels) -> StoreUndo:
        """`append` plus an undo token that restores the store BITWISE.

        `rollback(undo)` returns buffers, counts, AND capacity to the
        pre-append snapshot — capacity matters because a doubling that
        survived a rolled-back append would change the published buffer
        shapes and with them the engines' jit cache keys.  The serving
        platform uses this to quarantine a fold whose chunk produced a
        non-finite iterate.  The token is only valid against the store
        state it was issued for (one outstanding undo at a time).
        """
        task_ids = np.atleast_1d(np.asarray(task_ids, np.int64))
        old_cap = self.capacity
        old_counts = self._row_counts.copy()
        # Pre-compute the slots this append will write (arrival order)
        # and snapshot their current bytes; slots at/above the old
        # capacity vanish when rollback slices the growth away.
        counts = old_counts.copy()
        slots = []
        for t in task_ids:
            if 0 <= t < self.num_tasks:
                r = int(counts[t])
                counts[t] = r + 1
                if r < old_cap:
                    slots.append((int(t), r, self._xs[t, r].copy(),
                                  self._ys[t, r].copy()))
        self.append(task_ids, features, labels)
        return StoreUndo(old_cap, old_counts, slots)

    def rollback(self, undo: StoreUndo) -> None:
        """Undo one `append_undoable`; the store is bitwise pre-append."""
        if undo.capacity != self.capacity:
            self._xs = np.ascontiguousarray(self._xs[:, :undo.capacity])
            self._ys = np.ascontiguousarray(self._ys[:, :undo.capacity])
        for t, r, x_prev, y_prev in undo.slots:
            self._xs[t, r] = x_prev
            self._ys[t, r] = y_prev
        self._row_counts = undo.row_counts.copy()
        self._problem = None

    def _grow(self, need: int) -> None:
        """Double capacity until `need` rows fit (bounded jit retraces)."""
        cap = max(self.capacity, 1)
        while cap < need:
            cap *= 2
        grown_x = np.zeros((self.num_tasks, cap, self.dim), np.float32)
        grown_y = np.zeros((self.num_tasks, cap), np.float32)
        grown_x[:, :self.capacity] = self._xs
        grown_y[:, :self.capacity] = self._ys
        self._xs, self._ys = grown_x, grown_y

    # ------------------------------------------------------- checkpoint ---

    def state(self) -> TaskStoreState:
        return TaskStoreState(self._xs.copy(), self._ys.copy(),
                              self._row_counts.copy())

    def save(self, ckpt_dir: str, step: int,
             keep_last: Optional[int] = None) -> str:
        """Write the buffers as `step_<step>.npz` under `ckpt_dir`."""
        return save(ckpt_dir, step, self.state(), keep_last=keep_last)

    @classmethod
    def restore(cls, ckpt_dir: str, step: int, loss_name: str,
                reg_name: str, lam: float) -> "TaskStore":
        """Rebuild a store from a `save` record, bitwise.

        Shapes are read from the record itself (capacity at save time is
        part of the state — growth history must survive a resume or the
        buffer shapes, and with them the jit cache keys, would drift);
        the leaves then go through `repro.checkpoint.restore` against a
        shape/dtype skeleton for its strict layout validation.  A torn
        or corrupt record raises `CheckpointCorruptError` (from the
        shape read here or the CRC check inside `restore`), never a raw
        zip error — resume paths catch it and drop to older records.
        """
        path = _resolve_step_path(ckpt_dir, step)
        try:
            with np.load(path) as record:
                # Field keys as `repro.checkpoint` path-flattens this
                # NamedTuple (attribute path per field).
                like = TaskStoreState(
                    xs=np.empty(record[".xs"].shape, np.float32),
                    ys=np.empty(record[".ys"].shape, np.float32),
                    row_counts=np.empty(record[".row_counts"].shape,
                                        np.int32))
        except (FileNotFoundError, CheckpointCorruptError):
            raise
        except Exception as e:
            raise CheckpointCorruptError(
                path, [], f"unreadable store record: {e!r}")
        state = restore(ckpt_dir, step, like)
        return cls(np.asarray(state.xs), np.asarray(state.ys),
                   np.asarray(state.row_counts), loss_name, reg_name, lam)


def stack_ragged(xs_list: Sequence, ys_list: Sequence, loss_name: str,
                 reg_name: str, lam: float) -> MTLProblem:
    """Pad per-task cohorts straight into a ragged MTLProblem.

    Convenience over `TaskStore.from_ragged(...).problem()` for callers
    that never append (examples, tests).
    """
    return TaskStore.from_ragged(xs_list, ys_list, loss_name, reg_name,
                                 lam).problem()
