from repro.data.synthetic import (make_mtl_problem, make_school_like,
                                  make_mnist_like, synthetic_lm_batches)
from repro.data.pipeline import ShardedBatcher
from repro.data.store import (StoreUndo, TaskStore, TaskStoreState,
                              stack_ragged)

__all__ = ["make_mtl_problem", "make_school_like", "make_mnist_like",
           "synthetic_lm_batches", "ShardedBatcher", "TaskStore",
           "TaskStoreState", "StoreUndo", "stack_ragged"]
