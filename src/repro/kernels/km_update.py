"""Pallas TPU kernel for the fused AMTL/KM block update (paper Eq. III.4).

    v_out = v + eta_k * (p - eta*g - v)

Unfused, this is 3 HBM-bound elementwise ops over (d, T) blocks (the paper's
inner loop, executed once per activation).  The kernel streams v, p, g
through VMEM once and writes v_out once: 4 HBM transfers instead of 10.

Scalars (eta, eta_k) ride along as (1, 1) blocks mapped to every grid cell
— on TPU they live in SMEM-adjacent VMEM and are free relative to the
streams.  Tiles are (8k, 128)-aligned for the VPU lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BLOCK_D = 256   # sublane-multiple tile rows
BLOCK_T = 128   # lane-width tile cols


def _km_kernel(eta_ref, etak_ref, v_ref, p_ref, g_ref, out_ref):
    eta = eta_ref[0, 0]
    eta_k = etak_ref[0, 0]
    v = v_ref[...].astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    out = v + eta_k * (p - eta * g - v)
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "block_t", "interpret"))
def km_update(v: Array, p: Array, g: Array, eta: Array, eta_k: Array, *,
              block_d: int = BLOCK_D, block_t: int = BLOCK_T,
              interpret: bool = False) -> Array:
    """Fused Eq. III.4 on a (d, T) block matrix (TPU Pallas)."""
    if v.ndim != 2:
        raise ValueError(f"km_update expects 2D (d, T), got {v.shape}")
    d, t = v.shape
    bd, bt = min(block_d, _round_up(d, 8)), min(block_t, _round_up(t, 128))
    pd, pt = _round_up(d, bd), _round_up(t, bt)
    pad = lambda a: jnp.pad(a, ((0, pd - d), (0, pt - t)))
    v_p, p_p, g_p = pad(v), pad(p), pad(g)
    eta2 = jnp.asarray(eta, jnp.float32).reshape(1, 1)
    etak2 = jnp.asarray(eta_k, jnp.float32).reshape(1, 1)

    grid = (pd // bd, pt // bt)
    scalar_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    tile_spec = pl.BlockSpec((bd, bt), lambda i, j: (i, j))
    out = pl.pallas_call(
        _km_kernel,
        grid=grid,
        in_specs=[scalar_spec, scalar_spec, tile_spec, tile_spec, tile_spec],
        out_specs=tile_spec,
        out_shape=jax.ShapeDtypeStruct((pd, pt), v.dtype),
        interpret=interpret,
    )(eta2, etak2, v_p, p_p, g_p)
    return out[:d, :t]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
