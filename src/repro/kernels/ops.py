"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy: Pallas kernels target TPU.  On CPU (this container, and the
512-fake-device dry-run) the pure-jnp oracle path is used unless
`interpret=True` is requested (tests validate kernels in interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.amtl_event import amtl_event as _amtl_event_pallas
from repro.kernels.amtl_event_batch import \
    amtl_event_batch as _amtl_event_batch_pallas
from repro.kernels.gauss_sketch import gauss_sketch as _gauss_sketch_pallas
from repro.kernels.km_update import km_update as _km_pallas
from repro.kernels.l21_prox import l21_prox as _l21_pallas
from repro.kernels.lstsq_grad import lstsq_grad as _lstsq_pallas
from repro.kernels.lstsq_grad_sampled import \
    lstsq_grad_sampled as _lstsq_sampled_pallas
from repro.kernels.lstsq_grad_sampled import sample_mask as _sample_mask_pallas
from repro.kernels.svt_reconstruct import \
    svt_reconstruct as _svt_reconstruct_pallas

Array = jax.Array


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def km_update(v: Array, p: Array, g: Array, eta: Array, eta_k: Array, *,
              use_pallas: bool | None = None,
              interpret: bool = False) -> Array:
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _km_pallas(v, p, g, eta, eta_k, interpret=interpret)
    return ref.km_update_ref(v, p, g, eta, eta_k)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def amtl_event(v_t: Array, p_t: Array, g_t: Array, eta: Array, eta_k: Array,
               *, use_pallas: bool | None = None,
               interpret: bool = False) -> tuple[Array, Array]:
    """Fused delta-ring column event: returns (v_new, undo-log entry)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _amtl_event_pallas(v_t, p_t, g_t, eta, eta_k,
                                  interpret=interpret)
    return ref.amtl_event_ref(v_t, p_t, g_t, eta, eta_k)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def amtl_event_batch(v: Array, p_cols: Array, g_cols: Array, tasks: Array,
                     eta: Array, eta_ks: Array, *,
                     use_pallas: bool | None = None,
                     interpret: bool = False) -> tuple[Array, Array]:
    """Batched multi-event update: returns (v_new, undo_cols (B, d)).

    Within-batch duplicate tasks serialize in event order (see
    `ref.amtl_event_batch_ref`).  On CPU the oracle path is also the batch
    engine's hot path — its per-event arithmetic is the serial engines'
    expression, which is what the bitwise equivalence tests rely on.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _amtl_event_batch_pallas(v, p_cols, g_cols, tasks, eta,
                                        eta_ks, interpret=interpret)
    return ref.amtl_event_batch_ref(v, p_cols, g_cols, tasks, eta, eta_ks)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def amtl_event_batch_sharded(v_local: Array, p_cols: Array, g_cols: Array,
                             local_tasks: Array, eta: Array, eta_ks: Array,
                             *, use_pallas: bool | None = None,
                             interpret: bool = False) -> tuple[Array, Array]:
    """Shard-local batched multi-event update (engine='sharded').

    Same dispatch as `amtl_event_batch`, but `local_tasks` (from
    `ref.shard_local_tasks`) may carry the sentinel id T_local ==
    v_local.shape[1] for events owned by other shards.  Sentinel events are
    computed on clamped inputs and dropped at the scatter, leaving
    v_local's columns untouched; owned events issue bit-for-bit the
    arithmetic the unsharded batch op would, which is what makes the
    sharded engine's per-shard execution a masked replay of the global
    batch rather than a reimplementation.
    """
    return amtl_event_batch(v_local, p_cols, g_cols, local_tasks, eta,
                            eta_ks, use_pallas=use_pallas,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def svt_reconstruct(qu: Array, s: Array, vt: Array, *,
                    use_pallas: bool | None = None,
                    interpret: bool = False) -> Array:
    """Thresholded low-rank SVT apply (QU * sigma) @ V^T: (d, m).

    The tail of both `prox.svt_randomized` and the rank-distributed
    `prox.svt_randomized_dist` — routing every randomized prox through the
    same dispatch keeps the serial and distributed refreshes on identical
    arithmetic per backend (the bitwise 1-shard contract on CPU; on TPU
    both take the fused Pallas kernel, so they stay mutually consistent).
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _svt_reconstruct_pallas(qu, s, vt, interpret=interpret)
    return ref.svt_reconstruct_ref(qu, s, vt)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def l21_prox(w: Array, t: Array, *, use_pallas: bool | None = None,
             interpret: bool = False) -> Array:
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _l21_pallas(w, t, interpret=interpret)
    return ref.l21_prox_ref(w, t)


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def lstsq_grad(x: Array, w: Array, y: Array, *, n_t: Array | None = None,
               use_pallas: bool | None = None,
               interpret: bool = False) -> Array:
    """Fused 2 X^T (X w - y); `n_t` (traced, optional) masks a ragged
    buffer's rows >= n_t out of the residual.  n_t=None is the original
    unmasked expression on both dispatch targets."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _lstsq_pallas(x, w, y, n_t=n_t, interpret=interpret)
    if n_t is None:
        return ref.lstsq_grad_ref(x, w, y)
    return ref.lstsq_grad_masked_ref(x, w, y, n_t)


@functools.partial(jax.jit, static_argnames=("batch_size", "use_pallas",
                                             "interpret"))
def lstsq_grad_sampled(x: Array, w: Array, y: Array, seed: Array, *,
                       batch_size: int, n_t: Array | None = None,
                       use_pallas: bool | None = None,
                       interpret: bool = False) -> Array:
    """Unbiased seeded-minibatch gradient (n_t/bsz) * 2 X_S^T (X_S w - y_S).

    `seed` is the per-event uint32 sampling seed, `batch_size` static,
    `n_t` an optional TRACED valid-row count for ragged padded buffers
    (bsz = min(batch_size, n_t) clamp inside — the simulator's SGD-AMTL
    convention; selection restricted to rows < n_t).  S is the rank-bsz
    counter-hash selection of (seed, row): identical in the Pallas kernel
    and the jnp oracle, so the CPU oracle path and the TPU kernel sample
    the same minibatch, and every shard of the sharded engine re-derives
    an event's selection from the replicated seed.  The oracle gathers
    the static-size minibatch (O(bsz d) FLOPs on CPU); the kernel masks
    in VMEM and keeps its single O(n d) pass over X's strips.
    batch_size >= n_t degenerates to `lstsq_grad`'s masked expression
    bitwise per backend, and n_t == n keeps every bit of the uniform
    path.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _lstsq_sampled_pallas(x, w, y, seed, batch_size=batch_size,
                                     n_t=n_t, interpret=interpret)
    if n_t is None:
        return ref.lstsq_grad_sampled_ref(x, w, y, seed, batch_size)
    return ref.lstsq_grad_sampled_masked_ref(x, w, y, seed, batch_size, n_t)


@functools.partial(jax.jit, static_argnames=("n", "batch_size", "use_pallas",
                                             "interpret"))
def sample_mask(n: int, batch_size: int, seed: Array, *,
                n_t: Array | None = None,
                use_pallas: bool | None = None,
                interpret: bool = False) -> Array:
    """(n,) bool keep/drop bits of the seeded minibatch selection.

    The standalone view of `lstsq_grad_sampled`'s in-kernel sampler; both
    dispatch targets must agree exactly for every (n, batch_size, seed,
    n_t) (tests/test_sampling_properties.py pins this).  With ragged
    `n_t`, exactly min(batch_size, n_t) bits are set, all below n_t.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _sample_mask_pallas(n, batch_size, seed, n_t=n_t,
                                   interpret=interpret)
    if n_t is None:
        return ref.sample_mask_ref(n, batch_size, seed)
    return ref.sample_mask_masked_ref(n, batch_size, seed, n_t)


@functools.partial(jax.jit, static_argnames=("p", "use_pallas", "interpret"))
def gauss_sketch(w: Array, seed: Array, row_offset: Array, *, p: int,
                 use_pallas: bool | None = None,
                 interpret: bool = False) -> Array:
    """(d, p) f32 randomized-SVT sketch W @ Omega, Omega unmaterialized.

    Omega's entry (r, c) is a Box-Muller normal over counter hashes of
    (seed, r, c) — the Pallas kernel generates tiles in VMEM (Omega never
    touches HBM), the oracle materializes the same bits.  `row_offset`
    (traced) is the block's first global Omega row: 0 for the serial
    prox, the shard's global column offset for the rank-distributed one —
    partitioning rows this way keeps sum_s W_s @ Omega_s = W @ Omega over
    one global Omega, the distributed prox's psum identity.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _gauss_sketch_pallas(w, seed, row_offset, p=p,
                                    interpret=interpret)
    return ref.gauss_sketch_ref(w, seed, row_offset, p)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "use_pallas", "interpret"))
def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int | None = None,
                    softcap: float | None = None,
                    use_pallas: bool | None = None,
                    interpret: bool = False) -> Array:
    """q: (S, H, hd); k, v: (S, Hkv, hd) — GQA kv heads repeated here.
    Returns (S, H, hd).  Pads S to a 128 multiple and hd to a lane
    multiple before entering the kernel."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    s, h, hd = q.shape
    hkv = k.shape[1]
    if hkv != h:
        rep = h // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if not (use_pallas or interpret):
        return ref.sliding_flash_attention_ref(q, k, v, window=window,
                                               causal=causal,
                                               softcap=softcap)
    from repro.kernels.flash_attention import flash_attention as _fa
    blk = 128
    s_pad = (-s) % blk
    hd_pad = (-hd) % 128
    qt = jnp.pad(q, ((0, s_pad), (0, 0), (0, hd_pad))).transpose(1, 0, 2)
    kt = jnp.pad(k, ((0, s_pad), (0, 0), (0, hd_pad))).transpose(1, 0, 2)
    vt = jnp.pad(v, ((0, s_pad), (0, 0), (0, hd_pad))).transpose(1, 0, 2)
    out = _fa(qt, kt, vt, causal=causal, window=window, softcap=softcap,
              valid_len=s, true_hd=hd, interpret=interpret)
    return out.transpose(1, 0, 2)[:s, :, :hd]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def rwkv6_scan(r: Array, k: Array, v: Array, w: Array, u: Array, *,
               use_pallas: bool | None = None,
               interpret: bool = False) -> Array:
    """RWKV-6 WKV recurrence.  r,k,v,w: (S, H, D); u: (H, D)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not (use_pallas or interpret):
        return ref.rwkv6_scan_ref(r, k, v, w, u)
    from repro.kernels.rwkv6_scan import rwkv6_scan as _wkv
    s = r.shape[0]
    blk = 128
    pad = (-s) % blk
    if pad:
        pads = ((0, pad), (0, 0), (0, 0))
        # w=1 on padding keeps the (unused) state finite
        r2, k2, v2 = (jnp.pad(a, pads) for a in (r, k, v))
        w2 = jnp.pad(w, pads, constant_values=1.0)
        return _wkv(r2, k2, v2, w2, u, interpret=interpret)[:s]
    return _wkv(r, k, v, w, u, interpret=interpret)
