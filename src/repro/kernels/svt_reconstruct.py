"""Pallas TPU kernel for the thresholded low-rank SVT apply (QU * sigma) @ V^T.

The randomized SVT's reconstruction is a rank-p apply: scale the (d, p)
rotated range basis QU by the p thresholded singular values, then contract
with the (p, m) right factor (m = T for the serial prox, a shard's n_local
column block for the rank-distributed prox).  Done naively that is a
full-size (d, p) temporary (QU * sigma) streamed back out of HBM before the
matmul reads it again; at the engine's scale (d = 8192, p = 24, every prox
refresh) the temporary is pure memory traffic.

This kernel fuses the scale into the MXU contraction's operand load: each
(block_rows, p) tile of QU is read once, scaled in VMEM by the lane-resident
sigma row, and fed straight to the (p, m) matmul — one pass over QU, no
(d, p) temporary, and the small V^T block stays resident in VMEM across the
whole row grid.  p and m are padded to the 128-lane tile; padded sigma
lanes are zero, so padded columns of QU and padded rows of V^T contribute
exactly nothing to the contraction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

BLOCK_ROWS = 256   # sublane-multiple tile rows over d
LANES = 128


def _kernel(qu_ref, s_ref, vt_ref, out_ref):
    qu = qu_ref[...].astype(jnp.float32)           # (br, pp)
    s = s_ref[...].astype(jnp.float32)             # (1, pp) lane row
    vt = vt_ref[...].astype(jnp.float32)           # (pp, mp)
    out = jnp.dot(qu * s, vt, preferred_element_type=jnp.float32)
    out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def svt_reconstruct(qu: Array, s: Array, vt: Array, *,
                    block_rows: int = BLOCK_ROWS,
                    interpret: bool = False) -> Array:
    """Fused (QU * sigma) @ V^T on TPU (Pallas).

    qu: (d, p); s: (p,); vt: (p, m).  Returns (d, m) matching
    `ref.svt_reconstruct_ref` (ulp-level: the MXU contraction may group
    FMAs differently from the jnp matmul).
    """
    if qu.ndim != 2 or vt.ndim != 2 or qu.shape[1] != vt.shape[0]:
        raise ValueError(f"svt_reconstruct expects qu (d, p) and vt (p, m); "
                         f"got {qu.shape}, {vt.shape}")
    if s.shape != (qu.shape[1],):
        raise ValueError(f"s must be (p,) = ({qu.shape[1]},); got {s.shape}")
    d, p = qu.shape
    m = vt.shape[1]
    pp = _round_up(p, LANES)
    mp = _round_up(m, LANES)
    rows = _round_up(d, 8)
    br = min(block_rows, rows)
    rows = _round_up(rows, br)

    qu_p = jnp.pad(qu, ((0, rows - d), (0, pp - p)))
    vt_p = jnp.pad(vt, ((0, pp - p), (0, mp - m)))
    # padded lanes carry sigma = 0 -> padded columns contribute nothing
    s_row = jnp.pad(s.astype(jnp.float32), (0, pp - p)).reshape(1, pp)

    grid = (rows // br,)
    rep = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((br, pp), lambda i: (i, 0)),
                  rep((1, pp)), rep((pp, mp))],
        out_specs=pl.BlockSpec((br, mp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, mp), qu.dtype),
        interpret=interpret,
    )(qu_p, s_row, vt_p)
    return out[:d, :m]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
