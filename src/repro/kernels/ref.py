"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth: kernels must match these to
numerical tolerance across the shape/dtype sweeps in tests/test_kernels_*.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def km_update_ref(v: Array, p: Array, g: Array, eta: Array,
                  eta_k: Array) -> Array:
    """Fused AMTL update (paper Eq. III.4): v + eta_k*(p - eta*g - v)."""
    return v + eta_k * (p - eta * g - v)


def amtl_event_ref(v_t: Array, p_t: Array, g_t: Array, eta: Array,
                   eta_k: Array) -> tuple[Array, Array]:
    """Fused delta-ring column event: (Eq. III.4 update, undo-log entry).

    The update MUST stay arithmetically identical to km_update_ref (the
    dense engine's expression) or the engines' bitwise equivalence breaks —
    so it is km_update_ref, not a re-derivation.  The second output is the
    exact pre-write bits of v_t — it seeds the delta ring's rollback
    reconstruction, so it must be v_t verbatim.
    """
    return km_update_ref(v_t, p_t, g_t, eta, eta_k), v_t


def last_occurrence_mask(tasks: Array) -> Array:
    """(B,) bool: event i is the LAST in-batch occurrence of its task.

    The within-batch conflict-resolution predicate shared by the oracle and
    the Pallas kernel's host wrapper: only last occurrences scatter back,
    so duplicate tasks write conflict-free.
    """
    idx = jnp.arange(tasks.shape[0])
    later_dup = (tasks[None, :] == tasks[:, None]) & (idx[None, :] > idx[:, None])
    return ~jnp.any(later_dup, axis=1)


def shard_local_tasks(tasks: Array, t_offset: Array,
                      n_local: int) -> tuple[Array, Array]:
    """Map global task ids onto a shard's local column block.

    Returns (local_tasks, owned).  Owned events get their local column id
    in [0, n_local); events owned by other shards get the sentinel id
    `n_local` — one past the shard's last column.  Both amtl_event_batch
    paths treat the sentinel as a dropped event: the jnp oracle's gather
    clamps and its scatter targets column n_local (out of bounds,
    `mode="drop"`), and the Pallas kernel's one-hot either matches nothing
    (n_local lane-aligned) or a padded column that is sliced away.
    Sentinel events still flow through the per-event arithmetic, so
    shard-local execution issues exactly the op sequence of the global
    batch for the events it owns — the sharded engine's bitwise contract.
    """
    local = tasks.astype(jnp.int32) - t_offset
    owned = (local >= 0) & (local < n_local)
    return jnp.where(owned, local, n_local), owned


def amtl_event_batch_ref(v: Array, p_cols: Array, g_cols: Array,
                         tasks: Array, eta: Array,
                         eta_ks: Array) -> tuple[Array, Array]:
    """Batched fused column events, serialized in event order.

    v: (d, T) iterate; tasks: (B,) activated task per event; p_cols/g_cols:
    (d, B) per-event prox column and forward-step gradient; eta_ks: (B,)
    per-event KM relaxation.  Returns (v_new (d, T), undo_cols (B, d)).

    Within-batch conflict semantics: event i reads the column as left by
    the most recent EARLIER event in the batch that wrote the same task
    (duplicate tasks serialize), and its undo entry is that pre-write
    column — iterating `amtl_event_ref` in event order over a shared v is
    the specification.  The implementation gathers the B columns once,
    serializes each duplicate chain through a predecessor pointer inside a
    scan (O(d) per event instead of an O(d*T) scatter per event), and
    scatters back once through the conflict-free last occurrence of each
    task.  Every per-event expression is `amtl_event_ref` on the same bits
    sequential replay would see, so the result — and the batch engine's
    CPU-path iterates — stay bitwise-equal to serial replay.
    """
    b = tasks.shape[0]
    num_cols = v.shape[1]
    idx = jnp.arange(b)
    same = tasks[None, :] == tasks[:, None]
    # prev[i]: most recent earlier in-batch event on the same task (-1: none)
    prev = jnp.max(jnp.where(same & (idx[None, :] < idx[:, None]),
                             idx[None, :], -1), axis=1)
    # last occurrence per task scatters back; earlier duplicates are
    # shadowed, so the scatter indices are conflict-free (losers aim at
    # column T, out of bounds, dropped).
    scatter_to = jnp.where(last_occurrence_mask(tasks), tasks, num_cols)

    cols0 = v[:, tasks]                                      # (d, b) gather

    def one(outbuf, inp):
        i, pr, p_t, g_t, eta_k = inp
        mine = jax.lax.dynamic_slice_in_dim(cols0, i, 1, axis=1)
        inherited = jax.lax.dynamic_slice_in_dim(
            outbuf, jnp.maximum(pr, 0), 1, axis=1)
        cur = jnp.where(pr >= 0, inherited, mine)[:, 0]
        v_t_new, old = amtl_event_ref(cur, p_t, g_t, eta, eta_k)
        outbuf = jax.lax.dynamic_update_slice_in_dim(
            outbuf, v_t_new[:, None], i, axis=1)
        return outbuf, old

    outs, undos = jax.lax.scan(
        one, jnp.zeros_like(cols0),
        (idx, prev, p_cols.T, g_cols.T, eta_ks))
    return v.at[:, scatter_to].set(outs, mode="drop"), undos


def svt_reconstruct_ref(qu: Array, s: Array, vt: Array) -> Array:
    """Thresholded low-rank apply: (QU * sigma) @ V^T.

    qu: (d, p) rotated range basis Q @ U_b; s: (p,) thresholded singular
    values; vt: (p, m) right factor (m = T, or a shard's n_local column
    block in the distributed prox).  Returns (d, m) in float32 cast back
    to qu.dtype.  This expression IS the tail of `prox.svt_randomized` —
    both the serial and the rank-distributed SVT route their
    reconstruction through `ops.svt_reconstruct`, so the CPU oracle path
    keeps them on identical bits.
    """
    qu32 = qu.astype(jnp.float32)
    return ((qu32 * s.astype(jnp.float32)[None, :])
            @ vt.astype(jnp.float32)).astype(qu.dtype)


def l21_prox_ref(w: Array, t: Array) -> Array:
    """Row-group soft threshold: w^i * max(0, 1 - t/||w^i||)."""
    w32 = w.astype(jnp.float32)
    norms = jnp.linalg.norm(w32, axis=-1, keepdims=True)
    scale = jnp.maximum(0.0, 1.0 - t / jnp.maximum(norms, 1e-12))
    return (w32 * scale).astype(w.dtype)


def lstsq_grad_ref(x: Array, w: Array, y: Array) -> Array:
    """Fused least-squares gradient 2 X^T (X w - y) (paper forward step)."""
    x32, w32, y32 = (a.astype(jnp.float32) for a in (x, w, y))
    return (2.0 * (x32.T @ (x32 @ w32 - y32))).astype(w.dtype)


def lstsq_grad_masked_ref(x: Array, w: Array, y: Array, n_t: Array) -> Array:
    """Ragged least-squares gradient: rows >= n_t masked out of the residual.

    `x` is a (n, d) PADDED row buffer of which only the first `n_t` (traced
    int) rows are real task data.  Zeroing the residual of the padded tail
    removes it from the X^T r contraction exactly; with n_t == n the
    all-true `where` passes the residual's bits through untouched, so the
    uniform case reproduces `lstsq_grad_ref` bitwise — the ragged path's
    equivalence anchor.
    """
    x32, w32, y32 = (a.astype(jnp.float32) for a in (x, w, y))
    rows = jnp.arange(x.shape[0])
    r = jnp.where(rows < n_t, x32 @ w32 - y32, 0.0)
    return (2.0 * (x32.T @ r)).astype(w.dtype)


# ------------------------------------------------ counter-based sampling ---
#
# The SGD engines generate their per-event minibatch selection from a
# 32-bit counter hash instead of a materialized index array: the minibatch
# is the EXACTLY-bsz rows whose hash(seed, i) ranks smallest (ties broken
# by row index — a strict total order, so the set is well defined even
# under hash collisions).  That rank cut is summarized by two uint32
# scalars, the bsz-th smallest (hash, row) pair: row i's keep bit is then
# the purely local expression
#
#     h_i < cut_h  or  (h_i == cut_h and i <= cut_i)
#
# which is what the Pallas kernel evaluates per (block_n, 1) strip in VMEM
# — no gather, no index array crosses HBM, only (seed, cut_h, cut_i).  The
# SAME uint32 expressions run in the jnp oracle below and inside the
# kernel bodies (plain jnp; the kernel imports these helpers), so
# selection bits agree exactly by construction: the CPU oracle path and
# the TPU kernel sample identical minibatches, and every shard of the
# sharded engine re-derives an event's selection locally from the
# replicated seed.  Exact-size selection (vs thresholded Bernoulli) is
# what buys the CPU oracle its FLOP win: knowing |S| = bsz statically, the
# oracle gathers the bsz rows and contracts O(bsz * d) instead of masking
# a dense O(n * d) product — the same uniform-without-replacement law as
# the float64 simulator's `rng.choice`.

def counter_hash(seed: Array, ctr: Array) -> Array:
    """uint32 hash of (seed, counter): lowbias32 finalizer over the pair.

    `seed` is a uint32 scalar (one per sampling event), `ctr` any uint32
    array of counters (row indices, or flattened (row, col) positions).
    Pure jnp uint32 arithmetic — multiplies, xors, logical shifts — so the
    expression lowers identically on the oracle path and inside a Pallas
    TPU kernel body.
    """
    x = ctr * jnp.uint32(0x9E3779B9) ^ seed
    x = (x ^ (x >> 16)) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846CA68B)
    return x ^ (x >> 16)


def sample_cutoff(n: int, batch_size: int, seed: Array) -> tuple[Array, Array]:
    """(cut_h, cut_i) uint32 scalars: the bsz-th smallest (hash, row) pair.

    The minibatch S is the bsz = min(batch_size, n) rows of smallest
    counter_hash(seed, i), ties broken by row index (jnp.argsort is
    stable, so the sort order IS the (hash, row) lexicographic order).
    Row i is in S iff h_i < cut_h or (h_i == cut_h and i <= cut_i) — a
    per-row local predicate, which is how the Pallas kernel re-derives
    the selection in VMEM from just these two scalars.  batch_size >= n
    saturates the cutoff (every real row kept): the clamp that makes the
    saturated path degrade to the full gradient.  O(n log n) uint32 sort
    per event — noise next to the O(n d) (full) or O(bsz d) (sampled)
    gradient contraction it steers.
    """
    bsz = min(batch_size, n)
    if bsz >= n:
        return jnp.uint32(0xFFFFFFFF), jnp.uint32(n - 1)
    h = counter_hash(seed, jnp.arange(n, dtype=jnp.uint32))
    kth = jnp.argsort(h)[bsz - 1]
    return h[kth], kth.astype(jnp.uint32)


def sample_mask_ref(n: int, batch_size: int, seed: Array) -> Array:
    """(n,) bool keep/drop bits; exactly min(batch_size, n) are set."""
    cut_h, cut_i = sample_cutoff(n, batch_size, seed)
    idx = jnp.arange(n, dtype=jnp.uint32)
    h = counter_hash(seed, idx)
    return (h < cut_h) | ((h == cut_h) & (idx <= cut_i))


def lstsq_grad_sampled_ref(x: Array, w: Array, y: Array, seed: Array,
                           batch_size: int) -> Array:
    """Unbiased seeded-minibatch least-squares gradient.

        (n/bsz) * 2 X_S^T (X_S w - y_S),   S = rank-bsz selection

    with bsz = min(batch_size, n) — the simulator's `(n_t / bsz)` SGD-AMTL
    convention.  |S| = bsz is static, so the oracle GATHERS the selected
    rows (the argsort prefix — the same set `sample_mask_ref` flags) and
    contracts a (bsz, d) block: O(bsz * d) FLOPs where the full gradient
    pays O(n * d).  The kernel computes the identical quantity as a
    masked dense contraction (it may not gather), so kernel vs oracle
    agree to summation-order rounding, like every kernel pair here.  When
    batch_size >= n this IS `lstsq_grad_ref` — same call, bitwise.
    """
    n = x.shape[0]
    bsz = min(batch_size, n)
    if bsz >= n:
        return lstsq_grad_ref(x, w, y)
    h = counter_hash(seed, jnp.arange(n, dtype=jnp.uint32))
    sel = jnp.argsort(h)[:bsz]
    x32 = x[sel].astype(jnp.float32)
    y32 = y[sel].astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    r = x32 @ w32 - y32
    return ((2.0 * (n / bsz)) * (x32.T @ r)).astype(w.dtype)


def sample_cutoff_masked(n: int, batch_size: int, seed: Array,
                         n_t: Array) -> tuple[Array, Array]:
    """Ragged (cut_h, cut_i): bsz-th smallest (hash, row) among VALID rows.

    `n` is the static padded buffer height, `n_t` the traced count of real
    rows.  The selection law is `sample_cutoff` restricted to rows < n_t
    with bsz = min(batch_size, n_t): rank the stable (hash, row) order,
    walk it until bsz valid rows have been passed, and cut at that pair.
    The keep predicate gains the conjunct `i < n_t`, so padded rows that
    happen to hash under the cutoff stay dropped.  batch_size >= n_t
    saturates exactly like the uniform clamp (every valid row kept).  With
    n_t == n the cumulative-count walk lands on position bsz - 1 of the
    plain argsort — `sample_cutoff`'s pair, bitwise.
    """
    h = counter_hash(seed, jnp.arange(n, dtype=jnp.uint32))
    order = jnp.argsort(h)                     # stable: (hash, row) lex order
    valid_sorted = order < n_t
    bsz = jnp.minimum(jnp.int32(batch_size), n_t.astype(jnp.int32))
    pos = jnp.argmax(jnp.cumsum(valid_sorted.astype(jnp.int32)) >= bsz)
    kth = order[pos]
    sat = jnp.int32(batch_size) >= n_t.astype(jnp.int32)
    cut_h = jnp.where(sat, jnp.uint32(0xFFFFFFFF), h[kth])
    cut_i = jnp.where(sat, jnp.uint32(n - 1), kth.astype(jnp.uint32))
    return cut_h, cut_i


def sample_mask_masked_ref(n: int, batch_size: int, seed: Array,
                           n_t: Array) -> Array:
    """(n,) bool keep bits over a padded buffer; min(batch_size, n_t) set."""
    cut_h, cut_i = sample_cutoff_masked(n, batch_size, seed, n_t)
    idx = jnp.arange(n, dtype=jnp.uint32)
    h = counter_hash(seed, idx)
    keep = (h < cut_h) | ((h == cut_h) & (idx <= cut_i))
    return keep & (idx < n_t.astype(jnp.uint32))


def lstsq_grad_sampled_masked_ref(x: Array, w: Array, y: Array, seed: Array,
                                  batch_size: int, n_t: Array) -> Array:
    """Ragged unbiased minibatch gradient: (n_t/bsz) * 2 X_S^T (X_S w - y_S).

    S is `sample_mask_masked_ref`'s selection (rank cut over valid rows,
    bsz = min(batch_size, n_t) traced).  The gather stays static-shaped:
    bsz_max = min(batch_size, n) rows are gathered in (hash, row) rank
    order with valid rows partitioned first (stable argsort of the
    invalid flag), and rows at rank >= bsz are zero-masked out of the
    contraction.  The n_t/bsz scale is computed in f32 from traced
    scalars; both operands are integers < 2^24, where a single f32
    division rounds identically to the f64-then-f32 double rounding of
    the uniform path's Python-float constant — so with n_t == n the
    whole expression (selection, gather order, scale bits, contraction)
    reproduces `lstsq_grad_sampled_ref` bitwise.  n_t == 0 keeps zero
    rows and returns the zero vector (scale guard avoids 0/0).
    """
    n = x.shape[0]
    bsz_max = min(batch_size, n)
    if bsz_max >= n:
        return lstsq_grad_masked_ref(x, w, y, n_t)
    h = counter_hash(seed, jnp.arange(n, dtype=jnp.uint32))
    order = jnp.argsort(h)                     # stable: (hash, row) lex order
    valid_sorted = order < n_t
    sel = order[jnp.argsort(~valid_sorted, stable=True)[:bsz_max]]
    bsz = jnp.minimum(jnp.int32(batch_size), n_t.astype(jnp.int32))
    x32 = x[sel].astype(jnp.float32)
    y32 = y[sel].astype(jnp.float32)
    w32 = w.astype(jnp.float32)
    # Mask the RESIDUAL of over-rank rows, not the gathered x: a zero
    # residual row contributes exactly zero to x^T r, and keeping the
    # first dot's operands select-free leaves its compiled reduction
    # identical to the uniform path's — masking x instead was observed to
    # change the dot's summation order under jit by a ulp.
    row_ok = jnp.arange(bsz_max) < bsz
    r = jnp.where(row_ok, x32 @ w32 - y32, 0.0)
    scale = 2.0 * (n_t.astype(jnp.float32)
                   / jnp.maximum(bsz, 1).astype(jnp.float32))
    return (scale * (x32.T @ r)).astype(w.dtype)


def gauss_from_counters(seed: Array, ctr: Array) -> Array:
    """f32 standard normals from uint32 counters (Box-Muller).

    Two counter hashes (2*ctr, 2*ctr + 1) feed one Box-Muller cosine
    branch.  The top 24 bits of each hash give an exact f32 uniform —
    u1 in (0, 1] (never 0, so the log is finite), u2 in [0, 1).  Same
    jnp expression in the oracle and the Pallas sketch kernel, so the
    unmaterialized Omega tiles carry the oracle's exact bits.
    """
    u1 = counter_hash(seed, ctr * jnp.uint32(2))
    u2 = counter_hash(seed, ctr * jnp.uint32(2) + jnp.uint32(1))
    f1 = ((u1 >> 8).astype(jnp.float32) + 1.0) * jnp.float32(2.0 ** -24)
    f2 = (u2 >> 8).astype(jnp.float32) * jnp.float32(2.0 ** -24)
    return jnp.sqrt(-2.0 * jnp.log(f1)) * jnp.cos(
        jnp.float32(2.0 * 3.141592653589793) * f2)


def gauss_omega_ref(rows: int, p: int, seed: Array,
                    row_offset: Array | int = 0) -> Array:
    """(rows, p) f32 block of the counter-generated global sketch Omega.

    Entry (r, c) is gauss_from_counters(seed, (row_offset + r) * p + c):
    position-determined, so any row block of the global (T, p) Omega can
    be generated locally — the sharded prox re-derives ITS rows from the
    replicated seed and the partitioned-psum identity
    sum_s W_s @ Omega_s = W @ Omega holds over the same global matrix.
    """
    off = jnp.asarray(row_offset, jnp.uint32)
    r_idx = (off + jnp.arange(rows, dtype=jnp.uint32))[:, None]
    c_idx = jnp.arange(p, dtype=jnp.uint32)[None, :]
    return gauss_from_counters(seed, r_idx * jnp.uint32(p) + c_idx)


def gauss_sketch_ref(w: Array, seed: Array, row_offset: Array | int,
                     p: int) -> Array:
    """(d, p) f32 sketch W @ Omega over counter-generated normals.

    The oracle materializes its (rows, p) Omega block; the Pallas kernel
    generates the same bits tile-by-tile in VMEM without ever writing
    Omega to HBM.  `row_offset` is this block's first global Omega row
    (0 for the serial prox, t_off for a shard's column block).
    """
    omega = gauss_omega_ref(w.shape[1], p, seed, row_offset)
    return w.astype(jnp.float32) @ omega


def sliding_flash_attention_ref(q: Array, k: Array, v: Array, *,
                                window: int | None, causal: bool = True,
                                softcap: float | None = None) -> Array:
    """O(S^2) reference attention with optional sliding window + softcap.

    q,k,v: (S, H, D) single batch element; GQA is handled by the caller
    repeating kv heads.  Returns (S, H, D).
    """
    s, h, d = q.shape
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    logits = jnp.einsum("qhd,khd->hqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    pos_q = jnp.arange(s)[:, None]
    pos_k = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= pos_k <= pos_q
    if window is not None:
        mask &= pos_k > pos_q - window
    logits = jnp.where(mask[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("hqk,khd->qhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rwkv6_scan_ref(r: Array, k: Array, v: Array, w: Array, u: Array) -> Array:
    """RWKV-6 (Finch) WKV recurrence, sequential reference.

    r,k,v: (S, H, D); w: (S, H, D) data-dependent per-step decay (in (0,1));
    u: (H, D) bonus for the current token.  State S_h in R^{D x D}:
        out_t = r_t . (S + u * k_t v_t^T);   S <- diag(w_t) S + k_t v_t^T
    Returns (S, H, D).
    """
    s, h, d = r.shape

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp           # each (H, D)
        kv = k_t[:, :, None] * v_t[:, None, :]          # (H, D, D)
        out = jnp.einsum("hd,hde->he", r_t,
                         state + u[:, :, None] * kv)     # (H, D)
        state = w_t[:, :, None] * state + kv
        return state, out

    state0 = jnp.zeros((h, d, d), jnp.float32)
    _, outs = jax.lax.scan(
        step, state0,
        (r.astype(jnp.float32), k.astype(jnp.float32),
         v.astype(jnp.float32), w.astype(jnp.float32)))
    return outs.astype(r.dtype)
