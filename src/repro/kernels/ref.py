"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth: kernels must match these to
numerical tolerance across the shape/dtype sweeps in tests/test_kernels_*.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def km_update_ref(v: Array, p: Array, g: Array, eta: Array,
                  eta_k: Array) -> Array:
    """Fused AMTL update (paper Eq. III.4): v + eta_k*(p - eta*g - v)."""
    return v + eta_k * (p - eta * g - v)


def amtl_event_ref(v_t: Array, p_t: Array, g_t: Array, eta: Array,
                   eta_k: Array) -> tuple[Array, Array]:
    """Fused delta-ring column event: (Eq. III.4 update, undo-log entry).

    The update MUST stay arithmetically identical to km_update_ref (the
    dense engine's expression) or the engines' bitwise equivalence breaks —
    so it is km_update_ref, not a re-derivation.  The second output is the
    exact pre-write bits of v_t — it seeds the delta ring's rollback
    reconstruction, so it must be v_t verbatim.
    """
    return km_update_ref(v_t, p_t, g_t, eta, eta_k), v_t


def last_occurrence_mask(tasks: Array) -> Array:
    """(B,) bool: event i is the LAST in-batch occurrence of its task.

    The within-batch conflict-resolution predicate shared by the oracle and
    the Pallas kernel's host wrapper: only last occurrences scatter back,
    so duplicate tasks write conflict-free.
    """
    idx = jnp.arange(tasks.shape[0])
    later_dup = (tasks[None, :] == tasks[:, None]) & (idx[None, :] > idx[:, None])
    return ~jnp.any(later_dup, axis=1)


def shard_local_tasks(tasks: Array, t_offset: Array,
                      n_local: int) -> tuple[Array, Array]:
    """Map global task ids onto a shard's local column block.

    Returns (local_tasks, owned).  Owned events get their local column id
    in [0, n_local); events owned by other shards get the sentinel id
    `n_local` — one past the shard's last column.  Both amtl_event_batch
    paths treat the sentinel as a dropped event: the jnp oracle's gather
    clamps and its scatter targets column n_local (out of bounds,
    `mode="drop"`), and the Pallas kernel's one-hot either matches nothing
    (n_local lane-aligned) or a padded column that is sliced away.
    Sentinel events still flow through the per-event arithmetic, so
    shard-local execution issues exactly the op sequence of the global
    batch for the events it owns — the sharded engine's bitwise contract.
    """
    local = tasks.astype(jnp.int32) - t_offset
    owned = (local >= 0) & (local < n_local)
    return jnp.where(owned, local, n_local), owned


def amtl_event_batch_ref(v: Array, p_cols: Array, g_cols: Array,
                         tasks: Array, eta: Array,
                         eta_ks: Array) -> tuple[Array, Array]:
    """Batched fused column events, serialized in event order.

    v: (d, T) iterate; tasks: (B,) activated task per event; p_cols/g_cols:
    (d, B) per-event prox column and forward-step gradient; eta_ks: (B,)
    per-event KM relaxation.  Returns (v_new (d, T), undo_cols (B, d)).

    Within-batch conflict semantics: event i reads the column as left by
    the most recent EARLIER event in the batch that wrote the same task
    (duplicate tasks serialize), and its undo entry is that pre-write
    column — iterating `amtl_event_ref` in event order over a shared v is
    the specification.  The implementation gathers the B columns once,
    serializes each duplicate chain through a predecessor pointer inside a
    scan (O(d) per event instead of an O(d*T) scatter per event), and
    scatters back once through the conflict-free last occurrence of each
    task.  Every per-event expression is `amtl_event_ref` on the same bits
    sequential replay would see, so the result — and the batch engine's
    CPU-path iterates — stay bitwise-equal to serial replay.
    """
    b = tasks.shape[0]
    num_cols = v.shape[1]
    idx = jnp.arange(b)
    same = tasks[None, :] == tasks[:, None]
    # prev[i]: most recent earlier in-batch event on the same task (-1: none)
    prev = jnp.max(jnp.where(same & (idx[None, :] < idx[:, None]),
                             idx[None, :], -1), axis=1)
    # last occurrence per task scatters back; earlier duplicates are
    # shadowed, so the scatter indices are conflict-free (losers aim at
    # column T, out of bounds, dropped).
    scatter_to = jnp.where(last_occurrence_mask(tasks), tasks, num_cols)

    cols0 = v[:, tasks]                                      # (d, b) gather

    def one(outbuf, inp):
        i, pr, p_t, g_t, eta_k = inp
        mine = jax.lax.dynamic_slice_in_dim(cols0, i, 1, axis=1)
        inherited = jax.lax.dynamic_slice_in_dim(
            outbuf, jnp.maximum(pr, 0), 1, axis=1)
        cur = jnp.where(pr >= 0, inherited, mine)[:, 0]
        v_t_new, old = amtl_event_ref(cur, p_t, g_t, eta, eta_k)
        outbuf = jax.lax.dynamic_update_slice_in_dim(
            outbuf, v_t_new[:, None], i, axis=1)
        return outbuf, old

    outs, undos = jax.lax.scan(
        one, jnp.zeros_like(cols0),
        (idx, prev, p_cols.T, g_cols.T, eta_ks))
    return v.at[:, scatter_to].set(outs, mode="drop"), undos


def svt_reconstruct_ref(qu: Array, s: Array, vt: Array) -> Array:
    """Thresholded low-rank apply: (QU * sigma) @ V^T.

    qu: (d, p) rotated range basis Q @ U_b; s: (p,) thresholded singular
    values; vt: (p, m) right factor (m = T, or a shard's n_local column
    block in the distributed prox).  Returns (d, m) in float32 cast back
    to qu.dtype.  This expression IS the tail of `prox.svt_randomized` —
    both the serial and the rank-distributed SVT route their
    reconstruction through `ops.svt_reconstruct`, so the CPU oracle path
    keeps them on identical bits.
    """
    qu32 = qu.astype(jnp.float32)
    return ((qu32 * s.astype(jnp.float32)[None, :])
            @ vt.astype(jnp.float32)).astype(qu.dtype)


def l21_prox_ref(w: Array, t: Array) -> Array:
    """Row-group soft threshold: w^i * max(0, 1 - t/||w^i||)."""
    w32 = w.astype(jnp.float32)
    norms = jnp.linalg.norm(w32, axis=-1, keepdims=True)
    scale = jnp.maximum(0.0, 1.0 - t / jnp.maximum(norms, 1e-12))
    return (w32 * scale).astype(w.dtype)


def lstsq_grad_ref(x: Array, w: Array, y: Array) -> Array:
    """Fused least-squares gradient 2 X^T (X w - y) (paper forward step)."""
    x32, w32, y32 = (a.astype(jnp.float32) for a in (x, w, y))
    return (2.0 * (x32.T @ (x32 @ w32 - y32))).astype(w.dtype)


def sliding_flash_attention_ref(q: Array, k: Array, v: Array, *,
                                window: int | None, causal: bool = True,
                                softcap: float | None = None) -> Array:
    """O(S^2) reference attention with optional sliding window + softcap.

    q,k,v: (S, H, D) single batch element; GQA is handled by the caller
    repeating kv heads.  Returns (S, H, D).
    """
    s, h, d = q.shape
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    logits = jnp.einsum("qhd,khd->hqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    pos_q = jnp.arange(s)[:, None]
    pos_k = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= pos_k <= pos_q
    if window is not None:
        mask &= pos_k > pos_q - window
    logits = jnp.where(mask[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("hqk,khd->qhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rwkv6_scan_ref(r: Array, k: Array, v: Array, w: Array, u: Array) -> Array:
    """RWKV-6 (Finch) WKV recurrence, sequential reference.

    r,k,v: (S, H, D); w: (S, H, D) data-dependent per-step decay (in (0,1));
    u: (H, D) bonus for the current token.  State S_h in R^{D x D}:
        out_t = r_t . (S + u * k_t v_t^T);   S <- diag(w_t) S + k_t v_t^T
    Returns (S, H, D).
    """
    s, h, d = r.shape

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp           # each (H, D)
        kv = k_t[:, :, None] * v_t[:, None, :]          # (H, D, D)
        out = jnp.einsum("hd,hde->he", r_t,
                         state + u[:, :, None] * kv)     # (H, D)
        state = w_t[:, :, None] * state + kv
        return state, out

    state0 = jnp.zeros((h, d, d), jnp.float32)
    _, outs = jax.lax.scan(
        step, state0,
        (r.astype(jnp.float32), k.astype(jnp.float32),
         v.astype(jnp.float32), w.astype(jnp.float32)))
    return outs.astype(r.dtype)
