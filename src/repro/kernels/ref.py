"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth: kernels must match these to
numerical tolerance across the shape/dtype sweeps in tests/test_kernels_*.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def km_update_ref(v: Array, p: Array, g: Array, eta: Array,
                  eta_k: Array) -> Array:
    """Fused AMTL update (paper Eq. III.4): v + eta_k*(p - eta*g - v)."""
    return v + eta_k * (p - eta * g - v)


def amtl_event_ref(v_t: Array, p_t: Array, g_t: Array, eta: Array,
                   eta_k: Array) -> tuple[Array, Array]:
    """Fused delta-ring column event: (Eq. III.4 update, undo-log entry).

    The update MUST stay arithmetically identical to km_update_ref (the
    dense engine's expression) or the engines' bitwise equivalence breaks —
    so it is km_update_ref, not a re-derivation.  The second output is the
    exact pre-write bits of v_t — it seeds the delta ring's rollback
    reconstruction, so it must be v_t verbatim.
    """
    return km_update_ref(v_t, p_t, g_t, eta, eta_k), v_t


def l21_prox_ref(w: Array, t: Array) -> Array:
    """Row-group soft threshold: w^i * max(0, 1 - t/||w^i||)."""
    w32 = w.astype(jnp.float32)
    norms = jnp.linalg.norm(w32, axis=-1, keepdims=True)
    scale = jnp.maximum(0.0, 1.0 - t / jnp.maximum(norms, 1e-12))
    return (w32 * scale).astype(w.dtype)


def lstsq_grad_ref(x: Array, w: Array, y: Array) -> Array:
    """Fused least-squares gradient 2 X^T (X w - y) (paper forward step)."""
    x32, w32, y32 = (a.astype(jnp.float32) for a in (x, w, y))
    return (2.0 * (x32.T @ (x32 @ w32 - y32))).astype(w.dtype)


def sliding_flash_attention_ref(q: Array, k: Array, v: Array, *,
                                window: int | None, causal: bool = True,
                                softcap: float | None = None) -> Array:
    """O(S^2) reference attention with optional sliding window + softcap.

    q,k,v: (S, H, D) single batch element; GQA is handled by the caller
    repeating kv heads.  Returns (S, H, D).
    """
    s, h, d = q.shape
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)
    logits = jnp.einsum("qhd,khd->hqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    pos_q = jnp.arange(s)[:, None]
    pos_k = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= pos_k <= pos_q
    if window is not None:
        mask &= pos_k > pos_q - window
    logits = jnp.where(mask[None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("hqk,khd->qhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rwkv6_scan_ref(r: Array, k: Array, v: Array, w: Array, u: Array) -> Array:
    """RWKV-6 (Finch) WKV recurrence, sequential reference.

    r,k,v: (S, H, D); w: (S, H, D) data-dependent per-step decay (in (0,1));
    u: (H, D) bonus for the current token.  State S_h in R^{D x D}:
        out_t = r_t . (S + u * k_t v_t^T);   S <- diag(w_t) S + k_t v_t^T
    Returns (S, H, D).
    """
    s, h, d = r.shape

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp           # each (H, D)
        kv = k_t[:, :, None] * v_t[:, None, :]          # (H, D, D)
        out = jnp.einsum("hd,hde->he", r_t,
                         state + u[:, :, None] * kv)     # (H, D)
        state = w_t[:, :, None] * state + kv
        return state, out

    state0 = jnp.zeros((h, d, d), jnp.float32)
    _, outs = jax.lax.scan(
        step, state0,
        (r.astype(jnp.float32), k.astype(jnp.float32),
         v.astype(jnp.float32), w.astype(jnp.float32)))
    return outs.astype(r.dtype)
