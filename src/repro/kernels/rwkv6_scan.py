"""Pallas TPU kernel for the RWKV-6 (Finch) WKV recurrence.

    out_t = r_t . (S + u * k_t v_t^T);   S <- diag(w_t) S + k_t v_t^T

The jnp lax.scan reference round-trips the (H, D, D) fp32 state through
HBM on every token — for rwkv6-3b (40 heads x 64x64 state) that is
655 KB/token/layer of pure state traffic.  Here the per-head state lives
in VMEM scratch across the sequence-chunk grid dimension, so HBM sees
exactly one read of r/k/v/w and one write of out.

Layout: r,k,v,w are (S, H, D); grid (H, S/chunk) with the chunk axis
innermost/sequential; each step runs a fori_loop over the chunk with the
(D, D) state held in VMEM.  D = head_size (64 for rwkv6) — lane-aligned
by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

CHUNK = 128


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr, *,
                chunk: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    u = u_ref[0].astype(jnp.float32)                    # (D,)

    def step(t, _):
        r_t = r_ref[t, 0].astype(jnp.float32)           # (D,)
        k_t = k_ref[t, 0].astype(jnp.float32)
        v_t = v_ref[t, 0].astype(jnp.float32)
        w_t = w_ref[t, 0].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]                # (D, D)
        out = r_t @ (s_scr[...] + u[:, None] * kv)      # (D,)
        s_scr[...] = w_t[:, None] * s_scr[...] + kv
        o_ref[t, 0] = out.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)


def rwkv6_scan(r: Array, k: Array, v: Array, w: Array, u: Array, *,
               chunk: int = CHUNK, interpret: bool = True) -> Array:
    """r,k,v,w: (S, H, D); u: (H, D).  Returns (S, H, D).
    S must be a multiple of `chunk` (ops.py pads)."""
    s, h, d = r.shape
    c = min(chunk, s)
    assert s % c == 0, (s, c)
    grid = (h, s // c)
    kern = functools.partial(_wkv_kernel, chunk=c)
    seq_spec = pl.BlockSpec((c, 1, d), lambda hh, i: (i, hh, 0))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, d), lambda hh, i: (hh, 0))],
        out_specs=seq_spec,
        out_shape=jax.ShapeDtypeStruct((s, h, d), r.dtype),
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
