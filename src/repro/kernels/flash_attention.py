"""Pallas TPU flash attention with sliding-window masking + logit softcap.

This is the kernel the roofline analysis calls for (EXPERIMENTS.md §Perf,
deepseek-v3 train_4k it3): the chunked-softmax jnp path carries multi-GB
fp32 (m, l, acc) arrays through HBM on every kv-chunk iteration; here they
live in VMEM scratch across the innermost (kv) grid dimension, so HBM
traffic is exactly one read of q/k/v and one write of o.

Layout: q, k, v are (BH, S, hd) — batch and heads flattened by the ops.py
wrapper (GQA callers repeat kv heads; a production variant would fold the
group into the index_map instead).  Grid (BH, S/bq, S/bk): the kv axis is
innermost and sequential, scratch persists across it.  Block shapes are
(bq|bk, hd) with hd padded to a lane multiple of 128 by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30
BLOCK_Q = 128
BLOCK_K = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  bq: int, bk: int, causal: bool, window: int | None,
                  softcap: float | None, valid_len: int, true_hd: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                      # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                      # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(true_hd, jnp.float32))
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < valid_len
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
    p = jnp.exp(logits - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _final():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    window: int | None = None,
                    softcap: float | None = None,
                    valid_len: int | None = None,
                    true_hd: int | None = None,
                    block_q: int = BLOCK_Q, block_k: int = BLOCK_K,
                    interpret: bool = True) -> Array:
    """q, k, v: (BH, S, hd) with S % block == 0 and hd lane-aligned
    (handled by ops.flash_attention).  true_hd: unpadded head dim for the
    softmax scale.  Returns (BH, S, hd)."""
    bh, s, hd = q.shape
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    grid = (bh, s // bq, s // bk)
    kern = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal, window=window,
        softcap=softcap, valid_len=s if valid_len is None else valid_len,
        true_hd=hd if true_hd is None else true_hd)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max m
            pltpu.VMEM((bq,), jnp.float32),       # running denom l
            pltpu.VMEM((bq, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
