"""Pallas TPU kernel for the l2,1 row-group soft-threshold prox.

    out^i = w^i * max(0, 1 - t / ||w^i||_2)        (paper Sec. III-A)

The task dimension T (columns) is small in MTL (tens), so a whole row strip
fits VMEM: grid over row tiles only, each kernel instance reduces its
(block_d, T) tile along T and rescales in-register — one HBM read + one
write per element, versus 3 passes (square+sum, rsqrt, mul) unfused.

Zero-padding the T axis is safe: padded zeros do not change row norms.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BLOCK_D = 512


def _l21_kernel(t_ref, w_ref, out_ref):
    t = t_ref[0, 0]
    w = w_ref[...].astype(jnp.float32)
    norms = jnp.sqrt(jnp.sum(w * w, axis=1, keepdims=True))
    scale = jnp.maximum(0.0, 1.0 - t / jnp.maximum(norms, 1e-12))
    out_ref[...] = (w * scale).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def l21_prox(w: Array, t: Array, *, block_d: int = BLOCK_D,
             interpret: bool = False) -> Array:
    if w.ndim != 2:
        raise ValueError(f"l21_prox expects 2D (d, T), got {w.shape}")
    d, tt = w.shape
    pt = _round_up(tt, 128)
    bd = min(block_d, _round_up(d, 8))
    pd = _round_up(d, bd)
    w_p = jnp.pad(w, ((0, pd - d), (0, pt - tt)))
    t2 = jnp.asarray(t, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        _l21_kernel,
        grid=(pd // bd,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((bd, pt), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bd, pt), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pd, pt), w.dtype),
        interpret=interpret,
    )(t2, w_p)
    return out[:d, :tt]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
