"""Pallas TPU kernel for the fused seeded-minibatch least-squares gradient.

    g = (n/bsz) * 2 X_S^T (X_S w - y_S),   S = seeded rank-bsz selection

SGD-AMTL's forward step (the paper's §V future work): per activation only
a bsz-row minibatch of the task's n rows enters the gradient.  The
selection is generated INSIDE the kernel from a counter-based seed — row
i's keep bit is the local predicate over `counter_hash(seed, i)` and the
two rank-cutoff scalars (`repro.kernels.ref`, the same uint32 expressions
as the jnp oracle) — so there is no gather, no materialized index array,
and no second pass over X: each (block_n, d) strip of X is read from HBM
exactly once, the per-strip residual is masked in VMEM, and the fused
X^T r contraction only ever sees the surviving rows' residuals.
Grid/accumulation structure is `lstsq_grad`'s; (seed, cut_h, cut_i) ride
along as one (1, 3) uint32 scalar block, the (n/bsz) scale is a
trace-time constant (n, batch_size are static).

`sample_mask` exposes the kernel's selection bits on their own — the
hypothesis suite asserts them equal to `ref.sample_mask_ref` for arbitrary
(n, b, seed), which pins the in-kernel sampler to the oracle exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import counter_hash, sample_cutoff

Array = jax.Array

BLOCK_N = 512
LANES = 128


def _keep_bits(scal_ref, row0: Array, bn: int) -> Array:
    """(bn, 1) bool keep bits for rows [row0, row0 + bn).

    `scal_ref` is the (1, 3) uint32 scalar block (seed, cut_h, cut_i);
    `counter_hash` and the rank-cut predicate are the oracle's own uint32
    expressions, so the bits match `ref.sample_mask_ref` bit-for-bit (TPU
    iota must be >= 2D, hence the broadcasted (bn, 1) layout).  Padded
    rows beyond n may come out "kept": harmless — their X and y rows are
    zero, so their residual contributes nothing to the contraction.
    """
    seed, cut_h, cut_i = scal_ref[0, 0], scal_ref[0, 1], scal_ref[0, 2]
    rows = (jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0)
            + row0).astype(jnp.uint32)
    h = counter_hash(seed, rows)
    return (h < cut_h) | ((h == cut_h) & (rows <= cut_i))


def _sampled_kernel(scal_ref, x_ref, w_ref, y_ref, out_ref, *, bn: int,
                    scale2: float):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)          # (bn, d)
    w = w_ref[...].astype(jnp.float32)          # (d, 1)
    y = y_ref[...].astype(jnp.float32)          # (bn, 1)
    r = jnp.dot(x, w, preferred_element_type=jnp.float32) - y
    keep = _keep_bits(scal_ref, i * bn, bn)
    r = jnp.where(keep, r, 0.0)
    contrib = scale2 * jnp.dot(x.T, r, preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = contrib.astype(out_ref.dtype)

    @pl.when(i > 0)
    def _acc():
        out_ref[...] = (out_ref[...].astype(jnp.float32)
                        + contrib).astype(out_ref.dtype)


def _scalars(n: int, batch_size: int, seed: Array) -> Array:
    """(1, 3) uint32 scalar block: (seed, cut_h, cut_i)."""
    seed = jnp.asarray(seed, jnp.uint32)
    cut_h, cut_i = sample_cutoff(n, batch_size, seed)
    return jnp.stack([seed, cut_h, cut_i]).reshape(1, 3)


@functools.partial(jax.jit,
                   static_argnames=("batch_size", "block_n", "interpret"))
def lstsq_grad_sampled(x: Array, w: Array, y: Array, seed: Array, *,
                       batch_size: int, block_n: int = BLOCK_N,
                       interpret: bool = False) -> Array:
    """Fused (n/bsz) * 2 X_S^T (X_S w - y_S) with in-kernel selection.

    Returns (d,) in w.dtype (fp32 accumulate).  `seed` is the uint32
    per-event sampling seed; `batch_size` static (bsz = min(batch_size, n)
    clamp applied in the cutoff, matching the simulator's SGD-AMTL
    convention).
    """
    n, d = x.shape
    bsz = min(batch_size, n)
    pd = _round_up(d, 128)
    bn = min(block_n, _round_up(n, 128))
    pn = _round_up(n, bn)
    # Zero padding stays exact under sampling: a padded row's keep bit may
    # be set, but X_pad = 0 AND y_pad = 0 => r_pad = 0, so masked or not
    # it contributes nothing to the contraction.
    x_p = jnp.pad(x, ((0, pn - n), (0, pd - d)))
    y_p = jnp.pad(y.reshape(n, 1), ((0, pn - n), (0, 0)))
    w_p = jnp.pad(w.reshape(d, 1), ((0, pd - d), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_sampled_kernel, bn=bn, scale2=2.0 * (n / bsz)),
        grid=(pn // bn,),
        in_specs=[pl.BlockSpec((1, 3), lambda i: (0, 0)),
                  pl.BlockSpec((bn, pd), lambda i: (i, 0)),
                  pl.BlockSpec((pd, 1), lambda i: (0, 0)),
                  pl.BlockSpec((bn, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((pd, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((pd, 1), w.dtype),
        interpret=interpret,
    )(_scalars(n, batch_size, seed), x_p, w_p, y_p)
    return out[:d, 0]


def _mask_kernel(scal_ref, out_ref, *, bn: int):
    i = pl.program_id(0)
    out_ref[...] = _keep_bits(scal_ref, i * bn, bn).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("n", "batch_size", "block_n",
                                    "interpret"))
def sample_mask(n: int, batch_size: int, seed: Array, *,
                block_n: int = BLOCK_N, interpret: bool = False) -> Array:
    """(n,) bool — the kernel's selection bits, standalone.

    Runs `_keep_bits` (the gradient kernel's exact selection expression)
    through its own pallas_call so tests can pin the in-kernel sampler to
    `ref.sample_mask_ref` without inspecting gradient values.
    """
    bn = min(block_n, _round_up(n, 8))
    pn = _round_up(n, bn)
    out = pl.pallas_call(
        functools.partial(_mask_kernel, bn=bn),
        grid=(pn // bn,),
        in_specs=[pl.BlockSpec((1, 3), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pn, 1), jnp.int32),
        interpret=interpret,
    )(_scalars(n, batch_size, seed))
    return out[:n, 0] != 0


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
