"""Pallas TPU kernel for the fused seeded-minibatch least-squares gradient.

    g = (n_t/bsz) * 2 X_S^T (X_S w - y_S),   S = seeded rank-bsz selection

SGD-AMTL's forward step (the paper's §V future work): per activation only
a bsz-row minibatch of the task's valid rows enters the gradient.  The
selection is generated INSIDE the kernel from a counter-based seed — row
i's keep bit is the local predicate over `counter_hash(seed, i)` and the
two rank-cutoff scalars (`repro.kernels.ref`, the same uint32 expressions
as the jnp oracle) — so there is no gather, no materialized index array,
and no second pass over X: each (block_n, d) strip of X is read from HBM
exactly once, the per-strip residual is masked in VMEM, and the fused
X^T r contraction only ever sees the surviving rows' residuals.
Grid/accumulation structure is `lstsq_grad`'s; (seed, cut_h, cut_i, n_t)
ride along as one (1, 4) uint32 scalar block.  Ragged tasks hand a traced
`n_t` (valid-row count over a padded buffer): the cutoff is then computed
over valid rows only (`ref.sample_cutoff_masked`), the keep predicate
gains the conjunct `row < n_t`, and the unbiased (n_t/bsz) scale is
derived in-kernel from the scalar block — f32 division of integers
< 2^24, which rounds identically to the uniform path's trace-time
Python-float constant, so n_t == n keeps the kernel on the same bits.

`sample_mask` exposes the kernel's selection bits on their own — the
hypothesis suite asserts them equal to `ref.sample_mask_ref` /
`ref.sample_mask_masked_ref` for arbitrary (n, b, seed, n_t), which pins
the in-kernel sampler to the oracle exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import counter_hash, sample_cutoff, sample_cutoff_masked

Array = jax.Array

BLOCK_N = 512
LANES = 128


def _keep_bits(scal_ref, row0: Array, bn: int) -> Array:
    """(bn, 1) bool keep bits for rows [row0, row0 + bn).

    `scal_ref` is the (1, 4) uint32 scalar block (seed, cut_h, cut_i, n_t);
    `counter_hash` and the rank-cut predicate are the oracle's own uint32
    expressions, so the bits match `ref.sample_mask_masked_ref` bit-for-bit
    (TPU iota must be >= 2D, hence the broadcasted (bn, 1) layout).  The
    `row < n_t` conjunct drops padded rows: redundant for the gradient
    (X_pad = 0 and y_pad = 0 already zero their residuals) but it is the
    law `sample_mask` exposes, and ragged buffers carry REAL data past
    n_t that must never leak into a minibatch.
    """
    seed, cut_h, cut_i = scal_ref[0, 0], scal_ref[0, 1], scal_ref[0, 2]
    n_t = scal_ref[0, 3]
    rows = (jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0)
            + row0).astype(jnp.uint32)
    h = counter_hash(seed, rows)
    keep = (h < cut_h) | ((h == cut_h) & (rows <= cut_i))
    return keep & (rows < n_t)


def _sampled_kernel(scal_ref, x_ref, w_ref, y_ref, out_ref, *, bn: int,
                    batch_size: int):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)          # (bn, d)
    w = w_ref[...].astype(jnp.float32)          # (d, 1)
    y = y_ref[...].astype(jnp.float32)          # (bn, 1)
    r = jnp.dot(x, w, preferred_element_type=jnp.float32) - y
    keep = _keep_bits(scal_ref, i * bn, bn)
    r = jnp.where(keep, r, 0.0)
    # (n_t/bsz) unbiased scale from the scalar block: integer operands are
    # < 2^24, so this f32 division carries the exact bits of the former
    # trace-time Python-float constant (x2 is exact in binary fp).
    n_t = scal_ref[0, 3]
    bsz = jnp.minimum(jnp.uint32(batch_size), n_t)
    scale2 = 2.0 * (n_t.astype(jnp.float32)
                    / jnp.maximum(bsz, jnp.uint32(1)).astype(jnp.float32))
    contrib = scale2 * jnp.dot(x.T, r, preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = contrib.astype(out_ref.dtype)

    @pl.when(i > 0)
    def _acc():
        out_ref[...] = (out_ref[...].astype(jnp.float32)
                        + contrib).astype(out_ref.dtype)


def _scalars(n: int, batch_size: int, seed: Array,
             n_t: Array | None = None) -> Array:
    """(1, 4) uint32 scalar block: (seed, cut_h, cut_i, n_t)."""
    seed = jnp.asarray(seed, jnp.uint32)
    if n_t is None:
        cut_h, cut_i = sample_cutoff(n, batch_size, seed)
        n_t_u = jnp.uint32(n)
    else:
        n_t_u = jnp.asarray(n_t).astype(jnp.uint32)
        cut_h, cut_i = sample_cutoff_masked(n, batch_size, seed, n_t_u)
    return jnp.stack([seed, cut_h, cut_i, n_t_u]).reshape(1, 4)


@functools.partial(jax.jit,
                   static_argnames=("batch_size", "block_n", "interpret"))
def lstsq_grad_sampled(x: Array, w: Array, y: Array, seed: Array, *,
                       batch_size: int, n_t: Array | None = None,
                       block_n: int = BLOCK_N,
                       interpret: bool = False) -> Array:
    """Fused (n_t/bsz) * 2 X_S^T (X_S w - y_S) with in-kernel selection.

    Returns (d,) in w.dtype (fp32 accumulate).  `seed` is the uint32
    per-event sampling seed; `batch_size` static; `n_t` an optional traced
    valid-row count over a padded buffer (bsz = min(batch_size, n_t) clamp
    applied in the cutoff, matching the simulator's SGD-AMTL convention;
    n_t=None means every row is valid).
    """
    n, d = x.shape
    pd = _round_up(d, 128)
    bn = min(block_n, _round_up(n, 128))
    pn = _round_up(n, bn)
    # Zero padding stays exact under sampling: a padded row's keep bit is
    # dropped by the row < n_t conjunct, and even without it X_pad = 0 AND
    # y_pad = 0 => r_pad = 0, so it contributes nothing to the contraction.
    x_p = jnp.pad(x, ((0, pn - n), (0, pd - d)))
    y_p = jnp.pad(y.reshape(n, 1), ((0, pn - n), (0, 0)))
    w_p = jnp.pad(w.reshape(d, 1), ((0, pd - d), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_sampled_kernel, bn=bn, batch_size=batch_size),
        grid=(pn // bn,),
        in_specs=[pl.BlockSpec((1, 4), lambda i: (0, 0)),
                  pl.BlockSpec((bn, pd), lambda i: (i, 0)),
                  pl.BlockSpec((pd, 1), lambda i: (0, 0)),
                  pl.BlockSpec((bn, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((pd, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((pd, 1), w.dtype),
        interpret=interpret,
    )(_scalars(n, batch_size, seed, n_t), x_p, w_p, y_p)
    return out[:d, 0]


def _mask_kernel(scal_ref, out_ref, *, bn: int):
    i = pl.program_id(0)
    out_ref[...] = _keep_bits(scal_ref, i * bn, bn).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("n", "batch_size", "block_n",
                                    "interpret"))
def sample_mask(n: int, batch_size: int, seed: Array, *,
                n_t: Array | None = None,
                block_n: int = BLOCK_N, interpret: bool = False) -> Array:
    """(n,) bool — the kernel's selection bits, standalone.

    Runs `_keep_bits` (the gradient kernel's exact selection expression)
    through its own pallas_call so tests can pin the in-kernel sampler to
    `ref.sample_mask_ref` / `ref.sample_mask_masked_ref` without
    inspecting gradient values.
    """
    bn = min(block_n, _round_up(n, 8))
    pn = _round_up(n, bn)
    out = pl.pallas_call(
        functools.partial(_mask_kernel, bn=bn),
        grid=(pn // bn,),
        in_specs=[pl.BlockSpec((1, 4), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((bn, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pn, 1), jnp.int32),
        interpret=interpret,
    )(_scalars(n, batch_size, seed, n_t))
    return out[:n, 0] != 0


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
