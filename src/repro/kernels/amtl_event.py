"""Pallas TPU kernel for the fused AMTL delta-ring column event.

Per activation the delta engine needs, for the activated task's (d,) column:

    v_new = v + eta_k * (p - eta*g - v)     (Eq. III.4, KM-relaxed forward)
    old   = v                               (undo-log entry for the ring)

Unfused this is 3 elementwise passes plus a separate copy into the ring
slot: 6 HBM reads + 2 writes.  The kernel streams v, p, g through VMEM once
and emits both outputs in the same pass: 3 reads + 2 writes, and the ring
write rides along for free instead of being a second kernel launch.

The column is reshaped (d,) -> (d/128, 128) to match the VPU lanes; scalars
(eta, eta_k) ride along as (1, 1) blocks mapped to every grid cell.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BLOCK_ROWS = 512   # sublane-multiple tile rows over the reshaped column
LANES = 128


def _amtl_event_kernel(eta_ref, etak_ref, v_ref, p_ref, g_ref,
                       vnew_ref, old_ref):
    eta = eta_ref[0, 0]
    eta_k = etak_ref[0, 0]
    v_raw = v_ref[...]
    v = v_raw.astype(jnp.float32)
    p = p_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    vnew_ref[...] = (v + eta_k * (p - eta * g - v)).astype(vnew_ref.dtype)
    old_ref[...] = v_raw


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def amtl_event(v_t: Array, p_t: Array, g_t: Array, eta: Array, eta_k: Array,
               *, block_rows: int = BLOCK_ROWS,
               interpret: bool = False) -> tuple[Array, Array]:
    """Fused column event on a (d,) block (TPU Pallas).

    Returns (v_new, old) — the relaxed update and the exact pre-write bits
    of v_t (the delta-ring undo-log entry).
    """
    if v_t.ndim != 1:
        raise ValueError(f"amtl_event expects 1D (d,), got {v_t.shape}")
    d = v_t.shape[0]
    # pad d so the (rows, 128) reshape has a sublane-multiple row count
    pd = _round_up(d, 8 * LANES)
    rows = pd // LANES
    br = min(block_rows, rows)
    rows = _round_up(rows, br)
    pd = rows * LANES
    pad = lambda a: jnp.pad(a, (0, pd - d)).reshape(rows, LANES)
    v_p, p_p, g_p = pad(v_t), pad(p_t), pad(g_t)
    eta2 = jnp.asarray(eta, jnp.float32).reshape(1, 1)
    etak2 = jnp.asarray(eta_k, jnp.float32).reshape(1, 1)

    grid = (rows // br,)
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    tile_spec = pl.BlockSpec((br, LANES), lambda i: (i, 0))
    out = jax.ShapeDtypeStruct((rows, LANES), v_t.dtype)
    v_new, old = pl.pallas_call(
        _amtl_event_kernel,
        grid=grid,
        in_specs=[scalar_spec, scalar_spec, tile_spec, tile_spec, tile_spec],
        out_specs=[tile_spec, tile_spec],
        out_shape=[out, out],
        interpret=interpret,
    )(eta2, etak2, v_p, p_p, g_p)
    return v_new.reshape(pd)[:d], old.reshape(pd)[:d]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
