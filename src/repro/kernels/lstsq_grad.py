"""Pallas TPU kernel for the fused least-squares task gradient.

    g = 2 X^T (X w - y),   X: (n, d), w: (d,), y: (n,)

This is the paper's forward step — the dominant per-activation cost on a
task node (Sec. III-C: "the gradient computation is typically the most time
consuming step for large datasets").  Fusing the two matmuls means each
(block_n, d) strip of X is read from HBM exactly once and reused for both
X@w and X^T@r while resident in VMEM; arithmetic intensity doubles vs. the
two-pass form.

Grid iterates over row strips of X; the (d, 1) output block is revisited by
every grid step (TPU grid is sequential) and accumulated in fp32.
MXU alignment: d and block_n padded to 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BLOCK_N = 512


def _lstsq_kernel(x_ref, w_ref, y_ref, out_ref):
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)          # (bn, d)
    w = w_ref[...].astype(jnp.float32)          # (d, 1)
    y = y_ref[...].astype(jnp.float32)          # (bn, 1)
    r = jnp.dot(x, w, preferred_element_type=jnp.float32) - y
    contrib = 2.0 * jnp.dot(x.T, r, preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = contrib.astype(out_ref.dtype)

    @pl.when(i > 0)
    def _acc():
        out_ref[...] = (out_ref[...].astype(jnp.float32)
                        + contrib).astype(out_ref.dtype)


def _lstsq_kernel_masked(scal_ref, x_ref, w_ref, y_ref, out_ref, *, bn: int):
    """`_lstsq_kernel` plus a traced valid-row mask from a (1, 1) block.

    Ragged task buffers carry REAL rows past n_t (the store's padded
    capacity), so unlike the zero-padded tail the kernel pads on, they
    must be masked out of the residual in VMEM.
    """
    i = pl.program_id(0)
    n_t = scal_ref[0, 0]
    x = x_ref[...].astype(jnp.float32)          # (bn, d)
    w = w_ref[...].astype(jnp.float32)          # (d, 1)
    y = y_ref[...].astype(jnp.float32)          # (bn, 1)
    r = jnp.dot(x, w, preferred_element_type=jnp.float32) - y
    rows = (jax.lax.broadcasted_iota(jnp.int32, (bn, 1), 0)
            + i * bn).astype(jnp.uint32)
    r = jnp.where(rows < n_t, r, 0.0)
    contrib = 2.0 * jnp.dot(x.T, r, preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = contrib.astype(out_ref.dtype)

    @pl.when(i > 0)
    def _acc():
        out_ref[...] = (out_ref[...].astype(jnp.float32)
                        + contrib).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lstsq_grad(x: Array, w: Array, y: Array, *, n_t: Array | None = None,
               block_n: int = BLOCK_N, interpret: bool = False) -> Array:
    """Fused 2 X^T (X w - y).  Returns (d,) in w.dtype (fp32 accumulate).

    `n_t` (optional, traced) is a ragged buffer's valid-row count: rows
    >= n_t are masked out of the residual in VMEM (they may hold real
    appended-but-not-yet-counted data, unlike the kernel's own zero
    padding).  n_t=None keeps the original unmasked kernel body.
    """
    n, d = x.shape
    pd = _round_up(d, 128)
    bn = min(block_n, _round_up(n, 128))
    pn = _round_up(n, bn)
    # Zero padding is exact: padded rows contribute X_pad @ w - 0 = 0 rows
    # only when X_pad = 0 AND y_pad = 0 => r_pad = 0 => no gradient effect.
    x_p = jnp.pad(x, ((0, pn - n), (0, pd - d)))
    y_p = jnp.pad(y.reshape(n, 1), ((0, pn - n), (0, 0)))
    w_p = jnp.pad(w.reshape(d, 1), ((0, pd - d), (0, 0)))

    if n_t is None:
        kernel = _lstsq_kernel
        in_specs = []
        args = ()
    else:
        kernel = functools.partial(_lstsq_kernel_masked, bn=bn)
        in_specs = [pl.BlockSpec((1, 1), lambda i: (0, 0))]
        args = (jnp.asarray(n_t).astype(jnp.uint32).reshape(1, 1),)

    out = pl.pallas_call(
        kernel,
        grid=(pn // bn,),
        in_specs=in_specs + [
            pl.BlockSpec((bn, pd), lambda i: (i, 0)),
            pl.BlockSpec((pd, 1), lambda i: (0, 0)),
            pl.BlockSpec((bn, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((pd, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((pd, 1), w.dtype),
        interpret=interpret,
    )(*args, x_p, w_p, y_p)
    return out[:d, 0]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
