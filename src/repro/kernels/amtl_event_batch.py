"""Pallas TPU kernel for the batched AMTL multi-event column update.

The batch engine applies `B = event_batch` activations per loop step.  For
each event i the activated task's (d,) column needs

    undo_i = cur_i                                  (undo-log ring entry)
    out_i  = cur_i + eta_k_i * (p_i - eta*g_i - cur_i)   (Eq. III.4)

where cur_i is the column as left by the most recent EARLIER in-batch event
that wrote the same task (duplicate tasks serialize in event order).  Run
one event at a time this is B kernel launches, each re-streaming a column
of V through HBM.  This kernel does the whole batch in one pass over V:

  gather   — the B activated columns are pulled out of the (rows, T) V tile
             with a one-hot MXU matmul (T is lane-sized, so this is a
             single (rows,T)x(T,B) contraction, no dynamic lane indexing);
  fuse     — a static unroll over the B events runs the forward/KM update
             per event and forwards each output to later duplicate events
             with a lane-masked select (the within-batch serialization);
             the pre-write column is accumulated into the undo output;
  scatter  — only the LAST occurrence of each task writes back, via a
             second one-hot matmul masked to last occurrences (host-
             computed), so the scatter indices are conflict-free.

V streams through VMEM once: 3 tile reads + 2 writes for B events, and the
undo-log emit rides along instead of being B extra launches.  Scalars
(tasks, eta, per-event eta_k) live in SMEM; the lane-broadcast copies of
tasks / last-occurrence mask ride in VMEM for the vector compares.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import last_occurrence_mask

Array = jax.Array

BLOCK_ROWS = 256   # sublane-multiple tile rows over d
LANES = 128


def _make_kernel(batch: int):
    def kernel(tasks_s, etaks_s, eta_s, tasks_v, last_v,
               v_ref, p_ref, g_ref, vnew_ref, undo_ref):
        eta = eta_s[0]
        v = v_ref[...].astype(jnp.float32)             # (br, Tp)
        p = p_ref[...].astype(jnp.float32)             # (br, Bp)
        g = g_ref[...].astype(jnp.float32)             # (br, Bp)
        tv = tasks_v[...]                              # (1, Bp) int32
        tp = v.shape[1]
        bp = p.shape[1]

        # gather: one-hot (Tp, Bp) built from a lane iota; padded events
        # carry task -1 and match nothing.
        col_of = jax.lax.broadcasted_iota(jnp.int32, (tp, bp), 0)
        onehot = (col_of == tv).astype(jnp.float32)
        cols = jnp.dot(v, onehot, preferred_element_type=jnp.float32)

        # fuse: serialize the B events; each output is forwarded to later
        # duplicate events so their read sees the in-batch write.
        lane_b = jax.lax.broadcasted_iota(jnp.int32, (1, bp), 1)
        outs = jnp.zeros_like(cols)
        undos = jnp.zeros_like(cols)
        for i in range(batch):
            cur = cols[:, i:i + 1]
            eta_k = etaks_s[i]
            out = cur + eta_k * (p[:, i:i + 1] - eta * g[:, i:i + 1] - cur)
            undos = jnp.where(lane_b == i, cur, undos)
            outs = jnp.where(lane_b == i, out, outs)
            dup_later = (tv == tasks_s[i]) & (lane_b > i)
            cols = jnp.where(dup_later, out, cols)

        # scatter: last occurrence per task wins; (Bp, Tp) one-hot rows are
        # conflict-free so the contraction is an exact column placement.
        row_ev = jax.lax.broadcasted_iota(jnp.int32, (bp, tp), 1)
        # last_v carries task id for last occurrences, -1 otherwise, as a
        # (Bp, 1) column so no in-kernel transpose is needed.
        scat = (row_ev == last_v[...]).astype(jnp.float32)      # (Bp, Tp)
        covered = jnp.sum(scat, axis=0, keepdims=True)          # (1, Tp)
        placed = jnp.dot(outs, scat, preferred_element_type=jnp.float32)
        vnew = jnp.where(covered > 0, placed, v)
        vnew_ref[...] = vnew.astype(vnew_ref.dtype)
        undo_ref[...] = undos.astype(undo_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def amtl_event_batch(v: Array, p_cols: Array, g_cols: Array, tasks: Array,
                     eta: Array, eta_ks: Array, *,
                     block_rows: int = BLOCK_ROWS,
                     interpret: bool = False) -> tuple[Array, Array]:
    """Batched fused multi-event update on a (d, T) iterate (TPU Pallas).

    v: (d, T); p_cols/g_cols: (d, B); tasks: (B,) int32; eta_ks: (B,).
    Returns (v_new (d, T), undo_cols (B, d)) matching
    `ref.amtl_event_batch_ref` (ulp-level on the update — MXU one-hot
    contractions — and exact on the undo bits).
    """
    if v.ndim != 2:
        raise ValueError(f"amtl_event_batch expects v as (d, T), got {v.shape}")
    d, num_t = v.shape
    b = tasks.shape[0]
    if p_cols.shape != (d, b) or g_cols.shape != (d, b):
        raise ValueError("p_cols/g_cols must be (d, B) = "
                         f"({d}, {b}); got {p_cols.shape}, {g_cols.shape}")
    tp = _round_up(num_t, LANES)
    bp = _round_up(b, LANES)
    rows = _round_up(d, 8)
    br = min(block_rows, rows)
    rows = _round_up(rows, br)

    pad_rows = lambda a, w: jnp.pad(a, ((0, rows - d), (0, w - a.shape[1])))
    v_p = pad_rows(v, tp)
    p_p = pad_rows(p_cols, bp)
    g_p = pad_rows(g_cols, bp)
    tasks_pad = jnp.pad(tasks.astype(jnp.int32), (0, bp - b),
                        constant_values=-1)
    # last occurrence of each task within the batch (duplicates scatter
    # conflict-free); encoded as the task id for winners, -1 for losers.
    last_task = jnp.where(last_occurrence_mask(tasks),
                          tasks.astype(jnp.int32), -1)
    last_col = jnp.pad(last_task, (0, bp - b),
                       constant_values=-1).reshape(bp, 1)
    etaks_pad = jnp.pad(eta_ks.astype(jnp.float32), (0, bp - b))
    eta_s = jnp.asarray(eta, jnp.float32).reshape(1)

    grid = (rows // br,)
    smem = lambda shape: pl.BlockSpec(shape, lambda i: (0,) * len(shape),
                                      memory_space=pltpu.SMEM)
    rep = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))
    tile = lambda w: pl.BlockSpec((br, w), lambda i: (i, 0))
    v_new, undo = pl.pallas_call(
        _make_kernel(b),
        grid=grid,
        in_specs=[smem((bp,)), smem((bp,)), smem((1,)),
                  rep((1, bp)), rep((bp, 1)),
                  tile(tp), tile(bp), tile(bp)],
        out_specs=[tile(tp), tile(bp)],
        out_shape=[jax.ShapeDtypeStruct((rows, tp), v.dtype),
                   jax.ShapeDtypeStruct((rows, bp), v.dtype)],
        interpret=interpret,
    )(tasks_pad, etaks_pad, eta_s, tasks_pad.reshape(1, bp), last_col,
      v_p, p_p, g_p)
    return v_new[:d, :num_t], undo[:d, :b].T


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
