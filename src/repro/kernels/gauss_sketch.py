"""Pallas TPU kernel for the unmaterialized Gaussian sketch W @ Omega.

The randomized SVT's range finder contracts the (d, T) iterate against a
(T, p) Gaussian test matrix Omega.  Materializing Omega per refresh costs
a (T, p) HBM round-trip and an extra PRNG kernel launch for a matrix that
is consumed exactly once — instead, this kernel generates each (block_t,
p) tile of Omega in VMEM from the counter-based seed (Box-Muller over
`ref.counter_hash` bits, the jnp oracle's exact expression) while the
matching (block_d, block_t) tile of W is resident, and accumulates the
(block_d, p) partial product.  Omega never exists in HBM.

Entry (r, c) of the GLOBAL Omega depends only on (seed, r, c) — so a
shard of the task-sharded engine generates the rows of ITS column block
from the replicated seed (`row_offset` = its global column offset) and
the partitioned-psum identity sum_s W_s @ Omega_s = W @ Omega is over the
same matrix the serial prox uses.  `row_offset` is traced (it comes from
`lax.axis_index` inside shard_map), so it rides into the kernel as a
(1, 1) scalar block next to the seed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import gauss_from_counters

Array = jax.Array

BLOCK_D = 1024
BLOCK_T = 128
LANES = 128


def _sketch_kernel(seed_ref, off_ref, w_ref, out_ref, *, bt: int, p: int,
                   pp: int):
    j = pl.program_id(1)                        # t-strip (minor, sequential)
    w = w_ref[...].astype(jnp.float32)          # (bd, bt)
    # (bt, pp) Omega tile from global counters (row * p + col); lanes
    # >= p hold finite garbage normals whose output columns are sliced
    # away by the host wrapper, and padded t rows multiply zero columns
    # of W, so neither perturbs the first p output columns.
    row0 = (off_ref[0, 0] + j * bt).astype(jnp.uint32)
    rows = (jax.lax.broadcasted_iota(jnp.uint32, (bt, pp), 0)
            + row0)
    cols = jax.lax.broadcasted_iota(jnp.uint32, (bt, pp), 1)
    omega = gauss_from_counters(seed_ref[0, 0], rows * jnp.uint32(p) + cols)
    contrib = jnp.dot(w, omega, preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = contrib

    @pl.when(j > 0)
    def _acc():
        out_ref[...] = out_ref[...] + contrib


@functools.partial(jax.jit, static_argnames=("p", "block_d", "block_t",
                                             "interpret"))
def gauss_sketch(w: Array, seed: Array, row_offset: Array, *, p: int,
                 block_d: int = BLOCK_D, block_t: int = BLOCK_T,
                 interpret: bool = False) -> Array:
    """(d, p) f32 sketch W @ Omega, Omega generated in-kernel.

    `w` is (d, t_local) — the full iterate (serial prox, row_offset 0) or
    a shard's column block (row_offset = global column offset).  Returns
    f32 regardless of w.dtype (the sketch feeds a f32 QR).
    """
    d, tt = w.shape
    pd = _round_up(d, 8)
    bd = min(block_d, pd)
    pd = _round_up(pd, bd)
    bt = min(block_t, _round_up(tt, 8))
    pt = _round_up(tt, bt)
    pp = _round_up(p, LANES)
    w_p = jnp.pad(w, ((0, pd - d), (0, pt - tt)))
    seed2 = jnp.asarray(seed, jnp.uint32).reshape(1, 1)
    off2 = jnp.asarray(row_offset, jnp.int32).reshape(1, 1)

    out = pl.pallas_call(
        functools.partial(_sketch_kernel, bt=bt, p=p, pp=pp),
        grid=(pd // bd, pt // bt),
        in_specs=[pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
                  pl.BlockSpec((bd, bt), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bd, pp), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pd, pp), jnp.float32),
        interpret=interpret,
    )(seed2, off2, w_p)
    return out[:d, :p]


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m
