"""Production mesh construction (never touches device state at import)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 'pod' = DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate (1, 1) mesh for single-device correctness tests."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
