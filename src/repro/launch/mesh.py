"""Production mesh construction (never touches device state at import)."""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 'pod' = DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """Degenerate (1, 1) mesh for single-device correctness tests."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_task_mesh(num_shards: int | None = None) -> jax.sharding.Mesh:
    """1-D 'tasks' mesh for the task-sharded AMTL engine (engine='sharded').

    Uses the first `num_shards` local devices (default: all of them); the
    single-CPU correctness tests get a degenerate 1-shard mesh, the 8-fake-
    device suites a real multi-shard one from the same call.
    """
    devices = jax.devices()
    n = len(devices) if num_shards is None else num_shards
    if not 1 <= n <= len(devices):
        raise ValueError(f"num_shards must be in [1, {len(devices)}] "
                         f"(visible devices), got {num_shards}")
    return jax.sharding.Mesh(np.asarray(devices[:n]), ("tasks",))


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
