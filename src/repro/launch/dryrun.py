import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
#   This is dry-run-only; tests and benches see the real single CPU device.

"""Multi-pod dry run: lower + compile every (arch x shape x mesh) combo.

For each combo this:
  1. builds the production mesh (16x16 or 2x16x16),
  2. constructs ShapeDtypeStruct inputs (launch/shapes.py) and the rule-
     engine shardings (distributed/sharding.py),
  3. jits the real train/prefill/decode step with those shardings,
     .lower().compile() — any sharding mismatch, OOM-at-compile or
     unsupported collective is a bug in the system,
  4. records memory_analysis / cost_analysis / per-collective bytes parsed
     from the compiled HLO into a JSON row for §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k \
      [--multi-pod] [--out results.json]
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse
import json
import math
import re
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.configs.base import ArchConfig
from repro.distributed import sharding as shd
from repro.launch import shapes as shp
from repro.launch.mesh import data_axes, make_production_mesh
from repro.launch.steps import (default_optimizer, init_train_state,
                                make_decode_step, make_prefill_step,
                                make_train_step)
from repro.models import serving
from repro.models.moe import ParallelCtx

_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
          "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|"
                       r"pred|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")


def _shape_bytes(tok: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(tok):
        n = math.prod(int(d) for d in dims.split(",") if d) if dims else 1
        total += n * _BYTES[dt]
    return total


_CONVERT_RE = re.compile(
    r"=\s*f32\[([0-9,]*)\][^ ]*\s+convert\(")
_CONVERT_SRC_RE = re.compile(r"convert\(%[^)]*\)")


def convert_bf16_bytes(hlo_text: str) -> float:
    """Bytes written by bf16->f32 convert ops (XLA:CPU artifact — CPU has
    no native bf16 compute, TPU does; subtracted for the TPU-adjusted
    memory roofline term, EXPERIMENTS.md §Roofline)."""
    total = 0.0
    for line in hlo_text.splitlines():
        m = _CONVERT_RE.search(line)
        if not m:
            continue
        dims = m.group(1)
        n = math.prod(int(d) for d in dims.split(",") if d) if dims else 1
        # f32 result write + bf16 operand read
        total += n * 6.0
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum per-device collective bytes by op kind from post-SPMD HLO."""
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        result = _shape_bytes(line.split("=", 1)[1].split(kind)[0])
        if kind == "all-reduce":
            out[kind] += 2.0 * result          # ring RS + AG
        elif kind == "reduce-scatter":
            # operand bytes = what each device ships through the ring
            args = line.split(kind, 1)[1]
            out[kind] += _shape_bytes(args.split("),", 1)[0])
        else:
            out[kind] += result
    return out


def _replicated(tree):
    return jax.tree.map(lambda _: P(), tree)


def build_combo(cfg: ArchConfig, shape: shp.ShapeSpec, mesh,
                unroll: bool | int = 1, remat: bool | str = True):
    """Returns (fn, arg_structs, in_shardings) ready to lower.

    unroll=True fully unrolls the layer scans: required for accurate
    cost_analysis (XLA counts a while-loop body once, not x trip-count),
    at the price of longer compiles.  The multi-pod compile-proof runs
    with the production scan (unroll=1).
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    daxes = data_axes(mesh)
    ctx = ParallelCtx(mesh=mesh, data_axes=daxes, model_axis="model",
                      ep_data_axis="data")
    key = jax.random.PRNGKey(0)
    msize = axis_sizes.get("model", 1)

    def named(pspec_tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), pspec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "train":
        opt = default_optimizer(cfg)
        state_struct = jax.eval_shape(
            partial(init_train_state, cfg=cfg, optimizer=opt), key)
        state_spec = type(state_struct)(
            params=shd.param_pspecs(state_struct.params, cfg, axis_sizes),
            opt_state=shd.opt_state_pspecs(
                state_struct.opt_state, state_struct.params, cfg, axis_sizes,
                zero_axes=daxes),
            mtl=_replicated(state_struct.mtl),
            step=P(),
        )
        batch_struct = shp.batch_struct(cfg, shape)
        batch_spec = {k: shd.batch_pspec(k, v.shape, axis_sizes, daxes)
                      for k, v in batch_struct.items()}
        moe_spec = (P(daxes, "model", None)
                    if cfg.moe and shape.seq % msize == 0
                    else P(daxes, None, None)) if cfg.moe else None
        fn = make_train_step(cfg, opt, ctx, moe_token_spec=moe_spec,
                             unroll=unroll, remat=remat)
        return (fn, (state_struct, batch_struct),
                (named(state_spec), named(batch_spec)), ctx)

    params_struct = jax.eval_shape(
        lambda k: __import__("repro.models", fromlist=["init_params"])
        .init_params(k, cfg), key)
    param_spec = shd.param_pspecs(params_struct, cfg, axis_sizes)

    if shape.kind == "prefill":
        batch_struct = shp.batch_struct(cfg, shape)
        batch_spec = {k: shd.batch_pspec(k, v.shape, axis_sizes, daxes)
                      for k, v in batch_struct.items()}
        moe_spec = (P(daxes, "model", None)
                    if cfg.moe and shape.seq % msize == 0
                    else P(daxes, None, None)) if cfg.moe else None
        fn = make_prefill_step(cfg, ctx, moe_token_spec=moe_spec,
                               s_max=shape.seq, unroll=unroll)
        return (fn, (params_struct, batch_struct),
                (named(param_spec), named(batch_spec)), ctx)

    # decode
    cache_struct = jax.eval_shape(
        partial(serving.init_cache, cfg, shape.batch, shape.seq))
    cache_spec = shd.cache_pspecs(cache_struct, axis_sizes, daxes)
    token_struct, pos_struct = shp.decode_structs(cfg, shape)
    token_spec = shd.batch_pspec("token", token_struct.shape, axis_sizes,
                                 daxes)
    moe_spec = P(daxes if shape.batch > 1 else None, None, None) \
        if cfg.moe else None
    fn = make_decode_step(cfg, ctx, moe_token_spec=moe_spec, unroll=unroll)
    return (fn, (params_struct, cache_struct, token_struct, pos_struct),
            (named(param_spec), named(cache_spec), named(token_spec),
             NamedSharding(mesh, P())), ctx)


def param_counts(cfg: ArchConfig) -> tuple[float, float]:
    """(total params, active params) from the abstract param tree."""
    from repro.models import init_params
    struct = jax.eval_shape(partial(init_params, cfg=cfg),
                            jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(struct)[0]
    total = expert_n = 0
    for path, leaf in flat:
        n = math.prod(leaf.shape)
        total += n
        names = [getattr(k, "key", str(k)) for k in path]
        # routed expert weights: .../moe/{w_in,w_out,w_gate}, shape
        # (E, d, f)-like — +1 leading scan-stack dim in the stacked tree.
        if "moe" in names and names[-1] in ("w_in", "w_out", "w_gate"):
            expert_n += n
    if cfg.moe:
        active = total - expert_n + expert_n * cfg.moe.top_k \
            / cfg.moe.num_experts
    else:
        active = total
    return float(total), float(active)


def run_combo(arch: str, shape_name: str, multi_pod: bool,
              compile_only: bool = False,
              unroll: bool | int = 1) -> dict:
    cfg = get_config(arch)
    shape = shp.SHAPES[shape_name]
    ok, reason = shp.applicable(cfg, shape)
    row = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16"}
    if unroll not in (1, False):
        row["unroll"] = True
    if not ok:
        row.update(status="skip", reason=reason)
        return row

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    fn, structs, in_sh, ctx = build_combo(cfg, shape, mesh, unroll=unroll)
    # donate the train state / the decode KV cache (production semantics:
    # both are updated in place; without donation every step copies the
    # whole cache, which dominates the decode memory term)
    donate = {"train": (0,), "decode": (1,)}.get(shape.kind, ())
    jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
    with mesh:
        lowered = jitted.lower(*structs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = collective_bytes(hlo)
    total_p, active_p = param_counts(cfg)

    row.update(
        status="ok",
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        flops_per_device=cost.get("flops"),
        bytes_per_device=cost.get("bytes accessed"),
        collective_bytes=coll,
        convert_bytes=convert_bf16_bytes(hlo),
        params_total=total_p, params_active=active_p,
        argument_bytes=getattr(mem, "argument_size_in_bytes", None),
        output_bytes=getattr(mem, "output_size_in_bytes", None),
        temp_bytes=getattr(mem, "temp_size_in_bytes", None),
        generated_code_bytes=getattr(mem, "generated_code_size_in_bytes",
                                     None),
    )
    print(f"[dryrun] {arch} x {shape_name} x {row['mesh']}: "
          f"lower {t_lower:.1f}s compile {t_compile:.1f}s "
          f"flops/dev={row['flops_per_device']} "
          f"temp={row['temp_bytes']}", flush=True)
    print(f"[dryrun]   memory_analysis: {mem}", flush=True)
    return row


def _with_periods(cfg: ArchConfig, k: int) -> ArchConfig:
    import dataclasses
    n = (len(cfg.head_blocks) + len(cfg.period) * k + len(cfg.tail_blocks))
    return dataclasses.replace(cfg, num_periods=k, num_layers=n)


def _cost_fields(row: dict) -> dict:
    return {"flops_per_device": row["flops_per_device"] or 0.0,
            "bytes_per_device": row["bytes_per_device"] or 0.0,
            "convert_bytes": row.get("convert_bytes") or 0.0,
            "collective_bytes": dict(row["collective_bytes"])}


def _lincomb(c1: dict, c2: dict, p: int) -> dict:
    """c1 + (p-1) * (c2 - c1): per-period extrapolation of the cost terms."""
    out = {}
    for k in ("flops_per_device", "bytes_per_device", "convert_bytes"):
        out[k] = c1[k] + (p - 1) * (c2[k] - c1[k])
    out["collective_bytes"] = {
        kind: c1["collective_bytes"][kind]
        + (p - 1) * (c2["collective_bytes"][kind]
                     - c1["collective_bytes"][kind])
        for kind in c1["collective_bytes"]}
    return out


def _run_variant(cfg: ArchConfig, shape, mesh, unroll,
                 remat: bool | str = True) -> dict:
    """lower+compile one cfg variant, return the cost fields."""
    t0 = time.time()
    fn, structs, in_sh, _ = build_combo(cfg, shape, mesh, unroll=unroll,
                                        remat=remat)
    donate = {"train": (0,), "decode": (1,)}.get(shape.kind, ())
    jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate)
    with mesh:
        compiled = jitted.lower(*structs).compile()
    cost = compiled.cost_analysis() or {}
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    return {"flops_per_device": cost.get("flops") or 0.0,
            "bytes_per_device": cost.get("bytes accessed") or 0.0,
            "collective_bytes": collective_bytes(hlo),
            "convert_bytes": convert_bf16_bytes(hlo),
            "compile_s": time.time() - t0}


def run_combo_extrapolated(arch: str, shape_name: str,
                           multi_pod: bool = False,
                           remat: bool | str = True,
                           kv_int8: bool = False) -> dict:
    """Accurate cost terms without the full-unroll compile blow-up:

    compile the model with num_periods=1 and num_periods=2 (scans fully
    unrolled — tiny), then extrapolate cost = c1 + (P-1)*(c2-c1).  Exact
    when per-period cost is shape-identical (it is: scanned layers are
    homogeneous); validated against a true full unroll in tests.
    """
    cfg = get_config(arch)
    if kv_int8:
        import dataclasses
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    shape = shp.SHAPES[shape_name]
    ok, reason = shp.applicable(cfg, shape)
    row = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "unroll": "extrapolated"}
    if kv_int8:
        row["kv_cache"] = "int8"
    if not ok:
        row.update(status="skip", reason=reason)
        return row
    if cfg.num_periods < 2:
        return run_combo(arch, shape_name, multi_pod, unroll=True)

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    c1 = _run_variant(_with_periods(cfg, 1), shape, mesh, unroll=True,
                      remat=remat)
    c2 = _run_variant(_with_periods(cfg, 2), shape, mesh, unroll=True,
                      remat=remat)
    cost = _lincomb(_cost_fields(c1), _cost_fields(c2), cfg.num_periods)
    total_p, active_p = param_counts(cfg)
    row.update(status="ok", lower_s=0.0,
               compile_s=round(time.time() - t0, 1),
               params_total=total_p, params_active=active_p,
               argument_bytes=None, output_bytes=None, temp_bytes=None,
               generated_code_bytes=None, **cost)
    print(f"[dryrun] {arch} x {shape_name} x {row['mesh']} (extrapolated "
          f"from P=1,2 to P={cfg.num_periods}): "
          f"flops/dev={row['flops_per_device']:.3e} "
          f"compile {row['compile_s']}s", flush=True)
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(shp.SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="fully unroll layer scans for accurate "
                         "cost_analysis (slower compiles)")
    ap.add_argument("--extrapolate", action="store_true",
                    help="accurate cost terms via the P=1/P=2 unrolled "
                         "variants (fast; see run_combo_extrapolated)")
    ap.add_argument("--kv-int8", action="store_true",
                    help="int8-quantized KV caches (decode combos)")
    ap.add_argument("--remat", default="full",
                    choices=("full", "dots", "dots_no_batch", "none"),
                    help="activation-checkpoint policy for train combos")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    remat = False if args.remat == "none" else (
        True if args.remat == "full" else args.remat)

    combos = ([(a, s) for a in ARCH_NAMES for s in shp.SHAPES]
              if args.all else [(args.arch, args.shape)])
    rows = []
    for arch, shape_name in combos:
        try:
            if args.extrapolate:
                row = run_combo_extrapolated(arch, shape_name,
                                             args.multi_pod, remat=remat,
                                             kv_int8=args.kv_int8)
            else:
                row = run_combo(arch, shape_name, args.multi_pod,
                                unroll=True if args.unroll else 1)
        except Exception as e:  # a dry-run failure is a bug: surface it
            import traceback
            traceback.print_exc()
            row = {"arch": arch, "shape": shape_name,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "status": "fail", "error": f"{type(e).__name__}: {e}"}
        rows.append(row)
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(row) + "\n")
    bad = [r for r in rows if r["status"] == "fail"]
    print(f"[dryrun] done: {len(rows)} combos, {len(bad)} failures")
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
