"""Production serving driver: batched prefill + greedy decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --batch 4 --prompt-len 32 --gen 16          # CPU-runnable
    PYTHONPATH=src python -m repro.launch.serve --arch granite-8b \
        --mesh single-pod --batch 128               # on a real pod

Caches are sharded batch->data / seq->model by the rule engine; decode is
one jitted step reused across positions (cache donated, no re-compile).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_NAMES, get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import data_axes, make_host_mesh, \
    make_production_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import init_params, serving
from repro.models.moe import ParallelCtx


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", choices=("host", "single-pod", "multi-pod"),
                    default="host")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, name=cfg.name + "-reduced")
    if not cfg.has_decode:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode loop "
                         "(run prefill-only via repro.launch.dryrun)")

    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=(args.mesh == "multi-pod")))
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    daxes = data_axes(mesh)
    ctx = ParallelCtx(mesh=mesh, data_axes=daxes, model_axis="model",
                      ep_data_axis="data")
    s_max = args.prompt_len + args.gen

    with mesh:
        params = init_params(jax.random.PRNGKey(0), cfg)
        pspec = shd.param_pspecs(params, cfg, axis_sizes)
        params = jax.tree.map(
            jax.device_put, params,
            jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                         is_leaf=lambda x: isinstance(x, P)))
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"{cfg.name}: {n/1e6:.1f}M params, batch={args.batch}, "
              f"prompt={args.prompt_len}, gen={args.gen}")

        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
            cfg.vocab_size, jnp.int32)
        batch = {"tokens": prompts}
        if cfg.family == "vlm":
            batch["vision_embeds"] = 0.05 * jax.random.normal(
                jax.random.PRNGKey(2),
                (args.batch, cfg.vision_seq, cfg.d_model), jnp.float32
            ).astype(jnp.dtype(cfg.dtype))

        prefill_fn = jax.jit(make_prefill_step(cfg, ctx, s_max=s_max,
                                               remat=False))
        t0 = time.time()
        logits, cache = prefill_fn(params, batch)
        logits.block_until_ready()
        print(f"prefill: {time.time()-t0:.2f}s "
              f"({args.batch * args.prompt_len} tokens)")

        decode_fn = jax.jit(make_decode_step(cfg, ctx),
                            donate_argnums=1)

        def sample(lg, key):
            if args.temperature <= 0:
                return jnp.argmax(lg[:, -1], axis=-1)
            return jax.random.categorical(key, lg[:, -1] / args.temperature)

        key = jax.random.PRNGKey(3)
        tok = sample(logits, key)[:, None].astype(jnp.int32)
        out = [tok]
        t0 = time.time()
        for i in range(args.gen - 1):
            key = jax.random.fold_in(key, i)
            logits, cache = decode_fn(params, cache, tok,
                                      jnp.asarray(args.prompt_len + i,
                                                  jnp.int32))
            tok = sample(logits, key)[:, None].astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(tok)
        dt = time.time() - t0
        toks = jnp.concatenate(out, axis=1)
        print(f"decoded {args.gen} x {args.batch} tokens in {dt:.2f}s "
              f"({args.batch * args.gen / max(dt, 1e-9):.1f} tok/s)")
        for i in range(min(args.batch, 4)):
            print(f"  req{i}: {toks[i].tolist()}")


if __name__ == "__main__":
    main()
