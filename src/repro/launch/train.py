"""Production training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced \
        --steps 50                                  # CPU-runnable smoke
    PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
        --steps 10000 --mesh single-pod             # on a real pod

Builds the mesh, applies the rule-engine shardings (TP over `model`,
ZeRO-1 over `data`, `pod` = DCN data axis), jits the full train_step
(backbone fwd+bwd + smooth optimizer + the paper's AMTL head round), and
runs the sharded data pipeline with periodic checkpointing and resume.

On this CPU container use --reduced (2-layer, d_model<=256 variant of the
same family) with the default host mesh; the full configs and the
production meshes are exercised by `repro.launch.dryrun`.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint import latest_step, restore, save
from repro.configs import ARCH_NAMES, get_config
from repro.data import ShardedBatcher, synthetic_lm_batches
from repro.distributed import sharding as shd
from repro.launch.mesh import (data_axes, make_host_mesh,
                               make_production_mesh)
from repro.launch.steps import (default_optimizer, init_train_state,
                                make_train_step)
from repro.models.moe import ParallelCtx


def build_mesh(name: str):
    if name == "host":
        return make_host_mesh()
    return make_production_mesh(multi_pod=(name == "multi-pod"))


def batches_for(cfg, seq: int, batch: int, seed: int = 1):
    """Synthetic LM stream matching the arch's input modality."""
    import numpy as np
    base = synthetic_lm_batches(
        cfg.vocab_size, seq, batch, cfg.mtl.num_tasks, seed=seed,
        vision_seq=cfg.vision_seq if cfg.family == "vlm" else 0,
        d_model=cfg.d_model, audio_dim=cfg.feature_dim)
    if cfg.family != "audio":
        return base

    def with_mask():
        rng = np.random.default_rng(seed + 1)
        for b in base:
            b["mask"] = rng.random((batch, seq)) < 0.3
            b["targets"] = b["targets"] % cfg.vocab_size
            yield b
    return with_mask()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized variant of the same family")
    ap.add_argument("--mesh", choices=("host", "single-pod", "multi-pod"),
                    default="host")
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--no-remat", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, name=cfg.name + "-reduced")
    mesh = build_mesh(args.mesh)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    daxes = data_axes(mesh)
    ctx = ParallelCtx(mesh=mesh, data_axes=daxes, model_axis="model",
                      ep_data_axis="data")

    opt = default_optimizer(cfg, lr=args.lr, total_steps=args.steps)
    step_fn = make_train_step(cfg, opt, ctx, remat=not args.no_remat)

    with mesh:
        state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
        spec = type(state)(
            params=shd.param_pspecs(state.params, cfg, axis_sizes),
            opt_state=shd.opt_state_pspecs(state.opt_state, state.params,
                                           cfg, axis_sizes, zero_axes=daxes),
            mtl=jax.tree.map(lambda _: P(), state.mtl),
            step=P(),
        )
        shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                                 is_leaf=lambda x: isinstance(x, P))
        state = jax.tree.map(jax.device_put, state, shardings)

        start = 0
        if args.ckpt and (last := latest_step(args.ckpt)) is not None:
            state = state._replace(
                params=restore(args.ckpt, last, state.params,
                               shardings.params),
                step=jax.numpy.asarray(last, jax.numpy.int32))
            start = last
            print(f"resumed from {args.ckpt} step {last}")

        jit_step = jax.jit(step_fn, in_shardings=(shardings, None),
                           donate_argnums=0)
        n_params = sum(x.size for x in jax.tree.leaves(state.params))
        print(f"{cfg.name}: {n_params/1e6:.1f}M params on mesh "
              f"{dict(axis_sizes)} ({jax.device_count()} devices)")

        data = ShardedBatcher(batches_for(cfg, args.seq, args.batch),
                              mesh=mesh, data_axes=daxes)
        t0 = time.time()
        for i, batch in zip(range(start, args.steps), data):
            state, m = jit_step(state, batch)
            if i % args.log_every == 0 or i == args.steps - 1:
                print(f"step {i:5d}  loss {float(m['loss']):8.4f}  "
                      f"lm {float(m['lm_loss']):8.4f}  "
                      f"probe {float(m['probe_loss']):8.5f}  "
                      f"({time.time()-t0:6.1f}s)", flush=True)
            if args.ckpt and args.ckpt_every and \
                    (i + 1) % args.ckpt_every == 0:
                save(args.ckpt, i + 1, state.params)
        if args.ckpt:
            save(args.ckpt, args.steps, state.params)
            print(f"final checkpoint: {args.ckpt}/step_{args.steps:08d}.npz")


if __name__ == "__main__":
    main()
