"""Step builders: train_step / prefill_step / decode_step per architecture.

train_step = backbone forward+backward + smooth optimizer update + one
mesh-AMTL round on the multi-task head (the paper's technique as a
first-class feature of every training step — DESIGN.md §3).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.mtl_head import (MTLHeadState, amtl_head_update,
                                 init_mtl_state, probe_loss, stale_read)
from repro.core.prox import get_regularizer
from repro.models import serving
from repro.models.moe import ParallelCtx
from repro.models.transformer import forward, init_params
from repro.optim import Optimizer, cosine_warmup, make_optimizer

Array = jax.Array


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    mtl: MTLHeadState
    step: Array


def default_optimizer(cfg: ArchConfig, lr: float = 3e-4,
                      total_steps: int = 10000) -> Optimizer:
    """Adafactor for the 671B MoE (state must fit a pod), AdamW otherwise."""
    sched = cosine_warmup(lr, warmup=min(500, total_steps // 10),
                          total=total_steps)
    if cfg.name.startswith("deepseek"):
        return make_optimizer("adafactor", sched)
    return make_optimizer("adamw", sched)


def init_train_state(key: Array, cfg: ArchConfig,
                     optimizer: Optimizer) -> TrainState:
    params = init_params(key, cfg)
    return TrainState(
        params=params,
        opt_state=optimizer.init(params),
        mtl=init_mtl_state(cfg.d_model, cfg.mtl),
        step=jnp.zeros((), jnp.int32),
    )


def make_train_step(cfg: ArchConfig, optimizer: Optimizer,
                    ctx: ParallelCtx = ParallelCtx(),
                    moe_token_spec=None, remat: bool = True,
                    unroll: bool | int = 1):
    mtl_cfg = cfg.mtl
    reg = get_regularizer(mtl_cfg.reg_name)

    def train_step(state: TrainState, batch: dict):
        key = jax.random.fold_in(jax.random.PRNGKey(17), state.step)
        k_read, k_act = jax.random.split(key)

        # AMTL backward step (stale read + server prox) — shared between the
        # probe loss and the head update.
        v_hat, nu = stale_read(state.mtl, mtl_cfg, k_read)
        p = reg.prox(v_hat, jnp.asarray(mtl_cfg.eta * mtl_cfg.lam,
                                        jnp.float32))

        def loss_fn(params):
            loss, metrics = forward(params, batch, cfg, ctx, remat=remat,
                                    moe_token_spec=moe_token_spec,
                                    unroll=unroll)
            pl = probe_loss(p, metrics["pooled"], batch["task_ids"],
                            batch["mtl_targets"].astype(jnp.float32))
            total = loss + mtl_cfg.probe_weight * pl
            return total, (metrics, pl)

        (total, (metrics, pl)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params)
        new_params, new_opt = optimizer.update(grads, state.opt_state,
                                               state.params, state.step)
        pooled = jax.lax.stop_gradient(metrics["pooled"])
        new_mtl, mtl_metrics = amtl_head_update(
            state.mtl, pooled, batch["task_ids"],
            batch["mtl_targets"].astype(jnp.float32), mtl_cfg, k_act,
            read=(p, nu))

        out = {"loss": total, "lm_loss": metrics["lm_loss"],
               "probe_loss": pl, "aux_loss": metrics["aux_loss"],
               **mtl_metrics}
        if "mtp_loss" in metrics:
            out["mtp_loss"] = metrics["mtp_loss"]
        return TrainState(new_params, new_opt, new_mtl,
                          state.step + 1), out

    return train_step


def make_prefill_step(cfg: ArchConfig, ctx: ParallelCtx = ParallelCtx(),
                      moe_token_spec=None, s_max: Optional[int] = None,
                      remat: bool = True, unroll: bool | int = 1):
    def prefill_step(params, batch):
        return serving.prefill(params, batch, cfg, ctx, s_max=s_max,
                               remat=remat, moe_token_spec=moe_token_spec,
                               unroll=unroll)
    return prefill_step


def make_decode_step(cfg: ArchConfig, ctx: ParallelCtx = ParallelCtx(),
                     moe_token_spec=None, unroll: bool | int = 1):
    def decode(params, cache, token, pos):
        return serving.decode_step(params, cache, token, pos, cfg, ctx,
                                   moe_token_spec=moe_token_spec,
                                   unroll=unroll)
    return decode
