"""The 4 assigned input shapes, applicability matrix, and input_specs().

input_specs() returns ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, no device allocation — exactly what
jit(...).lower() needs for the 512-device dry run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

S = jax.ShapeDtypeStruct


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq: int
    batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Skip matrix of DESIGN.md §4."""
    if shape.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only: no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full attention: long_500k requires sub-quadratic"
    return True, ""


def batch_struct(cfg: ArchConfig, shape: ShapeSpec) -> dict[str, Any]:
    """Training / prefill batch ShapeDtypeStructs."""
    b, s = shape.batch, shape.seq
    dtype = jnp.dtype(cfg.dtype)
    if cfg.family == "audio":
        batch = {"features": S((b, s, cfg.feature_dim), dtype),
                 "mask": S((b, s), jnp.bool_),
                 "targets": S((b, s), jnp.int32)}
    else:
        batch = {"tokens": S((b, s), jnp.int32),
                 "targets": S((b, s), jnp.int32)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = S((b, cfg.vision_seq, cfg.d_model), dtype)
    if shape.kind == "train":
        batch["task_ids"] = S((b,), jnp.int32)
        batch["mtl_targets"] = S((b,), jnp.float32)
    return batch


def decode_structs(cfg: ArchConfig, shape: ShapeSpec):
    """(token, pos) structs; the cache struct comes from eval_shape of
    serving.init_cache."""
    return S((shape.batch, 1), jnp.int32), S((), jnp.int32)


def concrete_batch(cfg: ArchConfig, shape: ShapeSpec, key,
                   num_tasks: Optional[int] = None) -> dict[str, Any]:
    """Materialized random batch (smoke tests / examples)."""
    struct = batch_struct(cfg, shape)
    t = num_tasks or cfg.mtl.num_tasks
    out = {}
    import zlib
    for name, sd in struct.items():
        # crc32, not hash(): PYTHONHASHSEED randomizes hash() per process,
        # which made smoke-test batches non-reproducible
        k = jax.random.fold_in(key, zlib.crc32(name.encode()) % (2 ** 31))
        if name in ("tokens", "targets"):
            out[name] = jax.random.randint(k, sd.shape, 0, cfg.vocab_size)
        elif name == "task_ids":
            out[name] = jax.random.randint(k, sd.shape, 0, t)
        elif name == "mask":
            out[name] = jax.random.bernoulli(k, 0.3, sd.shape)
        elif sd.dtype == jnp.int32:
            out[name] = jnp.zeros(sd.shape, jnp.int32)
        else:
            out[name] = (jax.random.normal(k, sd.shape, jnp.float32)
                         * 0.05).astype(sd.dtype)
    return out
