"""Learning-while-serving: an AMTL session behind a prediction API.

    PYTHONPATH=src python examples/serve_amtl.py

Streams request batches through an `AMTLServer`: every batch is scored
off the committed serving snapshot (predictions never wait on a
learning chunk), labeled feedback is coalesced into engine chunks under
per-task QoS caps, and the session checkpoints on a rotating
`keep_last` window.  Midway, the server "crashes" and is resumed from
the newest rotated checkpoint — the restart is bitwise invisible to
every subsequent prediction, which is the serving platform's core
contract (see `repro.serve`).  A final part moves the chunk loop onto
the background learner thread (PR 8) with a latency SLO: predictions
flow from the main thread while the learner absorbs feedback
concurrently, and after the drain the session state is still bitwise
ONE plain `engine.run` over the coalesced chunk log.

The closing chaos part (PR 10) replays a session under a scripted
`FaultPlan` driving all four injected fault types — NaN feedback
rejected at admission, a learner-thread crash healed by the supervisor,
a poisoned iterate quarantined with its folded rows rolled back, and a
crash between the store and engine checkpoint writes bridged by
`resume` — with the served snapshot finite throughout.
"""
import os
import tempfile

import jax
import numpy as np

from repro.core import AMTLConfig
from repro.data import make_mtl_problem
from repro.serve import AMTLServer, FaultPlan, InjectedFault, ServeConfig

BATCHES = 12
REQUESTS = 16          # prediction rows per request batch
FEEDBACK = 5           # labeled feedback rows per request batch


def _traffic(problem, seed=0):
    rng = np.random.default_rng(seed)
    t = rng.integers(0, problem.num_tasks, size=(BATCHES, REQUESTS))
    x = rng.standard_normal((BATCHES, REQUESTS, problem.dim)) \
        .astype(np.float32)
    fb = rng.integers(0, problem.num_tasks, size=(BATCHES, FEEDBACK))
    return t, x, fb


def main():
    problem = make_mtl_problem(num_tasks=6, samples=40, dim=32, rank=2,
                               lam=0.1, seed=0)
    cfg = AMTLConfig(eta=1.0 / problem.lipschitz(), eta_k=0.9, tau=4,
                     engine="delta", prox_every=4, prox_rank=4)
    w0 = jax.numpy.zeros((problem.dim, problem.num_tasks), jax.numpy.float32)
    key = jax.random.PRNGKey(0)
    t, x, fb = _traffic(problem)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        serve_cfg = ServeConfig(chunk_events=16, task_chunk_quota=4,
                                max_pending_per_task=16,
                                ckpt_dir=ckpt_dir, checkpoint_every=10,
                                keep_last=3, max_batch=REQUESTS)
        # the reference server runs uninterrupted; the "production" one
        # will crash mid-stream and resume from its rotated checkpoints
        ref = AMTLServer(problem, cfg, w0, key,
                         serve_cfg._replace(ckpt_dir=None,
                                            checkpoint_every=None))
        server = AMTLServer(problem, cfg, w0, key, serve_cfg)

        for i in range(BATCHES // 2):
            preds, receipt, ran = server.serve(t[i], x[i], fb[i])
            ref.serve(t[i], x[i], fb[i])
            print(f"[serve] batch {i}: {preds.shape[0]} preds, "
                  f"{receipt.accepted} feedback accepted, "
                  f"{ran} events learned")
        # drain the queue on both (identical) servers, then flush a final
        # checkpoint: pending feedback is the one thing a crash loses, so
        # the demo crashes with an empty queue to keep the replay bitwise
        while server.pending_feedback:
            server.step()
            ref.step()
        server.checkpoint()
        records = sorted(os.listdir(ckpt_dir))
        print(f"[ckpt ] rotated window (keep_last=3): {records}")
        assert len(records) <= 3

        # -- crash + restart: resume from the newest rotated record ----
        del server
        server = AMTLServer.resume(problem, cfg, w0, key, serve_cfg)
        print(f"[boot ] resumed at event {server.event_count} "
              f"(pending feedback is the one thing a crash loses; "
              f"clients re-submit)")

        for i in range(BATCHES // 2, BATCHES):
            preds, _, _ = server.serve(t[i], x[i], fb[i])
            ref_preds, _, _ = ref.serve(t[i], x[i], fb[i])
            assert np.array_equal(np.asarray(preds), np.asarray(ref_preds)), \
                "restart must be bitwise invisible to predictions"
        print(f"[serve] batches {BATCHES // 2}..{BATCHES - 1}: resumed "
              "predictions bitwise == uninterrupted server")

        stats = server.stats()
        print(f"[stats] {stats}")
        assert stats["events"] == ref.stats()["events"]

        # -- threaded serving: the learner thread owns the chunk loop --
        from repro.core import make_engine
        start_event = server.event_count
        chunks_before = len(server.chunk_log)
        learner = server.start_learner()
        for i in range(BATCHES):
            server.predict(t[i % BATCHES], x[i % BATCHES])
            server.submit_feedback(fb[i % BATCHES])
        server.stop_learner(drain=True)   # finish every runnable chunk
        new_chunks = server.chunk_log[chunks_before:]
        print(f"[thread] learner absorbed {learner.events} events in "
              f"{learner.chunks} chunks while the main thread served")
        # replay law: the threaded session (including everything learned
        # before the crash) is bitwise ONE plain run over every event —
        # chunks compose bitwise at any boundary, threaded or not
        assert server.event_count == start_event + sum(new_chunks)
        eng = make_engine(problem, cfg)
        state = eng.run(eng.init(w0, key), None, server.event_count)
        assert np.array_equal(np.asarray(server.iterate()),
                              np.asarray(eng.iterate(state))), \
            "threaded serving must replay the chunk log bitwise"

    _chaos_part(problem, cfg, w0, key, t, x)
    print("OK: learning-while-serving with QoS, rotating checkpoints, a "
          "restart-transparent resume, a concurrent learner thread, and "
          "scripted-fault recovery (restart, quarantine, torn checkpoint).")


def _chaos_part(problem, cfg, w0, key, t, x):
    """Drive all four injected fault types against one supervised
    session and show every recovery contract holding."""
    import time

    rng = np.random.default_rng(1)

    def rows(k, seed):
        r = np.random.default_rng(seed)
        return (r.integers(0, problem.num_tasks, size=k),
                (r.standard_normal((k, problem.dim))
                 / np.sqrt(problem.dim)).astype(np.float32),
                r.standard_normal(k).astype(np.float32))

    with tempfile.TemporaryDirectory() as ckpt_dir:
        plan = FaultPlan(nan_feedback=[(0, 2)],      # labeled call 0, row 2
                         crash_on_chunks={1},        # learner dies, heals
                         poison_iterate_on_chunks={3},   # quarantined
                         fail_checkpoint_calls={1})  # store/engine split
        serve_cfg = ServeConfig(chunk_events=4, ckpt_dir=ckpt_dir,
                                restart_limit=2, restart_backoff_s=0.01)
        server = AMTLServer(problem, cfg, w0, key, serve_cfg,
                            fault_plan=plan)

        # 1) non-finite feedback dies at admission, not in the kernel
        receipt = server.submit_feedback(*rows(4, 0))
        print(f"[chaos] NaN feedback: {receipt.accepted} accepted, "
              f"{receipt.rejected} rejected (reason={receipt.reason})")
        assert receipt.reason == "nonfinite"

        # 2+3) supervised learner: scripted crash healed under backoff,
        # scripted iterate poison quarantined (folded rows rolled back)
        server.start_learner()
        for i in range(10):
            server.predict(t[i % len(t)], x[i % len(x)])
            server.submit_feedback(
                rng.integers(0, problem.num_tasks, size=4))
        deadline = time.perf_counter() + 60.0
        while time.perf_counter() < deadline:
            health = server.stats()["health"]
            if (health["learner_restarts"] >= 1
                    and health["nonfinite_chunks"] >= 1):
                break
            time.sleep(0.01)
        server.stop_learner(drain=True)
        health = server.stats()["health"]
        print(f"[chaos] crash healed: restarts={health['learner_restarts']}"
              f" recovery_ms={[round(ms, 1) for ms in health['recovery_ms']]}"
              f" | quarantined={health['quarantined_feedback']} events "
              f"across {health['nonfinite_chunks']} poisoned chunk(s)")
        assert health["learner_restarts"] >= 1
        assert health["nonfinite_chunks"] >= 1
        assert np.isfinite(np.asarray(server.iterate())).all(), \
            "the served snapshot must never go non-finite"

        # 4) checkpoint crash-split: the scripted kill lands between the
        # store write and the engine write; resume bridges the tear
        server.checkpoint()                    # call 0: whole record pair
        server.submit_feedback(rng.integers(0, problem.num_tasks, size=4))
        server.step()
        try:
            server.checkpoint()                # call 1: torn mid-pair
        except InjectedFault:
            print("[chaos] checkpoint torn between store and engine "
                  "writes (scripted)")
        resumed = AMTLServer.resume(problem, cfg, w0, key, serve_cfg)
        print(f"[chaos] resumed at event {resumed.event_count} from the "
              f"surviving record pair")
        assert resumed.event_count > 0
        assert np.isfinite(np.asarray(resumed.iterate())).all()


if __name__ == "__main__":
    main()
