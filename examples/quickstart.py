"""Quickstart: solve a low-rank multi-task regression with AMTL.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's synthetic shared-subspace problem, solves it three ways
(centralized FISTA, synchronous SMTL, asynchronous AMTL) and shows they
reach the same optimum — with AMTL running asynchronously under bounded
staleness (Theorem 1).

The AMTL run uses the session API (`make_engine`): events are streamed in
chunks, the engine state is checkpointed mid-run and restored — a
simulated server restart — and the resumed session reproduces the
uninterrupted solve bitwise.  `amtl_solve` is the same engine behind a
one-shot convenience wrapper.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.core import (AMTLConfig, amtl_solve, fista_solve, make_engine,
                        reference_optimum, smtl_solve)
from repro.data import make_mtl_problem


def main():
    problem = make_mtl_problem(num_tasks=8, samples=100, dim=40, rank=3,
                               lam=0.1, seed=0)
    eta = 1.0 / problem.lipschitz()
    d, t = problem.dim, problem.num_tasks
    w0 = jnp.zeros((d, t), jnp.float32)

    w_star, obj_star = reference_optimum(problem, num_iters=1000)
    print(f"[fista]  optimum objective      : {float(obj_star):.5f}")

    sync = smtl_solve(problem, w0, eta, 300)
    print(f"[smtl ]  objective after 300 it : {float(sync.objectives[-1]):.5f}")

    cfg = AMTLConfig(eta=eta, eta_k=0.9, tau=4)
    key = jax.random.PRNGKey(0)
    res = amtl_solve(problem, cfg, w0, key, num_epochs=300)
    print(f"[amtl ]  objective after 300 ep : {float(res.objectives[-1]):.5f}"
          f"   (fixed-point residual {float(res.residuals[-1]):.2e})")

    # -- the session API: same engine, streamed ------------------------
    # 300 epochs == 300*T events; stream them in chunks of 25 epochs,
    # checkpoint at half-time, restore, and finish the stream.
    engine = make_engine(problem, cfg)
    total, chunk = 300 * t, 25 * t
    state = engine.init(w0, key)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        while int(state.event) < total // 2:
            state = engine.run(state, None, chunk)
        checkpoint.save(ckpt_dir, int(state.event), state)
        print(f"[sess ]  checkpointed at event  : {int(state.event)}")
        # simulated restart: rebuild from the serialized state alone
        step = checkpoint.latest_step(ckpt_dir)
        state = checkpoint.restore(ckpt_dir, step,
                                   like=engine.init(w0, key))
        while int(state.event) < total:
            state = engine.run(state, None, chunk)
    assert np.array_equal(np.asarray(engine.iterate(state)),
                          np.asarray(res.v)), \
        "resumed session must replay the one-shot solve bitwise"
    print(f"[sess ]  resumed to event       : {int(state.event)}"
          "   (bitwise == one-shot amtl_solve)")

    gap = abs(float(res.objectives[-1]) - float(obj_star))
    print(f"[amtl ]  gap to global optimum  : {gap:.2e}")
    rank = int(jnp.sum(jnp.linalg.svd(res.w, compute_uv=False) > 1e-3))
    print(f"[amtl ]  learned rank (true 3)  : {rank}")
    assert gap < 1e-2, "AMTL failed to reach the optimum"
    print("OK: asynchronous updates reach the same optimum as FISTA/SMTL, "
          "and the session survives a checkpoint/restart.")


if __name__ == "__main__":
    main()
