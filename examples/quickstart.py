"""Quickstart: solve a low-rank multi-task regression with AMTL.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's synthetic shared-subspace problem, solves it three ways
(centralized FISTA, synchronous SMTL, asynchronous AMTL) and shows they
reach the same optimum — with AMTL running asynchronously under bounded
staleness (Theorem 1).
"""
import jax
import jax.numpy as jnp

from repro.core import (AMTLConfig, amtl_solve, fista_solve,
                        reference_optimum, smtl_solve)
from repro.data import make_mtl_problem


def main():
    problem = make_mtl_problem(num_tasks=8, samples=100, dim=40, rank=3,
                               lam=0.1, seed=0)
    eta = 1.0 / problem.lipschitz()
    d, t = problem.dim, problem.num_tasks
    w0 = jnp.zeros((d, t), jnp.float32)

    w_star, obj_star = reference_optimum(problem, num_iters=1000)
    print(f"[fista]  optimum objective      : {float(obj_star):.5f}")

    sync = smtl_solve(problem, w0, eta, 300)
    print(f"[smtl ]  objective after 300 it : {float(sync.objectives[-1]):.5f}")

    cfg = AMTLConfig(eta=eta, eta_k=0.9, tau=4)
    res = amtl_solve(problem, cfg, w0, jax.random.PRNGKey(0),
                     num_epochs=300)
    print(f"[amtl ]  objective after 300 ep : {float(res.objectives[-1]):.5f}"
          f"   (fixed-point residual {float(res.residuals[-1]):.2e})")

    gap = abs(float(res.objectives[-1]) - float(obj_star))
    print(f"[amtl ]  gap to global optimum  : {gap:.2e}")
    rank = int(jnp.sum(jnp.linalg.svd(res.w, compute_uv=False) > 1e-3))
    print(f"[amtl ]  learned rank (true 3)  : {rank}")
    assert gap < 1e-2, "AMTL failed to reach the optimum"
    print("OK: asynchronous updates reach the same optimum as FISTA/SMTL.")


if __name__ == "__main__":
    main()
