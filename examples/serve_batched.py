"""Serve a small model with batched requests: prefill + decode loop.

    PYTHONPATH=src python examples/serve_batched.py

Prefills a batch of 4 prompts through a reduced gemma2 (sliding-window +
global attention, ring caches) and greedily decodes 16 tokens per request,
verifying decode-vs-forward consistency as it goes.
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params, prefill


def main():
    cfg = get_config("gemma2-2b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    b, prompt_len, gen = 4, 24, 16
    s_max = prompt_len + gen

    prompts = jax.random.randint(jax.random.PRNGKey(1), (b, prompt_len), 0,
                                 cfg.vocab_size)
    # JAX dispatches asynchronously: block before BOTH timer reads so the
    # window covers the prefill compute, not just its dispatch (and not
    # the still-materializing params/prompts from above).
    jax.block_until_ready((params, prompts))
    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts}, cfg, s_max=s_max,
                            remat=False)
    jax.block_until_ready((logits, cache))
    print(f"prefill: batch={b} len={prompt_len} in {time.time()-t0:.2f}s")

    decode = jax.jit(lambda c, tok, pos: decode_step(params, c, tok, pos,
                                                     cfg))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    jax.block_until_ready(tok)      # don't charge the argmax to the loop
    t0 = time.time()
    for i in range(gen - 1):
        logits, cache = decode(cache, tok, jnp.asarray(prompt_len + i,
                                                       jnp.int32))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)      # drain the async queue before timing
    dt = time.time() - t0
    gen_toks = jnp.concatenate(out_tokens, axis=1)
    print(f"decoded {gen} tokens x {b} requests in {dt:.2f}s "
          f"({b * gen / dt:.1f} tok/s on 1 CPU core)")
    for i in range(b):
        print(f"  req{i}: {gen_toks[i].tolist()}")


if __name__ == "__main__":
    main()
