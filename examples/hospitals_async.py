"""The paper's motivating scenario: hospitals with private data, slow links.

    PYTHONPATH=src python examples/hospitals_async.py

12 'hospitals' (task nodes) each hold a private patient cohort of a
different size; 3 hospitals sit behind a slow network.  Heterogeneous
tasks: 6 regression (length-of-stay) + 6 classification (readmission).
Runs the event-driven simulators and reports wall-clock + objective for
synchronous vs asynchronous optimization, plus the dynamic-step variant.
"""
import numpy as np

from repro.core import NetworkModel, SimProblem, simulate_amtl, simulate_smtl


def make_hospitals(seed=0):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(80, 400, size=12)
    d = 32
    w_shared = rng.standard_normal(d)
    xs, ys, losses = [], [], []
    for i, n in enumerate(sizes):
        x = rng.standard_normal((n, d)) / np.sqrt(d)
        w_t = w_shared + 0.3 * rng.standard_normal(d)
        z = x @ w_t + 0.1 * rng.standard_normal(n)
        if i % 2 == 0:
            ys.append(z)                       # length-of-stay regression
            losses.append("lstsq")
        else:
            ys.append(np.where(z > 0, 1.0, -1.0))   # readmission classifier
            losses.append("logistic")
        xs.append(x)
    return SimProblem(xs, ys, losses, "nuclear", 0.1), sizes


def main():
    problem, sizes = make_hospitals()
    # three hospitals behind slow links: their delay offset is 5x
    compute = [n * 2e-4 for n in sizes]
    print(f"hospitals: {len(sizes)} cohorts, sizes {sizes.tolist()}")

    net = NetworkModel(delay_offset=2.0, delay_jitter=8.0,
                       compute_time=compute, prox_time=0.05)
    epochs = 15
    sync = simulate_smtl(problem, net, epochs, seed=0)
    async_ = simulate_amtl(problem, net, epochs, seed=0)
    dyn = simulate_amtl(problem, net, epochs, seed=0, dynamic_step=True)

    print(f"[smtl        ] {sync.total_time:8.1f} s   "
          f"objective {sync.objectives[-1]:10.2f}")
    print(f"[amtl        ] {async_.total_time:8.1f} s   "
          f"objective {async_.objectives[-1]:10.2f}")
    print(f"[amtl+dynstep] {dyn.total_time:8.1f} s   "
          f"objective {dyn.objectives[-1]:10.2f}")
    speedup = sync.total_time / async_.total_time
    print(f"asynchrony speedup at equal epochs: {speedup:.2f}x "
          f"(paper Tables I/III direction)")
    assert async_.total_time < sync.total_time
    print("OK: no hospital waits for the slowest link; raw data never "
          "leaves a node (only d-dim model vectors move).")


if __name__ == "__main__":
    main()
