"""The paper's motivating scenario: hospitals with private data, slow links.

    PYTHONPATH=src python examples/hospitals_async.py

12 'hospitals' (task nodes) each hold a private patient cohort of a
different size; 3 hospitals sit behind a slow network.  Heterogeneous
tasks: 6 regression (length-of-stay) + 6 classification (readmission).
Part 1 runs the event-driven simulators and reports wall-clock + objective
for synchronous vs asynchronous optimization, plus the dynamic-step
variant.

Part 2 is the deployment shape the session API exists for: the jitted
batch engine consumes the hospitals' gradient events as an open-ended
stream (chunks of whatever arrives), pays the server prox only at the
decoupled cadence (`prox_every = 4 * event_batch`), checkpoints the live
engine state mid-stream, and — after a simulated server restart — resumes
bitwise.  The engine runs the REAL ragged cohorts: `stack_ragged` pads
them into one `(T, cap, d)` buffer with per-task `row_counts`, every
gradient and minibatch selection masks on each hospital's true cohort
size, and no patient row is thrown away to equalize the tasks.
(Heterogeneous per-task losses stay on the simulator path; the engine
part uses the regression view of all 12 cohorts.)

Part 3 is the live-ingestion loop on top: an `AMTLServer` keeps serving
length-of-stay predictions while hospitals stream labeled feedback —
each accepted `(x, y)` row is both a gradient event and a NEW patient
record, folded into the server's `TaskStore` at the next chunk boundary.
The cohorts grow mid-session (crossing a capacity doubling), and the
grown data demonstrably moves later predictions against a label-free
twin fed the same events.
"""
import tempfile

import numpy as np

from repro.core import NetworkModel, SimProblem, simulate_amtl, simulate_smtl

SLOW = (2, 5, 8)                  # hospitals behind slow links


def make_hospitals(seed=0):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(80, 400, size=12)
    d = 32
    w_shared = rng.standard_normal(d)
    xs, ys, losses = [], [], []
    for i, n in enumerate(sizes):
        x = rng.standard_normal((n, d)) / np.sqrt(d)
        w_t = w_shared + 0.3 * rng.standard_normal(d)
        z = x @ w_t + 0.1 * rng.standard_normal(n)
        if i % 2 == 0:
            ys.append(z)                       # length-of-stay regression
            losses.append("lstsq")
        else:
            ys.append(np.where(z > 0, 1.0, -1.0))   # readmission classifier
            losses.append("logistic")
        xs.append(x)
    return SimProblem(xs, ys, losses, "nuclear", 0.1), sizes


def simulate(problem, sizes):
    """Part 1: wall-clock study on the event-driven simulator."""
    compute = [n * 2e-4 for n in sizes]
    net = NetworkModel(delay_offset=2.0, delay_jitter=8.0,
                       compute_time=compute, prox_time=0.05)
    epochs = 15
    sync = simulate_smtl(problem, net, epochs, seed=0)
    async_ = simulate_amtl(problem, net, epochs, seed=0)
    dyn = simulate_amtl(problem, net, epochs, seed=0, dynamic_step=True)

    print(f"[smtl        ] {sync.total_time:8.1f} s   "
          f"objective {sync.objectives[-1]:10.2f}")
    print(f"[amtl        ] {async_.total_time:8.1f} s   "
          f"objective {async_.objectives[-1]:10.2f}")
    print(f"[amtl+dynstep] {dyn.total_time:8.1f} s   "
          f"objective {dyn.objectives[-1]:10.2f}")
    speedup = sync.total_time / async_.total_time
    print(f"asynchrony speedup at equal epochs: {speedup:.2f}x "
          f"(paper Tables I/III direction)")
    assert async_.total_time < sync.total_time


def ragged_engine_problem(problem):
    """The hospitals' cohorts, ragged, as one padded engine problem."""
    from repro.data import stack_ragged
    xs = [np.asarray(x, np.float32) for x in problem.xs]
    ys = [np.asarray(y, np.float32) for y in problem.ys]
    return stack_ragged(xs, ys, "lstsq", "nuclear", 0.1)


def stream(problem, sizes):
    """Part 2: the jitted engine as a long-lived checkpointed session."""
    import jax
    import jax.numpy as jnp

    from repro import checkpoint
    from repro.core import default_config, make_engine

    ragged = ragged_engine_problem(problem)
    counts = np.asarray(ragged.row_counts)
    assert counts.tolist() == [len(x) for x in problem.xs]
    print(f"[stream      ] ragged cohorts {counts.min()}..{counts.max()} "
          f"padded to cap {ragged.xs.shape[1]} "
          f"({counts.sum()} of {ragged.num_tasks * ragged.xs.shape[1]} "
          f"rows valid)")

    # Engine selection through default_config's validated kwargs: batched
    # events, server prox every 4 batches (one (d, T) SVT per 32 events),
    # SGD-AMTL forward steps — each activation computes its gradient on a
    # seeded 32-patient minibatch of ITS OWN cohort (the masked selection
    # never touches padding; unbiased (n_t/32)-scaled; the restart
    # contract below is unchanged because the per-event sampling seeds
    # are re-derived from the checkpointed PRNG chain, not stored).
    cfg = default_config(ragged, tau=8, engine="batch", event_batch=8,
                         prox_every=32, dynamic_step=True, batch_size=32)
    engine = make_engine(ragged, cfg)

    # Slow hospitals read at ~5x the mean staleness of the fast ones.
    offsets = jnp.asarray([5.0 if i in SLOW else 1.0
                           for i in range(ragged.num_tasks)], jnp.float32)

    key = jax.random.PRNGKey(0)
    w0 = jnp.zeros((ragged.dim, ragged.num_tasks), jnp.float32)
    obj0 = float(ragged.objective(w0))

    # The stream: 30 chunks of 64 events arrive; the server dies after 15.
    chunk, n_chunks = 64, 30
    state = engine.init(w0, key)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        for _ in range(n_chunks // 2):
            state = engine.run(state, offsets, chunk)
        checkpoint.save(ckpt_dir, int(state.event), state)
        print(f"[stream      ] checkpoint at event {int(state.event)}")
        state = checkpoint.restore(ckpt_dir, checkpoint.latest_step(ckpt_dir),
                                   like=engine.init(w0, key))
        for _ in range(n_chunks - n_chunks // 2):
            state = engine.run(state, offsets, chunk)

    # Reference: the same session without the restart — must match bitwise.
    ref = engine.run(engine.init(w0, key), offsets, n_chunks * chunk)
    assert np.array_equal(np.asarray(engine.iterate(state)),
                          np.asarray(engine.iterate(ref)))

    from repro.core import backward
    w = backward(ragged, engine.iterate(state), cfg.eta)
    obj = float(ragged.objective(w))
    print(f"[stream      ] {int(state.event)} events, objective "
          f"{obj0:.1f} -> {obj:.1f} (restart was bitwise-invisible)")
    assert obj < obj0


def feedback(problem, sizes):
    """Part 3: learn-while-serve with label-carrying feedback ingestion."""
    import jax
    import jax.numpy as jnp

    from repro.core import default_config
    from repro.serve import AMTLServer, ServeConfig

    ragged = ragged_engine_problem(problem)
    cfg = default_config(ragged, tau=8, engine="batch", event_batch=8,
                         prox_every=8)
    w0 = jnp.zeros((ragged.dim, ragged.num_tasks), jnp.float32)
    serve_cfg = ServeConfig(chunk_events=32)
    server = AMTLServer(ragged, cfg, w0, jax.random.PRNGKey(1), serve_cfg)
    twin = AMTLServer(ragged, cfg, w0, jax.random.PRNGKey(1), serve_cfg)

    rng = np.random.default_rng(42)
    n_queries = 8
    q_t = rng.integers(0, ragged.num_tasks, size=n_queries)
    q_x = (rng.standard_normal((n_queries, ragged.dim))
           / np.sqrt(ragged.dim)).astype(np.float32)

    cap0 = server.problem.xs.shape[1]
    busy = int(np.argmax(sizes))           # the busiest hospital admits most
    for _ in range(24):
        k = 32
        fb_t = np.full(k, busy, np.int64)
        fb_t[: k // 2] = rng.integers(0, ragged.num_tasks, size=k // 2)
        fb_x = (rng.standard_normal((k, ragged.dim))
                / np.sqrt(ragged.dim)).astype(np.float32)
        fb_y = fb_x @ rng.standard_normal(ragged.dim).astype(np.float32)
        # server ingests the labeled rows; the twin gets the same EVENTS
        # with no data — isolating what the grown cohorts contribute
        server.submit_feedback(fb_t, fb_x, fb_y)
        twin.submit_feedback(fb_t)
        server.step()
        twin.step()

    grown = server._store
    print(f"[feedback    ] {grown.num_rows - int(np.sum(sizes))} new "
          f"patient rows ingested at chunk boundaries; busiest hospital "
          f"{sizes[busy]} -> {grown.row_counts[busy]} rows; buffer "
          f"capacity {cap0} -> {grown.capacity} (power-of-two doubling)")
    assert grown.capacity > cap0
    assert server.chunk_log == twin.chunk_log

    p_grown = np.asarray(server.predict(q_t, q_x))
    p_twin = np.asarray(twin.predict(q_t, q_x))
    drift = float(np.max(np.abs(p_grown - p_twin)))
    print(f"[feedback    ] same events, +/- the ingested rows: predictions "
          f"moved by up to {drift:.4f}")
    assert drift > 0.0


def main():
    problem, sizes = make_hospitals()
    print(f"hospitals: {len(sizes)} cohorts, sizes {sizes.tolist()}")
    simulate(problem, sizes)
    stream(problem, sizes)
    feedback(problem, sizes)
    print("OK: no hospital waits for the slowest link; raw data never "
          "leaves a node (only d-dim model vectors move); cohorts of any "
          "size join unpadded and keep growing mid-session; the server "
          "checkpoints and resumes mid-stream without perturbing the "
          "event sequence.")


if __name__ == "__main__":
    main()
