"""The paper's motivating scenario: hospitals with private data, slow links.

    PYTHONPATH=src python examples/hospitals_async.py

12 'hospitals' (task nodes) each hold a private patient cohort of a
different size; 3 hospitals sit behind a slow network.  Heterogeneous
tasks: 6 regression (length-of-stay) + 6 classification (readmission).
Part 1 runs the event-driven simulators and reports wall-clock + objective
for synchronous vs asynchronous optimization, plus the dynamic-step
variant.

Part 2 is the deployment shape the session API exists for: the jitted
batch engine consumes the hospitals' gradient events as an open-ended
stream (chunks of whatever arrives), pays the server prox only at the
decoupled cadence (`prox_every = 4 * event_batch`), checkpoints the live
engine state mid-stream, and — after a simulated server restart — resumes
bitwise.  The engine path uses an equal-cohort stacked copy of the data
(ragged cohorts are simulator-only for now, see ROADMAP) with the slow
hospitals modeled as `delay_offsets` staleness.
"""
import tempfile

import numpy as np

from repro.core import NetworkModel, SimProblem, simulate_amtl, simulate_smtl

SLOW = (2, 5, 8)                  # hospitals behind slow links


def make_hospitals(seed=0):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(80, 400, size=12)
    d = 32
    w_shared = rng.standard_normal(d)
    xs, ys, losses = [], [], []
    for i, n in enumerate(sizes):
        x = rng.standard_normal((n, d)) / np.sqrt(d)
        w_t = w_shared + 0.3 * rng.standard_normal(d)
        z = x @ w_t + 0.1 * rng.standard_normal(n)
        if i % 2 == 0:
            ys.append(z)                       # length-of-stay regression
            losses.append("lstsq")
        else:
            ys.append(np.where(z > 0, 1.0, -1.0))   # readmission classifier
            losses.append("logistic")
        xs.append(x)
    return SimProblem(xs, ys, losses, "nuclear", 0.1), sizes


def simulate(problem, sizes):
    """Part 1: wall-clock study on the event-driven simulator."""
    compute = [n * 2e-4 for n in sizes]
    net = NetworkModel(delay_offset=2.0, delay_jitter=8.0,
                       compute_time=compute, prox_time=0.05)
    epochs = 15
    sync = simulate_smtl(problem, net, epochs, seed=0)
    async_ = simulate_amtl(problem, net, epochs, seed=0)
    dyn = simulate_amtl(problem, net, epochs, seed=0, dynamic_step=True)

    print(f"[smtl        ] {sync.total_time:8.1f} s   "
          f"objective {sync.objectives[-1]:10.2f}")
    print(f"[amtl        ] {async_.total_time:8.1f} s   "
          f"objective {async_.objectives[-1]:10.2f}")
    print(f"[amtl+dynstep] {dyn.total_time:8.1f} s   "
          f"objective {dyn.objectives[-1]:10.2f}")
    speedup = sync.total_time / async_.total_time
    print(f"asynchrony speedup at equal epochs: {speedup:.2f}x "
          f"(paper Tables I/III direction)")
    assert async_.total_time < sync.total_time


def stream(problem, sizes):
    """Part 2: the jitted engine as a long-lived checkpointed session."""
    import jax
    import jax.numpy as jnp

    from repro import checkpoint
    from repro.core import MTLProblem, default_config, make_engine

    # Stacked equal-cohort copy: trim every cohort to the smallest one.
    # (Heterogeneous losses / ragged cohorts stay on the simulator path.)
    n_min = int(min(sizes))
    xs = jnp.asarray(np.stack([x[:n_min] for x in problem.xs]), jnp.float32)
    ys = jnp.asarray(np.stack([np.asarray(y[:n_min], np.float64)
                               for y in problem.ys]), jnp.float32)
    stacked = MTLProblem(xs, ys, "lstsq", "nuclear", 0.1)

    # Engine selection through default_config's validated kwargs: batched
    # events, server prox every 4 batches (one (d, T) SVT per 32 events),
    # SGD-AMTL forward steps — each activation computes its gradient on a
    # seeded 32-patient minibatch of the cohort instead of all n_min rows
    # (unbiased (n/32)-scaled; the restart contract below is unchanged
    # because the per-event sampling seeds are re-derived from the
    # checkpointed PRNG chain, not stored).
    cfg = default_config(stacked, tau=8, engine="batch", event_batch=8,
                         prox_every=32, dynamic_step=True, batch_size=32)
    engine = make_engine(stacked, cfg)

    # Slow hospitals read at ~5x the mean staleness of the fast ones.
    offsets = jnp.asarray([5.0 if i in SLOW else 1.0
                           for i in range(stacked.num_tasks)], jnp.float32)

    key = jax.random.PRNGKey(0)
    w0 = jnp.zeros((stacked.dim, stacked.num_tasks), jnp.float32)
    obj0 = float(stacked.objective(w0))

    # The stream: 30 chunks of 64 events arrive; the server dies after 15.
    chunk, n_chunks = 64, 30
    state = engine.init(w0, key)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        for _ in range(n_chunks // 2):
            state = engine.run(state, offsets, chunk)
        checkpoint.save(ckpt_dir, int(state.event), state)
        print(f"[stream      ] checkpoint at event {int(state.event)}")
        state = checkpoint.restore(ckpt_dir, checkpoint.latest_step(ckpt_dir),
                                   like=engine.init(w0, key))
        for _ in range(n_chunks - n_chunks // 2):
            state = engine.run(state, offsets, chunk)

    # Reference: the same session without the restart — must match bitwise.
    ref = engine.run(engine.init(w0, key), offsets, n_chunks * chunk)
    assert np.array_equal(np.asarray(engine.iterate(state)),
                          np.asarray(engine.iterate(ref)))

    from repro.core import backward
    w = backward(stacked, engine.iterate(state), cfg.eta)
    obj = float(stacked.objective(w))
    print(f"[stream      ] {int(state.event)} events, objective "
          f"{obj0:.1f} -> {obj:.1f} (restart was bitwise-invisible)")
    assert obj < obj0


def main():
    problem, sizes = make_hospitals()
    print(f"hospitals: {len(sizes)} cohorts, sizes {sizes.tolist()}")
    simulate(problem, sizes)
    stream(problem, sizes)
    print("OK: no hospital waits for the slowest link; raw data never "
          "leaves a node (only d-dim model vectors move); the server "
          "checkpoints and resumes mid-stream without perturbing the "
          "event sequence.")


if __name__ == "__main__":
    main()
