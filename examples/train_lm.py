"""End-to-end driver: train a ~100M-param LM with mesh-AMTL MTL heads.

    PYTHONPATH=src python examples/train_lm.py --steps 200          # full
    PYTHONPATH=src python examples/train_lm.py --steps 20 --small   # quick

Uses a granite-family config scaled to ~100M params (12L x 768), the full
production train_step (AdamW + remat + the paper's AMTL head updates with
nuclear-norm coupling), the sharded data pipeline on a host mesh, and
periodic checkpointing.  Prints loss curves for the LM and the MTL probes.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save
from repro.configs import get_config
from repro.core.mtl_head import head_weights
from repro.data import ShardedBatcher, synthetic_lm_batches
from repro.launch.steps import (default_optimizer, init_train_state,
                                make_train_step)


def build_config(small: bool):
    base = get_config("granite-8b")
    if small:
        return dataclasses.replace(
            base, name="granite-20m", num_layers=4, d_model=256,
            num_heads=4, num_kv_heads=2, head_dim=64, d_ff=1024,
            vocab_size=8192, num_periods=4, dtype="float32")
    # ~100M: 12 x (d=768, ff=3072), vocab 16384
    return dataclasses.replace(
        base, name="granite-100m", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, head_dim=64, d_ff=3072,
        vocab_size=16384, num_periods=12, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = build_config(args.small)
    opt = default_optimizer(cfg, lr=3e-4, total_steps=args.steps)
    state = init_train_state(jax.random.PRNGKey(0), cfg, opt)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{cfg.num_layers}L x d{cfg.d_model}, vocab {cfg.vocab_size}")

    step_fn = jax.jit(make_train_step(cfg, opt, remat=True),
                      donate_argnums=0)
    data = ShardedBatcher(synthetic_lm_batches(
        cfg.vocab_size, args.seq, args.batch, cfg.mtl.num_tasks, seed=1))

    t0 = time.time()
    for i, batch in zip(range(args.steps), data):
        state, m = step_fn(state, batch)
        if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(m['loss']):7.4f}  "
                  f"lm {float(m['lm_loss']):7.4f}  "
                  f"probe {float(m['probe_loss']):8.5f}  "
                  f"Vnorm {float(m['mtl_v_norm']):7.4f}  "
                  f"({time.time()-t0:5.1f}s)")
    save(args.ckpt, int(state.step), state.params)
    w = head_weights(state.mtl, cfg.mtl)
    s = jnp.linalg.svd(w.astype(jnp.float32), compute_uv=False)
    print(f"checkpoint saved to {args.ckpt}; MTL head singular values "
          f"(nuclear coupling): {[round(float(x),4) for x in s[:6]]}")


if __name__ == "__main__":
    main()
