"""Paper Table III: AMTL vs SMTL on public-dataset-shaped workloads
(School: 139 ragged regression tasks; MNIST-like: 5 binary tasks d=100;
MTFL-like: 4 binary tasks d=10)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timed
from repro.core import NetworkModel, SimProblem, simulate_amtl, simulate_smtl
from repro.data import make_mnist_like, make_school_like


def _mtfl_like(seed=0):
    rng = np.random.default_rng(seed)
    sizes = [2224, 4000, 8000, 10000]
    dim = 10
    w = rng.standard_normal(dim)
    xs, ys = [], []
    for n in sizes:
        x = rng.standard_normal((n, dim)) / np.sqrt(dim)
        ys.append(np.where(x @ (w + 0.4 * rng.standard_normal(dim)) > 0,
                           1.0, -1.0))
        xs.append(x)
    return SimProblem(xs, ys, "logistic", "nuclear", 0.05)


def run() -> list[Row]:
    rows = []
    datasets = {"school": make_school_like(), "mnist": make_mnist_like(),
                "mtfl": _mtfl_like()}
    epochs = {"school": 3, "mnist": 5, "mtfl": 5}
    # School carries 139 serialized server proxes per epoch; with the
    # conservative 20 ms prox model the server (not the network)
    # bottlenecks and the async queue inverts.  A realistic prox cost for
    # a 28x139 SVD (~0.1 ms) restores the paper's ordering — report both
    # regimes (EXPERIMENTS.md §Paper-claims).
    datasets["school_fastprox"] = datasets["school"]
    epochs["school_fastprox"] = 3
    prox_times = {"school_fastprox": 1e-4}
    # second mitigation, beyond-paper but suggested by the paper's own
    # Sec. III-C: batch the server prox every K writes (K=5) so the
    # serialized SVT stops bottlenecking the T=139 async queue
    datasets["school_proxbatch"] = datasets["school"]
    epochs["school_proxbatch"] = 3
    amtl_kw = {"school_proxbatch": {"prox_every": 5, "eta_k": 1.0}}
    for dname, prob in datasets.items():
        for offset in (1.0, 2.0, 3.0):
            net = NetworkModel(delay_offset=offset, compute_time=0.05,
                               prox_time=prox_times.get(dname, 0.02))
            ra, us_a = timed(lambda: simulate_amtl(
                prob, net, epochs[dname], seed=1, record_objective=False,
                **amtl_kw.get(dname, {})))
            rs, us_s = timed(lambda: simulate_smtl(
                prob, net, epochs[dname], seed=1, record_objective=False))
            rows.append(Row(f"table3/AMTL-{offset:g}_{dname}", us_a,
                            f"sim_time_s={ra.total_time:.2f}"))
            rows.append(Row(f"table3/SMTL-{offset:g}_{dname}", us_s,
                            f"sim_time_s={rs.total_time:.2f}"))
    return rows
