"""Paper Fig. 3: computation time scaling in (a) #tasks, (b) sample size,
(c) dimensionality — AMTL vs SMTL at fixed iterations."""
from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import NetworkModel, make_synthetic, simulate_amtl, \
    simulate_smtl

NET = NetworkModel(delay_offset=1.0, compute_time=0.05, prox_time=0.02)
EPOCHS = 5


def _pair(rows, tag, prob):
    ra, us_a = timed(lambda: simulate_amtl(prob, NET, EPOCHS, seed=1,
                                           record_objective=False))
    rs, us_s = timed(lambda: simulate_smtl(prob, NET, EPOCHS, seed=1,
                                           record_objective=False))
    rows.append(Row(f"fig3/{tag}_amtl", us_a,
                    f"sim_time_s={ra.total_time:.2f}"))
    rows.append(Row(f"fig3/{tag}_smtl", us_s,
                    f"sim_time_s={rs.total_time:.2f}"))


def run() -> list[Row]:
    rows: list[Row] = []
    for t in (5, 25, 50, 100):                      # (a) tasks
        _pair(rows, f"tasks{t}", make_synthetic(t, 100, 50, seed=0))
    for n in (100, 500, 1000):                      # (b) samples
        _pair(rows, f"samples{n}", make_synthetic(5, n, 50, seed=0))
    for d in (50, 200, 500):                        # (c) dims
        _pair(rows, f"dim{d}", make_synthetic(5, 100, d, seed=0))
    return rows
