"""Paper Table I: AMTL vs SMTL wall-clock under delay offsets 5/10/30 s for
5/10/15 tasks (synthetic: 100 samples, d=50, nuclear norm)."""
from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import NetworkModel, make_synthetic, simulate_amtl, \
    simulate_smtl

EPOCHS = 10


def run() -> list[Row]:
    rows = []
    for tasks in (5, 10, 15):
        prob = make_synthetic(num_tasks=tasks, samples=100, dim=50, seed=0)
        for offset in (5.0, 10.0, 30.0):
            net = NetworkModel(delay_offset=offset, compute_time=0.1,
                               prox_time=0.05)
            ra, us_a = timed(lambda: simulate_amtl(
                prob, net, EPOCHS, seed=1, record_objective=False))
            rs, us_s = timed(lambda: simulate_smtl(
                prob, net, EPOCHS, seed=1, record_objective=False))
            rows.append(Row(f"table1/AMTL-{offset:g}_tasks{tasks}", us_a,
                            f"sim_time_s={ra.total_time:.2f}"))
            rows.append(Row(f"table1/SMTL-{offset:g}_tasks{tasks}", us_s,
                            f"sim_time_s={rs.total_time:.2f}"))
    return rows
