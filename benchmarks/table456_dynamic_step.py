"""Paper Tables IV-VI: objective after a fixed iteration budget, with vs
without the delay-adaptive dynamic step size (5/10/15 tasks, offsets
5/10/15/20 s)."""
from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import NetworkModel, make_synthetic, simulate_amtl

EPOCHS = 10


def run() -> list[Row]:
    rows = []
    for tasks in (5, 10, 15):
        prob = make_synthetic(num_tasks=tasks, samples=100, dim=50, seed=0)
        for offset in (5.0, 10.0, 15.0, 20.0):
            net = NetworkModel(delay_offset=offset, compute_time=0.1,
                               prox_time=0.05)
            rf, us_f = timed(lambda: simulate_amtl(
                prob, net, EPOCHS, seed=3, dynamic_step=False))
            rd, us_d = timed(lambda: simulate_amtl(
                prob, net, EPOCHS, seed=3, dynamic_step=True))
            rows.append(Row(
                f"table456/fixed_AMTL-{offset:g}_tasks{tasks}", us_f,
                f"objective={rf.objectives[-1]:.2f}"))
            rows.append(Row(
                f"table456/dynamic_AMTL-{offset:g}_tasks{tasks}", us_d,
                f"objective={rd.objectives[-1]:.2f}"))
    return rows
