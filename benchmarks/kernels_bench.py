"""Kernel micro-benchmarks: fused ops vs unfused jnp chains on CPU.

On this container the Pallas TPU kernels only run in interpret mode (not a
performance mode), so the timing compares the FUSED reference (what the
kernel computes in one pass) against the UNFUSED multi-pass jnp chain —
the fusion payoff the kernel encodes, measurable on any backend.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import Row
from repro.kernels import ref


def _bench(fn, *args, iters=20) -> float:
    # single warm-up call; block_until_ready handles tuple/pytree returns
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


@jax.jit
def _km_unfused(v, p, g, eta, eta_k):
    step = p - eta * g          # pass 1
    delta = step - v            # pass 2
    return v + eta_k * delta    # pass 3


@jax.jit
def _km_fused(v, p, g, eta, eta_k):
    return ref.km_update_ref(v, p, g, eta, eta_k)


@jax.jit
def _amtl_event_unfused(v, p, g, eta, eta_k):
    step = p - eta * g              # pass 1
    delta = step - v                # pass 2
    v_new = v + eta_k * delta       # pass 3
    old = v + 0.0                   # separate undo-log copy pass
    return v_new, old


@jax.jit
def _amtl_event_fused(v, p, g, eta, eta_k):
    return ref.amtl_event_ref(v, p, g, eta, eta_k)


@jax.jit
def _lstsq_unfused(x, w, y):
    pred = x @ w
    r = pred - y
    return 2.0 * (x.T @ r)


@jax.jit
def _lstsq_fused(x, w, y):
    return ref.lstsq_grad_ref(x, w, y)


def run() -> list[Row]:
    rows = []
    k = jax.random.PRNGKey(0)
    d, t = 8192, 128
    v, p, g = (jax.random.normal(kk, (d, t)) for kk in jax.random.split(k, 3))
    eta = jnp.asarray(0.05)
    eta_k = jnp.asarray(0.8)
    us_u = _bench(_km_unfused, v, p, g, eta, eta_k)
    us_f = _bench(_km_fused, v, p, g, eta, eta_k)
    rows.append(Row("kernels/km_update_unfused", us_u, f"d={d}xT={t}"))
    rows.append(Row("kernels/km_update_fused", us_f,
                    f"speedup={us_u / max(us_f, 1e-9):.2f}x"))

    d_col = 8192
    kv, kp, kg = jax.random.split(jax.random.PRNGKey(1), 3)
    vc, pc, gc = (jax.random.normal(kk, (d_col,)) for kk in (kv, kp, kg))
    us_u = _bench(_amtl_event_unfused, vc, pc, gc, eta, eta_k)
    us_f = _bench(_amtl_event_fused, vc, pc, gc, eta, eta_k)
    rows.append(Row("kernels/amtl_event_unfused", us_u, f"d={d_col}"))
    rows.append(Row("kernels/amtl_event_fused", us_f,
                    f"speedup={us_u / max(us_f, 1e-9):.2f}x"))

    n, dd = 8192, 512
    kx, kw, ky = jax.random.split(k, 3)
    x = jax.random.normal(kx, (n, dd)) / jnp.sqrt(dd)
    w = jax.random.normal(kw, (dd,))
    y = jax.random.normal(ky, (n,))
    us_u = _bench(_lstsq_unfused, x, w, y)
    us_f = _bench(_lstsq_fused, x, w, y)
    rows.append(Row("kernels/lstsq_grad_unfused", us_u, f"n={n}xd={dd}"))
    rows.append(Row("kernels/lstsq_grad_fused", us_f,
                    f"speedup={us_u / max(us_f, 1e-9):.2f}x"))

    wmat = jax.random.normal(k, (8192, 64))
    us = _bench(jax.jit(lambda a: ref.l21_prox_ref(a, jnp.asarray(0.3))),
                wmat)
    rows.append(Row("kernels/l21_prox", us, "d=8192xT=64"))
    return rows
