"""SGD-AMTL (the paper's §V stated future work, implemented here):
minibatch asynchronous coordinate updates vs full-gradient AMTL at EQUAL
WALL-CLOCK.

Finding (EXPERIMENTS.md §Paper-claims): every asynchronous cycle pays the
network delay once, so cheap minibatch gradients only help when gradient
compute dominates the delay — in the compute-bound regime SGD-AMTL
pipelines ~n/b more KM writes into the same wall-clock and reaches a
lower objective; in the delay-bound regime it degenerates to
noisier-but-not-faster and loses.  Both regimes are reported.

The `engine_*` rows re-measure the compute-bound finding on the JITTED
path (`AMTLConfig(batch_size=...)`, the seeded in-kernel selection of
PR 6) instead of the numpy simulator: the minibatch engine's measured
events/sec sets how many extra events fit the full-gradient run's
wall-clock, and the objective it reaches in that budget is reported next
to the full-gradient objective at equal wall-clock.
"""
from __future__ import annotations

import time

from benchmarks.common import Row, timed
from repro.core import NetworkModel, make_synthetic, simulate_amtl

EPOCHS = 10
SAMPLES = 200

# engine-backed row: large-n stacked problem, jitted delta engine
E_TASKS, E_SAMPLES, E_DIM, E_BSZ, E_EVENTS = 8, 512, 1024, 32, 256


def _engine_rows() -> list[Row]:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import AMTLConfig, MTLProblem, amtl_max_step
    from repro.core.amtl import amtl_events_only, current_iterate

    kx, ky = jax.random.split(jax.random.PRNGKey(0))
    xs = jax.random.normal(kx, (E_TASKS, E_SAMPLES, E_DIM)) / np.sqrt(E_DIM)
    ys = jax.random.normal(ky, (E_TASKS, E_SAMPLES))
    problem = MTLProblem(xs, ys, "lstsq", "nuclear", 0.1)
    cfg_full = AMTLConfig(eta=1.0 / problem.lipschitz(),
                          eta_k=amtl_max_step(4, E_TASKS), tau=4,
                          engine="delta", prox_every=8, prox_rank=8)
    cfg_sgd = cfg_full._replace(batch_size=E_BSZ)
    w0 = jnp.zeros((E_DIM, E_TASKS), jnp.float32)
    key = jax.random.PRNGKey(7)

    def eps(cfg, events):
        run_ = lambda: jax.block_until_ready(
            amtl_events_only(problem, cfg, w0, key, events))
        run_()                              # compile + warm-up
        t0 = time.perf_counter()
        st = run_()
        return events / (time.perf_counter() - t0), st

    full_eps, full_st = eps(cfg_full, E_EVENTS)
    sgd_eps, _ = eps(cfg_sgd, E_EVENTS)
    # equal wall-clock: the minibatch engine fits speedup-times more
    # events into the full-gradient run's budget
    sgd_events = max(1, int(E_EVENTS * sgd_eps / full_eps))
    _, sgd_st = eps(cfg_sgd, sgd_events)
    obj_full = float(problem.objective(current_iterate(full_st)))
    obj_sgd = float(problem.objective(current_iterate(sgd_st)))
    return [
        Row("sgd_amtl/engine_full", 1e6 / full_eps,
            f"events={E_EVENTS};events_per_sec={full_eps:.1f};"
            f"objective={obj_full:.3f}"),
        Row(f"sgd_amtl/engine_b{E_BSZ}_equalwallclock", 1e6 / sgd_eps,
            f"events={sgd_events};events_per_sec={sgd_eps:.1f};"
            f"speedup={sgd_eps / full_eps:.2f}x;objective={obj_sgd:.3f}"),
    ]


def run() -> list[Row]:
    rows = _engine_rows()
    regimes = {
        "computebound": NetworkModel(delay_offset=0.05, delay_jitter=0.05,
                                     compute_time=2.0, prox_time=0.01),
        "delaybound": NetworkModel(delay_offset=2.0, delay_jitter=0.5,
                                   compute_time=0.5, prox_time=0.01),
    }
    for regime, net in regimes.items():
        for tasks in (5, 10):
            prob = make_synthetic(num_tasks=tasks, samples=SAMPLES, dim=50,
                                  seed=0)
            r_full, us_f = timed(lambda: simulate_amtl(
                prob, net, EPOCHS, eta_k=1.0, seed=1,
                record_objective=False))
            budget = r_full.total_time
            rows.append(Row(f"sgd_amtl/{regime}_full_tasks{tasks}", us_f,
                            f"sim_time_s={budget:.2f};"
                            f"objective={prob.objective(r_full.w):.3f}"))
            for bsz in (25, 50):
                # cycles that fit the SAME wall-clock budget
                cyc_t = (net.node_compute(0) * bsz / SAMPLES
                         + net.delay_offset + net.delay_jitter / 2
                         + net.prox_time)
                cycles = max(1, int(budget / cyc_t))
                r_sgd, us_s = timed(lambda: simulate_amtl(
                    prob, net, cycles, eta_k=1.0, seed=1,
                    record_objective=False, batch_size=bsz))
                rows.append(Row(
                    f"sgd_amtl/{regime}_b{bsz}_tasks{tasks}", us_s,
                    f"sim_time_s={r_sgd.total_time:.2f};"
                    f"objective={prob.objective(r_sgd.w):.3f}"))
    return rows
