"""SGD-AMTL (the paper's §V stated future work, implemented here):
minibatch asynchronous coordinate updates vs full-gradient AMTL at EQUAL
WALL-CLOCK.

Finding (EXPERIMENTS.md §Paper-claims): every asynchronous cycle pays the
network delay once, so cheap minibatch gradients only help when gradient
compute dominates the delay — in the compute-bound regime SGD-AMTL
pipelines ~n/b more KM writes into the same wall-clock and reaches a
lower objective; in the delay-bound regime it degenerates to
noisier-but-not-faster and loses.  Both regimes are reported.
"""
from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import NetworkModel, make_synthetic, simulate_amtl

EPOCHS = 10
SAMPLES = 200


def run() -> list[Row]:
    rows = []
    regimes = {
        "computebound": NetworkModel(delay_offset=0.05, delay_jitter=0.05,
                                     compute_time=2.0, prox_time=0.01),
        "delaybound": NetworkModel(delay_offset=2.0, delay_jitter=0.5,
                                   compute_time=0.5, prox_time=0.01),
    }
    for regime, net in regimes.items():
        for tasks in (5, 10):
            prob = make_synthetic(num_tasks=tasks, samples=SAMPLES, dim=50,
                                  seed=0)
            r_full, us_f = timed(lambda: simulate_amtl(
                prob, net, EPOCHS, eta_k=1.0, seed=1,
                record_objective=False))
            budget = r_full.total_time
            rows.append(Row(f"sgd_amtl/{regime}_full_tasks{tasks}", us_f,
                            f"sim_time_s={budget:.2f};"
                            f"objective={prob.objective(r_full.w):.3f}"))
            for bsz in (25, 50):
                # cycles that fit the SAME wall-clock budget
                cyc_t = (net.node_compute(0) * bsz / SAMPLES
                         + net.delay_offset + net.delay_jitter / 2
                         + net.prox_time)
                cycles = max(1, int(budget / cyc_t))
                r_sgd, us_s = timed(lambda: simulate_amtl(
                    prob, net, cycles, eta_k=1.0, seed=1,
                    record_objective=False, batch_size=bsz))
                rows.append(Row(
                    f"sgd_amtl/{regime}_b{bsz}_tasks{tasks}", us_s,
                    f"sim_time_s={r_sgd.total_time:.2f};"
                    f"objective={prob.objective(r_sgd.w):.3f}"))
    return rows
