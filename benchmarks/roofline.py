"""Roofline analysis from the dry-run JSONL (deliverable (g)).

Per (arch x shape) on the single-pod mesh:
  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = collective_bytes_per_device / (links * link_bw)

(cost_analysis on the SPMD-partitioned module reports PER-DEVICE flops and
bytes, so no further division by chip count is needed.)

TWO dry-run artifacts feed this report:
  - dryrun_single_unrolled.jsonl  (scan fully unrolled): flops / bytes /
    collective bytes.  Required because XLA's cost_analysis counts a
    while-loop body ONCE, not x trip count — a scanned 36-layer model
    reports ~1/36 of its real flops (verified; EXPERIMENTS.md §Dry-run).
  - dryrun_single.jsonl  (production lax.scan): temp bytes per device (the
    "fits in HBM" story — the scanned module is what would actually run).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI with 4 links usable per chip on a 2D torus (2 per in-mesh axis).
MODEL_FLOPS = 6*N(_active)*D tokens (train), 2*N*D (prefill/decode).
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_LINK_BW = 50e9           # bytes/s per link
ICI_LINKS = 4                # usable links per chip (2D torus)

TOKENS = {"train_4k": 4096 * 256, "prefill_32k": 32768 * 32,
          "decode_32k": 128, "long_500k": 1}
TRAIN_MULT = {"train_4k": 3, "prefill_32k": 1, "decode_32k": 1,
              "long_500k": 1}   # fwd+bwd = 3x fwd FLOPs


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float
    hlo_flops_total: float
    useful_frac: float
    temp_gb: float
    memory_s_tpu: float = 0.0   # memory term minus bf16->f32 convert traffic
    #                             (XLA:CPU artifact; TPU runs bf16 natively)

    def table_row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.compute_s:.2e} | "
                f"{self.memory_s:.2e} | {self.memory_s_tpu:.2e} | "
                f"{self.collective_s:.2e} | "
                f"**{self.bottleneck}** | {self.useful_frac:.2f} | "
                f"{self.temp_gb:.1f} |")


def analyse(row: dict, chips: int = 256,
            temp_bytes: float | None = None) -> RooflineRow | None:
    if row.get("status") != "ok":
        return None
    flops_dev = row.get("flops_per_device") or 0.0
    bytes_dev = row.get("bytes_per_device") or 0.0
    coll = row.get("collective_bytes") or {}
    coll_dev = sum(coll.values())
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    # lower bound: with fused converts the estimate can exceed the
    # aggregate count, clamping to 0 — the true TPU memory term lies in
    # [memory_s_tpu, memory_s]; the bottleneck label uses the raw upper
    # bound (consistent, and conservative for memory)
    memory_s_tpu = max(bytes_dev - (row.get("convert_bytes") or 0.0),
                       0.0) / HBM_BW
    collective_s = coll_dev / (ICI_LINKS * ICI_LINK_BW)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    n_active = row.get("params_active") or 0.0
    model_flops = (TRAIN_MULT[row["shape"]] * 2.0 * n_active
                   * TOKENS[row["shape"]])
    hlo_total = flops_dev * chips
    useful = model_flops / hlo_total if hlo_total else 0.0
    tb = temp_bytes if temp_bytes is not None else row.get("temp_bytes")
    return RooflineRow(
        arch=row["arch"], shape=row["shape"], mesh=row["mesh"],
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, model_flops=model_flops,
        hlo_flops_total=hlo_total, useful_frac=useful,
        temp_gb=(tb or 0) / 2 ** 30, memory_s_tpu=memory_s_tpu)


def load(path: str) -> list[dict]:
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    # keep the LAST occurrence per combo (re-runs supersede)
    dedup: dict[tuple, dict] = {}
    for r in rows:
        dedup[(r["arch"], r["shape"], r["mesh"])] = r
    return list(dedup.values())


def report(path: str = "dryrun_single_unrolled.jsonl",
           scan_path: str = "dryrun_single.jsonl") -> str:
    """path: unrolled run (cost terms); scan_path: production-scan run
    (temp bytes).  Falls back to single-file mode if one is missing."""
    if not os.path.exists(path) and os.path.exists(scan_path):
        path = scan_path
    rows = load(path)
    scan_temp = {}
    if scan_path != path and os.path.exists(scan_path):
        scan_temp = {(r["arch"], r["shape"]): r.get("temp_bytes")
                     for r in load(scan_path) if r.get("status") == "ok"}
    lines = ["| arch | shape | compute_s | memory_s | memory_s(tpu) | "
             "collective_s | bottleneck | useful_frac | temp_GB(scan) |",
             "|---|---|---|---|---|---|---|---|---|"]
    skips = []
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        rr = analyse(r, temp_bytes=scan_temp.get((r["arch"], r["shape"])))
        if rr is None:
            skips.append(f"| {r['arch']} | {r['shape']} | "
                         f"{r.get('reason', r.get('error', '?'))} |")
            continue
        lines.append(rr.table_row())
    out = "\n".join(lines)
    if skips:
        out += ("\n\nSkipped combos (documented, DESIGN.md §4):\n"
                "| arch | shape | reason |\n|---|---|---|\n"
                + "\n".join(skips))
    return out


def main() -> None:
    import sys
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_single_unrolled.jsonl"
    print(report(path))


if __name__ == "__main__":
    main()
