"""Paper Fig. 4: objective vs iteration, AMTL vs SMTL (5 and 10 tasks).

Two AMTL step-size regimes are reported (EXPERIMENTS.md §Paper-claims):
  - `theory`:   eta_k = c/(2 tau/sqrt(T)+1), the convergence-guaranteed bound
                of Theorem 1 — heavily damped (~0.17 at T=5), so per-iteration
                progress trails SMTL's full prox-gradient step.
  - `practical`: eta_k = 1.0 (undamped KM), which is what the paper's own
                Fig. 4 implies: AMTL's async Gauss-Seidel-style block updates
                then make "nearly identical progress per iteration" (paper
                Sec. IV-B.1) to SMTL's synchronous Jacobi sweep — reproduced
                here to 3 decimals.
"""
from __future__ import annotations

from benchmarks.common import Row, timed
from repro.core import NetworkModel, make_synthetic, simulate_amtl, \
    simulate_smtl

EPOCHS = 30


def run() -> list[Row]:
    rows = []
    net = NetworkModel(delay_offset=1.0, compute_time=0.05, prox_time=0.02)
    for tasks in (5, 10):
        prob = make_synthetic(num_tasks=tasks, samples=100, dim=50, seed=0)
        variants = {
            "amtl_theory": lambda: simulate_amtl(prob, net, EPOCHS, seed=1),
            "amtl_practical": lambda: simulate_amtl(prob, net, EPOCHS,
                                                    eta_k=1.0, seed=1),
        }
        curves = {}
        for name, fn in variants.items():
            r, us = timed(fn)
            curves[name] = (r.objectives, us)
        rs, us_s = timed(lambda: simulate_smtl(prob, net, EPOCHS, seed=1))
        curves["smtl"] = (rs.objectives, us_s)
        for name, (obj, us) in curves.items():
            for idx, tag in ((len(obj) // 3, "third"),
                             (2 * len(obj) // 3, "two_thirds"), (-1, "final")):
                rows.append(Row(f"fig4/{name}_tasks{tasks}_{tag}", us,
                                f"objective={obj[idx]:.3f}"))
    return rows
