"""AMTL event-engine benchmark: dense ring vs delta ring vs event batch.

Measures events/sec of the jitted event loop (`amtl_events_only`, no
per-epoch metric tail) at the ISSUE's target scale d=8192, T=128, tau=8 on
the CPU oracle path, plus the staleness-state memory footprint of each
engine.  Results are emitted both as CSV rows and as `BENCH_amtl_events.json`
(schema documented in ROADMAP.md "Performance notes") so perf trajectories
can be tracked across PRs.

The dense engine is the seed baseline: full f32 SVD prox + O(d*T) ring write
per event.  The delta engine runs its production configuration: prox
refreshed every PROX_EVERY events via rank-PROX_RANK randomized SVT, O(d)
ring writes.  The batch engine runs EVENT_BATCH events per loop step with
one rank-PROX_RANK prox per batch and batched conflict-aware column
updates — the amortization axis the delta engine pays per event (the prox
`lax.cond` carries a (d, T) cache copy) is hoisted to once per batch.
Because the batch engine's default prox cadence is EVENT_BATCH (not
PROX_EVERY), a `delta_matched` row runs the delta engine at
prox_every=EVENT_BATCH too: `batch_over_delta_matched` isolates the
batching machinery's gain from the cheaper prox schedule, while
`batch_over_delta` is the end-to-end win over the recorded delta
production config.  The `batch_k4` row runs the DECOUPLED prox cadence
(prox_every = 4*EVENT_BATCH, the session API's k=4): one prox refresh per
four batches through the carried (d, T) prox cache;
`speedup.batch_k4_over_batch` quantifies what the cadence decoupling buys
on top of per-batch refreshes.  The `sharded` row runs the batch
configuration with the T task columns partitioned over ALL visible devices
(`config.task_shards`; CI forces 8 fake host devices) — one all_gather +
replicated prox per batch, shard-local column updates.  On fake host
devices the replicated prox multiplies total CPU work, so
`speedup.sharded_over_batch` measures collective/masking overhead there,
not real multi-chip scaling; the row exists to track that overhead across
PRs.  Engine equivalence (bitwise, aligned configs) is covered by
tests/test_amtl_delta.py, tests/test_amtl_batch.py, and
tests/test_amtl_sharded.py, not timed here.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.core import AMTLConfig, MTLProblem, amtl_max_step
from repro.core.amtl import amtl_events_only

D, T, TAU = 8192, 128, 8
N_SAMPLES = 4          # tiny per-task n: the engines, not the grads, dominate
DENSE_EVENTS = 8       # one full SVD per event — keep the baseline affordable
DELTA_EVENTS = 64
BATCH_EVENTS = 256
PROX_EVERY = 8
PROX_RANK = 16
EVENT_BATCH = 32       # CPU sweet spot: larger batches amortize the prox
                       # further but the per-batch gather/scatter fixed cost
                       # grows; 32 maximizes events/sec at this scale
PROX_K = 4             # batch_k4 row: prox_every = PROX_K * EVENT_BATCH
JSON_PATH = "BENCH_amtl_events.json"


def _problem() -> MTLProblem:
    kx, ky = jax.random.split(jax.random.PRNGKey(0))
    xs = jax.random.normal(kx, (T, N_SAMPLES, D)) / np.sqrt(D)
    ys = jax.random.normal(ky, (T, N_SAMPLES))
    return MTLProblem(xs, ys, "lstsq", "nuclear", 0.1)


def _events_per_sec(problem: MTLProblem, cfg: AMTLConfig, events: int,
                    reps: int = 3, mesh=None) -> float:
    v0 = jnp.zeros((D, T), jnp.float32)
    key = jax.random.PRNGKey(7)
    run = lambda: jax.block_until_ready(
        amtl_events_only(problem, cfg, v0, key, events, mesh=mesh))
    run()                                   # compile + warm-up
    best = float("inf")                     # best-of-k: stable under noise
    for _ in range(reps):
        t0 = time.perf_counter()
        run()
        best = min(best, time.perf_counter() - t0)
    return events / best


def _state_bytes(cfg: AMTLConfig, task_shards: int = 1) -> dict:
    itemsize = 4  # f32
    if cfg.engine == "dense":
        ring = (cfg.tau + 1) * D * T * itemsize
        total = ring  # the ring holds every iterate incl. the newest
    else:
        # engine="sharded" keeps one private (tau+1, d) undo ring per
        # shard; aggregate bytes scale with the shard count while the
        # per-device footprint stays the batch engine's.
        ring = (task_shards * (cfg.tau + 1) * D * itemsize
                + (cfg.tau + 1) * 4)
        total = ring + D * T * itemsize                # + v
        # live (d, T) prox cache: delta with any amortization, batch/
        # sharded only at the decoupled cadence (prox_every > event_batch;
        # at the aligned cadence each batch refreshes before reading).
        aligned = cfg.event_batch if cfg.engine in ("batch", "sharded") \
            else 1
        if cfg.prox_every > aligned:
            total += D * T * itemsize
    return {"ring_bytes": ring, "state_bytes": total}


def run() -> list[Row]:
    problem = _problem()
    eta_k = amtl_max_step(TAU, T)
    dense_cfg = AMTLConfig(eta=0.05, eta_k=eta_k, tau=TAU, engine="dense")
    delta_cfg = AMTLConfig(eta=0.05, eta_k=eta_k, tau=TAU, engine="delta",
                           prox_every=PROX_EVERY, prox_rank=PROX_RANK)
    # same prox cadence as the batch engine: isolates the batching gain
    delta_matched_cfg = delta_cfg._replace(prox_every=EVENT_BATCH)
    batch_cfg = AMTLConfig(eta=0.05, eta_k=eta_k, tau=TAU, engine="batch",
                           prox_every=EVENT_BATCH, event_batch=EVENT_BATCH,
                           prox_rank=PROX_RANK)
    # decoupled cadence: one prox per PROX_K batches via the carried cache
    batch_k4_cfg = batch_cfg._replace(prox_every=PROX_K * EVENT_BATCH)

    # task-sharded engine: batch config over all visible devices (T=128 is
    # divisible by any power-of-two host-device count CI uses)
    task_shards = jax.local_device_count()
    from repro.launch.mesh import make_task_mesh
    mesh = make_task_mesh(task_shards)
    sharded_cfg = AMTLConfig(eta=0.05, eta_k=eta_k, tau=TAU,
                             engine="sharded", prox_every=EVENT_BATCH,
                             event_batch=EVENT_BATCH, prox_rank=PROX_RANK)

    dense_eps = _events_per_sec(problem, dense_cfg, DENSE_EVENTS)
    delta_eps = _events_per_sec(problem, delta_cfg, DELTA_EVENTS)
    matched_eps = _events_per_sec(problem, delta_matched_cfg, BATCH_EVENTS)
    batch_eps = _events_per_sec(problem, batch_cfg, BATCH_EVENTS)
    batch_k4_eps = _events_per_sec(problem, batch_k4_cfg, BATCH_EVENTS)
    sharded_eps = _events_per_sec(problem, sharded_cfg, BATCH_EVENTS,
                                  mesh=mesh)
    dense_mem = _state_bytes(dense_cfg)
    delta_mem = _state_bytes(delta_cfg)
    batch_mem = _state_bytes(batch_cfg)
    batch_k4_mem = _state_bytes(batch_k4_cfg)
    sharded_mem = _state_bytes(sharded_cfg, task_shards)
    speedup = {
        "delta_over_dense": delta_eps / max(dense_eps, 1e-12),
        "batch_over_dense": batch_eps / max(dense_eps, 1e-12),
        "batch_over_delta": batch_eps / max(delta_eps, 1e-12),
        "batch_over_delta_matched": batch_eps / max(matched_eps, 1e-12),
        "batch_k4_over_batch": batch_k4_eps / max(batch_eps, 1e-12),
        "sharded_over_batch": sharded_eps / max(batch_eps, 1e-12),
    }

    report = {
        # prox_every is the delta row's cadence; the batch, delta_matched,
        # and sharded rows run at prox cadence event_batch; batch_k4 at
        # prox cadence prox_k * event_batch (decoupled).
        "config": {"d": D, "T": T, "tau": TAU, "n_samples": N_SAMPLES,
                   "prox_every": PROX_EVERY, "prox_rank": PROX_RANK,
                   "event_batch": EVENT_BATCH, "prox_k": PROX_K,
                   "task_shards": task_shards,
                   "backend": jax.default_backend()},
        "dense": {"events_per_sec": dense_eps,
                  "us_per_event": 1e6 / dense_eps, **dense_mem},
        "delta": {"events_per_sec": delta_eps,
                  "us_per_event": 1e6 / delta_eps, **delta_mem},
        "delta_matched": {"events_per_sec": matched_eps,
                          "us_per_event": 1e6 / matched_eps, **delta_mem},
        "batch": {"events_per_sec": batch_eps,
                  "us_per_event": 1e6 / batch_eps, **batch_mem},
        # prox cadence PROX_K * event_batch (the decoupled session cadence)
        "batch_k4": {"events_per_sec": batch_k4_eps,
                     "us_per_event": 1e6 / batch_k4_eps, **batch_k4_mem},
        "sharded": {"events_per_sec": sharded_eps,
                    "us_per_event": 1e6 / sharded_eps, **sharded_mem},
        "speedup": speedup,
        # kept for cross-PR continuity with the PR-1 schema
        "speedup_events_per_sec": speedup["delta_over_dense"],
        "ring_memory_ratio": dense_mem["ring_bytes"] / delta_mem["ring_bytes"],
    }
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2)

    return [
        Row("amtl_events/dense_ring", 1e6 / dense_eps,
            f"events/sec={dense_eps:.2f}"),
        Row("amtl_events/delta_ring", 1e6 / delta_eps,
            f"events/sec={delta_eps:.2f} "
            f"speedup={speedup['delta_over_dense']:.2f}x"),
        Row("amtl_events/delta_matched", 1e6 / matched_eps,
            f"events/sec={matched_eps:.2f} (prox_every={EVENT_BATCH})"),
        Row("amtl_events/event_batch", 1e6 / batch_eps,
            f"events/sec={batch_eps:.2f} "
            f"vs_delta={speedup['batch_over_delta']:.2f}x "
            f"vs_delta_matched={speedup['batch_over_delta_matched']:.2f}x "
            f"vs_dense={speedup['batch_over_dense']:.2f}x"),
        Row("amtl_events/batch_k4", 1e6 / batch_k4_eps,
            f"events/sec={batch_k4_eps:.2f} "
            f"(prox_every={PROX_K * EVENT_BATCH}) "
            f"vs_batch={speedup['batch_k4_over_batch']:.2f}x"),
        Row("amtl_events/sharded", 1e6 / sharded_eps,
            f"events/sec={sharded_eps:.2f} shards={task_shards} "
            f"vs_batch={speedup['sharded_over_batch']:.2f}x"),
        Row("amtl_events/ring_memory", 0.0,
            f"dense={dense_mem['ring_bytes']}B delta={delta_mem['ring_bytes']}B "
            f"ratio={report['ring_memory_ratio']:.0f}x"),
        Row("amtl_events/state_memory", 0.0,
            f"dense={dense_mem['state_bytes']}B "
            f"delta={delta_mem['state_bytes']}B "
            f"batch={batch_mem['state_bytes']}B"),
    ]
