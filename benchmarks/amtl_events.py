"""AMTL event-engine benchmark: dense ring vs delta ring vs event batch.

Measures events/sec of the jitted event loop (`amtl_events_only`, no
per-epoch metric tail) at the ISSUE's target scale d=8192, T=128, tau=8 on
the CPU oracle path, plus the staleness-state memory footprint of each
engine.  Results are emitted both as CSV rows and as `BENCH_amtl_events.json`
(schema documented in ROADMAP.md "Performance notes") so perf trajectories
can be tracked across PRs.

The dense engine is the seed baseline: full f32 SVD prox + O(d*T) ring write
per event.  The delta engine runs its production configuration: prox
refreshed every PROX_EVERY events via rank-PROX_RANK randomized SVT, O(d)
ring writes.  The batch engine runs EVENT_BATCH events per loop step with
one rank-PROX_RANK prox per batch and batched conflict-aware column
updates — the amortization axis the delta engine pays per event (the prox
`lax.cond` carries a (d, T) cache copy) is hoisted to once per batch.
Because the batch engine's default prox cadence is EVENT_BATCH (not
PROX_EVERY), a `delta_matched` row runs the delta engine at
prox_every=EVENT_BATCH too: `batch_over_delta_matched` isolates the
batching machinery's gain from the cheaper prox schedule, while
`batch_over_delta` is the end-to-end win over the recorded delta
production config.  The `batch_k4` row runs the DECOUPLED prox cadence
(prox_every = 4*EVENT_BATCH, the session API's k=4): one prox refresh per
four batches through the carried (d, T) prox cache;
`speedup.batch_k4_over_batch` quantifies what the cadence decoupling buys
on top of per-batch refreshes.  The `sharded` row runs the batch
configuration with the T task columns partitioned over ALL visible devices
(`config.task_shards`; CI forces 8 fake host devices) and the production
`prox_mode="distributed"` server prox — each shard sketches only its own
column block (one (d, p) psum), the projected core is assembled with a
small (p, T/n) all_gather, and the thresholded reconstruction stays
shard-local.  A `sharded_repl` row keeps the PR-3 replicated prox (one
(d, T) all_gather, identical SVT on every shard) so
`speedup.distprox_over_sharded` tracks what distributing the prox buys;
every engine row records its `prox_mode` and `comm_bytes_per_refresh`
(collective payload per prox refresh: 0 for the single-device engines,
d*T*4 for the replicated gather, (d*p + p*T)*4 for the distributed
sketch).  On fake host devices all shards share one CPU, so
`speedup.sharded_over_batch` measures collective/masking overhead there,
not real multi-chip scaling — but `distprox_over_sharded` is meaningful
even there: the replicated prox DUPLICATES the sketch on every shard
while the distributed prox divides it, so killing that duplication shows
up as wall-clock even on a shared CPU.

The `batch_ragged` row (PR 9) runs the batch engine on the SAME (T, n, d)
buffer with skewed per-task `row_counts` (task t owns 1 + t % n rows) and
the same event budget: every gradient masks on its task's count.  Its
`batch_trimmed` twin runs the pre-ragged workaround — trim every cohort
to n_min and drop `row_counts` — so `speedup.ragged_over_trimmed`
records what keeping ALL rows costs in events/sec against throwing the
surplus away (the masked buffer carries n_max rows per task where the
trimmed one carries n_min).

The SGD-AMTL rows (`delta_full`/`delta_sgd`, `batch_full`/`batch_sgd`)
run on a SECOND problem with large per-task n (D_SGD x T_SGD, N_SGD
samples) where the per-event gradient dominates — the paper's §III-C
regime that minibatching targets.  The `*_sgd` rows set
`batch_size=SGD_BATCH` (seeded rank-bsz in-kernel selection; on this CPU
bench the oracle path gathers a static (bsz, d) block, an n/bsz FLOP
cut); `speedup.delta_sgd_over_full` / `batch_sgd_over_full` compare each
against its full-gradient twin and `speedup.sgd_over_full` (the CI
floor) is their min.  Engine equivalence (bitwise, aligned configs) is
covered by tests/test_amtl_delta.py, tests/test_amtl_batch.py, and
tests/test_amtl_sharded.py, not timed here.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.core import AMTLConfig, MTLProblem, amtl_max_step
from repro.core.amtl import amtl_events_only
from repro.core.prox import ProxPlan
from repro.distributed.sharding import TASK_AXIS

D, T, TAU = 8192, 128, 8
N_SAMPLES = 4          # tiny per-task n: the engines, not the grads, dominate
DENSE_EVENTS = 8       # one full SVD per event — keep the baseline affordable
DELTA_EVENTS = 64
BATCH_EVENTS = 256
PROX_EVERY = 8
PROX_RANK = 16
EVENT_BATCH = 32       # CPU sweet spot: larger batches amortize the prox
                       # further but the per-batch gather/scatter fixed cost
                       # grows; 32 maximizes events/sec at this scale
PROX_K = 4             # batch_k4 row: prox_every = PROX_K * EVENT_BATCH
# SGD-AMTL rows run their own problem: large per-task n so the per-event
# gradient (not the engine machinery) dominates — the regime the paper's
# §III-C "gradient computation is typically the most time consuming step"
# describes and the one minibatching targets.  n/bsz = 16 is the available
# FLOP lever; the recorded speedup is smaller (prox + column update are
# unchanged).
D_SGD, T_SGD, N_SGD = 4096, 32, 512
SGD_BATCH = 32
SGD_EVENTS = 64
JSON_PATH = "BENCH_amtl_events.json"


def _problem() -> MTLProblem:
    kx, ky = jax.random.split(jax.random.PRNGKey(0))
    xs = jax.random.normal(kx, (T, N_SAMPLES, D)) / np.sqrt(D)
    ys = jax.random.normal(ky, (T, N_SAMPLES))
    return MTLProblem(xs, ys, "lstsq", "nuclear", 0.1)


def _ragged_problem(problem: MTLProblem) -> MTLProblem:
    # skewed cohorts: task t owns 1 + t % n of the n buffered rows
    counts = 1 + (np.arange(T) % N_SAMPLES)
    return problem._replace(row_counts=jnp.asarray(counts, jnp.int32))


def _trimmed_problem(problem: MTLProblem) -> MTLProblem:
    # the pre-ragged workaround: every cohort cut to n_min, no masking
    n_min = 1
    return MTLProblem(problem.xs[:, :n_min], problem.ys[:, :n_min],
                      problem.loss_name, problem.reg_name, problem.lam)


def _sgd_problem() -> MTLProblem:
    kx, ky = jax.random.split(jax.random.PRNGKey(1))
    xs = jax.random.normal(kx, (T_SGD, N_SGD, D_SGD)) / np.sqrt(D_SGD)
    ys = jax.random.normal(ky, (T_SGD, N_SGD))
    return MTLProblem(xs, ys, "lstsq", "nuclear", 0.1)


def _events_per_sec(problem: MTLProblem, cfg: AMTLConfig, events: int,
                    reps: int = 3, mesh=None) -> float:
    v0 = jnp.zeros((problem.dim, problem.num_tasks), jnp.float32)
    key = jax.random.PRNGKey(7)
    run = lambda: jax.block_until_ready(
        amtl_events_only(problem, cfg, v0, key, events, mesh=mesh))
    run()                                   # compile + warm-up
    best = float("inf")                     # best-of-k: stable under noise
    for _ in range(reps):
        t0 = time.perf_counter()
        run()
        best = min(best, time.perf_counter() - t0)
    return events / best


def _comm_bytes_per_refresh(cfg: AMTLConfig, task_shards: int) -> int:
    """Collective payload of ONE server-prox refresh (f32 bytes).

    Single-device engines pay nothing.  The sharded replicated prox
    all_gathers the (d, T) stale iterate; the rank-distributed prox moves
    a (d, p) psum partial plus the gathered (p, T) projected core.
    """
    if cfg.engine != "sharded":
        return 0
    if cfg.prox_mode == "distributed":
        plan = ProxPlan(axis=TASK_AXIS, num_tasks=T,
                        n_local=T // task_shards)
        return plan.comm_bytes_per_refresh(D, cfg.prox_rank)
    return D * T * 4


def _state_bytes(cfg: AMTLConfig, task_shards: int = 1, d: int = D,
                 t: int = T) -> dict:
    itemsize = 4  # f32
    if cfg.engine == "dense":
        ring = (cfg.tau + 1) * d * t * itemsize
        total = ring  # the ring holds every iterate incl. the newest
    else:
        # engine="sharded" keeps one private (tau+1, d) undo ring per
        # shard; aggregate bytes scale with the shard count while the
        # per-device footprint stays the batch engine's.
        ring = (task_shards * (cfg.tau + 1) * d * itemsize
                + (cfg.tau + 1) * 4)
        total = ring + d * t * itemsize                # + v
        # live (d, T) prox cache: delta with any amortization, batch/
        # sharded only at the decoupled cadence (prox_every > event_batch;
        # at the aligned cadence each batch refreshes before reading).
        aligned = cfg.event_batch if cfg.engine in ("batch", "sharded") \
            else 1
        if cfg.prox_every > aligned:
            total += d * t * itemsize
    return {"ring_bytes": ring, "state_bytes": total}


def run(repeats: int = 3) -> list[Row]:
    """`repeats` timed reps per row (best-of; first run compiles/warms).
    The ROADMAP's ±25% machine-noise caveat on absolute rows is
    controllable from CI via `benchmarks.run --repeats N`."""
    problem = _problem()
    eta_k = amtl_max_step(TAU, T)
    dense_cfg = AMTLConfig(eta=0.05, eta_k=eta_k, tau=TAU, engine="dense")
    delta_cfg = AMTLConfig(eta=0.05, eta_k=eta_k, tau=TAU, engine="delta",
                           prox_every=PROX_EVERY, prox_rank=PROX_RANK)
    # same prox cadence as the batch engine: isolates the batching gain
    delta_matched_cfg = delta_cfg._replace(prox_every=EVENT_BATCH)
    batch_cfg = AMTLConfig(eta=0.05, eta_k=eta_k, tau=TAU, engine="batch",
                           prox_every=EVENT_BATCH, event_batch=EVENT_BATCH,
                           prox_rank=PROX_RANK)
    # decoupled cadence: one prox per PROX_K batches via the carried cache
    batch_k4_cfg = batch_cfg._replace(prox_every=PROX_K * EVENT_BATCH)

    # task-sharded engine: batch config over all visible devices (T=128 is
    # divisible by any power-of-two host-device count CI uses), production
    # rank-distributed server prox; the _repl row keeps the replicated
    # prox so its duplication cost stays tracked across PRs.
    task_shards = jax.local_device_count()
    from repro.launch.mesh import make_task_mesh
    mesh = make_task_mesh(task_shards)
    sharded_cfg = AMTLConfig(eta=0.05, eta_k=eta_k, tau=TAU,
                             engine="sharded", prox_every=EVENT_BATCH,
                             event_batch=EVENT_BATCH, prox_rank=PROX_RANK,
                             prox_mode="distributed")
    sharded_repl_cfg = sharded_cfg._replace(prox_mode="replicated")

    # SGD-AMTL: the same delta/batch engines on the large-n problem, full
    # gradient vs batch_size=SGD_BATCH seeded minibatch (rank-bsz in-kernel
    # selection; the CPU oracle path gathers a static (bsz, d) block).
    sgd_problem = _sgd_problem()
    delta_full_cfg = AMTLConfig(eta=0.05, eta_k=eta_k, tau=TAU,
                                engine="delta", prox_every=PROX_EVERY,
                                prox_rank=PROX_RANK)
    delta_sgd_cfg = delta_full_cfg._replace(batch_size=SGD_BATCH)
    batch_full_cfg = AMTLConfig(eta=0.05, eta_k=eta_k, tau=TAU,
                                engine="batch", prox_every=EVENT_BATCH,
                                event_batch=EVENT_BATCH,
                                prox_rank=PROX_RANK)
    batch_sgd_cfg = batch_full_cfg._replace(batch_size=SGD_BATCH)

    dense_eps = _events_per_sec(problem, dense_cfg, DENSE_EVENTS, repeats)
    delta_eps = _events_per_sec(problem, delta_cfg, DELTA_EVENTS, repeats)
    matched_eps = _events_per_sec(problem, delta_matched_cfg, BATCH_EVENTS,
                                  repeats)
    batch_eps = _events_per_sec(problem, batch_cfg, BATCH_EVENTS, repeats)
    batch_k4_eps = _events_per_sec(problem, batch_k4_cfg, BATCH_EVENTS,
                                   repeats)
    sharded_eps = _events_per_sec(problem, sharded_cfg, BATCH_EVENTS,
                                  repeats, mesh=mesh)
    sharded_repl_eps = _events_per_sec(problem, sharded_repl_cfg,
                                       BATCH_EVENTS, repeats, mesh=mesh)
    ragged_problem = _ragged_problem(problem)
    trimmed_problem = _trimmed_problem(problem)
    ragged_eps = _events_per_sec(ragged_problem, batch_cfg, BATCH_EVENTS,
                                 repeats)
    trimmed_eps = _events_per_sec(trimmed_problem, batch_cfg, BATCH_EVENTS,
                                  repeats)
    delta_full_eps = _events_per_sec(sgd_problem, delta_full_cfg,
                                     SGD_EVENTS, repeats)
    delta_sgd_eps = _events_per_sec(sgd_problem, delta_sgd_cfg,
                                    SGD_EVENTS, repeats)
    batch_full_eps = _events_per_sec(sgd_problem, batch_full_cfg,
                                     SGD_EVENTS, repeats)
    batch_sgd_eps = _events_per_sec(sgd_problem, batch_sgd_cfg,
                                    SGD_EVENTS, repeats)
    dense_mem = _state_bytes(dense_cfg)
    delta_mem = _state_bytes(delta_cfg)
    batch_mem = _state_bytes(batch_cfg)
    batch_k4_mem = _state_bytes(batch_k4_cfg)
    sharded_mem = _state_bytes(sharded_cfg, task_shards)
    speedup = {
        "delta_over_dense": delta_eps / max(dense_eps, 1e-12),
        "batch_over_dense": batch_eps / max(dense_eps, 1e-12),
        "batch_over_delta": batch_eps / max(delta_eps, 1e-12),
        "batch_over_delta_matched": batch_eps / max(matched_eps, 1e-12),
        "batch_k4_over_batch": batch_k4_eps / max(batch_eps, 1e-12),
        "sharded_over_batch": sharded_eps / max(batch_eps, 1e-12),
        "distprox_over_sharded": sharded_eps / max(sharded_repl_eps, 1e-12),
        "delta_sgd_over_full": delta_sgd_eps / max(delta_full_eps, 1e-12),
        "batch_sgd_over_full": batch_sgd_eps / max(batch_full_eps, 1e-12),
        # keeping ALL skewed cohorts (masked n_max buffer) vs the old
        # trim-to-n_min workaround, same batch engine + event budget
        "ragged_over_trimmed": ragged_eps / max(trimmed_eps, 1e-12),
    }
    # the CI floor: BOTH SGD rows must beat their full-gradient twin
    speedup["sgd_over_full"] = min(speedup["delta_sgd_over_full"],
                                   speedup["batch_sgd_over_full"])

    def _row(cfg: AMTLConfig, eps: float, mem: dict) -> dict:
        return {"events_per_sec": eps, "us_per_event": 1e6 / eps,
                "prox_mode": cfg.prox_mode,
                "batch_size": cfg.batch_size,
                "comm_bytes_per_refresh": _comm_bytes_per_refresh(
                    cfg, task_shards), **mem}

    report = {
        # prox_every is the delta row's cadence; the batch, delta_matched,
        # and sharded rows run at prox cadence event_batch; batch_k4 at
        # prox cadence prox_k * event_batch (decoupled).
        "config": {"d": D, "T": T, "tau": TAU, "n_samples": N_SAMPLES,
                   "prox_every": PROX_EVERY, "prox_rank": PROX_RANK,
                   "event_batch": EVENT_BATCH, "prox_k": PROX_K,
                   "task_shards": task_shards,
                   # SGD rows' problem + minibatch (the *_full/*_sgd pairs)
                   "d_sgd": D_SGD, "T_sgd": T_SGD, "n_samples_sgd": N_SGD,
                   "batch_size": SGD_BATCH,
                   "backend": jax.default_backend()},
        "dense": _row(dense_cfg, dense_eps, dense_mem),
        "delta": _row(delta_cfg, delta_eps, delta_mem),
        "delta_matched": _row(delta_matched_cfg, matched_eps, delta_mem),
        "batch": _row(batch_cfg, batch_eps, batch_mem),
        # prox cadence PROX_K * event_batch (the decoupled session cadence)
        "batch_k4": _row(batch_k4_cfg, batch_k4_eps, batch_k4_mem),
        # production sharded config: rank-distributed server prox
        "sharded": _row(sharded_cfg, sharded_eps, sharded_mem),
        # PR-3 replicated prox, kept as the distprox_over_sharded baseline
        "sharded_repl": _row(sharded_repl_cfg, sharded_repl_eps,
                             sharded_mem),
        # ragged cohorts (skewed row_counts over the full n-row buffer)
        # vs the trim-to-n_min workaround, both on the batch engine
        "batch_ragged": {**_row(batch_cfg, ragged_eps, batch_mem),
                         "row_counts_min": 1, "row_counts_max": N_SAMPLES,
                         "rows_valid": int(np.sum(
                             np.asarray(ragged_problem.row_counts))),
                         "rows_buffered": T * N_SAMPLES},
        "batch_trimmed": {**_row(batch_cfg, trimmed_eps, batch_mem),
                          "rows_valid": T, "rows_buffered": T},
        # SGD-AMTL pairs on the large-n problem: full gradient vs the
        # seeded rank-bsz minibatch, same engine/cadence otherwise
        "delta_full": _row(delta_full_cfg, delta_full_eps,
                           _state_bytes(delta_full_cfg, d=D_SGD, t=T_SGD)),
        "delta_sgd": _row(delta_sgd_cfg, delta_sgd_eps,
                          _state_bytes(delta_sgd_cfg, d=D_SGD, t=T_SGD)),
        "batch_full": _row(batch_full_cfg, batch_full_eps,
                           _state_bytes(batch_full_cfg, d=D_SGD, t=T_SGD)),
        "batch_sgd": _row(batch_sgd_cfg, batch_sgd_eps,
                          _state_bytes(batch_sgd_cfg, d=D_SGD, t=T_SGD)),
        "speedup": speedup,
        # kept for cross-PR continuity with the PR-1 schema
        "speedup_events_per_sec": speedup["delta_over_dense"],
        "ring_memory_ratio": dense_mem["ring_bytes"] / delta_mem["ring_bytes"],
    }
    # carry over the `serving` row written by benchmarks.serving so
    # `--only amtl_events,serving` composes in either order: both benches
    # share one tracked JSON and each preserves the other's key.
    try:
        with open(JSON_PATH) as f:
            prev = json.load(f)
        if "serving" in prev:
            report["serving"] = prev["serving"]
    except (FileNotFoundError, json.JSONDecodeError):
        pass
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2)

    return [
        Row("amtl_events/dense_ring", 1e6 / dense_eps,
            f"events/sec={dense_eps:.2f}"),
        Row("amtl_events/delta_ring", 1e6 / delta_eps,
            f"events/sec={delta_eps:.2f} "
            f"speedup={speedup['delta_over_dense']:.2f}x"),
        Row("amtl_events/delta_matched", 1e6 / matched_eps,
            f"events/sec={matched_eps:.2f} (prox_every={EVENT_BATCH})"),
        Row("amtl_events/event_batch", 1e6 / batch_eps,
            f"events/sec={batch_eps:.2f} "
            f"vs_delta={speedup['batch_over_delta']:.2f}x "
            f"vs_delta_matched={speedup['batch_over_delta_matched']:.2f}x "
            f"vs_dense={speedup['batch_over_dense']:.2f}x"),
        Row("amtl_events/batch_k4", 1e6 / batch_k4_eps,
            f"events/sec={batch_k4_eps:.2f} "
            f"(prox_every={PROX_K * EVENT_BATCH}) "
            f"vs_batch={speedup['batch_k4_over_batch']:.2f}x"),
        Row("amtl_events/sharded", 1e6 / sharded_eps,
            f"events/sec={sharded_eps:.2f} shards={task_shards} "
            f"prox=distributed "
            f"vs_batch={speedup['sharded_over_batch']:.2f}x "
            f"vs_repl={speedup['distprox_over_sharded']:.2f}x"),
        Row("amtl_events/sharded_repl", 1e6 / sharded_repl_eps,
            f"events/sec={sharded_repl_eps:.2f} shards={task_shards} "
            f"prox=replicated "
            f"comm={report['sharded_repl']['comm_bytes_per_refresh']}B "
            f"vs_dist_comm={report['sharded']['comm_bytes_per_refresh']}B"),
        Row("amtl_events/batch_ragged", 1e6 / ragged_eps,
            f"events/sec={ragged_eps:.2f} "
            f"row_counts=1..{N_SAMPLES} (skewed) "
            f"vs_trimmed={speedup['ragged_over_trimmed']:.2f}x "
            f"(trimmed={trimmed_eps:.2f})"),
        Row("amtl_events/delta_sgd", 1e6 / delta_sgd_eps,
            f"events/sec={delta_sgd_eps:.2f} bsz={SGD_BATCH}/{N_SGD} "
            f"vs_full={speedup['delta_sgd_over_full']:.2f}x "
            f"(full={delta_full_eps:.2f})"),
        Row("amtl_events/batch_sgd", 1e6 / batch_sgd_eps,
            f"events/sec={batch_sgd_eps:.2f} bsz={SGD_BATCH}/{N_SGD} "
            f"vs_full={speedup['batch_sgd_over_full']:.2f}x "
            f"(full={batch_full_eps:.2f})"),
        Row("amtl_events/ring_memory", 0.0,
            f"dense={dense_mem['ring_bytes']}B delta={delta_mem['ring_bytes']}B "
            f"ratio={report['ring_memory_ratio']:.0f}x"),
        Row("amtl_events/state_memory", 0.0,
            f"dense={dense_mem['state_bytes']}B "
            f"delta={delta_mem['state_bytes']}B "
            f"batch={batch_mem['state_bytes']}B"),
    ]
