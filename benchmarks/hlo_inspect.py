"""Hillclimb helper: lower one (arch x shape) combo and print the largest
collective ops and a byte histogram from the compiled HLO.

    PYTHONPATH=src python -m benchmarks.hlo_inspect gemma2-2b decode_32k \
        [--unroll] [--top 15] [--grep all-gather]
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import re
import sys

from repro.launch.dryrun import (_COLL_RE, _shape_bytes, build_combo,
                                 collective_bytes)
from repro.launch import shapes as shp
from repro.launch.mesh import make_production_mesh
from repro.configs import get_config

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("arch")
    ap.add_argument("shape")
    ap.add_argument("--unroll", action="store_true")
    ap.add_argument("--periods", type=int, default=0,
                    help="override num_periods (0 = config value)")
    ap.add_argument("--top", type=int, default=12)
    ap.add_argument("--grep", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.periods:
        from repro.launch.dryrun import _with_periods
        cfg = _with_periods(cfg, args.periods)
    shape = shp.SHAPES[args.shape]
    mesh = make_production_mesh()
    fn, structs, in_sh, _ = build_combo(
        cfg, shape, mesh, unroll=True if args.unroll else 1)
    jitted = jax.jit(fn, in_shardings=in_sh,
                     donate_argnums=0 if shape.kind == "train" else ())
    with mesh:
        compiled = jitted.lower(*structs).compile()
    hlo = compiled.as_text()
    print(f"# cost: {compiled.cost_analysis()}")
    print(f"# collective bytes/device: {collective_bytes(hlo)}")

    rows = []
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(3)
        result = _shape_bytes(line.split("=", 1)[1].split(kind)[0])
        rows.append((result, kind, line.strip()[:240]))
    rows.sort(reverse=True)
    print(f"\n# top {args.top} collectives by result bytes:")
    for b, kind, line in rows[:args.top]:
        print(f"{b/2**20:9.1f} MiB {kind:>18}  {line[:200]}")

    if args.grep:
        print(f"\n# lines matching {args.grep!r}:")
        for line in hlo.splitlines():
            if args.grep in line:
                print(line.strip()[:240])


if __name__ == "__main__":
    main()
