"""Learn-while-serve throughput bench: the `serving` telemetry row.

Measures the `AMTLServer` request path at a serving-shaped scale
(d=1024, T=32) in three modes: learning ON cooperatively (every request
batch also submits feedback and runs one coalesced engine chunk),
FROZEN (same traffic, learning off — the pure snapshot read path), and
THREADED (PR 8: the background learner thread absorbs the same feedback
stream concurrently while the main thread hammers predicts — the
request path never takes the learner's lock).

Since PR 9 the serving problem is RAGGED (skewed `row_counts`, task t
owns 1 + t % n of the n buffered rows) and every feedback item on the
learning paths carries a labeled `(x, y)` row: each accepted item is
both a gradient event and a new store row, folded into the server's
`TaskStore` at the next chunk boundary (the cohorts grow live and cross
power-of-two capacity doublings mid-drive — the engine rebuilds the
bench measures are the real ingestion cost).  `appends_per_sec` is the
labeled-row ingestion rate of the cooperative learning drive.  Per-batch predict
latency is recorded on the learning paths (p50/p95 cooperative,
p99 + SLO-violation count threaded, via the `slo_ms` admission
controller).  Every timer read sits behind `jax.block_until_ready` —
the wall-clock numbers measure compute, not async dispatch.

The row is MERGED into `BENCH_amtl_events.json` under the key
`"serving"` (the engine rows written by `benchmarks.amtl_events` are
left untouched, and that bench preserves this row when it rewrites the
file), so one tracked record carries both the engine and the serving
trajectories across PRs.  Keys:

    requests_per_sec_learning   rows served/sec, cooperative learning on
    requests_per_sec_frozen     rows served/sec, frozen server
    requests_per_sec_threaded   rows served/sec, learner thread hot
    predict_p50_ms              median per-batch predict latency (ms)
    predict_p95_ms              95th-pct per-batch predict latency (ms)
    predict_p99_ms              99th-pct latency on the threaded path
    slo_violations              threaded predict batches over slo_ms
    events_per_sec_learning     engine events absorbed/sec while serving
    appends_per_sec             labeled rows ingested/sec (cooperative)
    learning_slowdown           frozen/learning requests/sec ratio
    learner_restarts            crashes healed in the chaos drive (PR 10)
    quarantined_feedback        events quarantined by the non-finite
                                guard in the chaos drive
    recovery_ms                 crash-detect -> re-serving wall ms, per
                                healed crash
    config                      problem + traffic shape (incl. slo_ms and
                                the `ragged` row_counts summary)

The chaos drive (PR 10) replays the threaded traffic once more under a
scripted `FaultPlan` — one learner crash healed by the supervisor, one
poisoned iterate quarantined by the non-finite guard — and reports the
recovery telemetry; it is correctness plumbing exercised at bench
scale, not a timed row.

Serving equivalence (frozen == frozen engine bitwise, learning == plain
`run` over the same chunks bitwise, threaded snapshots == committed
chunk-boundary iterates) is covered by tests/test_serve.py and
tests/test_serve_threaded.py, not timed here.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row
from repro.core import AMTLConfig, MTLProblem, amtl_max_step
from repro.serve import AMTLServer, FaultPlan, ServeConfig

D_S, T_S, N_S, TAU_S = 1024, 32, 8, 8
EVENT_BATCH = 8
CHUNK_EVENTS = 32          # per-chunk coalescing budget (4 batches)
BATCH_REQ = 64             # prediction rows per request batch
FEEDBACK_PER_BATCH = 16    # labeled feedback rows per request batch
N_BATCHES = 32             # request batches per timed rep
SLO_MS = 250.0             # generous predict SLO for the threaded row
JSON_PATH = "BENCH_amtl_events.json"


def _problem() -> MTLProblem:
    kx, ky = jax.random.split(jax.random.PRNGKey(2))
    xs = jax.random.normal(kx, (T_S, N_S, D_S)) / np.sqrt(D_S)
    ys = jax.random.normal(ky, (T_S, N_S))
    # skewed ragged cohorts: task t owns 1 + t % n of the n buffered rows
    counts = jnp.asarray(1 + (np.arange(T_S) % N_S), jnp.int32)
    return MTLProblem(xs, ys, "lstsq", "nuclear", 0.1, row_counts=counts)


def _cfg() -> AMTLConfig:
    return AMTLConfig(eta=0.05, eta_k=amtl_max_step(TAU_S, T_S), tau=TAU_S,
                      engine="batch", event_batch=EVENT_BATCH,
                      prox_every=EVENT_BATCH, prox_rank=8)


def _traffic(problem: MTLProblem, seed: int = 0):
    rng = np.random.default_rng(seed)
    t = rng.integers(0, problem.num_tasks, size=(N_BATCHES, BATCH_REQ))
    x = rng.standard_normal((N_BATCHES, BATCH_REQ, problem.dim)) \
        .astype(np.float32)
    fb = rng.integers(0, problem.num_tasks,
                      size=(N_BATCHES, FEEDBACK_PER_BATCH))
    # labeled rows riding the feedback: each accepted item is one event
    # AND one new store row (folded at the next chunk boundary)
    fb_x = (rng.standard_normal(
        (N_BATCHES, FEEDBACK_PER_BATCH, problem.dim))
        / np.sqrt(problem.dim)).astype(np.float32)
    fb_y = rng.standard_normal((N_BATCHES, FEEDBACK_PER_BATCH)) \
        .astype(np.float32)
    return t, x, fb, fb_x, fb_y


def _server(problem: MTLProblem, learning: bool,
            slo_ms: float | None = None,
            fault_plan: FaultPlan | None = None,
            restart_limit: int | None = None) -> AMTLServer:
    w0 = jnp.zeros((problem.dim, problem.num_tasks), jnp.float32)
    return AMTLServer(problem, _cfg(), w0, jax.random.PRNGKey(7),
                      ServeConfig(chunk_events=CHUNK_EVENTS,
                                  learning=learning, max_batch=BATCH_REQ,
                                  slo_ms=slo_ms,
                                  restart_limit=restart_limit,
                                  restart_backoff_s=0.01),
                      fault_plan=fault_plan)


def _drive(problem: MTLProblem, learning: bool):
    """One full traffic replay; returns (wall secs, per-batch predict ms,
    events learned, labeled rows appended).  Fresh server per rep so
    chunk state (and the store's capacity ladder) is identical."""
    server = _server(problem, learning)
    t, x, fb, fb_x, fb_y = _traffic(problem)
    lat_ms = []
    events = appends = 0
    t0 = time.perf_counter()
    for i in range(N_BATCHES):
        tb = time.perf_counter()
        preds = server.predict(t[i], x[i])
        jax.block_until_ready(preds)      # latency = computed, not dispatched
        lat_ms.append(1e3 * (time.perf_counter() - tb))
        if learning:
            appends += server.submit_feedback(fb[i], fb_x[i],
                                              fb_y[i]).accepted
            events += server.step()       # step() commits (blocks) the swap
    total = time.perf_counter() - t0
    return total, lat_ms, events, appends


def _drive_threaded(problem: MTLProblem):
    """Same traffic with the learner thread hot: the main thread serves
    every request batch and enqueues feedback; the background learner
    coalesces/runs chunks concurrently under the SLO controller.
    Returns (wall secs of the serving loop, per-batch ms, SLO
    violations, events learned)."""
    server = _server(problem, learning=True, slo_ms=SLO_MS)
    t, x, fb, fb_x, fb_y = _traffic(problem)
    server.start_learner()
    lat_ms = []
    t0 = time.perf_counter()
    for i in range(N_BATCHES):
        tb = time.perf_counter()
        preds = server.predict(t[i], x[i])
        jax.block_until_ready(preds)
        lat_ms.append(1e3 * (time.perf_counter() - tb))
        server.submit_feedback(fb[i], fb_x[i], fb_y[i])
    total = time.perf_counter() - t0      # serving loop only, not drain
    events = server.stop_learner(drain=True)
    violations = server.stats()["slo"]["violations"]
    return total, lat_ms, violations, events


def _drive_chaos(problem: MTLProblem):
    """Threaded traffic under a scripted FaultPlan: one learner crash
    (healed by the supervisor under backoff) and one poisoned iterate
    (quarantined by the non-finite guard).  Returns the server's health
    telemetry after a full drain — the serving row's recovery keys."""
    plan = FaultPlan(crash_on_chunks={1}, poison_iterate_on_chunks={3})
    server = _server(problem, learning=True, fault_plan=plan,
                     restart_limit=1)
    t, x, fb, fb_x, fb_y = _traffic(problem)
    server.start_learner()
    for i in range(N_BATCHES):
        jax.block_until_ready(server.predict(t[i], x[i]))
        server.submit_feedback(fb[i], fb_x[i], fb_y[i])
    # let the heal land before stopping: a crash inside the stop-drain
    # window is (correctly) surfaced rather than healed, which is the
    # breaker contract, not the telemetry this drive reports
    deadline = time.perf_counter() + 60.0
    while (server.stats()["health"]["learner_restarts"] < 1
           and time.perf_counter() < deadline):
        time.sleep(0.01)
    server.stop_learner(drain=True)
    return server.stats()["health"]


def run(repeats: int = 3) -> list[Row]:
    problem = _problem()
    # warm-up: compile predict (both padded shapes are the same bucket),
    # the engine run at the steady chunk size, and the init path
    _drive(problem, learning=True)
    _drive(problem, learning=False)

    n_requests = N_BATCHES * BATCH_REQ
    best_learn, best_frozen = float("inf"), float("inf")
    best_thread = float("inf")
    lat_ms, events, appends = [], 0, 0
    lat_thread, violations = [], 0
    for _ in range(repeats):
        total, lat, ev, app = _drive(problem, learning=True)
        if total < best_learn:
            best_learn, lat_ms, events, appends = total, lat, ev, app
        best_frozen = min(best_frozen, _drive(problem, learning=False)[0])
        total, lat, viol, _ = _drive_threaded(problem)
        if total < best_thread:
            best_thread, lat_thread, violations = total, lat, viol
    health = _drive_chaos(problem)

    rps_learn = n_requests / best_learn
    rps_frozen = n_requests / best_frozen
    rps_thread = n_requests / best_thread
    row = {
        "requests_per_sec_learning": rps_learn,
        "requests_per_sec_frozen": rps_frozen,
        "requests_per_sec_threaded": rps_thread,
        "predict_p50_ms": float(np.percentile(lat_ms, 50)),
        "predict_p95_ms": float(np.percentile(lat_ms, 95)),
        "predict_p99_ms": float(np.percentile(lat_thread, 99)),
        "slo_violations": int(violations),
        "events_per_sec_learning": events / best_learn,
        "appends_per_sec": appends / best_learn,
        "learning_slowdown": rps_frozen / max(rps_learn, 1e-12),
        "learner_restarts": int(health["learner_restarts"]),
        "quarantined_feedback": int(health["quarantined_feedback"]),
        "recovery_ms": [float(ms) for ms in health["recovery_ms"]],
        "config": {"d": D_S, "T": T_S, "n_samples": N_S, "tau": TAU_S,
                   "engine": "batch", "event_batch": EVENT_BATCH,
                   "chunk_events": CHUNK_EVENTS,
                   "batch_requests": BATCH_REQ,
                   "feedback_per_batch": FEEDBACK_PER_BATCH,
                   "n_batches": N_BATCHES,
                   "slo_ms": SLO_MS,
                   "ragged": {"row_counts_min": 1, "row_counts_max": N_S,
                              "rows_valid": int(np.sum(
                                  np.asarray(problem.row_counts))),
                              "rows_buffered": T_S * N_S,
                              "labeled_feedback": True},
                   "backend": jax.default_backend()},
    }
    try:
        with open(JSON_PATH) as f:
            report = json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        report = {}
    report["serving"] = row
    with open(JSON_PATH, "w") as f:
        json.dump(report, f, indent=2)

    return [
        Row("serving/requests_learning", 1e6 / rps_learn,
            f"req/sec={rps_learn:.1f} "
            f"events/sec={row['events_per_sec_learning']:.1f} "
            f"appends/sec={row['appends_per_sec']:.1f}"),
        Row("serving/requests_frozen", 1e6 / rps_frozen,
            f"req/sec={rps_frozen:.1f} "
            f"slowdown_learning={row['learning_slowdown']:.2f}x"),
        Row("serving/requests_threaded", 1e6 / rps_thread,
            f"req/sec={rps_thread:.1f} p99={row['predict_p99_ms']:.2f}ms "
            f"slo_violations={violations}"),
        Row("serving/predict_latency", 1e3 * row["predict_p50_ms"],
            f"p50={row['predict_p50_ms']:.2f}ms "
            f"p95={row['predict_p95_ms']:.2f}ms batch={BATCH_REQ}"),
        Row("serving/chaos_recovery",
            1e3 * (row["recovery_ms"][0] if row["recovery_ms"] else 0.0),
            f"restarts={row['learner_restarts']} "
            f"quarantined={row['quarantined_feedback']} "
            f"crashes={health['learner_crashes']}"),
    ]
