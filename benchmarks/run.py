# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: `PYTHONPATH=src python -m benchmarks.run [--only X]`.

Paper artifacts:   table1 (Table I), table3 (Table III), fig3 (Fig. 3),
                   fig4 (Fig. 4), table456 (Tables IV-VI)
Beyond paper:      kernels (fusion microbench), serving (learn-while-serve
                   request throughput + predict latency), roofline (from
                   dry-run JSONL, printed if the file exists)
"""
from __future__ import annotations

import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed reps per amtl_events row (best-of; the "
                         "±25%% machine-noise caveat in ROADMAP shrinks "
                         "with more reps — raise on noisy CI runners)")
    args = ap.parse_args()
    if args.repeats < 1:
        ap.error("--repeats must be >= 1")

    import functools

    from benchmarks import (amtl_events, fig3_scaling, fig4_convergence,
                            kernels_bench, serving, sgd_amtl, table1_timing,
                            table3_public, table456_dynamic_step)
    suites = {
        "table1": table1_timing.run,
        "table3": table3_public.run,
        "fig3": fig3_scaling.run,
        "fig4": fig4_convergence.run,
        "table456": table456_dynamic_step.run,
        "sgd_amtl": sgd_amtl.run,
        "kernels": kernels_bench.run,
        "amtl_events": functools.partial(amtl_events.run,
                                         repeats=args.repeats),
        "serving": functools.partial(serving.run, repeats=args.repeats),
    }
    names = args.only.split(",") if args.only else list(suites)

    print("name,us_per_call,derived")
    for name in names:
        for row in suites[name]():
            print(row.csv())
        sys.stdout.flush()

    if (os.path.exists("dryrun_single_unrolled.jsonl")
            or os.path.exists("dryrun_single.jsonl")) and (
            args.only is None or "roofline" in names):
        from benchmarks import roofline
        print("\n# Roofline (single-pod; cost terms from the unrolled "
              "dry-run, temp bytes from the production-scan dry-run)")
        print(roofline.report())


if __name__ == "__main__":
    main()
